"""Benchmark orchestrator: one function per paper table/figure.

``python -m benchmarks.run [--quick] [--only NAME] [--inline] [--compare]``

Each benchmark runs in its own subprocess (XLA's CPU JIT keeps every
compiled executable resident; a single process running all benches
exhausts memory on the 1-core container).  ``--only`` executes one
benchmark inline.  Prints one ``name,us_per_call,derived`` CSV line per
benchmark; detailed CSVs land in results/bench/, and ``kernels_micro``
/ ``serving_load`` additionally persist cross-PR perf baselines
(dense-dequant vs quantized-execution weight bytes, step latency) as
``results/BENCH_<name>.json``.

``--compare`` is the regression mode: it re-runs every benchmark that
has a persisted ``results/BENCH_*.json`` baseline into a scratch
results dir (via ``REPRO_RESULTS_DIR``) and recursively diffs every
numeric leaf of the fresh payload against the baseline.  Host
wall-clock metrics (``*_us``, ``*wall*``, ``*speedup*``) are skipped —
everything else in these payloads is produced by the deterministic cost
model and must reproduce to the per-metric tolerance.  New keys in the
fresh payload are reported but allowed (a PR may *add* numbers);
missing or moved numbers fail the run with a per-leaf report.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys
import tempfile
import traceback

BENCH_NAMES = ["table1_amat", "fig8_accuracy", "fig9_energy",
               "fig10_warmup", "ablations", "roofline", "kernels_micro",
               "serving_load", "sim_fidelity", "controller_soak"]

REPO_RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

# --compare leaf policy.  Skip-list: substring match on the leaf key for
# metrics that measure *host* wall time (nondeterministic on a shared
# CI box).  Tolerance table: substring-matched relative tolerance, first
# match wins; the "" entry is the default for every simulated metric.
COMPARE_SKIP = ("_us", "wall", "speedup", "steps_per_s")
COMPARE_RTOL = (
    ("bytes", 0.0),        # traffic counters are exact integer counts
    ("", 1e-6),
)

_MISSING = object()


def _leaf_rtol(key: str):
    """None => skip this leaf; otherwise the relative tolerance."""
    if any(s in key for s in COMPARE_SKIP):
        return None
    for sub, rtol in COMPARE_RTOL:
        if sub in key:
            return rtol
    return COMPARE_RTOL[-1][1]


def _diff_payload(prev, cur, path: str, diffs: list, news: list) -> None:
    """Recursively diff ``cur`` against baseline ``prev``.

    Appends ``(path, baseline, current, note)`` rows: regressions to
    ``diffs`` (fail), additions only present in ``cur`` to ``news``
    (allowed — benchmarks may grow new sections/metrics).
    """
    key = path.rsplit(".", 1)[-1]
    if cur is _MISSING:
        if _leaf_rtol(key) is not None or isinstance(prev, (dict, list)):
            diffs.append((path, prev, "<missing>", "dropped from payload"))
        return
    if prev is _MISSING:
        news.append((path, "<none>", cur, "new in payload"))
        return
    if isinstance(prev, dict) or isinstance(cur, dict):
        if not (isinstance(prev, dict) and isinstance(cur, dict)):
            diffs.append((path, prev, cur, "type changed"))
            return
        for k in sorted(set(prev) | set(cur), key=str):
            _diff_payload(prev.get(k, _MISSING), cur.get(k, _MISSING),
                          f"{path}.{k}", diffs, news)
        return
    if isinstance(prev, list) or isinstance(cur, list):
        if not (isinstance(prev, list) and isinstance(cur, list)):
            diffs.append((path, prev, cur, "type changed"))
            return
        if len(prev) != len(cur):
            diffs.append((path, f"len={len(prev)}", f"len={len(cur)}",
                          "length changed"))
        for i, (p, c) in enumerate(zip(prev, cur)):
            _diff_payload(p, c, f"{path}[{i}]", diffs, news)
        return
    if isinstance(prev, bool) or isinstance(prev, str) or prev is None \
            or isinstance(cur, bool) or isinstance(cur, str) or cur is None:
        if prev != cur:
            diffs.append((path, prev, cur, "value changed"))
        return
    rtol = _leaf_rtol(key)
    if rtol is None:
        return                                   # host-time metric
    a, b = float(prev), float(cur)
    if a != b and abs(a - b) > rtol * max(abs(a), abs(b), 1e-30):
        diffs.append((path, prev, cur, f"rtol={rtol:g}"))


def run_compare(only: str | None) -> None:
    """Re-run baselined benchmarks into a scratch dir and diff."""
    baselines = {}
    for p in sorted(glob.glob(os.path.join(REPO_RESULTS, "BENCH_*.json"))):
        name = os.path.basename(p)[len("BENCH_"):-len(".json")]
        if only is None or name == only:
            baselines[name] = p
    if not baselines:
        sys.exit(f"--compare: no results/BENCH_*.json baseline"
                 f"{' for ' + only if only else 's'} to diff against")

    scratch = tempfile.mkdtemp(prefix="bench_compare_")
    print(f"compare mode: {len(baselines)} baselined benchmark(s), "
          f"fresh results -> {scratch}")
    failed = []
    for name, base_path in baselines.items():
        print(f"\n--- {name}: re-running (full sweep) ---", flush=True)
        env = {**os.environ, "REPRO_RESULTS_DIR": scratch}
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--only", name],
            env=env, capture_output=True, text=True)
        fresh_path = os.path.join(scratch, f"BENCH_{name}.json")
        if r.returncode != 0 or not os.path.exists(fresh_path):
            failed.append(name)
            print(f"{name}: benchmark FAILED to produce a fresh payload "
                  f"(rc={r.returncode})")
            sys.stderr.write(r.stderr[-2000:])
            continue
        with open(base_path) as f:
            prev = json.load(f)
        with open(fresh_path) as f:
            cur = json.load(f)
        diffs, news = [], []
        _diff_payload(prev, cur, name, diffs, news)
        for path, _, cur_v, note in news:
            print(f"  NEW  {path} = {cur_v}  ({note})")
        if diffs:
            failed.append(name)
            print(f"{name}: {len(diffs)} regression(s) vs {base_path}")
            for path, prev_v, cur_v, note in diffs:
                print(f"  DIFF {path}: baseline={prev_v} "
                      f"current={cur_v}  ({note})")
        else:
            print(f"{name}: OK — every gated leaf reproduces the "
                  f"baseline ({len(news)} new metric(s) allowed)")
    print()
    if failed:
        sys.exit(f"--compare: regressions in {failed}")
    print(f"--compare: all {len(baselines)} baselined benchmark(s) "
          "reproduce their persisted payloads")


def _run_inline(name: str, quick: bool) -> None:
    import importlib

    mod = importlib.import_module(f"benchmarks.{name}")
    mod.main(quick=quick)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps for CI-speed runs")
    ap.add_argument("--only", default=None, choices=BENCH_NAMES)
    ap.add_argument("--inline", action="store_true",
                    help="run all benches in this process (debug only)")
    ap.add_argument("--compare", action="store_true",
                    help="re-run baselined benchmarks into a scratch "
                         "results dir and diff every numeric leaf "
                         "against results/BENCH_*.json")
    args = ap.parse_args()

    if args.compare:
        run_compare(args.only)
        return

    if args.only:
        print("name,us_per_call,derived")
        _run_inline(args.only, args.quick)
        return

    print("name,us_per_call,derived", flush=True)
    failures = []
    for name in BENCH_NAMES:
        if args.inline:
            try:
                _run_inline(name, args.quick)
            except Exception as e:          # noqa: BLE001
                failures.append(name)
                print(f"{name},-1,ERROR:{e!r}", flush=True)
                traceback.print_exc(file=sys.stderr)
            continue
        cmd = [sys.executable, "-m", "benchmarks.run", "--only", name]
        if args.quick:
            cmd.append("--quick")
        r = subprocess.run(cmd, env={**os.environ},
                           capture_output=True, text=True)
        out = [ln for ln in r.stdout.splitlines()
               if ln.startswith(name + ",")]
        if r.returncode != 0 or not out:
            failures.append(name)
            print(f"{name},-1,ERROR(subprocess rc={r.returncode})",
                  flush=True)
            sys.stderr.write(r.stderr[-2000:])
        else:
            print(out[-1], flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
