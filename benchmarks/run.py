"""Benchmark orchestrator: one function per paper table/figure.

``python -m benchmarks.run [--quick] [--only NAME] [--inline]``

Each benchmark runs in its own subprocess (XLA's CPU JIT keeps every
compiled executable resident; a single process running all benches
exhausts memory on the 1-core container).  ``--only`` executes one
benchmark inline.  Prints one ``name,us_per_call,derived`` CSV line per
benchmark; detailed CSVs land in results/bench/, and ``kernels_micro``
/ ``serving_load`` additionally persist cross-PR perf baselines
(dense-dequant vs quantized-execution weight bytes, step latency) as
``results/BENCH_<name>.json``.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import traceback

BENCH_NAMES = ["table1_amat", "fig8_accuracy", "fig9_energy",
               "fig10_warmup", "ablations", "roofline", "kernels_micro",
               "serving_load", "sim_fidelity", "controller_soak"]


def _run_inline(name: str, quick: bool) -> None:
    import importlib

    mod = importlib.import_module(f"benchmarks.{name}")
    mod.main(quick=quick)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps for CI-speed runs")
    ap.add_argument("--only", default=None, choices=BENCH_NAMES)
    ap.add_argument("--inline", action="store_true",
                    help="run all benches in this process (debug only)")
    args = ap.parse_args()

    if args.only:
        print("name,us_per_call,derived")
        _run_inline(args.only, args.quick)
        return

    print("name,us_per_call,derived", flush=True)
    failures = []
    for name in BENCH_NAMES:
        if args.inline:
            try:
                _run_inline(name, args.quick)
            except Exception as e:          # noqa: BLE001
                failures.append(name)
                print(f"{name},-1,ERROR:{e!r}", flush=True)
                traceback.print_exc(file=sys.stderr)
            continue
        cmd = [sys.executable, "-m", "benchmarks.run", "--only", name]
        if args.quick:
            cmd.append("--quick")
        r = subprocess.run(cmd, env={**os.environ},
                           capture_output=True, text=True)
        out = [ln for ln in r.stdout.splitlines()
               if ln.startswith(name + ",")]
        if r.returncode != 0 or not out:
            failures.append(name)
            print(f"{name},-1,ERROR(subprocess rc={r.returncode})",
                  flush=True)
            sys.stderr.write(r.stderr[-2000:])
        else:
            print(out[-1], flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
