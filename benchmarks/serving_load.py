"""Serving-load benchmark: arrival rate × batch size sweep.

Exercises the continuous-batching subsystem on a tiny MoE config and
reports, per (arrival_rate, max_batch) cell, the simulated decode
throughput, TTFT percentiles, steady-state miss rate and energy per
token.  Two claims are demonstrated with printed numbers:

  (a) **batching pays**: decode throughput (simulated tokens/s) rises
      with ``max_batch`` — the resident non-expert weights are read once
      per *step*, so their DRAM traffic amortizes over the batch;
  (b) **warm beats cold**: a persistent engine (shared slice cache +
      accumulated hotness) yields a lower steady-state miss rate and
      lower energy/token than the seed's fresh-engine-per-request
      baseline on the identical workload;
  (c) **overlap pays, blind prefetch doesn't**: the asynchronous
      slice-I/O timeline (``EngineConfig.async_io`` — per-channel
      Flash/DRAM/XPU clocks, pipelined fill→read→matmul chains) yields
      lower decode latency than the serialized replay on the same
      workload seed at identical energy, while layer-transition
      prefetching on top wastes most of its Flash traffic under
      stochastic routing (the paper's §2.1 argument, quantitatively);
  (d) **request-level prediction pays where markov cannot**: on
      rotating multi-tenant traffic with an empty-warmup cache, the
      sparsity-aware request predictor (prefill-seeded activation
      matrix, multi-layer lookahead, confidence-gated issuance on a
      background-priority Flash lane) yields useful > wasted fills and
      a lower per-token p50 than plain async at equal-or-lower energy
      per token.

The serialized cells double as a regression gate: their numbers must
reproduce the previously persisted results/BENCH_serving_load.json
within tolerance (the timeline refactor may not move the sync model).

Run:  PYTHONPATH=src python benchmarks/serving_load.py [--quick]
"""

from __future__ import annotations

import os as _os
import sys as _sys

_root = _os.path.join(_os.path.dirname(_os.path.abspath(__file__)), "..")
for _p in (_os.path.join(_root, "src"), _root):
    if _p not in _sys.path:
        _sys.path.insert(0, _p)

import argparse
import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import CsvSink, json_record, report
from repro.configs.base import get_config
from repro.core.amat import MatConfig
from repro.core.engine import EngineConfig, PersistentEngine
from repro.models.model import init_params
from repro.models.moe import RoutingPolicy
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     SchedulerConfig)
from repro.serving.workloads import (LengthDist, TenantSpec, WorkloadConfig,
                                     generate)

ARCH = "qwen15-moe-repro"
PROMPT_LEN = 24
MAX_NEW = 12
CACHE_BYTES = 2.5e6
MAX_SEQ = 64


def _engine_cfg(quant_execution: bool = False, *, async_io: bool = False,
                prefetch_top_m=None, prefetch_min_obs: int = 0,
                prefetch_kind: str = "transition",
                prefetch_lookahead: int = 2,
                prefetch_min_score: float = 0.02,
                warmup: str = "pcw",
                ep_shards: int = 1,
                placement: str = "round_robin",
                placement_period: int = 64,
                cache_bytes: float = CACHE_BYTES) -> EngineConfig:
    return EngineConfig(
        mat=MatConfig(8, 4), cache_bytes=cache_bytes,
        policy=RoutingPolicy(kind="cache_prior", slice_mode="dbsc",
                             quant_execution=quant_execution),
        miss_rate_target=0.1, warmup=warmup, max_seq=MAX_SEQ,
        async_io=async_io, prefetch_top_m=prefetch_top_m,
        prefetch_min_obs=prefetch_min_obs, prefetch_kind=prefetch_kind,
        prefetch_lookahead=prefetch_lookahead,
        prefetch_min_score=prefetch_min_score, ep_shards=ep_shards,
        placement=placement, placement_period=placement_period)


def _workload(n_requests: int, seed: int, *, kind: str = "closed_loop",
              rate: float = 2.0):
    # Fixed lengths keep the jit-trace count at one prefill + one decode
    # shape per max_batch, so the sweep measures scheduling, not compiles.
    tenant = TenantSpec(
        prompt_len=LengthDist("fixed", PROMPT_LEN),
        output_len=LengthDist("fixed", MAX_NEW))
    cfg = WorkloadConfig(kind=kind, n_requests=n_requests, rate=rate,
                         seed=seed, tenants=(tenant,))
    return generate(cfg, get_config(ARCH).vocab_size)


def _tenant_mix_workload(n_requests: int, seed: int, *, max_new: int,
                         n_tenants: int = 3, zipf_a: float = 1.6,
                         rate: float = 300.0):
    """Rotating multi-tenant Poisson traffic: each tenant's Zipf token
    stream exercises its own expert subset, so a returning tenant
    re-demands slices evicted during its absence — the demand a
    request-level predictor can see coming from prefill routing."""
    tenants = tuple(
        TenantSpec(name=f"t{i}",
                   prompt_len=LengthDist("fixed", PROMPT_LEN),
                   output_len=LengthDist("fixed", max_new),
                   zipf_a=zipf_a)
        for i in range(n_tenants))
    cfg = WorkloadConfig(kind="poisson", n_requests=n_requests,
                         rate=rate, seed=seed, tenants=tenants)
    return generate(cfg, get_config(ARCH).vocab_size)


def run_cell(cfg, params, *, max_batch: int, n_requests: int,
             kind: str = "closed_loop", rate: float = 2.0,
             quant_execution: bool = False, async_io: bool = False,
             prefetch_top_m=None, prefetch_min_obs: int = 0,
             prefetch_kind: str = "transition",
             prefetch_lookahead: int = 2,
             prefetch_min_score: float = 0.02,
             warmup: str = "pcw", requests=None,
             ep_shards: int = 1, placement: str = "round_robin",
             placement_period: int = 64, cache_bytes: float = CACHE_BYTES,
             recorder=None, tracer=None):
    engine = PersistentEngine(cfg, params, _engine_cfg(
        quant_execution, async_io=async_io, prefetch_top_m=prefetch_top_m,
        prefetch_min_obs=prefetch_min_obs, prefetch_kind=prefetch_kind,
        prefetch_lookahead=prefetch_lookahead,
        prefetch_min_score=prefetch_min_score, warmup=warmup,
        ep_shards=ep_shards, placement=placement,
        placement_period=placement_period, cache_bytes=cache_bytes))
    if recorder is not None:
        recorder.attach(engine)
    if tracer is not None:
        engine.attach_tracer(tracer)
    sched = ContinuousBatchingScheduler(
        engine, SchedulerConfig(max_batch=max_batch,
                                max_queue=n_requests + 1))
    t0 = time.perf_counter()
    if requests is None:
        requests = _workload(n_requests, seed=0, kind=kind, rate=rate)
    for r in requests:
        sched.submit(r)
    sched.run()
    wall = time.perf_counter() - t0
    return sched.summary(wall_s=wall), engine


def _epoch_miss_rate(cache, skip_requests: int = 0) -> float:
    """Whole-request (prefill+decode) miss rate over archived epochs.

    ``skip_requests`` drops the leading warm-up requests so the number
    reflects steady state.
    """
    from repro.core.cache import CacheStats

    acc = miss = 0
    for label, snap in cache.epochs:
        rid = int(label.split("/")[0][3:])     # 'req<N>/<phase>'
        if rid < skip_requests:
            continue
        stats = CacheStats(**snap)
        acc += stats.accesses
        miss += stats.misses
    return miss / max(acc, 1)


def run_cold_baseline(cfg, params, *, n_requests: int) -> dict:
    """Seed behavior: a fresh engine (cold cache) per request.

    Runs each request through its own one-shot scheduler so the
    accounting path is *identical* to the warm cell — the only variable
    is whether the slice cache / hotness survive between requests.
    """
    reqs = _workload(n_requests, seed=0)
    total_energy = 0.0
    total_tokens = 0
    miss_rates = []
    sim_time = 0.0
    for r in reqs:
        engine = PersistentEngine(cfg, params, _engine_cfg())
        sched = ContinuousBatchingScheduler(
            engine, SchedulerConfig(max_batch=1, max_queue=2))
        sched.submit(Request(
            request_id=0, prompt=r.prompt,
            max_new_tokens=r.max_new_tokens))
        done = sched.run()
        total_energy += engine.ledger.total_energy_j
        sim_time += engine.ledger.total_latency_s
        total_tokens += sum(len(c.tokens) for c in done)
        miss_rates.append(_epoch_miss_rate(engine.cache))
    return {
        "n_tokens": total_tokens,
        "sim_time_s": sim_time,
        "throughput_tok_per_s": total_tokens / sim_time,
        "steady_state_miss_rate": float(np.mean(miss_rates)),
        "energy_per_token_j": total_energy / total_tokens,
    }


def _check_against_baseline(payload: dict, *, quick: bool,
                            rtol: float = 1e-6) -> None:
    """Regression gate: the serialized cells must reproduce the persisted
    results/BENCH_serving_load.json — the event-timeline refactor may
    only *add* numbers, never move the sync cost model."""
    import json

    from benchmarks.common import RESULTS

    path = _os.path.join(RESULTS, "BENCH_serving_load.json")
    if quick or not _os.path.exists(path):
        return
    with open(path) as f:
        prev = json.load(f)
    if prev.get("n_requests") != payload["n_requests"]:
        return                      # different sweep size, incomparable
    # A persisted baseline from an incompatible benchmark version would
    # otherwise surface as a bare KeyError (or silently gate nothing);
    # fail with an actionable message instead.
    required = ("throughput_by_batch", "warm_vs_cold", "ep_scaling",
                "placement")
    missing = [k for k in required if k not in prev]
    if missing:
        raise RuntimeError(
            f"persisted baseline {path} is missing section(s) "
            f"{missing} — its schema predates this benchmark version. "
            "Regenerate it with: PYTHONPATH=src python "
            "benchmarks/serving_load.py (without --quick), or delete "
            "the file to skip the regression gate once.")

    def _close(a, b):
        return a == b or abs(a - b) <= rtol * max(abs(a), abs(b), 1e-30)

    mismatches = []
    for mb, v in prev.get("throughput_by_batch", {}).items():
        cur = payload["throughput_by_batch"].get(mb)
        if cur is None or not _close(v, cur):
            mismatches.append(("throughput_by_batch", mb, v, cur))
    for k, v in prev.get("warm_vs_cold", {}).items():
        cur = payload["warm_vs_cold"].get(k)
        if cur is None or not _close(v, cur):
            mismatches.append(("warm_vs_cold", k, v, cur))
    # EP scaling and placement rows are deterministic too: gate them
    # like the serialized cells (scalar metrics only).
    for section in ("ep_scaling", "placement"):
        for name, row in prev.get(section, {}).items():
            cur_row = payload.get(section, {}).get(name)
            for k, v in row.items():
                if not isinstance(v, (int, float)) or isinstance(v, bool):
                    continue
                cur = None if cur_row is None else cur_row.get(k)
                if cur is None or not _close(v, cur):
                    mismatches.append((f"{section}[{name}]", k, v, cur))
    # The observability cell is a flat scalar row — a traced run's event
    # count and modeled p50/energy are deterministic, gate them too.
    for k, v in prev.get("observability", {}).items():
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            continue
        cur = payload.get("observability", {}).get(k)
        if cur is None or not _close(v, cur):
            mismatches.append(("observability", k, v, cur))
    assert not mismatches, \
        f"serialized path diverged from persisted baseline: {mismatches}"
    print(f"baseline check: serialized cells reproduce {path} "
          f"(rtol={rtol:g})")


def main(quick: bool = False) -> None:
    n_requests = 6 if quick else 12
    rates = [2.0] if quick else [2.0, 20.0]
    batches = [1, 4] if quick else [1, 2, 4, 8]

    cfg = get_config(ARCH)
    cfg = dataclasses.replace(cfg, n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))

    sink = CsvSink("serving_load", [
        "scenario", "max_batch", "throughput_tok_per_s", "ttft_p50_s",
        "ttft_p95_s", "per_token_p50_s", "steady_miss_rate",
        "energy_per_token_j", "mean_batch_occupancy"])

    # Cells: rate-limited Poisson arrivals (latency-oriented) plus a
    # closed-loop saturated scenario (capacity-oriented — this is where
    # batch size moves throughput; under light load it's arrival-bound).
    cells = [(f"poisson@{rate:g}", "poisson", rate) for rate in rates]
    cells.append(("saturated", "closed_loop", 0.0))

    print(f"=== serving load sweep: {ARCH} (2 layers), "
          f"{n_requests} requests/cell ===")
    by_batch = {}
    for name, kind, rate in cells:
        for mb in batches:
            s, _ = run_cell(cfg, params, max_batch=mb,
                            n_requests=n_requests, kind=kind, rate=rate)
            sink.add(name, mb, s["throughput_tok_per_s"], s["ttft_p50_s"],
                     s["ttft_p95_s"], s["per_token_p50_s"],
                     s["steady_state_miss_rate"], s["energy_per_token_j"],
                     s["mean_batch_occupancy"])
            by_batch.setdefault(name, {})[mb] = s
            print(f"{name:>12} batch={mb}: "
                  f"{s['throughput_tok_per_s']:8.1f} tok/s  "
                  f"ttft_p50={s['ttft_p50_s']*1e3:6.2f} ms  "
                  f"miss={s['steady_state_miss_rate']:.3f}  "
                  f"E/tok={s['energy_per_token_j']*1e3:.4f} mJ  "
                  f"occ={s['mean_batch_occupancy']:.2f}")

    print("\n=== warm persistent engine vs fresh-engine-per-request "
          "(seed baseline) ===")
    # Same workload, same single-slot scheduler, same accounting — the
    # only difference is cache/hotness persistence across requests.
    cold = run_cold_baseline(cfg, params, n_requests=n_requests)
    warm_s, warm_engine = run_cell(cfg, params, max_batch=1,
                                   n_requests=n_requests)
    warm_miss = _epoch_miss_rate(warm_engine.cache,
                                 skip_requests=n_requests // 2)
    print(f"cold (fresh engine/request): "
          f"{cold['throughput_tok_per_s']:8.1f} tok/s  "
          f"miss={cold['steady_state_miss_rate']:.3f}  "
          f"E/tok={cold['energy_per_token_j']*1e3:.4f} mJ")
    print(f"warm (persistent slice cache): "
          f"{warm_s['throughput_tok_per_s']:8.1f} tok/s  "
          f"miss={warm_miss:.3f}  "
          f"E/tok={warm_s['energy_per_token_j']*1e3:.4f} mJ")
    curve = [f"{m:.2f}" for label, m in
             warm_engine.cache.epoch_miss_rates()
             if label.endswith("/prefill")]
    print(f"warm prefill miss-rate curve (per request): "
          f"{' '.join(curve)}")

    print("\n=== serialized vs asynchronous slice-I/O timeline ===")
    # Same workload seed, same scheduler, same energy model — the only
    # variable is whether slice fills / DRAM reads / expert matmuls are
    # replayed blocking (the paper's serialized decode) or pipelined on
    # per-channel clocks, optionally with async next-layer prefetch.
    mb_async = max(batches)
    timeline_rows = {}
    # The markov row is the paper's §2.1 negative result: one-step
    # layer-transition prefetch under stochastic routing wastes most of
    # its Flash traffic.  (Its min-obs confidence-floor monotonicity is
    # asserted by tests/test_prefetch_invariants.py, not re-run here.)
    for label, kw in (
            ("serialized", {}),
            ("async", dict(async_io=True)),
            ("async+prefetch(markov)",
             dict(async_io=True, prefetch_top_m=4,
                  prefetch_kind="transition"))):
        s, eng = run_cell(cfg, params, max_batch=mb_async,
                          n_requests=n_requests, **kw)
        row = {
            "throughput_tok_per_s": s["throughput_tok_per_s"],
            "per_token_p50_s": s["per_token_p50_s"],
            "energy_per_token_j": s["energy_per_token_j"],
            "decode_io_stall_frac": s["decode_io_stall_frac"],
            "decode_overlap_saved_s": s["decode_overlap_saved_s"],
        }
        if eng.prefetcher is not None:
            row["prefetch"] = eng.prefetcher.summary()
            row["prefetch_wasted_energy_j"] = \
                eng.ledger.prefetch_wasted_energy_j
        timeline_rows[label] = row
        sink.add(f"timeline[{label}]", mb_async,
                 s["throughput_tok_per_s"], s["ttft_p50_s"],
                 s["ttft_p95_s"], s["per_token_p50_s"],
                 s["steady_state_miss_rate"], s["energy_per_token_j"],
                 s["mean_batch_occupancy"])
        extra = ""
        if "prefetch" in row:
            pf = row["prefetch"]
            extra = (f"  prefetch acc={pf['accuracy']:.2f} "
                     f"wasted={pf['wasted']}/{pf['issued']}")
        print(f"{label:>16}: {s['throughput_tok_per_s']:8.1f} tok/s  "
              f"per-token p50={s['per_token_p50_s']*1e6:7.1f} us  "
              f"stall={s['decode_io_stall_frac']:.2f}  "
              f"saved={s['decode_overlap_saved_s']*1e3:.3f} ms{extra}")

    # The acceptance claims, asserted so CI catches regressions.
    tp = {mb: by_batch["saturated"][mb]["throughput_tok_per_s"]
          for mb in batches}
    assert tp[max(batches)] > tp[1], \
        f"batched decode no faster than single: {tp}"
    assert warm_miss < cold["steady_state_miss_rate"], \
        (warm_miss, cold["steady_state_miss_rate"])
    assert warm_s["energy_per_token_j"] < cold["energy_per_token_j"], \
        (warm_s["energy_per_token_j"], cold["energy_per_token_j"])
    # (c) the async timeline beats the serialized replay on decode
    # latency/throughput at (near-)identical energy per token, and blind
    # layer-transition prefetch wastes most of its Flash traffic under
    # this model's stochastic routing (paper §2.1, quantitatively).
    # Note the overlap win is asserted for the async timeline itself
    # (prefetch off): per the paper's §2.1 argument — which this
    # benchmark reproduces on purpose — *enabling* blind prefetch on top
    # is expected to LOSE latency under diversity-regularized routing
    # (wasted fills clog the Flash channel), so asserting
    # async+prefetch < serialized would contradict the claim under test.
    t_sync, t_async = timeline_rows["serialized"], timeline_rows["async"]
    assert t_async["throughput_tok_per_s"] > t_sync["throughput_tok_per_s"], \
        (t_async["throughput_tok_per_s"], t_sync["throughput_tok_per_s"])
    assert t_async["per_token_p50_s"] < t_sync["per_token_p50_s"], \
        (t_async["per_token_p50_s"], t_sync["per_token_p50_s"])
    assert abs(t_async["energy_per_token_j"] - t_sync["energy_per_token_j"]) \
        <= 1e-6 * t_sync["energy_per_token_j"], "overlap changed energy"
    pf = timeline_rows["async+prefetch(markov)"]["prefetch"]
    assert pf["wasted"] > pf["useful"], pf
    print("\nclaims verified: throughput(batch) increasing, warm miss "
          "rate and energy/token below cold baseline, async timeline "
          "faster than serialized at identical energy, markov prefetch "
          "mostly wasted under stochastic routing "
          f"({pf['wasted']}/{pf['issued']} fills wasted)")

    print("\n=== observability overhead: tracing on vs off ===")
    # The async cell re-run with a TimelineTracer attached.  Capture
    # hangs off the charge path as a pure sink, so the *modeled*
    # quantities must not move: energy per token exactly equal, p50
    # within 5% (it is exactly equal too — the bound guards against a
    # future tracer accidentally becoming a participant in the
    # timeline).  Conservation ties the capture to the ledger: the
    # traced makespan must equal the ledger's total latency.
    from repro.obs import TimelineTracer
    trc = TimelineTracer()
    s_tr, eng_tr = run_cell(cfg, params, max_batch=mb_async,
                            n_requests=n_requests, async_io=True,
                            tracer=trc)
    untr = timeline_rows["async"]
    obs_row = {
        "per_token_p50_s": s_tr["per_token_p50_s"],
        "energy_per_token_j": s_tr["energy_per_token_j"],
        "n_trace_events": len(trc.events),
        "n_spans": len(trc.spans),
    }
    assert obs_row["n_trace_events"] > 0 and obs_row["n_spans"] > 0, obs_row
    assert obs_row["energy_per_token_j"] == untr["energy_per_token_j"], \
        ("tracing changed modeled energy", obs_row, untr)
    p50_rel = abs(obs_row["per_token_p50_s"] - untr["per_token_p50_s"]) \
        / untr["per_token_p50_s"]
    assert p50_rel <= 0.05, ("tracing-on p50 off by", p50_rel, obs_row, untr)
    assert abs(trc.makespan() - eng_tr.ledger.total_latency_s) \
        <= 1e-6 * eng_tr.ledger.total_latency_s, \
        (trc.makespan(), eng_tr.ledger.total_latency_s)
    print(f"   traced async: {obs_row['n_trace_events']} events, "
          f"{obs_row['n_spans']} spans  p50 rel diff={p50_rel:.2e}  "
          f"E/tok identical  makespan == ledger latency")
    print("claims verified: tracing perturbs neither modeled p50 "
          "(<=5% bound, measured exact) nor modeled energy (exact)")

    print("\n=== request-level activation predictor: "
          "multi-tenant cold-start cells ===")
    # The tentpole comparison: rotating multi-tenant traffic on an
    # empty-warmup cache (no PCW reshape — the reshape would pre-fill
    # the very slices under test, hiding predictor quality).  A
    # returning tenant's experts were evicted during its absence and
    # its own prefill routing reveals them, so the request-level
    # predictor has real signal where the markov baseline has none.
    # Judged on energy truth: a fill is wasted only if the slice never
    # serves a demand before eviction (or the end-of-run flush).
    PF_REQS, PF_NEW, PF_BATCH, PF_SEED = 24, 24, 4, 1
    pf_rows = {}
    for label, kw in (
            ("plain-async", {}),
            ("async+prefetch(request)",
             dict(prefetch_top_m=6, prefetch_kind="request",
                  prefetch_lookahead=3, prefetch_min_obs=4,
                  prefetch_min_score=0.18))):
        s, eng = run_cell(
            cfg, params, max_batch=PF_BATCH, n_requests=PF_REQS,
            requests=_tenant_mix_workload(PF_REQS, seed=PF_SEED,
                                          max_new=PF_NEW),
            warmup="empty", async_io=True, **kw)
        row = {
            "throughput_tok_per_s": s["throughput_tok_per_s"],
            "per_token_p50_s": s["per_token_p50_s"],
            "energy_per_token_j": s["energy_per_token_j"],
            "steady_miss_rate": s["steady_state_miss_rate"],
            "n_flash_transfers": eng.ledger.n_flash_transfers,
        }
        if eng.prefetcher is not None:
            row["prefetch"] = eng.prefetcher.summary()
            row["prefetch_wasted_energy_j"] = \
                eng.ledger.prefetch_wasted_energy_j
        pf_rows[label] = row
        sink.add(f"request_pf[{label}]", PF_BATCH,
                 s["throughput_tok_per_s"], s["ttft_p50_s"],
                 s["ttft_p95_s"], s["per_token_p50_s"],
                 s["steady_state_miss_rate"], s["energy_per_token_j"],
                 s["mean_batch_occupancy"])
        extra = ""
        if "prefetch" in row:
            p = row["prefetch"]
            extra = (f"  useful/late/wasted={p['useful']}/{p['late']}/"
                     f"{p['wasted']} of {p['issued']}")
        print(f"{label:>24}: per-token p50="
              f"{s['per_token_p50_s']*1e6:7.1f} us  "
              f"E/tok={s['energy_per_token_j']*1e3:.4f} mJ  "
              f"miss={s['steady_state_miss_rate']:.4f}{extra}")
    # The tentpole acceptance triple, on the identical workload seed:
    # the predictor's fills must be net-useful, cut p50, and cost no
    # extra energy per token (useful fills replace demand fills 1:1;
    # the residency concentration under cache-prior routing claws back
    # the few never-used fills).
    pa = pf_rows["plain-async"]
    pr = pf_rows["async+prefetch(request)"]
    rpf = pr["prefetch"]
    assert rpf["useful"] > rpf["wasted"], rpf
    assert pr["per_token_p50_s"] < pa["per_token_p50_s"], (pr, pa)
    assert pr["energy_per_token_j"] <= pa["energy_per_token_j"], (pr, pa)
    print("claims verified: request predictor useful > wasted "
          f"({rpf['useful']} > {rpf['wasted']}), p50 "
          f"{pa['per_token_p50_s']*1e6:.1f} -> "
          f"{pr['per_token_p50_s']*1e6:.1f} us at "
          f"{pr['energy_per_token_j']/pa['energy_per_token_j']*100:.2f}% "
          "of plain-async energy per token")

    print("\n=== expert-parallel sharding: ep ∈ {1, 2, 4} ===")
    # Same saturated workload and async timeline; the only variable is
    # how many shards the experts (and their DRAM slice caches +
    # Flash/DRAM channels) are partitioned across.  Shard timelines
    # progress independently, so per-token latency drops with ep while
    # the all-to-all token dispatch shows up as interconnect bytes and
    # energy (charged, reported, and zero at ep=1).
    ep_values = [1, 2] if quick else [1, 2, 4]
    ep_rows = {}
    for ep in ep_values:
        s, eng = run_cell(cfg, params, max_batch=mb_async,
                          n_requests=n_requests, async_io=True,
                          ep_shards=ep)
        snap = eng.ledger.snapshot()
        ep_rows[ep] = {
            "throughput_tok_per_s": s["throughput_tok_per_s"],
            "per_token_p50_s": s["per_token_p50_s"],
            "energy_per_token_j": s["energy_per_token_j"],
            "steady_miss_rate": s["steady_state_miss_rate"],
            "ici_bytes": snap["ici_bytes"],
            "ici_energy_j": snap["ici_energy_j"],
        }
        if s.get("per_shard"):
            ep_rows[ep]["per_shard_miss"] = [
                round(r["miss_rate"], 4) for r in s["per_shard"]]
        sink.add(f"ep[{ep}]", mb_async, s["throughput_tok_per_s"],
                 s["ttft_p50_s"], s["ttft_p95_s"], s["per_token_p50_s"],
                 s["steady_state_miss_rate"], s["energy_per_token_j"],
                 s["mean_batch_occupancy"])
        extra = "" if ep == 1 else (
            f"  a2a={snap['ici_bytes']/1e6:.2f} MB "
            f"({snap['ici_energy_j']*1e3:.4f} mJ)  "
            f"shard_miss={ep_rows[ep].get('per_shard_miss')}")
        print(f"{'ep=' + str(ep):>12}: "
              f"{s['throughput_tok_per_s']:8.1f} tok/s  "
              f"per-token p50={s['per_token_p50_s']*1e6:7.1f} us  "
              f"E/tok={s['energy_per_token_j']*1e3:.4f} mJ{extra}")
    # Acceptance: shard-parallel timelines must beat the single-device
    # run on per-token p50 latency, with all-to-all charged at ep > 1
    # (and never charged at ep = 1).
    assert ep_rows[1]["ici_bytes"] == 0.0, ep_rows[1]
    for ep in ep_values[1:]:
        assert ep_rows[ep]["per_token_p50_s"] \
            < ep_rows[1]["per_token_p50_s"], (ep, ep_rows)
        assert ep_rows[ep]["ici_bytes"] > 0 \
            and ep_rows[ep]["ici_energy_j"] > 0, (ep, ep_rows)
    print("claims verified: per-token p50 improves at every ep > 1, "
          "all-to-all bytes/energy charged and reported")

    # The ISSUE's numeric bar: the round-robin ep=4 cell's p50 must stay
    # at/below the 280 us baseline the placement refactor started from.
    if 4 in ep_values:
        assert ep_rows[4]["per_token_p50_s"] <= 280e-6, ep_rows[4]

    placement_rows = {}
    if not quick:
        print("\n=== expert placement policies @ ep=4 "
              "(capacity-pressured) ===")
        # Ownership policy is the only variable.  The comparison runs a
        # tighter cache (0.8 MB vs the sweep's 2.5 MB) over a longer
        # stream: at 2.5 MB this tiny workload's misses are almost all
        # cold-start, so any placement signal drowns in warmup noise —
        # under sustained capacity pressure the per-shard miss spread is
        # a steady-state property the policy can actually move.  Hotness
        # bin-packing must narrow the spread round-robin leaves (hot
        # shards thrash while cold shards idle), and replicating the
        # hottest experts must cut all-to-all dispatch bytes (replica
        # accesses resolve to the token's home shard).  Migration
        # traffic is tagged separately inside ici_bytes so the a2a
        # comparison is honest.
        PLACE_N, PLACE_PERIOD, PLACE_CACHE = 24, 8, 0.8e6
        for label, kw in (
                ("round_robin", dict(placement="round_robin")),
                ("hotness", dict(placement="hotness")),
                ("hotness+replicate:2",
                 dict(placement="hotness+replicate:2"))):
            s, eng = run_cell(cfg, params, max_batch=mb_async,
                              n_requests=PLACE_N, async_io=True,
                              ep_shards=4, placement_period=PLACE_PERIOD,
                              cache_bytes=PLACE_CACHE, **kw)
            snap = eng.ledger.snapshot()
            row = {
                "throughput_tok_per_s": s["throughput_tok_per_s"],
                "per_token_p50_s": s["per_token_p50_s"],
                "energy_per_token_j": s["energy_per_token_j"],
                "shard_miss_spread": s["shard_miss_spread"],
                "shard_access_imbalance": s["shard_access_imbalance"],
                "per_shard_miss": [round(r["miss_rate"], 4)
                                   for r in s["per_shard"]],
                "ici_bytes": snap["ici_bytes"],
                "migration_bytes": snap["migration_bytes"],
                "a2a_bytes": snap["ici_bytes"] - snap["migration_bytes"],
                "n_migration_events": len(eng.migration_events),
            }
            placement_rows[label] = row
            sink.add(f"placement[{label}]", mb_async,
                     s["throughput_tok_per_s"], s["ttft_p50_s"],
                     s["ttft_p95_s"], s["per_token_p50_s"],
                     s["steady_state_miss_rate"],
                     s["energy_per_token_j"], s["mean_batch_occupancy"])
            print(f"{label:>20}: per-token p50="
                  f"{row['per_token_p50_s']*1e6:7.1f} us  "
                  f"miss_spread={row['shard_miss_spread']:.4f} "
                  f"{row['per_shard_miss']}  "
                  f"a2a={row['a2a_bytes']/1e6:.2f} MB  "
                  f"migr={row['migration_bytes']/1e6:.2f} MB")
        rr = placement_rows["round_robin"]
        hot = placement_rows["hotness"]
        repl = placement_rows["hotness+replicate:2"]
        # Acceptance: hotness narrows the per-shard miss spread and does
        # not regress p50 vs round-robin on the same workload; the
        # replicated variant cuts all-to-all dispatch bytes (its replica
        # fills may cost a little latency, bounded at 3%).
        assert hot["shard_miss_spread"] < rr["shard_miss_spread"], \
            (hot["shard_miss_spread"], rr["shard_miss_spread"])
        assert repl["a2a_bytes"] < rr["a2a_bytes"], \
            (repl["a2a_bytes"], rr["a2a_bytes"])
        assert hot["per_token_p50_s"] <= rr["per_token_p50_s"], (hot, rr)
        assert repl["per_token_p50_s"] <= 1.03 * rr["per_token_p50_s"], \
            (repl, rr)

        # Live-vs-replay placement fidelity: a single-slot scheduler
        # labels each request's stats epoch, so replaying its recorded
        # trace must reproduce every shard's per-epoch miss counts AND
        # the migration event sequence exactly (placement decisions
        # consume only charge-path hotness, which the replay recomputes
        # bit-for-bit — same argument as the controller fidelity gate).
        from repro.sim import TraceRecorder
        from repro.sim.replay import ReplayEngine

        rec = TraceRecorder()
        _, live_eng = run_cell(cfg, params, max_batch=1, n_requests=8,
                               ep_shards=4, placement="hotness",
                               placement_period=PLACE_PERIOD,
                               cache_bytes=PLACE_CACHE, recorder=rec)
        tr = rec.trace()
        reng = ReplayEngine(tr.meta)
        reng.consume_all(tr.events)
        rep = reng.finish()
        assert (rep.migration_events or []) == live_eng.migration_events, \
            (rep.migration_events, live_eng.migration_events)
        assert rep.per_shard_epoch_counts \
            == live_eng.cache.per_shard_epoch_counts()
        assert reng.cache.per_shard_counts() \
            == live_eng.cache.per_shard_counts()
        n_mig = len(live_eng.migration_events)
        print("claims verified: hotness narrows per-shard miss spread "
              f"({rr['shard_miss_spread']:.4f} -> "
              f"{hot['shard_miss_spread']:.4f}) at no p50 cost, "
              f"replication cuts a2a bytes ({rr['a2a_bytes']/1e6:.2f} "
              f"-> {repl['a2a_bytes']/1e6:.2f} MB); hotness "
              "live-vs-replay fidelity exact (per-shard epoch counts + "
              f"{n_mig} migration events)")

    print("\n=== dense-dequant vs quantized-execution expert FFN ===")
    # Same workload/scheduler; the only variable is whether the jitted
    # steps materialize dense expert weights or run the batched-expert
    # Pallas kernel directly on packed AMAT codes.  Wall-clock on CPU
    # reflects interpret-mode kernel emulation, NOT TPU behavior; the
    # weight-byte column is the shared analytic traffic model
    # (hw/energy.py::expert_weight_step_bytes) at this config's dense
    # dtype (bf16), not a runtime measurement.
    mb = max(batches)
    qe_rows = {}
    for label, qe in (("dense_dequant", False), ("quant_execution", True)):
        s, eng = run_cell(cfg, params, max_batch=mb,
                          n_requests=n_requests, quant_execution=qe)
        wb = eng.expert_weight_bytes_per_step(quant_execution=qe)
        qe_rows[label] = {
            "per_token_p50_s": s["per_token_p50_s"],
            "throughput_tok_per_s": s["throughput_tok_per_s"],
            "expert_weight_bytes_per_step": wb,
        }
        sink.add(f"expert_ffn[{label}]", mb, s["throughput_tok_per_s"],
                 s["ttft_p50_s"], s["ttft_p95_s"], s["per_token_p50_s"],
                 s["steady_state_miss_rate"], s["energy_per_token_j"],
                 s["mean_batch_occupancy"])
        print(f"{label:>16}: per-token p50 = "
              f"{s['per_token_p50_s']*1e3:7.2f} ms  "
              f"weight bytes/step = {wb/1e6:6.2f} MB")
    reduction = (qe_rows["dense_dequant"]["expert_weight_bytes_per_step"]
                 / qe_rows["quant_execution"]["expert_weight_bytes_per_step"])
    print(f"quantized execution moves {reduction:.1f}x fewer expert "
          f"weight bytes per step (bf16 dense baseline; the >=2x MAT84 "
          f"bound is asserted in kernels_micro)")

    path = sink.flush()
    payload = {
        "arch": ARCH, "n_requests": n_requests,
        "throughput_by_batch": {str(mb_): tp[mb_] for mb_ in batches},
        "warm_vs_cold": {
            "warm_miss": warm_miss,
            "cold_miss": cold["steady_state_miss_rate"],
            "warm_energy_per_token_j": warm_s["energy_per_token_j"],
            "cold_energy_per_token_j": cold["energy_per_token_j"],
        },
        "dense_vs_quant_execution": dict(
            qe_rows, weight_bytes_reduction_x=reduction),
        "sync_vs_async_timeline": timeline_rows,
        "request_prefetch": pf_rows,
        "ep_scaling": {str(ep): row for ep, row in ep_rows.items()},
        "placement": placement_rows,
        "observability": obs_row,
    }
    _check_against_baseline(payload, quick=quick)
    if not quick:
        # --quick is a CI smoke run at a smaller sweep; persisting it
        # would clobber the cross-PR regression baseline.
        json_record("serving_load", payload)
    speedup = (t_async["throughput_tok_per_s"]
               / t_sync["throughput_tok_per_s"])
    report("serving_load", 0.0,
           f"async_speedup={speedup:.3f}x;"
           f"qexec_bytes_reduction={reduction:.1f}x;csv={path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
