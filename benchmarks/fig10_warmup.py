"""Paper Fig. 10 (+Fig. 3): cache warmup strategies at the prefill→decode
transition, and the prefill-hotness → early-decode carryover that makes
PCW work.

Initial states compared: empty / last-layer-only / random / PCW(hot).
Metrics: early-decode energy & latency (first 10 steps, where cold misses
dominate) and whole-decode totals, plus the Spearman-style rank
correlation between prefill expert hotness and early-decode expert usage
(the Fig. 3 observation, reported as `hotness_corr`).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CsvSink, report, train_or_load
from repro.core.amat import MatConfig
from repro.core.engine import EngineConfig, SliceMoEEngine
from repro.models.moe import RoutingPolicy

ARCH = "deepseek-v2-lite-repro"
DECODE_STEPS = 24
EARLY = 10
PROMPT = 48


def run_init(cfg, params, toks, warmup: str, cache_bytes: float):
    ecfg = EngineConfig(
        mat=MatConfig(8, 4), cache_bytes=cache_bytes,
        policy=RoutingPolicy(kind="cache_prior", slice_mode="dbsc"),
        miss_rate_target=0.05, warmup=warmup, max_seq=96)
    eng = SliceMoEEngine(cfg, params, ecfg)

    logits = eng.prefill(toks)
    prefill_hot = eng.tracker.hotness().copy()

    first = jnp.argmax(logits, -1).astype(jnp.int32)
    _, metrics = eng.decode(first, DECODE_STEPS)
    steps = metrics["per_step"]
    early_e = sum(s["total_energy_j"] for s in steps[:EARLY])
    early_l = sum(s["total_latency_s"] for s in steps[:EARLY])
    tot = metrics["decode_totals"]

    decode_hot = eng.tracker.hotness()
    corr = _rank_corr(prefill_hot.reshape(-1), decode_hot.reshape(-1))
    return dict(early_energy=early_e, early_latency=early_l,
                total_energy=tot["total_energy_j"],
                total_latency=tot["total_latency_s"],
                hotness_corr=corr,
                misses=metrics["cache_stats"]["msb_misses"]
                + metrics["cache_stats"]["lsb_misses"])


def _rank_corr(a: np.ndarray, b: np.ndarray) -> float:
    ra = np.argsort(np.argsort(a)).astype(np.float64)
    rb = np.argsort(np.argsort(b)).astype(np.float64)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra * ra).sum() * (rb * rb).sum())
    return float((ra * rb).sum() / max(denom, 1e-12))


def main(quick: bool = False) -> None:
    t0 = time.perf_counter()
    cfg, params = train_or_load(ARCH)
    toks = jax.random.randint(jax.random.PRNGKey(11), (1, PROMPT), 0,
                              cfg.vocab_size)
    probe = SliceMoEEngine(cfg, params, EngineConfig(max_seq=96))
    cache_bytes = 0.3 * probe.store.total_bytes()

    sink = CsvSink("fig10_warmup",
                   ["init_state", "early_energy_j", "early_latency_s",
                    "total_energy_j", "total_latency_s", "misses",
                    "hotness_corr"])
    inits = ("empty", "last_layer", "random", "pcw") if not quick \
        else ("empty", "pcw")
    results = {}
    for init in inits:
        r = run_init(cfg, params, toks, init, cache_bytes)
        results[init] = r
        sink.add(init, f"{r['early_energy']:.5e}",
                 f"{r['early_latency']:.5e}", f"{r['total_energy']:.5e}",
                 f"{r['total_latency']:.5e}", r["misses"],
                 round(r["hotness_corr"], 3))

    path = sink.flush()
    us = (time.perf_counter() - t0) * 1e6
    gain = results["empty"]["early_energy"] / \
        max(results["pcw"]["early_energy"], 1e-12)
    speed = results["empty"]["early_latency"] / \
        max(results["pcw"]["early_latency"], 1e-12)
    report("fig10_warmup", us,
           f"pcw_vs_empty:E{gain:.2f}x/S{speed:.2f}x;"
           f"hotness_corr={results['pcw']['hotness_corr']:.2f};csv={path}")


if __name__ == "__main__":
    main()
