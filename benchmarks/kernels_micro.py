"""Micro-benchmarks of the Pallas kernels (interpret mode on CPU — the
numbers gauge the *reference path*; real VMEM-tiled timings need a TPU)
plus the pure-jnp oracle for comparison."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import CsvSink, report, time_call
from repro.kernels.amat_matmul.ops import amat_matmul_qt
from repro.kernels.amat_matmul.ref import amat_matmul_ref
from repro.kernels.expert_matmul.ops import expert_matmul_qt
from repro.kernels.expert_matmul.ref import expert_matmul_ref
from repro.quant.groupquant import quantize


def main(quick: bool = False) -> None:
    t0 = time.perf_counter()
    sink = CsvSink("kernels_micro", ["kernel", "shape", "us_per_call"])
    key = jax.random.PRNGKey(0)

    M, K, N = (64, 256, 128) if quick else (128, 512, 256)
    x = jax.random.normal(key, (M, K))
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N)) * 0.1
    qt = quantize(w, bits=8, group_size=32, asymmetric=True)

    us_k = time_call(lambda: amat_matmul_qt(x, qt, shift=4, mode="low"))
    us_r = time_call(lambda: jax.jit(
        lambda: amat_matmul_ref(x, qt.codes, qt.scales, qt.zero_points,
                                group_size=32, shift=4, mode="low"))())
    sink.add("amat_matmul_pallas_interp", f"{M}x{K}x{N}", round(us_k, 1))
    sink.add("amat_matmul_ref_jit", f"{M}x{K}x{N}", round(us_r, 1))

    E, C = (4, 32) if quick else (8, 64)
    xe = jax.random.normal(key, (E, C, K))
    we = jax.random.normal(jax.random.fold_in(key, 2), (E, K, N)) * 0.1
    qte = quantize(we, bits=8, group_size=32, asymmetric=True)
    ul = jnp.arange(E) % 2 == 0
    us_e = time_call(lambda: expert_matmul_qt(xe, qte, ul, shift=4))
    us_er = time_call(lambda: jax.jit(
        lambda: expert_matmul_ref(xe, qte.codes, qte.scales,
                                  qte.zero_points, ul, group_size=32,
                                  shift=4))())
    sink.add("expert_matmul_pallas_interp", f"{E}x{C}x{K}x{N}",
             round(us_e, 1))
    sink.add("expert_matmul_ref_jit", f"{E}x{C}x{K}x{N}", round(us_er, 1))

    path = sink.flush()
    us = (time.perf_counter() - t0) * 1e6
    report("kernels_micro", us,
           f"amat={us_k:.0f}us;expert={us_e:.0f}us;csv={path}")


if __name__ == "__main__":
    main()
