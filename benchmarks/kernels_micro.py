"""Micro-benchmarks of the Pallas kernels (interpret mode on CPU — the
numbers gauge the *reference path*; real VMEM-tiled timings need a TPU)
plus the pure-jnp oracle for comparison.

Also reports, per paper MAT config, the analytic HBM weight bytes moved
by one expert-FFN step under **dense dequantization** (read codes, write
the dense f32 tensor, read it back into the matmul) vs **quantized
execution** (stream packed codes straight into the fused kernel) — the
tentpole claim (>= 2x fewer bytes for MAT84, asserted) and the
cross-PR baseline recorded in results/BENCH_kernels_micro.json."""

from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CsvSink, json_record, report, time_call
from repro.core.amat import PAPER_CONFIGS, amat_quantize
from repro.kernels.amat_matmul.ops import (amat_expert_matmul_qt,
                                           amat_matmul_qt)
from repro.kernels.amat_matmul.ref import (amat_batched_matmul_ref,
                                           amat_matmul_ref)
from repro.hw.energy import expert_weight_step_bytes
from repro.kernels.expert_matmul.ops import expert_matmul_qt
from repro.kernels.expert_matmul.ref import expert_matmul_ref
from repro.quant.groupquant import quantize


def main(quick: bool = False) -> None:
    t0 = time.perf_counter()
    sink = CsvSink("kernels_micro", ["kernel", "shape", "us_per_call"])
    key = jax.random.PRNGKey(0)

    M, K, N = (64, 256, 128) if quick else (128, 512, 256)
    x = jax.random.normal(key, (M, K))
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N)) * 0.1
    qt = quantize(w, bits=8, group_size=32, asymmetric=True)

    us_k = time_call(lambda: amat_matmul_qt(x, qt, shift=4, mode="low"))
    amat_ref_fn = jax.jit(partial(amat_matmul_ref, group_size=32, shift=4,
                                  mode="low"))
    us_r = time_call(lambda: amat_ref_fn(x, qt.codes, qt.scales,
                                         qt.zero_points))
    sink.add("amat_matmul_pallas_interp", f"{M}x{K}x{N}", round(us_k, 1))
    sink.add("amat_matmul_ref_jit", f"{M}x{K}x{N}", round(us_r, 1))

    E, C = (4, 32) if quick else (8, 64)
    xe = jax.random.normal(key, (E, C, K))
    we = jax.random.normal(jax.random.fold_in(key, 2), (E, K, N)) * 0.1
    qte = quantize(we, bits=8, group_size=32, asymmetric=True)
    ul = jnp.arange(E) % 2 == 0
    us_e = time_call(lambda: expert_matmul_qt(xe, qte, ul, shift=4))
    expert_ref_fn = jax.jit(partial(expert_matmul_ref, group_size=32,
                                    shift=4))
    us_er = time_call(lambda: expert_ref_fn(xe, qte.codes, qte.scales,
                                            qte.zero_points, ul))
    sink.add("expert_matmul_pallas_interp", f"{E}x{C}x{K}x{N}",
             round(us_e, 1))
    sink.add("expert_matmul_ref_jit", f"{E}x{C}x{K}x{N}", round(us_er, 1))

    # --- quantized execution vs dense dequant: the batched-expert kernel
    # (scalar-prefetched per-expert use_lsb) against the materialize-
    # then-einsum reference, plus analytic HBM weight-byte accounting.
    us_b = us_br = 0.0
    bytes_rows = {}
    for mat in PAPER_CONFIGS:
        qtm = amat_quantize(we, mat)
        us_b = time_call(lambda q=qtm, m=mat: amat_expert_matmul_qt(
            xe, q, ul, shift=m.shift))
        # jit once, time only execution (a jit built inside the timed
        # lambda would measure recompilation on every call)
        ref_fn = jax.jit(partial(amat_batched_matmul_ref,
                                 group_size=mat.group_size,
                                 shift=mat.shift))
        us_br = time_call(lambda q=qtm: ref_fn(
            xe, q.codes, q.scales, q.zero_points, ul))
        sink.add(f"amat_batched_pallas_interp[{mat.name}]",
                 f"{E}x{C}x{K}x{N}", round(us_b, 1))
        sink.add(f"amat_batched_dense_ref_jit[{mat.name}]",
                 f"{E}x{C}x{K}x{N}", round(us_br, 1))

        n_elems = float(np.prod(qtm.codes.shape))
        n_groups = float(np.prod(qtm.scales.shape))
        # dense_itemsize=4: the dense reference here materializes f32
        dense_b = expert_weight_step_bytes(n_elems, n_groups,
                                           quant_execution=False,
                                           dense_itemsize=4)
        quant_b = expert_weight_step_bytes(n_elems, n_groups,
                                           quant_execution=True)
        bytes_rows[mat.name] = {
            "dense_dequant_bytes": dense_b,
            "quant_execution_bytes": quant_b,
            "reduction_x": dense_b / quant_b,
            "pallas_interp_us": us_b,
            "dense_ref_jit_us": us_br,
        }
        sink.add(f"weight_bytes_dense[{mat.name}]", f"{E}x{C}x{K}x{N}",
                 round(dense_b, 1))
        sink.add(f"weight_bytes_quant_exec[{mat.name}]", f"{E}x{C}x{K}x{N}",
                 round(quant_b, 1))
    # Pins the analytic traffic model's headline claim (the bytes are a
    # model of the two execution paths, not a runtime measurement — a
    # kernel regression shows up in the parity tests, not here).
    assert bytes_rows["MAT84"]["reduction_x"] >= 2.0, bytes_rows["MAT84"]

    path = sink.flush()
    json_record("kernels_micro", {
        "shape": {"E": E, "C": C, "K": K, "N": N},
        "dense_vs_quant_execution": bytes_rows,
        "amat_matmul_us": us_k,
        "expert_matmul_us": us_e,
    })
    us = (time.perf_counter() - t0) * 1e6
    report("kernels_micro", us,
           f"amat={us_k:.0f}us;expert={us_e:.0f}us;"
           f"batched={us_b:.0f}us;"
           f"mat84_bytes_reduction="
           f"{bytes_rows['MAT84']['reduction_x']:.1f}x;csv={path}")


if __name__ == "__main__":
    main()
