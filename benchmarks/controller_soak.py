"""SLO-controller soak: closed-loop adaptation vs every static config.

Drives a phase-shifting multi-tenant workload (tenant mix AND expert
hotness change at every phase boundary — :func:`repro.sim.synthetic.
tenant_phase_trace`) through the model-free replay under three static
configs and under the closed-loop SLO controller (:mod:`repro.control`),
then scores everyone on the same per-(tenant, phase) SLO grid:

* a cell is **attained** iff the tenant's charged miss rate in that
  phase meets its miss SLO *and* its critical-selection low-bit exposure
  meets its accuracy SLO (``lowbit_frac``);
* **attainment** is the fraction of attained cells.

Acceptance (asserted, and persisted as the regression baseline):

  (a) the controller's attainment is strictly higher than every static
      config's, at equal-or-lower energy than the best static
      (best = highest attainment, ties broken toward lower energy) —
      adaptation beats any fixed choice under shifting load;
  (b) **fidelity**: a *live* 2-tenant serving run with the controller
      enabled records a trace whose bare replay reproduces the live
      per-epoch miss counts exactly and per-step miss/energy curves
      within rtol 1e-6 — controller decisions are a deterministic
      function of the charge stream, so the bit plan is never recorded,
      only recomputed;
  (c) replay determinism: two replays of the controller config agree
      step-for-step.

Run:  PYTHONPATH=src python benchmarks/controller_soak.py [--quick]
"""

from __future__ import annotations

import os as _os
import sys as _sys

_root = _os.path.join(_os.path.dirname(_os.path.abspath(__file__)), "..")
for _p in (_os.path.join(_root, "src"), _root):
    if _p not in _sys.path:
        _sys.path.insert(0, _p)

import argparse
import json

from benchmarks.common import RESULTS, json_record, report
from repro.control import ControllerConfig, TenantSLO
from repro.sim import replay_trace
from repro.sim.synthetic import SyntheticSpec, tenant_phase_trace

# The SLO grid everyone is judged on.  Premium is accuracy-sensitive
# (at most 5% of its critical selections may be served low-bit, and it
# is pinned at full precision) with a loose miss SLO — under the dbsc
# plan its miss rate is dominated by structural LSB refetches
# (``lsb_keep_frac``), which no actuator that respects its bit floor
# can remove.  Batch tolerates full low-bit service but carries a tight
# miss SLO that only MSB-only service can hold on this workload.  The
# miss targets are calibrated so each static config fails somewhere
# across the phase shifts while the controller's demotion actuator can
# hold the whole grid (see results/BENCH_controller_soak.json).
SLOS = {
    "premium": TenantSLO(miss_rate=0.60, lowbit_frac=0.05,
                         bit_floor="high"),
    "batch": TenantSLO(miss_rate=0.15, lowbit_frac=1.0,
                       bit_floor="low"),
}

STATICS = {
    "static:dbsc": {},
    "static:lowbit": {"slice_mode": "lowbit"},
    "static:highbit": {"slice_mode": "highbit"},
}


def _controller_cfg(interval: int = 4, *,
                    partition: bool = False) -> ControllerConfig:
    # Partitioning is off for the replayed soak: this workload is
    # capacity-starved, so fragmenting the shared cache into per-tenant
    # segments costs more misses than isolation saves.  The partition
    # actuator is still exercised live (the fidelity gate below runs
    # with it on) and by tests/test_control.py.
    return ControllerConfig(slos=dict(SLOS), interval=interval,
                            window=32, cooldown=2 * interval,
                            hysteresis=0.1, partition=partition)


def _soak_trace(quick: bool):
    # Mix shifts every phase: batch-heavy -> premium-only -> batch-heavy
    # again, on freshly drawn hotness each time.  zipf_a = 2.0 gives
    # each tenant a compact hot set, so miss rates reflect policy, not
    # pure capacity starvation.
    mixes = [{"premium": 1.0, "batch": 3.0},
             {"premium": 1.0},
             {"premium": 1.0, "batch": 3.0}]
    phases = 2 if quick else 3
    return tenant_phase_trace(
        SyntheticSpec(cache_frac=0.35),
        tenants=mixes[:phases], phases=phases,
        requests_per_phase=4 if quick else 8,
        prompt_len=12, decode_steps=12 if quick else 24,
        zipf_a=2.0, seed=0)


# ---------------------------------------------------------------- scoring
def _step_cells(trace):
    """(tenant, phase) per decode event, in trace order."""
    cells = []
    phase, tenant = 0, "default"
    for e in trace.events:
        if e.kind == "prefill":
            if e.label and e.label.startswith("ph"):
                phase = int(e.label.split("/")[0][2:])
            tenant = getattr(e, "tenant", None) or "default"
        else:
            cells.append((tenant, phase))
    return cells


def score(trace, rep) -> dict:
    """Attainment over the per-(tenant, phase) SLO grid."""
    cells = _step_cells(trace)
    rows = rep.per_tenant_rows or []
    assert len(cells) == len(rows), (len(cells), len(rows))
    agg: dict = {}
    for (_, phase), by_tenant in zip(cells, rows):
        for tenant, row in (by_tenant or {}).items():
            c = agg.setdefault((tenant, phase),
                               {"accesses": 0, "misses": 0,
                                "critical": 0, "critical_low": 0})
            for k in c:
                c[k] += int(row.get(k, 0))
    grid = {}
    attained = 0
    for (tenant, phase), c in sorted(agg.items()):
        slo = SLOS[tenant]
        miss = c["misses"] / max(c["accesses"], 1)
        low = c["critical_low"] / max(c["critical"], 1)
        ok = (slo.miss_rate is None or miss <= slo.miss_rate) \
            and low <= slo.lowbit_frac
        attained += ok
        grid[f"{tenant}/ph{phase}"] = {
            "miss_rate": miss, "lowbit_frac": low, "attained": bool(ok)}
    return {
        "attainment": attained / max(len(agg), 1),
        "n_cells": len(agg),
        "energy_j": rep.total_energy_j,
        "latency_s": rep.total_latency_s,
        "decode_miss_rate": rep.decode_miss_rate,
        "grid": grid,
    }


# --------------------------------------------------------- fidelity gate
def _close(a: float, b: float, rtol: float = 1e-6) -> bool:
    return a == b or abs(a - b) <= rtol * max(abs(a), abs(b), 1e-30)


def _live_fidelity(quick: bool) -> dict:
    """Record a live controller-enabled 2-tenant serving run and assert
    its bare replay reproduces it (same template as sim_fidelity)."""
    import dataclasses

    import jax

    from repro.configs.base import get_config
    from repro.core.amat import MatConfig
    from repro.core.engine import EngineConfig, PersistentEngine
    from repro.models.model import init_params
    from repro.models.moe import RoutingPolicy
    from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                         SchedulerConfig)
    from repro.serving.workloads import (LengthDist, TenantSpec,
                                         WorkloadConfig, generate)
    from repro.sim import TraceRecorder

    n_requests = 4 if quick else 6
    cfg = get_config("qwen15-moe-repro")
    cfg = dataclasses.replace(cfg, n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(
        mat=MatConfig(8, 4), cache_bytes=1.0e6,
        policy=RoutingPolicy(kind="cache_prior", slice_mode="dbsc"),
        miss_rate_target=0.1, warmup="pcw", max_seq=64,
        controller=_controller_cfg(interval=4, partition=True))
    engine = PersistentEngine(cfg, params, ecfg)
    sched = ContinuousBatchingScheduler(
        engine, SchedulerConfig(max_batch=1, max_queue=n_requests + 1))
    rec = sched.attach_recorder(TraceRecorder())
    tenants = tuple(
        TenantSpec(name=t, weight=w,
                   prompt_len=LengthDist("fixed", 24),
                   output_len=LengthDist("fixed", 12))
        for t, w in (("premium", 1.0), ("batch", 2.0)))
    for r in generate(WorkloadConfig(kind="closed_loop",
                                     n_requests=n_requests, seed=0,
                                     tenants=tenants), cfg.vocab_size):
        sched.submit(r)
    sched.run()
    live = {
        "miss_curve": sched.telemetry.miss_rate_curve(),
        "energy_curve": sched.telemetry.energy_curve(),
        "epoch_counts": engine.cache.epoch_counts(),
        "ledger": engine.ledger.snapshot(),
        "controller": engine.slo_controller.summary(),
    }

    rep = replay_trace(rec.trace())
    assert rep.epoch_counts == live["epoch_counts"], \
        (rep.epoch_counts, live["epoch_counts"])
    assert rep.miss_curve == live["miss_curve"], "per-step miss drifted"
    assert all(_close(a, b) for a, b in
               zip(rep.energy_curve, live["energy_curve"])), \
        "per-step energy drifted"
    for key in ("total_energy_j", "total_latency_s", "flash_bytes",
                "dram_bytes"):
        assert _close(rep.ledger[key], live["ledger"][key]), key
    ctl = rep.controller_summary
    assert ctl is not None \
        and ctl["levels"] == live["controller"]["levels"] \
        and ctl["budgets"] == live["controller"]["budgets"] \
        and ctl["n_actions"] == live["controller"]["n_actions"], \
        (ctl, live["controller"])
    print(f"fidelity: live controller run == bare replay "
          f"({len(live['miss_curve'])} steps, epochs exact, "
          f"{ctl['n_actions']} controller actions reproduced)")
    return {"n_steps": len(live["miss_curve"]),
            "n_actions": ctl["n_actions"],
            "levels": ctl["levels"]}


def _check_against_baseline(payload: dict, *, quick: bool,
                            rtol: float = 1e-6) -> None:
    """The replayed soak cells are deterministic; they must reproduce
    the persisted results/BENCH_controller_soak.json."""
    path = _os.path.join(RESULTS, "BENCH_controller_soak.json")
    if quick or not _os.path.exists(path):
        return
    with open(path) as f:
        prev = json.load(f)
    if prev.get("n_decode_steps") != payload["n_decode_steps"]:
        return                      # different horizon, incomparable
    mismatches = []
    for name, row in prev.get("configs", {}).items():
        cur_row = payload["configs"].get(name)
        for k in ("attainment", "energy_j", "latency_s",
                  "decode_miss_rate"):
            v = row.get(k)
            cur = None if cur_row is None else cur_row.get(k)
            if not isinstance(v, (int, float)):
                continue
            if cur is None or not _close(v, cur, rtol):
                mismatches.append((name, k, v, cur))
    assert not mismatches, \
        f"soak diverged from persisted baseline: {mismatches}"
    print(f"baseline check: soak cells reproduce {path} (rtol={rtol:g})")


def main(quick: bool = False) -> None:
    trace = _soak_trace(quick)
    n_steps = trace.n_decode_steps
    print(f"=== controller soak: {trace.meta.model}, "
          f"{trace.n_prefills} requests / {n_steps} decode steps, "
          f"phase-shifting tenant mix ===")

    results = {}
    for name, overrides in STATICS.items():
        results[name] = score(trace, replay_trace(trace, **overrides))
    ctl_cfg = _controller_cfg()
    ctl_rep = replay_trace(trace, controller=ctl_cfg)
    results["controller"] = score(trace, ctl_rep)

    # (c) replay determinism: same trace + same controller -> identical
    # curves and identical decisions.
    ctl_rep2 = replay_trace(trace, controller=ctl_cfg)
    assert ctl_rep2.miss_curve == ctl_rep.miss_curve
    assert ctl_rep2.controller_summary == ctl_rep.controller_summary

    for name, r in results.items():
        cells = " ".join(
            f"{cell}[{'ok' if v['attained'] else 'VIOL'} "
            f"m={v['miss_rate']:.2f} l={v['lowbit_frac']:.2f}]"
            for cell, v in r["grid"].items())
        print(f"{name:>16}: attainment={r['attainment']:.3f} "
              f"energy={r['energy_j'] * 1e3:.3f} mJ  {cells}")
    ctl_sum = ctl_rep.controller_summary
    print(f"controller actions: {ctl_sum['n_actions']} "
          f"(levels={ctl_sum['levels']}, "
          f"admit={ctl_sum['admit_fracs']})")

    # (a) adaptation beats every static on attainment, at equal-or-lower
    # energy than the best static.
    ctl = results["controller"]
    for name in STATICS:
        assert ctl["attainment"] > results[name]["attainment"], \
            (name, ctl["attainment"], results[name]["attainment"])
    best = max(STATICS, key=lambda n: (results[n]["attainment"],
                                       -results[n]["energy_j"]))
    assert ctl["energy_j"] <= results[best]["energy_j"], \
        (best, ctl["energy_j"], results[best]["energy_j"])
    print(f"claims verified: controller attainment "
          f"{ctl['attainment']:.3f} > best static "
          f"({best}: {results[best]['attainment']:.3f}) at "
          f"{results[best]['energy_j'] / ctl['energy_j']:.2f}x lower "
          f"energy")

    # (b) live-vs-replay fidelity with the controller in the loop.
    print("\n=== live controller serving run vs bare replay ===")
    fidelity = _live_fidelity(quick)

    payload = {
        "n_requests": trace.n_prefills,
        "n_decode_steps": n_steps,
        "slos": {t: s.to_dict() for t, s in SLOS.items()},
        "configs": results,
        "best_static": best,
        "controller_actions": ctl_sum["n_actions"],
        "fidelity": fidelity,
    }
    _check_against_baseline(payload, quick=quick)
    if not quick:
        # --quick is the CI smoke at a shorter horizon; persisting it
        # would clobber the cross-PR regression baseline.
        json_record("controller_soak", payload)
    report("controller_soak", 0.0,
           f"attainment={ctl['attainment']:.3f}"
           f"(best_static={results[best]['attainment']:.3f});"
           f"energy_vs_best={ctl['energy_j'] / results[best]['energy_j']:.3f}x;"
           f"fidelity=exact")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
