"""Ablations beyond the paper's headline figures.

1. **DBSC criticality threshold theta** (paper §4.1 "single-head"):
   sweep theta ∈ {0.3 … 0.9} — lower theta marks more experts critical
   (more LSB traffic, higher precision); theta=1.0 degenerates to
   uniform low-bit.
2. **LSB keep fraction in PCW** (paper §4.3 ties it to the single-head
   ratio): sweep lsb_keep_frac.
3. **Slice-aware vs single-LRU cache** (paper §4.1's heterogeneous
   management): same DBSC routing, cache with/without the LSB
   low-priority segment.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import CsvSink, report, train_or_load
from repro.core.amat import MatConfig
from repro.core.engine import EngineConfig, SliceMoEEngine
from repro.models.moe import RoutingPolicy

ARCH = "qwen15-moe-repro"
STEPS = 20


def run(cfg, params, toks, **over):
    base = dict(mat=MatConfig(8, 4), cache_bytes=4e6,
                policy=RoutingPolicy(kind="cache_prior", slice_mode="dbsc"),
                miss_rate_target=0.05, warmup="pcw", max_seq=96)
    base.update(over)
    eng = SliceMoEEngine(cfg, params, EngineConfig(**base))
    logits = eng.prefill(toks)
    first = jnp.argmax(logits, -1).astype(jnp.int32)
    _, m = eng.decode(first, STEPS)
    d = m["decode_totals"]
    s = m["cache_stats"]
    return {
        "energy_mj": d["total_energy_j"] * 1e3,
        "latency_ms": d["total_latency_s"] * 1e3,
        "lsb_fetches": s["lsb_hits"] + s["lsb_misses"],
        "miss_rate": s.miss_rate if hasattr(s, "miss_rate")
        else (s["msb_misses"] + s["lsb_misses"])
        / max(s["msb_hits"] + s["msb_misses"]
              + s["lsb_hits"] + s["lsb_misses"], 1),
    }


def main(quick: bool = False) -> None:
    t0 = time.perf_counter()
    cfg, params = train_or_load(ARCH)
    toks = jax.random.randint(jax.random.PRNGKey(21), (1, 48), 0,
                              cfg.vocab_size)
    sink = CsvSink("ablations", ["ablation", "setting", "energy_mj",
                                 "latency_ms", "lsb_fetches", "miss_rate"])

    thetas = (0.3, 0.5, 0.7, 0.9) if not quick else (0.5,)
    for th in thetas:
        r = run(cfg, params, toks,
                policy=RoutingPolicy(kind="cache_prior", slice_mode="dbsc",
                                     theta=th))
        sink.add("theta", th, round(r["energy_mj"], 4),
                 round(r["latency_ms"], 4), r["lsb_fetches"],
                 round(r["miss_rate"], 4))

    fracs = (0.05, 0.125, 0.3) if not quick else (0.125,)
    for fr in fracs:
        r = run(cfg, params, toks, lsb_keep_frac=fr)
        sink.add("lsb_keep_frac", fr, round(r["energy_mj"], 4),
                 round(r["latency_ms"], 4), r["lsb_fetches"],
                 round(r["miss_rate"], 4))

    for fused in (False, True):
        r = run(cfg, params, toks, fused_slices=fused)
        sink.add("slice_aware_cache", not fused, round(r["energy_mj"], 4),
                 round(r["latency_ms"], 4), r["lsb_fetches"],
                 round(r["miss_rate"], 4))

    # Prefetching baseline (paper §2.1): flash traffic vs cache-aware.
    r_pf = run(cfg, params, toks,
               policy=RoutingPolicy(kind="topk", slice_mode="highbit"),
               fused_slices=True, warmup="empty", miss_rate_target=None,
               prefetch_top_m=4)
    sink.add("prefetch_topk", 4, round(r_pf["energy_mj"], 4),
             round(r_pf["latency_ms"], 4), r_pf["lsb_fetches"],
             round(r_pf["miss_rate"], 4))

    # HOBBIT-style duplicated mixed precision vs AMAT Matryoshka storage
    # (paper §2.2): bytes to support {high, low} expert precisions.
    probe = SliceMoEEngine(cfg, params, EngineConfig(max_seq=96))
    st = probe.store
    matryoshka = st.highbit_expert_bytes()
    duplicated = st.highbit_expert_bytes() + st.msb_bytes_per_expert
    sink.add("storage_per_expert_bytes", "amat_matryoshka",
             round(matryoshka), "", "", "")
    sink.add("storage_per_expert_bytes", "hobbit_duplicated",
             round(duplicated), "", "", "")

    path = sink.flush()
    us = (time.perf_counter() - t0) * 1e6
    sliced = [r for r in sink.rows if r[0] == "slice_aware_cache"]
    gain = sliced[1][2] / max(sliced[0][2], 1e-12) if len(sliced) == 2 else 0
    report("ablations", us, f"fused/sliced_energy={gain:.2f}x;csv={path}")


if __name__ == "__main__":
    main()
