"""Shared benchmark infrastructure: trained-model cache, CSV sink, timers."""

from __future__ import annotations

import os
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as CKPT
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import adamw as OPT

# REPRO_RESULTS_DIR redirects every benchmark output (CSV sinks,
# BENCH_*.json baselines) — ``benchmarks.run --compare`` uses it to run
# a fresh sweep into a scratch dir and diff against the persisted
# baselines without clobbering them.
_REPO_RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
RESULTS = os.environ.get("REPRO_RESULTS_DIR", _REPO_RESULTS)
BENCH_DIR = os.path.join(RESULTS, "bench")
# The trained-model cache is deterministic in (arch, steps, seed): keep
# it anchored at the repo default so redirected runs reuse it instead of
# re-training.
TRAINED_DIR = os.path.join(_REPO_RESULTS, "trained")


def train_or_load(arch: str, *, steps: int = 80, seq: int = 64,
                  batch: int = 8, lr: float = 2e-3, seed: int = 0):
    """Briefly train the repro-scale model on synthetic data (cached).

    The SliceMoE experiments need non-degenerate routing distributions;
    a fresh-init router routes near-uniformly, a briefly-trained one
    develops the skewed, input-dependent gating the paper exploits.
    """
    cfg = get_config(arch)
    path = os.path.join(TRAINED_DIR, f"{arch}_s{steps}")
    if os.path.exists(os.path.join(path, "manifest.msgpack")):
        params = CKPT.restore(path)["params"]
        return cfg, jax.tree_util.tree_map(jnp.asarray, params)

    from repro.launch.train import train_loop
    opt_cfg = OPT.AdamWConfig(lr=lr, total_steps=steps,
                              warmup_steps=max(steps // 10, 1))
    params, _, _ = train_loop(cfg, steps=steps, global_batch=batch,
                              seq_len=seq, opt_cfg=opt_cfg,
                              log_every=max(steps // 4, 1), seed=seed)
    CKPT.save(path, {"params": params}, step=steps)
    return cfg, params


def eval_batches(cfg, *, n_batches: int = 4, batch: int = 4, seq: int = 64,
                 seed: int = 1234):
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                  global_batch=batch, seed=seed))
    return [data.sample_batch(10_000 + i, batch) for i in range(n_batches)]


def synthetic_ppl(params, cfg, batches) -> float:
    """Perplexity on held-out synthetic data."""
    from repro.models.model import lm_loss

    losses = []
    for full in batches:
        toks = jnp.asarray(full[:, :-1])
        labels = jnp.asarray(full[:, 1:])
        loss, _ = lm_loss(params, cfg, toks, labels, aux_weight=0.0)
        losses.append(float(loss))
    return float(np.exp(np.mean(losses)))


class CsvSink:
    def __init__(self, name: str, header: list[str]):
        os.makedirs(BENCH_DIR, exist_ok=True)
        self.path = os.path.join(BENCH_DIR, name + ".csv")
        self.header = header
        self.rows: list[list] = []

    def add(self, *row) -> None:
        assert len(row) == len(self.header)
        self.rows.append(list(row))

    def flush(self) -> str:
        with open(self.path, "w") as f:
            f.write(",".join(self.header) + "\n")
            for r in self.rows:
                f.write(",".join(str(x) for x in r) + "\n")
        return self.path


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (blocks on jax arrays)."""
    def run():
        out = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready()
            if hasattr(x, "block_until_ready") else x, out)
        return out

    for _ in range(warmup):
        run()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        run()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def report(name: str, us_per_call: float, derived: str) -> None:
    """The required ``name,us_per_call,derived`` CSV line to stdout."""
    print(f"{name},{us_per_call:.1f},{derived}")


def json_record(name: str, payload: dict) -> str:
    """Persist a benchmark's structured results as results/BENCH_<name>.json.

    These files are the cross-PR perf baselines: the next session diffs
    its numbers against them (see docs/architecture.md §benchmarks).
    """
    import json

    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
