"""Paper Table 1: AMAT accuracy (PPL) across Base / Trunc / AMAT schemes.

For each eval model (DeepSeek-V2-Lite-repro, Qwen1.5-MoE-repro) and each
MAT(h,l) config, expert weights are replaced by dequantized variants:

  Base(b)   — independent b-bit quantization (quality reference),
  Trunc(l)  — naive truncation of the h-bit codes (no zp/scale fix),
  AMAT(l)   — joint code+zero-point truncation (the paper's scheme),

under symmetric and asymmetric group-32 quantization, and synthetic-data
perplexity is measured.  Expected orderings (the paper's claims):
AMAT(h) == Base(h); AMAT(l) ~ Base(l); Trunc(l) catastrophically worse.
"""

from __future__ import annotations

import time

import jax.numpy as jnp

from benchmarks.common import (CsvSink, eval_batches, report, synthetic_ppl,
                               train_or_load)
from repro.core.amat import PAPER_CONFIGS, truncate
from repro.quant.groupquant import dequantize, quantize

MODELS = ("deepseek-v2-lite-repro", "qwen15-moe-repro")


def _replace_experts(params, transform):
    """Apply ``transform(wi, wo) -> (wi', wo')`` to every MoE layer."""
    new_blocks = {}
    for pos, blk in params["blocks"].items():
        if "moe" in blk:
            blk = dict(blk)
            moe = dict(blk["moe"])
            e = moe["experts"]
            wi, wo = transform(e["wi"], e["wo"])
            moe["experts"] = {"wi": wi.astype(e["wi"].dtype),
                              "wo": wo.astype(e["wo"].dtype)}
            blk["moe"] = moe
        new_blocks[pos] = blk
    out = dict(params)
    out["blocks"] = new_blocks
    return out


def _scheme_weights(w, *, scheme: str, high: int, low: int, asym: bool,
                    group: int = 32):
    wf = w.astype(jnp.float32)
    if scheme == "base_high":
        return dequantize(quantize(wf, bits=high, group_size=group,
                                   asymmetric=asym))
    if scheme == "base_low":
        return dequantize(quantize(wf, bits=low, group_size=group,
                                   asymmetric=asym))
    qt = quantize(wf, bits=high, group_size=group, asymmetric=asym)
    if scheme == "trunc_low":
        return dequantize(truncate(qt, low_bits=low, truncate_zp=False,
                                   rescale=False))
    if scheme == "amat_low":
        return dequantize(truncate(qt, low_bits=low))
    if scheme == "amat_high":
        return dequantize(qt)
    raise ValueError(scheme)


def main(quick: bool = False) -> None:
    sink = CsvSink("table1_amat",
                   ["model", "quant", "scheme", "mat", "bits", "ppl"])
    mats = PAPER_CONFIGS if not quick else PAPER_CONFIGS[-1:]
    models = MODELS if not quick else MODELS[:1]
    t0 = time.perf_counter()

    for arch in models:
        cfg, params = train_or_load(arch)
        batches = eval_batches(cfg, n_batches=2 if quick else 4)
        fp_ppl = synthetic_ppl(params, cfg, batches)
        sink.add(arch, "fp", "float", "-", "-", round(fp_ppl, 4))

        for mat in mats:
            for asym in (False, True):
                qname = "asym" if asym else "sym"
                schemes = [("base_high", mat.high_bits),
                           ("base_low", mat.low_bits),
                           ("trunc_low", mat.low_bits)]
                if asym:
                    schemes += [("amat_high", mat.high_bits),
                                ("amat_low", mat.low_bits)]
                for scheme, bits in schemes:
                    def tf(wi, wo, scheme=scheme):
                        return (_scheme_weights(wi, scheme=scheme,
                                                high=mat.high_bits,
                                                low=mat.low_bits, asym=asym),
                                _scheme_weights(wo, scheme=scheme,
                                                high=mat.high_bits,
                                                low=mat.low_bits, asym=asym))
                    qparams = _replace_experts(params, tf)
                    ppl = synthetic_ppl(qparams, cfg, batches)
                    sink.add(arch, qname, scheme, mat.name, bits,
                             round(ppl, 4))

    path = sink.flush()
    us = (time.perf_counter() - t0) * 1e6
    # headline derived metric: AMAT-low vs naive-trunc PPL ratio (asym, MAT84)
    amat = [r for r in sink.rows if r[2] == "amat_low" and r[3] == "MAT84"]
    trunc = [r for r in sink.rows
             if r[2] == "trunc_low" and r[1] == "asym" and r[3] == "MAT84"]
    derived = "n/a"
    if amat and trunc:
        derived = f"trunc/amat_ppl_ratio={trunc[0][5] / amat[0][5]:.1f}"
    report("table1_amat", us, derived + f";csv={path}")


if __name__ == "__main__":
    main()
