"""Paper Fig. 9: decode-stage energy gain & speed-up across routing/caching
schemes at three cache capacities, on both eval models.

Schemes (matched to the paper's comparison):
  cache_prior_highbit — SOTA baseline: Cache-Prior routing, whole high-bit
                        experts in an LRU cache,
  cumsum              — cumulative-threshold routing (accuracy-first,
                        locality-blind; "prohibitively expensive"),
  dbsc                — bit-sliced caching + AMAT, no warmup,
  dbsc_pcw            — + predictive cache warmup.

Reported: decode-stage energy (J) and latency (s) from the deterministic
cost model (Fig. 7 constants), normalized per model to the best scheme.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import CsvSink, report, train_or_load
from repro.core.amat import MatConfig
from repro.core.engine import EngineConfig, SliceMoEEngine
from repro.models.moe import RoutingPolicy

MODELS = ("deepseek-v2-lite-repro", "qwen15-moe-repro")
DECODE_STEPS = 24
PROMPT = 48

SCHEMES = {
    "cache_prior_highbit": dict(
        policy=RoutingPolicy(kind="cache_prior", slice_mode="highbit"),
        fused_slices=True, warmup="empty"),
    "buddy_highbit": dict(
        policy=RoutingPolicy(kind="buddy", slice_mode="highbit"),
        fused_slices=True, warmup="empty"),
    "prefetch_highbit": dict(
        policy=RoutingPolicy(kind="topk", slice_mode="highbit"),
        fused_slices=True, warmup="empty", prefetch_top_m=4),
    "cumsum": dict(
        policy=RoutingPolicy(kind="cumsum", slice_mode="highbit",
                             cumsum_tau=0.9),
        fused_slices=True, warmup="empty"),
    "dbsc": dict(
        policy=RoutingPolicy(kind="cache_prior", slice_mode="dbsc"),
        fused_slices=False, warmup="empty"),
    "dbsc_pcw": dict(
        policy=RoutingPolicy(kind="cache_prior", slice_mode="dbsc"),
        fused_slices=False, warmup="pcw"),
}


def run_one(cfg, params, toks, cache_bytes, scheme_kw):
    ecfg = EngineConfig(mat=MatConfig(8, 4), cache_bytes=cache_bytes,
                        miss_rate_target=0.05, max_seq=96, **scheme_kw)
    eng = SliceMoEEngine(cfg, params, ecfg)
    logits = eng.prefill(toks)
    first = jnp.argmax(logits, -1).astype(jnp.int32)
    _, metrics = eng.decode(first, DECODE_STEPS)
    d = metrics["decode_totals"]
    return d["total_energy_j"], d["total_latency_s"], \
        metrics["cache_stats"]["msb_misses"]


def main(quick: bool = False) -> None:
    t0 = time.perf_counter()
    sink = CsvSink("fig9_energy",
                   ["model", "cache_frac", "scheme", "energy_j",
                    "latency_s", "msb_misses", "energy_gain_vs_highbit",
                    "speedup_vs_highbit"])
    models = MODELS if not quick else MODELS[:1]
    fracs = (0.15, 0.3, 0.6) if not quick else (0.3,)
    headline = []

    for arch in models:
        cfg, params = train_or_load(arch)
        toks = jax.random.randint(jax.random.PRNGKey(9), (1, PROMPT), 0,
                                  cfg.vocab_size)
        probe = SliceMoEEngine(cfg, params, EngineConfig(max_seq=96))
        total = probe.store.total_bytes()
        for frac in fracs:
            results = {}
            for name, kw in SCHEMES.items():
                e, lat, miss = run_one(cfg, params, toks, frac * total, kw)
                results[name] = (e, lat, miss)
            e_ref, l_ref, _ = results["cache_prior_highbit"]
            for name, (e, lat, miss) in results.items():
                sink.add(arch, frac, name, f"{e:.5e}", f"{lat:.5e}", miss,
                         round(e_ref / max(e, 1e-12), 3),
                         round(l_ref / max(lat, 1e-12), 3))
            e_d, l_d, _ = results["dbsc_pcw"]
            headline.append((arch, e_ref / max(e_d, 1e-12),
                             l_ref / max(l_d, 1e-12)))

    path = sink.flush()
    us = (time.perf_counter() - t0) * 1e6
    h = ";".join(f"{a}:E{g:.2f}x/S{s:.2f}x" for a, g, s in headline[:2])
    report("fig9_energy", us, h + f";csv={path}")


if __name__ == "__main__":
    main()
