"""Paper Fig. 8: accuracy vs high-bit-normalized miss rate.

The paper's tradeoff: enforcing a miss-rate constraint forces cache-aware
routing to divert tokens away from their preferred experts; schemes that
cache *more* experts under the same byte budget (low-bit, DBSC slices)
need less routing distortion at a given miss target and keep accuracy.

We sweep miss-rate targets x cache budgets for four precision schemes
(high-bit fused / uniform low-bit / AMAT-static / DBSC) and measure:
  * achieved decode miss rate (high-bit-normalized: misses weighted by
    slice bytes relative to a full high-bit expert),
  * fidelity = top-1 agreement of decode logits with the float-model
    no-constraint oracle over the decode trajectory.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CsvSink, report, train_or_load
from repro.core.amat import MatConfig
from repro.core.engine import EngineConfig, SliceMoEEngine
from repro.models.model import decode_step, prefill
from repro.models.moe import RoutingPolicy

ARCH = "qwen15-moe-repro"
DECODE_STEPS = 24
PROMPT = 48


def _oracle_trajectory(cfg, params, toks):
    """Greedy decode with float params, no cache constraints."""
    logits, cache, _ = prefill(params, cfg, toks, max_seq=96)
    token = jnp.argmax(logits, -1).astype(jnp.int32)
    traj = []
    for _ in range(DECODE_STEPS):
        traj.append(int(token[0]))
        logits, cache, _ = decode_step(params, cfg, token, cache)
        token = jnp.argmax(logits, -1).astype(jnp.int32)
    return traj


def _run_scheme(cfg, params, toks, *, mode, cache_bytes, miss_target):
    fused = mode == "highbit"
    ecfg = EngineConfig(
        mat=MatConfig(8, 4),
        cache_bytes=cache_bytes,
        policy=RoutingPolicy(kind="cache_prior", slice_mode=mode,
                             theta=0.5),
        miss_rate_target=miss_target,
        warmup="pcw", max_seq=96, fused_slices=fused)
    eng = SliceMoEEngine(cfg, params, ecfg)
    logits = eng.prefill(toks)
    first = jnp.argmax(logits, -1).astype(jnp.int32)
    out, metrics = eng.decode(first, DECODE_STEPS)
    stats = metrics["cache_stats"]
    # high-bit-normalized miss rate: miss bytes / (accesses x high-bit size)
    hb = eng.store.highbit_expert_bytes()
    miss_bytes = (stats["msb_misses"] * (hb if fused
                                         else eng.store.msb_bytes_per_expert)
                  + stats["lsb_misses"] * eng.store.lsb_bytes_per_expert)
    access_bytes = (stats["msb_hits"] + stats["msb_misses"]) * hb
    norm_miss = miss_bytes / max(access_bytes, 1)
    return np.asarray(out[0]).tolist(), norm_miss, metrics


def main(quick: bool = False) -> None:
    t0 = time.perf_counter()
    cfg, params = train_or_load(ARCH)
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, PROMPT), 0,
                              cfg.vocab_size)
    oracle = _oracle_trajectory(cfg, params, toks)

    sink = CsvSink("fig8_accuracy",
                   ["scheme", "cache_frac", "miss_target",
                    "norm_miss_rate", "top1_agreement"])

    # cache budgets as fractions of the full high-bit store
    eng_probe = SliceMoEEngine(cfg, params, EngineConfig(max_seq=96))
    total = eng_probe.store.total_bytes()
    fracs = (0.15, 0.3, 0.6) if not quick else (0.3,)
    targets = (0.01, 0.05, 0.2) if not quick else (0.05,)
    schemes = ("highbit", "lowbit", "amat_static", "dbsc")

    best = {}
    for mode in schemes:
        for frac in fracs:
            for tgt in targets:
                traj, miss, _ = _run_scheme(
                    cfg, params, toks, mode=mode,
                    cache_bytes=frac * total, miss_target=tgt)
                agree = float(np.mean([a == b for a, b
                                       in zip(traj, oracle)]))
                sink.add(mode, frac, tgt, round(miss, 4), round(agree, 4))
                best[mode] = max(best.get(mode, 0.0), agree)

    path = sink.flush()
    us = (time.perf_counter() - t0) * 1e6
    report("fig8_accuracy", us,
           f"best_top1:dbsc={best.get('dbsc', 0):.2f}"
           f"/highbit={best.get('highbit', 0):.2f};csv={path}")


if __name__ == "__main__":
    main()
