"""Sim fidelity gate + offline-autotune demonstration.

Records a routing trace from a *live* persistent-engine serving run,
then asserts the three claims that make the trace-driven simulator
(:mod:`repro.sim`) load-bearing:

  (a) **fidelity**: replaying the trace under the recorded config
      reproduces the live run's per-epoch miss counts *exactly* and its
      per-step miss/energy curves and total energy/latency within
      rtol 1e-6 (in practice bit-for-bit: it is the same charge code);
  (b) **speed**: the model-free replay evaluates >= 100x more decode
      steps/sec than the live engine took on the same trace (this is
      what makes policy sweeps tractable);
  (c) **autotuning pays**: sweeping cache budget / bit plan / warmup /
      prefetch over the recorded trace yields a Pareto frontier
      containing a config that meets a 5% decode miss-rate SLO at
      measurably lower energy than the recorded default config.

Replay results double as a regression gate: the deterministic cells must
reproduce the previously persisted results/BENCH_sim_fidelity.json
within tolerance (the replay path may not silently drift).

Run:  PYTHONPATH=src python benchmarks/sim_fidelity.py [--quick]
"""

from __future__ import annotations

import os as _os
import sys as _sys

_root = _os.path.join(_os.path.dirname(_os.path.abspath(__file__)), "..")
for _p in (_os.path.join(_root, "src"), _root):
    if _p not in _sys.path:
        _sys.path.insert(0, _p)

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from benchmarks.common import BENCH_DIR, RESULTS, json_record, report
from repro.configs.base import get_config
from repro.core.amat import MatConfig
from repro.core.engine import EngineConfig, PersistentEngine
from repro.models.model import init_params
from repro.models.moe import RoutingPolicy
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     SchedulerConfig)
from repro.serving.workloads import (LengthDist, TenantSpec,
                                     WorkloadConfig, generate)
from repro.sim import (ReplayEngine, Trace, TraceRecorder, replay_trace,
                       traces_equal)
from repro.sim import autotune as at

ARCH = "qwen15-moe-repro"
PROMPT_LEN = 24
MAX_NEW = 12
CACHE_BYTES = 1.0e6      # deliberately tight: the default misses a lot
MAX_SEQ = 64
MISS_SLO = 0.05


def _engine_cfg(**overrides) -> EngineConfig:
    kw = dict(
        mat=MatConfig(8, 4), cache_bytes=CACHE_BYTES,
        policy=RoutingPolicy(kind="cache_prior", slice_mode="dbsc"),
        miss_rate_target=0.1, warmup="pcw", max_seq=MAX_SEQ)
    kw.update(overrides)
    return EngineConfig(**kw)


def _record_live(cfg, params, n_requests: int, **ecfg_overrides):
    """Serve a closed-loop workload live, recording its routing trace."""
    engine = PersistentEngine(cfg, params, _engine_cfg(**ecfg_overrides))
    sched = ContinuousBatchingScheduler(
        engine, SchedulerConfig(max_batch=1, max_queue=n_requests + 1))
    rec = sched.attach_recorder(TraceRecorder())
    tenant = TenantSpec(prompt_len=LengthDist("fixed", PROMPT_LEN),
                        output_len=LengthDist("fixed", MAX_NEW))
    reqs = generate(WorkloadConfig(kind="closed_loop",
                                   n_requests=n_requests, seed=0,
                                   tenants=(tenant,)), cfg.vocab_size)
    t0 = time.perf_counter()
    for r in reqs:
        sched.submit(r)
    completions = sched.run()
    wall = time.perf_counter() - t0
    # Decode-only host time (max_batch=1: the per-request decode spans
    # are disjoint and exclude prefill), so the throughput ratio below
    # compares decode rates on both sides, not decode-vs-everything.
    decode_wall = sum(c.decode_s for c in completions)
    live = {
        "miss_curve": sched.telemetry.miss_rate_curve(),
        "energy_curve": sched.telemetry.energy_curve(),
        "epoch_counts": engine.cache.epoch_counts(),
        "per_shard_epoch_counts": (
            engine.cache.per_shard_epoch_counts()
            if hasattr(engine.cache, "per_shard_epoch_counts") else None),
        "ledger": engine.ledger.snapshot(),
        "wall_s": wall,
        "steps_per_s": len(sched.telemetry.steps) / decode_wall,
    }
    return rec.trace(), live


def _close(a: float, b: float, rtol: float = 1e-6) -> bool:
    return a == b or abs(a - b) <= rtol * max(abs(a), abs(b), 1e-30)


def _check_against_baseline(payload: dict, *, quick: bool,
                            rtol: float = 1e-6) -> None:
    """The deterministic replay cells must reproduce the persisted
    baseline (results/BENCH_sim_fidelity.json) — sim drift is a bug."""
    path = _os.path.join(RESULTS, "BENCH_sim_fidelity.json")
    if quick or not _os.path.exists(path):
        return
    with open(path) as f:
        prev = json.load(f)
    if prev.get("n_requests") != payload["n_requests"]:
        return                      # different sweep size, incomparable
    mismatches = []
    for section in ("default_replay", "best_under_slo", "cumsum_replay",
                    "ep2_replay"):
        for k, v in prev.get(section, {}).items():
            cur = payload[section].get(k)
            if isinstance(v, (int, float)) and (
                    cur is None or not _close(v, cur, rtol)):
                mismatches.append((section, k, v, cur))
    assert not mismatches, \
        f"replay diverged from persisted baseline: {mismatches}"
    print(f"baseline check: replay cells reproduce {path} (rtol={rtol:g})")


def main(quick: bool = False) -> None:
    n_requests = 4 if quick else 8

    cfg = get_config(ARCH)
    cfg = dataclasses.replace(cfg, n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))

    print(f"=== record live serving run: {ARCH} (2 layers), "
          f"{n_requests} requests ===")
    trace, live = _record_live(cfg, params, n_requests)
    print(f"recorded {trace.n_prefills} prefills + "
          f"{trace.n_decode_steps} decode steps; "
          f"live {live['steps_per_s']:.1f} decode steps/s")

    # --- (de)serialization round trip: npz and jsonl must agree with
    # the in-memory trace and with each other, and replay identically.
    _os.makedirs(BENCH_DIR, exist_ok=True)
    p_npz = trace.save(_os.path.join(BENCH_DIR, "sim_fidelity.npz"))
    p_jsonl = trace.save(_os.path.join(BENCH_DIR, "sim_fidelity.jsonl"))
    t_npz, t_jsonl = Trace.load(p_npz), Trace.load(p_jsonl)
    assert traces_equal(trace, t_npz) and traces_equal(t_npz, t_jsonl), \
        "serialization round trip not exact"

    # --- fidelity gate (acceptance): exact per-epoch miss counts,
    # exact per-step curves, energy/latency within rtol 1e-6.
    rep = replay_trace(t_npz)
    assert rep.epoch_counts == live["epoch_counts"], \
        (rep.epoch_counts, live["epoch_counts"])
    assert rep.miss_curve == live["miss_curve"], "per-step miss drifted"
    assert all(_close(a, b) for a, b in
               zip(rep.energy_curve, live["energy_curve"])), \
        "per-step energy drifted"
    for key in ("total_energy_j", "total_latency_s", "flash_bytes",
                "dram_bytes", "compute_ops"):
        assert _close(rep.ledger[key], live["ledger"][key]), \
            (key, rep.ledger[key], live["ledger"][key])
    print(f"fidelity: replay == live (epochs exact, "
          f"energy {rep.total_energy_j * 1e3:.3f} mJ, "
          f"latency {rep.total_latency_s * 1e3:.3f} ms, rtol<=1e-6)")

    # --- replay throughput (acceptance: >= 100x live).  Best-of-3 to
    # de-noise the host clock: one replay is only tens of ms, so a
    # single scheduler hiccup can halve its apparent rate.
    replay_sps = max([rep.steps_per_s] +
                     [replay_trace(t_npz).steps_per_s for _ in range(2)])
    ratio = replay_sps / live["steps_per_s"]
    print(f"throughput: replay {replay_sps:.0f} steps/s vs live "
          f"{live['steps_per_s']:.1f} steps/s = {ratio:.0f}x")
    assert ratio >= 100.0, \
        f"replay only {ratio:.1f}x live (acceptance needs >= 100x)"

    # --- charge-path variant gates: the PR-5 charge fixes (prefill
    # active masking under cumsum, EP sharding) must keep live and
    # simulated accounting identical under the configs that exercise
    # them — otherwise the two paths silently fork.
    n_small = 2 if quick else 3

    print("\n=== cumsum-routing fidelity (prefill active mask) ===")
    cum_trace, cum_live = _record_live(
        cfg, params, n_small,
        policy=RoutingPolicy(kind="cumsum", slice_mode="dbsc",
                             cumsum_tau=0.05, cumsum_kmax=8))
    pf = next(e for e in cum_trace.events if e.kind == "prefill")
    assert pf.active is not None \
        and not bool(np.asarray(pf.active).all()), \
        "cumsum prefill emitted no deactivated slots"
    cum_rep = replay_trace(cum_trace)
    assert cum_rep.epoch_counts == cum_live["epoch_counts"], \
        (cum_rep.epoch_counts, cum_live["epoch_counts"])
    assert cum_rep.miss_curve == cum_live["miss_curve"]
    for key in ("total_energy_j", "total_latency_s"):
        assert _close(cum_rep.ledger[key], cum_live["ledger"][key]), key
    print(f"cumsum: prefill active frac "
          f"{float(np.asarray(pf.active).mean()):.3f}; replay == live "
          f"(epochs exact)")

    print("\n=== expert-parallel fidelity: ep=2 live vs replay, "
          "ep=1 sharded == single-device ===")
    ep_trace, ep_live = _record_live(cfg, params, n_small, ep_shards=2,
                                     async_io=True)
    ep_rep = replay_trace(ep_trace)
    assert ep_rep.per_shard_epoch_counts \
        == ep_live["per_shard_epoch_counts"], "per-shard miss counts drifted"
    for key in ("total_energy_j", "total_latency_s", "ici_bytes",
                "ici_energy_j"):
        assert _close(ep_rep.ledger[key], ep_live["ledger"][key]), key
    assert ep_live["ledger"]["ici_bytes"] > 0, \
        "ep=2 charged no all-to-all traffic"
    print(f"ep=2: per-shard miss counts exact over both shards; "
          f"a2a {ep_live['ledger']['ici_bytes']/1e3:.1f} kB charged")

    # ep=1 equivalence (acceptance): the sharded cache/ledger machinery
    # forced onto the recorded single-device trace reproduces the plain
    # replay exactly — per-epoch miss counts identical, energy/latency
    # within rtol 1e-6.
    forced = ReplayEngine(t_npz.meta).force_sharded(1)
    forced.consume_all(t_npz.events)
    frep = forced.finish()
    assert frep.epoch_counts == live["epoch_counts"]
    assert frep.miss_curve == live["miss_curve"]
    for key in ("total_energy_j", "total_latency_s"):
        assert _close(frep.ledger[key], live["ledger"][key]), key
    print("ep=1: sharded engine reproduces the single-device run "
          "exactly (epochs exact, energy/latency rtol<=1e-6)")

    # --- autotune: sweep cache budget x warmup x bit plan x prefetch
    # over the recorded trace; the frontier must contain a config that
    # meets the 5% decode-miss SLO at lower energy than the default.
    policies = [("default(recorded)", {})]
    policies += [(f"cache={mb:g}MB{', empty' if w == 'empty' else ''}",
                  {"cache_bytes": mb * 1e6, "warmup": w})
                 for mb in (2.0, 4.0, 6.5)
                 for w in ("pcw", "empty")]
    policies += [("cache=4MB,MAT63",
                  {"cache_bytes": 4.0e6, "high_bits": 6, "low_bits": 3}),
                 # Pinned to the Markov baseline: the persisted frontier
                 # predates the request-kind predictor and must not move
                 # when the default prefetch_kind changes.
                 ("cache=4MB,prefetch4",
                  {"cache_bytes": 4.0e6, "prefetch_top_m": 4,
                   "prefetch_kind": "transition"}),
                 ("cache=4MB,async",
                  {"cache_bytes": 4.0e6, "async_io": True}),
                 ("cache=4MB,ep2",
                  {"cache_bytes": 4.0e6, "ep_shards": 2})]
    t0 = time.perf_counter()
    results = at.sweep(t_npz, policies, miss_slo=MISS_SLO)
    sweep_wall = time.perf_counter() - t0
    print()
    print(at.format_results(results, miss_slo=MISS_SLO,
                            title=f"autotune sweep ({len(results)} "
                                  f"configs in {sweep_wall:.2f}s)"))
    default = next(r for r in results if r.name == "default(recorded)")
    frontier = at.pareto_frontier(results)
    best = at.best_under_slo(frontier, MISS_SLO)
    assert best is not None, \
        f"no swept config met the {MISS_SLO:.0%} miss SLO"
    assert best.energy_j < 0.999 * default.energy_j, \
        (best.energy_j, default.energy_j)
    print(f"\nSLO winner: {best.name} — miss "
          f"{best.miss_rate:.3f} <= {MISS_SLO}, energy "
          f"{best.energy_j * 1e3:.3f} mJ vs default "
          f"{default.energy_j * 1e3:.3f} mJ "
          f"({default.energy_j / best.energy_j:.2f}x cheaper)")

    payload = {
        "arch": ARCH, "n_requests": n_requests,
        "n_events": len(t_npz),
        "default_replay": {
            "miss_rate": default.miss_rate,
            "energy_j": default.energy_j,
            "latency_s": default.latency_s,
        },
        "best_under_slo": {
            "name": best.name,
            "miss_rate": best.miss_rate,
            "energy_j": best.energy_j,
            "latency_s": best.latency_s,
        },
        "cumsum_replay": {
            "miss_rate": cum_rep.decode_miss_rate,
            "energy_j": cum_rep.total_energy_j,
            "latency_s": cum_rep.total_latency_s,
        },
        "ep2_replay": {
            "miss_rate": ep_rep.decode_miss_rate,
            "energy_j": ep_rep.total_energy_j,
            "latency_s": ep_rep.total_latency_s,
            "ici_bytes": ep_rep.ledger["ici_bytes"],
        },
        "pareto": [r.name for r in frontier],
        "replay_speedup_x": ratio,
        "sweep_wall_s": sweep_wall,
    }
    _check_against_baseline(payload, quick=quick)
    if not quick:
        json_record("sim_fidelity", payload)
    report("sim_fidelity", 0.0,
           f"replay_speedup={ratio:.0f}x;"
           f"slo_energy_saving={default.energy_j / best.energy_j:.2f}x;"
           f"fidelity=exact")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
