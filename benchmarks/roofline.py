"""Roofline aggregation: read results/dryrun/*.json into the §Roofline table.

Single-pod (16x16) numbers feed the table; multi-pod rows prove the pod
axis shards.  For each (arch, shape): the three terms in seconds, the
dominant term, MODEL_FLOPS/HLO_FLOPS usefulness ratio, and bytes/chip.
"""

from __future__ import annotations

import json
import os
import time

from benchmarks.common import CsvSink, report
from repro.configs.base import ARCH_IDS, SHAPES

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


def load_all(mesh: str = "single") -> dict:
    out = {}
    for arch in ARCH_IDS:
        for shape in SHAPES:
            p = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh}.json")
            if os.path.exists(p):
                with open(p) as f:
                    out[(arch, shape)] = json.load(f)
    return out


def main(quick: bool = False) -> None:
    t0 = time.perf_counter()
    sink = CsvSink("roofline",
                   ["arch", "shape", "mesh", "status", "compute_s",
                    "memory_s", "collective_s", "dominant",
                    "useful_flops_ratio", "bytes_per_chip_gb",
                    "compile_s"])
    n_ok = n_skip = n_missing = 0
    dominants = {}
    for mesh in ("single", "multi"):
        recs = load_all(mesh)
        for arch in ARCH_IDS:
            for shape in SHAPES:
                rec = recs.get((arch, shape))
                if rec is None:
                    n_missing += 1
                    continue
                if rec["status"] == "skipped":
                    if mesh == "single":
                        n_skip += 1
                    sink.add(arch, shape, mesh, "skipped", "", "", "", "",
                             "", "", "")
                    continue
                if rec["status"] != "ok":
                    sink.add(arch, shape, mesh, rec["status"], "", "", "",
                             "", "", "", "")
                    continue
                if mesh == "single":
                    n_ok += 1
                rl = rec["roofline"]
                if mesh == "single":
                    dominants[rl["dominant"]] = \
                        dominants.get(rl["dominant"], 0) + 1
                sink.add(arch, shape, mesh, "ok",
                         f"{rl['compute_s']:.3e}", f"{rl['memory_s']:.3e}",
                         f"{rl['collective_s']:.3e}",
                         rl["dominant"].replace("_s", ""),
                         round(rl["useful_flops_ratio"] or 0, 3),
                         round(rl["bytes_per_chip"] / 2**30, 3),
                         rec.get("compile_s", ""))
    path = sink.flush()
    us = (time.perf_counter() - t0) * 1e6
    report("roofline", us,
           f"ok={n_ok}/40;skipped={n_skip};missing={n_missing};"
           f"dominant={dominants};csv={path}")


if __name__ == "__main__":
    main()
