"""Qwen1.5-MoE-A2.7B routing-structure reproduction (paper eval model 2).

Faithful expert structure (60 routed experts, top-4, 4 shared experts)
at reduced width.  [Qwen blog, Feb 2024]
"""
from repro.configs.base import ModelConfig
from repro.models.moe import MoECfg

CONFIG = ModelConfig(
    name="qwen15-moe-repro",
    arch_type="moe",
    n_layers=4,
    d_model=256,
    n_heads=8,
    n_kv_heads=8,
    head_dim=32,
    d_ff=512,
    vocab_size=2048,
    mlp_type="swiglu",
    moe=MoECfg(n_experts=60, top_k=4, d_ff=64,
               n_shared_experts=4, d_ff_shared=256,
               capacity_factor=2.0, mlp_type="swiglu"),
    source="Qwen1.5-MoE-A2.7B blog (reduced width, faithful routing)",
)
