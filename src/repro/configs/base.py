"""Model / shape configuration system.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG``; they register here.  ``reduced()`` derives the CPU-smoke-test
variant (2 layers, d_model <= 512, <= 4 experts) of the same family.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

from repro.models.moe import MoECfg
from repro.models.ssm import SSMCfg


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One position in the repeating layer pattern."""

    mixer: str          # 'attn' | 'ssm'
    ffn: str            # 'dense' | 'moe' | 'none'


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                       # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    mlp_type: str = "swiglu"
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    # Repeating block pattern; default = uniform attention+dense.
    pattern: Optional[Tuple[BlockSpec, ...]] = None
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # Sliding-window attention width used for the long-context decode shape
    # (and, if ``always_swa``, everywhere).
    sliding_window: Optional[int] = None
    always_swa: bool = False
    logit_softcap: Optional[float] = None
    tie_embeddings: bool = False
    qkv_bias: bool = False
    # Encoder-decoder (whisper): encoder layers share d_model/heads/d_ff.
    encoder_layers: int = 0
    encoder_seq: int = 0                 # e.g. 1500 audio frames
    # Modality frontend stub: first `prefix_len` positions of the decoder
    # input come from precomputed embeddings (vision patches / audio frames
    # already encoded) instead of token ids.
    prefix_len: int = 0
    dtype: str = "bfloat16"
    source: str = ""                     # citation

    # ---- performance variants (hillclimb knobs; EXPERIMENTS.md §Perf) ----
    # Megatron-style sequence parallelism: residual stream sharded over
    # the model axis between blocks (all-reduce -> reduce-scatter+gather).
    seq_parallel: bool = False
    # Embedding lookup as one-hot matmul (avoids the SPMD involuntary
    # full-remat on gather from a vocab-sharded table).
    onehot_embed: bool = False
    # KV cache dtype for decode ('bfloat16' | 'int8'); int8 stores
    # per-(token, head) dynamic scales (the paper's INT8 KV cache).
    kv_dtype: str = "bfloat16"
    # Serve-time expert weights as AMAT int8 codes (the paper's storage
    # format) instead of bf16 — halves decode weight traffic for MoE.
    quantized_serve: bool = False
    # Ring-buffer KV cache of size `sliding_window` for windowed decode:
    # O(window) memory AND no cross-shard gather of the window (the
    # attention set is permutation-invariant, so wraparound needs no
    # reordering).  Decode-only.
    ring_kv: bool = False
    # Activation-checkpoint policy for the layer scan:
    #   'full' — recompute everything (default, min memory, ~4x fwd FLOPs)
    #   'dots' — save matmul outputs, recompute elementwise only
    #            (~3x fwd FLOPs, more live activation memory)
    remat_policy: str = "full"
    # Pad the unembedding (and tied embedding) vocab dim to a multiple of
    # this so it shards over the model axis; padded columns are masked to
    # -inf in the logits.  1 = off.  Fixes the giant logits all-reduce
    # when vocab % mesh_model != 0 (e.g. internvl2's V=151655).
    pad_vocab_to: int = 1

    @property
    def padded_vocab(self) -> int:
        pv = self.pad_vocab_to
        return ((self.vocab_size + pv - 1) // pv) * pv if pv > 1 \
            else self.vocab_size

    # ------------------------------------------------------------------ api
    @property
    def block_pattern(self) -> Tuple[BlockSpec, ...]:
        if self.pattern is not None:
            return self.pattern
        ffn = "moe" if self.moe is not None else "dense"
        mixer = "ssm" if self.arch_type == "ssm" else "attn"
        if self.arch_type == "ssm":
            ffn = "none"
        return (BlockSpec(mixer, ffn),)

    @property
    def n_periods(self) -> int:
        plen = len(self.block_pattern)
        if self.n_layers % plen != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern length {plen}")
        return self.n_layers // plen

    @property
    def has_attention(self) -> bool:
        return any(b.mixer == "attn" for b in self.block_pattern)

    @property
    def has_ssm(self) -> bool:
        return any(b.mixer == "ssm" for b in self.block_pattern)

    @property
    def has_moe(self) -> bool:
        return any(b.ffn == "moe" for b in self.block_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch run the 500k decode shape?"""
        if self.arch_type in ("ssm",):
            return True
        if self.arch_type == "hybrid":
            return True      # attention layers get the sliding window
        return self.sliding_window is not None

    def param_count(self) -> int:
        """Total parameters (embedding included)."""
        from repro.models.model import param_shapes
        import numpy as np

        shapes = param_shapes(self)
        total = 0
        for leaf in jax.tree_util.tree_leaves(
                shapes, is_leaf=lambda x: isinstance(x, tuple)):
            total += int(np.prod(leaf))
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        total = self.param_count()
        if self.moe is None:
            return total
        import numpy as np
        from repro.models.moe import moe_param_shapes

        es = moe_param_shapes(self.d_model, self.moe)["experts"]
        per_expert = sum(int(np.prod(s[1:])) for s in es.values())
        n_moe_layers = sum(
            1 for b in self.block_pattern if b.ffn == "moe") * self.n_periods
        inactive = per_expert * (self.moe.n_experts - self.moe.top_k) \
            * n_moe_layers
        return total - inactive

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims."""
        plen = len(self.block_pattern)
        n_layers = 2 * plen if plen > 1 else 2
        d_model = min(self.d_model, 256)
        head_dim = 32
        n_kv = min(self.n_kv_heads, 2)
        n_heads = n_kv * max(1, min(self.n_heads // self.n_kv_heads, 2))
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff=min(self.moe.d_ff, 128),
                d_ff_shared=min(self.moe.d_ff_shared, 128)
                if self.moe.d_ff_shared else 0,
            )
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(
                self.ssm, d_state=min(self.ssm.d_state, 16),
                head_dim=32, chunk=32)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            moe=moe,
            ssm=ssm,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 16) if self.encoder_seq else 0,
            prefix_len=min(self.prefix_len, 8) if self.prefix_len else 0,
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window else None,
        )


import jax  # noqa: E402  (used by param_count)


# --------------------------------------------------------------------------
# Input shapes (assigned)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


ARCH_IDS = (
    "internvl2-1b",
    "llama4-maverick-400b-a17b",
    "jamba-v0.1-52b",
    "starcoder2-3b",
    "llama4-scout-17b-a16e",
    "nemotron-4-15b",
    "gemma-7b",
    "smollm-360m",
    "mamba2-2.7b",
    "whisper-small",
)

# Paper-reproduction MoE configs (DeepSeek-V2-Lite / Qwen1.5-MoE structure).
REPRO_IDS = ("deepseek-v2-lite-repro", "qwen15-moe-repro")


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))
    return mod.CONFIG


def list_configs():
    return {a: get_config(a) for a in ARCH_IDS}
