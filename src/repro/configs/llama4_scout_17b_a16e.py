"""Llama-4 Scout 17B-A16E — MoE 16 experts top-1 + shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E].
"""
from repro.configs.base import ModelConfig
from repro.models.moe import MoECfg

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    mlp_type="swiglu",
    moe=MoECfg(n_experts=16, top_k=1, d_ff=8192,
               n_shared_experts=1, d_ff_shared=8192,
               capacity_factor=1.25, mlp_type="swiglu"),
    rope_theta=500000.0,
    sliding_window=8192,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
