"""Mamba2-2.7B — pure SSD stack, attention-free. [arXiv:2405.21060]

64 layers, d_model=2560, d_state=128, expand=2, head_dim=64 (80 heads).
No FFN (d_ff=0), no attention; decode state is O(1) in sequence length,
so decode_32k and long_500k have identical per-step cost.
"""
from repro.configs.base import ModelConfig
from repro.models.ssm import SSMCfg

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    mlp_type="swiglu",    # unused
    ssm=SSMCfg(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    source="arXiv:2405.21060 (Mamba2 / SSD)",
)
