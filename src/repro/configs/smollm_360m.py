"""SmolLM-360M — llama-architecture small model. [hf:HuggingFaceTB/SmolLM-135M]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    arch_type="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    mlp_type="swiglu",
    rope_theta=10000.0,
    sliding_window=8192,          # long_500k variant only
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-360M (assignment card cites SmolLM-135M)",
)
