"""StarCoder2-3B — dense GQA code model. [arXiv:2402.19173]

GQA kv=2, RoPE, GELU MLP (pile-style FFN), 16k training window in the
original (sliding window 4096); we expose the sliding window for the
long_500k decode shape.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    arch_type="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    mlp_type="gelu",
    rope_theta=1e5,
    sliding_window=4096,
    qkv_bias=True,
    source="arXiv:2402.19173 (StarCoder2)",
)
