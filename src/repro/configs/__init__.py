"""Model configurations (paper eval + assigned architecture pool)."""
