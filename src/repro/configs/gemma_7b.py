"""Gemma-7B — dense, GeGLU, head_dim=256, kv=16 (MHA at 7B). [arXiv:2403.08295]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    arch_type="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    mlp_type="geglu",
    rope_theta=10000.0,
    sliding_window=8192,          # long_500k variant only
    logit_softcap=30.0,
    tie_embeddings=True,
    source="arXiv:2403.08295 (Gemma)",
)
