"""Jamba-v0.1 52B — Mamba+attention 1:7 hybrid with MoE every 2 layers.

[arXiv:2403.19887].  Period-8 block pattern: one attention layer per 8
(position 3), the rest Mamba; MoE FFN on every other layer (odd
positions), dense FFN otherwise.  Jamba uses Mamba-1 (d_state=16); we
adapt to the SSD formulation with the same state size (DESIGN.md §3).
Sub-quadratic: runs long_500k natively.
"""
from repro.configs.base import ModelConfig, BlockSpec
from repro.models.moe import MoECfg
from repro.models.ssm import SSMCfg

_PATTERN = tuple(
    BlockSpec(mixer=("attn" if i == 3 else "ssm"),
              ffn=("moe" if i % 2 == 1 else "dense"))
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    mlp_type="swiglu",
    moe=MoECfg(n_experts=16, top_k=2, d_ff=14336,
               capacity_factor=1.25, mlp_type="swiglu"),
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=64, chunk=256),
    pattern=_PATTERN,
    source="arXiv:2403.19887 (Jamba)",
)
