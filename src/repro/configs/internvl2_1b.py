"""InternVL2-1B — InternViT vision encoder + InternLM2 LM backbone.

[arXiv:2404.16821].  The assignment specifies the transformer backbone;
the ViT/projector frontend is a stub: ``input_specs`` supplies 256
precomputed patch embeddings (d_model) as a decoder prefix.
Dense full-attention LM; long_500k runs via the sliding-window variant
(documented deviation, DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    arch_type="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    mlp_type="swiglu",
    rope_theta=1e6,
    sliding_window=8192,          # long_500k variant only (not always_swa)
    prefix_len=256,
    source="arXiv:2404.16821 (InternVL2); backbone=InternLM2/Qwen2-0.5B",
)
