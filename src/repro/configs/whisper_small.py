"""Whisper-small — encoder-decoder ASR transformer. [arXiv:2212.04356]

12+12 layers, d_model=768, MHA (kv=12), GELU MLP.  The mel-spectrogram +
conv frontend is a stub: ``input_specs`` supplies 1500 precomputed frame
embeddings as the encoder input.  Decoder = causal self-attn + cross-attn.
Full attention only -> long_500k is skipped (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    arch_type="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    mlp_type="gelu",
    encoder_layers=12,
    encoder_seq=1500,
    source="arXiv:2212.04356 (Whisper)",
)
