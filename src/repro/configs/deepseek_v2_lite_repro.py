"""DeepSeek-V2-Lite routing-structure reproduction (paper eval model 1).

Faithful expert structure (64 routed experts, top-6, 2 shared experts)
at reduced width so routing-trace experiments run on CPU.
[arXiv:2405.04434]
"""
from repro.configs.base import ModelConfig
from repro.models.moe import MoECfg

CONFIG = ModelConfig(
    name="deepseek-v2-lite-repro",
    arch_type="moe",
    n_layers=4,
    d_model=256,
    n_heads=8,
    n_kv_heads=8,
    head_dim=32,
    d_ff=512,
    vocab_size=2048,
    mlp_type="swiglu",
    moe=MoECfg(n_experts=64, top_k=6, d_ff=64,
               n_shared_experts=2, d_ff_shared=128,
               capacity_factor=2.0, mlp_type="swiglu"),
    source="arXiv:2405.04434 (DeepSeek-V2-Lite; reduced width, faithful routing)",
)
