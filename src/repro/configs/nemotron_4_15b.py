"""Nemotron-4 15B — dense, squared-ReLU MLP, GQA kv=8. [arXiv:2402.16819]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    arch_type="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    mlp_type="relu2",
    rope_theta=10000.0,
    sliding_window=8192,          # long_500k variant only
    source="arXiv:2402.16819 (Nemotron-4)",
)
