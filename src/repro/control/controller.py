"""Closed-loop per-tenant SLO controller (bits / partition / admission).

:class:`SLOController` holds the paper's miss-rate constraint *online*:
instead of one static config from the offline autotuner, it watches
per-tenant sliding windows and moves three bounded actuators:

* **Bit plan** (HOBBIT-style): demote a tenant to MSB-only decode.
  AMAT's truncation property makes demotion *free* — the MSB slice is
  itself a valid low-precision tensor, so no re-quantization or extra
  I/O happens; the tenant simply stops demanding LSB slices, which
  removes its LSB fetch misses (miss-rate relief) and its LSB
  fetch/read energy plus the high-bit matmul premium (energy relief).
  Promotion is driven by the *accuracy guard*: a demoted tenant whose
  served low-bit fraction exceeds its ``lowbit_frac`` SLO is promoted
  back.  ``bit_floor="high"`` pins a tenant at full precision.
* **Cache partition**: shift DRAM bytes between tenant segments of a
  :class:`~repro.control.partition.TenantPartitionedCache` (bounded
  step size, per-tenant floor).
* **Admission** (live serving only): deterministically thin admission
  of tenants without a TTFT SLO when some tenant's TTFT p95 violates
  its SLO.  This actuator never touches cache/plan state.

Stability comes from hysteresis (act only beyond ``(1 + hysteresis)``
of the target) and per-tenant cooldowns (no tenant is re-actuated for
``cooldown`` decision-steps after a move).

Replay fidelity — the load-bearing property: the bit and partition
actuators consume **only charge-path counters** (``StepCharge.
per_tenant``), which a recorded trace reproduces exactly, and they are
applied at a fixed point *inside* the engine's charge path.  A replayed
controller run therefore recomputes the identical decision sequence and
the identical per-epoch miss counts as the live run (gated by
``benchmarks/controller_soak.py``).  The admission actuator consumes
wall-clock telemetry and is deliberately excluded from that loop.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional

import numpy as np

from repro.control.signals import TenantSignals, TenantWindow

__all__ = ["TenantSLO", "ControllerConfig", "SLOController"]


@dataclasses.dataclass(frozen=True)
class TenantSLO:
    """Per-tenant service-level objectives.

    ``None`` disables the corresponding objective.  ``lowbit_frac`` is
    the tolerated fraction of *critical* (gate >= theta) selections
    served at low precision — 1.0 means the tenant accepts full
    demotion, 0.0 none.  ``bit_floor="high"`` exempts the tenant from
    bit demotion entirely.
    """

    miss_rate: Optional[float] = None
    lowbit_frac: float = 1.0
    ttft_s: Optional[float] = None
    bit_floor: str = "low"          # "low" (demotable) | "high" (pinned)

    def __post_init__(self):
        if self.bit_floor not in ("low", "high"):
            raise ValueError(f"bit_floor must be 'low' or 'high', "
                             f"got {self.bit_floor!r}")
        if not 0.0 <= self.lowbit_frac <= 1.0:
            raise ValueError(f"lowbit_frac must be in [0, 1], "
                             f"got {self.lowbit_frac}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TenantSLO":
        return cls(**d)


@dataclasses.dataclass
class ControllerConfig:
    """SLO specs plus loop-stability and actuator-bound knobs.

    JSON-clean via :meth:`to_dict` / :meth:`from_dict` so it rides in
    ``TraceMeta.engine`` like every other policy knob (which is what
    makes controller policies sweepable in :mod:`repro.sim.autotune`).
    """

    slos: Dict[str, TenantSLO]
    interval: int = 16              # decode steps between decisions
    window: int = 64                # steps of signal history per tenant
    cooldown: int = 32              # steps before re-actuating a tenant
    hysteresis: float = 0.1         # act only beyond (1+h) * target
    bits: bool = True               # enable the bit-plan actuator
    partition: bool = True          # enable the cache-partition actuator
    admission: bool = True          # enable the admission actuator (live)
    partition_step_frac: float = 0.1    # bytes moved per decision, as a
    partition_floor_frac: float = 0.1   # fraction of the tenant pool
    shared_frac: float = 0.25       # cache fraction kept unpartitioned
    admit_step: float = 0.25        # admit_frac cut per violation tick
    min_admit_frac: float = 0.25    # throttling never drops below this

    def __post_init__(self):
        self.slos = {t: (s if isinstance(s, TenantSLO)
                         else TenantSLO.from_dict(dict(s)))
                     for t, s in self.slos.items()}
        if not self.slos:
            raise ValueError("ControllerConfig needs >= 1 tenant SLO")
        if self.interval < 1:
            raise ValueError(f"interval must be >= 1, got {self.interval}")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["slos"] = {t: s.to_dict() for t, s in self.slos.items()}
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ControllerConfig":
        return cls(**dict(d))


class SLOController:
    """The decision loop.  One instance per engine, tenants fixed at
    construction (the sorted SLO keys — also the cache partition)."""

    def __init__(self, cfg: ControllerConfig, *, cache_bytes: float):
        self.cfg = cfg
        self.tenants: List[str] = sorted(cfg.slos)
        # --- charge-path state (replay-reproducible) ---
        self.levels: Dict[str, int] = {t: 0 for t in self.tenants}
        self.windows: Dict[str, TenantWindow] = {
            t: TenantWindow(cfg.window) for t in self.tenants}
        pool = (1.0 - cfg.shared_frac) * float(cache_bytes)
        self.budgets: Dict[str, float] = {
            t: pool / len(self.tenants) for t in self.tenants}
        self._pool = pool
        self._step = 0
        self._cooldown_until: Dict[str, int] = {t: 0 for t in self.tenants}
        self.actions: List[dict] = []
        # --- telemetry-side state (live serving only) ---
        self.signals: Dict[str, TenantSignals] = {
            t: TenantSignals(cfg.window) for t in self.tenants}
        self.admit_fracs: Dict[str, float] = {t: 1.0 for t in self.tenants}
        self._admit_seen: Dict[str, int] = {}
        self._live_steps = 0

    # ================= charge-path side (replay-reproducible) =========
    def plan_bits(self, slot_tenants: Optional[list],
                  n_slots: int) -> np.ndarray:
        """Per-slot bit level for this decode step: 0 = full AMAT plan,
        1 = demoted (MSB-only).  Unknown tenants run at full precision."""
        levels = np.zeros(n_slots, np.int8)
        if slot_tenants is not None:
            for b, t in enumerate(slot_tenants[:n_slots]):
                if t is not None:
                    levels[b] = self.levels.get(t, 0)
        return levels

    def observe_step(self, per_tenant: Dict[str, Dict[str, int]],
                     ledger_delta: Optional[dict] = None
                     ) -> Dict[str, Any]:
        """Ingest one decode step's charge counters; every ``interval``
        steps run the decision pass.  Returns actuator outputs for the
        engine to apply (currently only ``{"budgets": ...}``)."""
        for t, row in per_tenant.items():
            if t in self.windows:
                self.windows[t].push(row)
        self._step += 1
        if self._step % self.cfg.interval != 0:
            return {}
        return self._decide()

    def _log(self, kind: str, tenant: str, **detail) -> None:
        self.actions.append({"step": self._step, "kind": kind,
                             "tenant": tenant, **detail})

    def _cooled(self, tenant: str) -> bool:
        return self._step >= self._cooldown_until[tenant]

    def _touch(self, tenant: str) -> None:
        self._cooldown_until[tenant] = self._step + self.cfg.cooldown

    def _decide(self) -> Dict[str, Any]:
        cfg = self.cfg
        out: Dict[str, Any] = {}

        # 1. Accuracy guard: promote any demoted tenant whose served
        #    low-bit fraction exceeds its SLO.  Runs before the miss
        #    pass so a promotion and a re-demotion cannot land in the
        #    same tick (the cooldown then keeps them apart).
        for t in self.tenants:
            if self.levels[t] == 0 or not self._cooled(t):
                continue
            lf = self.windows[t].lowbit_frac()
            if lf is not None and lf > cfg.slos[t].lowbit_frac:
                self.levels[t] = 0
                self._touch(t)
                self._log("promote", t, lowbit_frac=lf)

        # 2. Miss-rate pass: for each violating tenant, escalate
        #    demote-self -> pull budget from the richest quiet tenant.
        violators = []
        for t in self.tenants:
            target = cfg.slos[t].miss_rate
            if target is None:
                continue
            mr = self.windows[t].miss_rate()
            if mr is not None and mr > target * (1.0 + cfg.hysteresis):
                violators.append(t)

        step_bytes = cfg.partition_step_frac * self._pool
        floor = cfg.partition_floor_frac * self._pool
        for t in violators:
            if not self._cooled(t):
                continue
            mr = self.windows[t].miss_rate()
            if (cfg.bits and self.levels[t] == 0
                    and cfg.slos[t].bit_floor != "high"):
                self.levels[t] = 1
                self._touch(t)
                self._log("demote", t, miss_rate=mr)
                continue
            if not cfg.partition:
                continue
            donors = [d for d in self.tenants
                      if d not in violators
                      and self.budgets[d] - step_bytes >= floor]
            if not donors:
                continue
            donor = max(donors, key=lambda d: (self.budgets[d], d))
            self.budgets[donor] -= step_bytes
            self.budgets[t] += step_bytes
            self._touch(t)
            self._log("repartition", t, donor=donor,
                      bytes=step_bytes, miss_rate=mr)
            out["budgets"] = dict(self.budgets)
        return out

    # ================= telemetry side (live serving only) =============
    def attach_telemetry(self, telemetry) -> None:
        telemetry.add_listener(self)

    def on_submit(self, record) -> None:
        t = getattr(record, "tenant", None)
        if t in self.signals:
            self.signals[t].on_submit()

    def on_first_token(self, record) -> None:
        t = getattr(record, "tenant", None)
        if t in self.signals:
            self.signals[t].on_first_token(record.ttft)

    def on_step(self, step) -> None:
        self._live_steps += 1
        if self.cfg.admission and self._live_steps % self.cfg.interval == 0:
            self._admit_tick()

    def _admit_tick(self) -> None:
        cfg = self.cfg
        violated = False
        for t in self.tenants:
            slo = cfg.slos[t]
            if slo.ttft_s is None:
                continue
            p95 = self.signals[t].ttft_s.percentile(95)
            if p95 is not None and p95 > slo.ttft_s * (1 + cfg.hysteresis):
                violated = True
                self._log("ttft_violation", t, ttft_p95_s=p95)
        # Throttle the tenants *without* a TTFT SLO (background traffic)
        # when any latency-sensitive tenant is violating; relax everyone
        # back toward full admission otherwise.
        for t in self.tenants:
            if violated and cfg.slos[t].ttft_s is None:
                self.admit_fracs[t] = max(
                    cfg.min_admit_frac,
                    self.admit_fracs[t] - cfg.admit_step)
            elif not violated:
                self.admit_fracs[t] = min(
                    1.0, self.admit_fracs[t] + cfg.admit_step)

    def admit_request(self, req) -> bool:
        """Deterministic admission thinning: with ``admit_frac = f``,
        admit the n-th arrival of a tenant iff ``floor(n*f)`` advanced —
        an evenly spaced f-fraction, reproducible run to run."""
        t = getattr(req, "tenant", "default")
        frac = self.admit_fracs.get(t, 1.0)
        n = self._admit_seen.get(t, 0) + 1
        self._admit_seen[t] = n
        if frac >= 1.0:
            return True
        return math.floor(n * frac) > math.floor((n - 1) * frac)

    # ================= reporting ======================================
    def low_bit_fraction(self) -> float:
        """Fraction of tenants currently actuated below full precision
        (level > 0) — the scalar the metrics registry samples per step
        instead of diffing the whole ``levels`` dict."""
        if not self.levels:
            return 0.0
        return sum(1 for lvl in self.levels.values() if lvl > 0) \
            / len(self.levels)

    def summary(self) -> dict:
        return {
            "steps": self._step,
            "levels": dict(self.levels),
            "budgets": dict(self.budgets),
            "admit_fracs": dict(self.admit_fracs),
            "n_actions": len(self.actions),
            "actions_tail": self.actions[-8:],
            "low_bit_fraction": self.low_bit_fraction(),
        }
