"""Per-tenant byte-budget partitioning of the slice cache.

:class:`TenantPartitionedCache` splits one DRAM budget into per-tenant
segments plus a shared segment, behind the exact
:class:`~repro.core.cache.SliceCache` surface the engine's charge path,
PCW reshape and the init states consume (the same composition move as
:class:`~repro.core.shard.ShardedSliceCache`, but along the *tenant*
axis instead of the expert-placement axis, and with **resizable**
budgets — the controller's partition actuator calls
:meth:`set_budgets`).

Semantics:

* **Lookup is shared.**  An access hits if the slice is resident in
  *any* segment — tenants routing to the same hot expert share one
  copy; partitioning controls eviction pressure, not visibility.
* **Eviction is isolated.**  A fill lands in the *active tenant's*
  segment (set by the engine via :meth:`set_active_tenant` before each
  expert's accesses) and can only evict within that segment.  A noisy
  tenant's miss storm therefore cannot evict a quiet tenant's working
  set — the isolation property the controller's partition actuator
  relies on.
* **Unattributed fills go to the shared segment**: prefetch inserts,
  warmup installs for unknown tenants, and any access with no active
  tenant set.

Hit/miss stats and epochs live on the wrapper (an access is one event
regardless of which segment holds the slice); segment-level counters
stay zero by construction, and :meth:`segment_summary` reports byte
occupancy instead.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.cache import CacheStats, SliceCache, SliceTooLargeError
from repro.core.slices import SliceKey

__all__ = ["SHARED_SEGMENT", "TenantPartitionedCache"]

SHARED_SEGMENT = "shared"


class TenantPartitionedCache:
    """Per-tenant :class:`SliceCache` segments behind one cache surface."""

    n_shards: int = 1

    def shard_index(self, key: SliceKey) -> int:
        return 0

    def __init__(self, capacity_bytes: float, tenants: Iterable[str], *,
                 shared_frac: float = 0.25, slice_aware: bool = True):
        names = sorted(set(tenants))
        if not names:
            raise ValueError("TenantPartitionedCache needs >= 1 tenant")
        if SHARED_SEGMENT in names:
            raise ValueError(
                f"tenant name {SHARED_SEGMENT!r} is reserved")
        if not 0.0 <= shared_frac < 1.0:
            raise ValueError(f"shared_frac must be in [0, 1), "
                             f"got {shared_frac}")
        self.slice_aware = slice_aware
        total = float(capacity_bytes)
        shared_bytes = shared_frac * total
        per_tenant = (total - shared_bytes) / len(names)
        self.segments: Dict[str, SliceCache] = {
            t: SliceCache(per_tenant, slice_aware=slice_aware)
            for t in names}
        self.segments[SHARED_SEGMENT] = SliceCache(
            shared_bytes, slice_aware=slice_aware)
        self.tenants = names
        self._active: Optional[str] = None
        self.stats = CacheStats()
        self.epochs: List[Tuple[str, dict]] = []
        self._epoch_label: Optional[str] = None

    # ------------------------------------------------------------ routing
    def set_active_tenant(self, tenant: Optional[str]) -> None:
        """Sticky fill-routing hint: subsequent miss fills land in this
        tenant's segment (unknown / ``None`` -> shared)."""
        self._active = tenant

    def _fill_segment(self) -> SliceCache:
        return self.segments.get(self._active or SHARED_SEGMENT,
                                 self.segments[SHARED_SEGMENT])

    def _find(self, key: SliceKey) -> Optional[SliceCache]:
        """Owning segment of a resident key, deterministic scan order."""
        for name in self.tenants:
            if key in self.segments[name]:
                return self.segments[name]
        if key in self.segments[SHARED_SEGMENT]:
            return self.segments[SHARED_SEGMENT]
        return None

    # ----------------------------------------------------- aggregate state
    @property
    def capacity(self) -> float:
        return sum(s.capacity for s in self.segments.values())

    @property
    def used(self) -> float:
        return sum(s.used for s in self.segments.values())

    def __contains__(self, key: SliceKey) -> bool:
        return self._find(key) is not None

    def __len__(self) -> int:
        return sum(len(s) for s in self.segments.values())

    def contains(self, key: SliceKey) -> bool:
        return key in self

    def can_fit(self, key: SliceKey, nbytes: float) -> bool:
        s = self._fill_segment()
        return s.used + nbytes <= s.capacity

    def fill_capacity(self) -> float:
        """Capacity of the segment a miss fill would land in right now —
        the engine's dropped-fill check (a slice bigger than the target
        segment streams Flash->XPU instead of filling DRAM)."""
        return self._fill_segment().capacity

    # ------------------------------------------------------------- mutate
    def access(self, key: SliceKey, nbytes: float,
               *, fill_on_miss: bool = True) -> bool:
        seg = self._find(key)
        hit = seg is not None
        self.stats.record(key.kind, hit)
        if hit:
            if key.kind == "msb" or not self.slice_aware:
                seg._segment(key).move_to_end(key)
            return True
        if fill_on_miss:
            try:
                self.insert(key, nbytes)
            except SliceTooLargeError:
                self.stats.n_dropped += 1
        return False

    def insert(self, key: SliceKey, nbytes: float) -> List[SliceKey]:
        seg = self._find(key)
        if seg is not None:
            seg._segment(key).move_to_end(key)
            return []
        return self._fill_segment().insert(key, nbytes)

    def evict(self, key: SliceKey) -> bool:
        seg = self._find(key)
        return seg.evict(key) if seg is not None else False

    def evict_where(self, pred) -> List[SliceKey]:
        out: List[SliceKey] = []
        for s in self.segments.values():
            out.extend(s.evict_where(pred))
        return out

    def reorder_by(self, ranking) -> None:
        for s in self.segments.values():
            s.reorder_by(ranking)

    def clear(self) -> None:
        for s in self.segments.values():
            s.clear()

    # ---------------------------------------------------- budget actuator
    def budgets(self) -> Dict[str, float]:
        """Current per-segment capacities (tenants + shared)."""
        return {name: s.capacity for name, s in self.segments.items()}

    def set_budgets(self, budgets: Dict[str, float]) -> List[SliceKey]:
        """Resize segment capacities; evict LRU overflow from any
        segment that shrank below its occupancy.  Returns evicted keys.

        Partial dicts are fine — unnamed segments keep their budget.
        The controller is responsible for conserving the total; this
        method only enforces per-segment occupancy <= capacity.
        """
        evicted: List[SliceKey] = []
        for name, cap in budgets.items():
            if name not in self.segments:
                raise KeyError(f"unknown cache segment {name!r}")
            if cap < 0:
                raise ValueError(f"negative budget for {name!r}: {cap}")
            seg = self.segments[name]
            seg.capacity = float(cap)
            while seg.used > seg.capacity:
                e = seg._evict_one()
                if e is None:
                    break
                evicted.append(e[0])
        return evicted

    # --------------------------------------------------- in-flight fills
    def mark_inflight(self, key: SliceKey, ready_t: float) -> None:
        seg = self._find(key)
        if seg is not None:
            seg.mark_inflight(key, ready_t)

    def ready_time(self, key: SliceKey, default: float = 0.0) -> float:
        seg = self._find(key)
        return seg.ready_time(key, default) if seg is not None else default

    def settle(self, now: float) -> None:
        for s in self.segments.values():
            s.settle(now)

    # ------------------------------------------------------------- reads
    def resident_keys(self) -> List[SliceKey]:
        out: List[SliceKey] = []
        for name in self.tenants:
            out.extend(self.segments[name].resident_keys())
        out.extend(self.segments[SHARED_SEGMENT].resident_keys())
        return out

    def residency(self, n_layers: int, n_experts: int):
        import numpy as np

        msb = np.zeros((n_layers, n_experts), bool)
        lsb = np.zeros((n_layers, n_experts), bool)
        for s in self.segments.values():
            m, l = s.residency(n_layers, n_experts)
            msb |= m
            lsb |= l
        return msb, lsb

    def segment_summary(self) -> Dict[str, dict]:
        """Byte occupancy per segment (stats live on the wrapper)."""
        return {name: {"capacity_bytes": s.capacity,
                       "used_bytes": s.used, "n_slices": len(s)}
                for name, s in self.segments.items()}

    # ------------------------------------------------------------- epochs
    # The wrapper owns the hit/miss counters (an access is one event no
    # matter which segment holds the slice), so epochs roll over here —
    # same shape as SliceCache's, which the fidelity gate compares.
    def begin_epoch(self, label: str) -> None:
        self.end_epoch()
        self._epoch_label = label
        self.stats = CacheStats()

    def end_epoch(self) -> None:
        if self._epoch_label is None:
            return
        self.epochs.append((self._epoch_label, self.stats.snapshot()))
        self._epoch_label = None
        self.stats = CacheStats()

    def epoch_miss_rates(self) -> List[Tuple[str, float]]:
        return [(label, CacheStats(**snap).miss_rate)
                for label, snap in self.epochs]

    def epoch_counts(self) -> List[Tuple[str, int, int]]:
        return [(label, CacheStats(**snap).accesses,
                 CacheStats(**snap).misses)
                for label, snap in self.epochs]

    def usage(self) -> dict:
        """Occupancy + lifetime counts, same shape as
        :meth:`SliceCache.usage` (the metrics-registry view)."""
        return SliceCache.usage(self)

    def clone(self) -> "TenantPartitionedCache":
        import copy

        return copy.deepcopy(self)
