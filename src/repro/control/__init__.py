"""Online SLO control: closed-loop policy adaptation for serving.

The subsystem that holds SliceMoE's miss-rate constraint *live*, when
tenant mixes and expert hotness shift and no static config is right for
long (ROADMAP item 4).  Three pieces:

* :mod:`repro.control.signals` — per-tenant sliding windows over the
  charge-path counters (miss rate, low-bit fraction) and the serving
  telemetry stream (TTFT, per-token latency, energy/token).
* :mod:`repro.control.partition` — :class:`TenantPartitionedCache`, the
  slice cache split into per-tenant byte-budget segments with shared
  lookup visibility but isolated eviction domains (the resizable
  analogue of the per-shard split in :mod:`repro.core.shard`).
* :mod:`repro.control.controller` — :class:`SLOController`, the
  decision loop: HOBBIT-style bit-plan demotion/promotion, partition
  resizing and admission throttling, each bounded by hysteresis and
  cooldown.

Enabled via ``EngineConfig.controller``; see docs/control.md for the
loop diagram and the replay-fidelity argument (every cache-affecting
decision is a pure function of the charge-path stream, so a recorded
controller run replays bit-identically through
:mod:`repro.sim.replay`).
"""

from repro.control.controller import (ControllerConfig, SLOController,
                                      TenantSLO)
from repro.control.partition import TenantPartitionedCache
from repro.control.signals import SlidingWindow, TenantSignals

__all__ = ["ControllerConfig", "SLOController", "TenantSLO",
           "TenantPartitionedCache", "SlidingWindow", "TenantSignals"]
