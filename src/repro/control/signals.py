"""Per-tenant sliding-window signals for the SLO controller.

Two kinds of window, split by where the sample comes from:

* :class:`TenantWindow` — *charge-path* counters (accesses, misses,
  critical selections, low-bit-served criticals) pushed once per decode
  step from ``StepCharge.per_tenant``.  These exist identically in live
  serving and in trace replay, so every controller decision derived
  from them is replay-reproducible.
* :class:`SlidingWindow` — scalar samples from the *telemetry* stream
  (TTFT, per-token latency, energy per token).  These only exist live;
  the controller consumes them for admission throttling, which never
  touches cache/plan state (see docs/control.md).

Windows are bounded deques: O(window) memory per tenant, O(window)
aggregation at decision epochs (every ``interval`` steps), which is
noise next to a decode step.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Optional

from repro.serving.telemetry import percentile

__all__ = ["SlidingWindow", "TenantWindow", "TenantSignals"]


class SlidingWindow:
    """Bounded window of scalar samples with mean / percentile queries."""

    def __init__(self, maxlen: int = 64):
        self._buf: Deque[float] = deque(maxlen=maxlen)

    def push(self, value: float) -> None:
        self._buf.append(float(value))

    def __len__(self) -> int:
        return len(self._buf)

    def mean(self) -> Optional[float]:
        if not self._buf:
            return None
        return sum(self._buf) / len(self._buf)

    def percentile(self, q: float) -> Optional[float]:
        if not self._buf:
            return None             # telemetry's percentile() gives nan
        return percentile(list(self._buf), q)

    def clear(self) -> None:
        self._buf.clear()


class TenantWindow:
    """Window of per-step charge-path count rows for one tenant.

    A row is the tenant's slice of ``StepCharge.per_tenant``:
    ``{"tokens", "accesses", "misses", "critical", "critical_low"}``.
    Ratios are computed over the *summed* window, not averaged per step,
    so steps with more traffic weigh more — the quantity the paper's
    miss-rate constraint is stated over.
    """

    _KEYS = ("tokens", "accesses", "misses", "critical", "critical_low")

    def __init__(self, maxlen: int = 64):
        self._buf: Deque[Dict[str, int]] = deque(maxlen=maxlen)

    def push(self, row: Dict[str, int]) -> None:
        self._buf.append({k: int(row.get(k, 0)) for k in self._KEYS})

    def __len__(self) -> int:
        return len(self._buf)

    def _sum(self, key: str) -> int:
        return sum(r[key] for r in self._buf)

    @property
    def total_accesses(self) -> int:
        return self._sum("accesses")

    @property
    def total_tokens(self) -> int:
        return self._sum("tokens")

    def miss_rate(self) -> Optional[float]:
        acc = self._sum("accesses")
        if acc == 0:
            return None
        return self._sum("misses") / acc

    def lowbit_frac(self) -> Optional[float]:
        """Fraction of critical selections served at low precision."""
        crit = self._sum("critical")
        if crit == 0:
            return None
        return self._sum("critical_low") / crit

    def clear(self) -> None:
        self._buf.clear()


@dataclasses.dataclass
class TenantSignals:
    """Telemetry-side windows for one tenant (live serving only)."""

    window: int = 64

    def __post_init__(self):
        self.ttft_s = SlidingWindow(self.window)
        self.per_token_s = SlidingWindow(self.window)
        self.energy_per_token_j = SlidingWindow(self.window)
        self.n_submitted = 0

    def on_submit(self) -> None:
        self.n_submitted += 1

    def on_first_token(self, ttft_s: Optional[float]) -> None:
        if ttft_s is not None:
            self.ttft_s.push(ttft_s)

    def on_finish(self, per_token_s: Optional[float],
                  energy_per_token_j: Optional[float] = None) -> None:
        if per_token_s is not None:
            self.per_token_s.push(per_token_s)
        if energy_per_token_j is not None:
            self.energy_per_token_j.push(energy_per_token_j)

    def summary(self) -> dict:
        return {
            "n_submitted": self.n_submitted,
            "ttft_p50_s": self.ttft_s.percentile(50),
            "ttft_p95_s": self.ttft_s.percentile(95),
            "per_token_p50_s": self.per_token_s.percentile(50),
            "per_token_p95_s": self.per_token_s.percentile(95),
            "energy_per_token_p50_j":
                self.energy_per_token_j.percentile(50),
        }
