"""Group quantization primitives."""
