"""Group quantization primitives (pure jnp).

The paper quantizes expert weights with **G32 asymmetric** integer
quantization and all non-expert weights with **G128 symmetric** INT8.
Groups run along the *input* (contraction) dimension of each weight matrix,
matching per-group dequantization inside the matmul's K loop.

Conventions
-----------
* ``w`` has shape ``(..., K, N)``; groups tile K: ``K = G * group_size``.
* Asymmetric: ``q = clip(round(w / s) + zp, 0, 2^b - 1)``;
  ``dequant = (q - zp) * s`` with integer zero-point ``zp`` (uint domain).
* Symmetric:  ``q = clip(round(w / s), -2^(b-1), 2^(b-1) - 1)``;
  ``dequant = q * s``.
* Codes are stored in ``uint8``/``int8`` regardless of bit-width b <= 8;
  the *logical* width lives in the metadata.  This is exactly what the
  bit-sliced store needs: an 8-bit AMAT code whose MSB slice is a shift.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantMeta:
    bits: int
    group_size: int
    asymmetric: bool


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Group-quantized tensor.

    Attributes:
      codes:  integer codes, ``uint8`` (asym) or ``int8`` (sym), shape
              ``(..., K, N)``.
      scales: per-group scales, shape ``(..., K // group_size, N)``.
      zero_points: per-group integer zero-points (uint domain), same shape
              as ``scales``; all-zero for symmetric quantization.
      bits / group_size / asymmetric: static metadata.
    """

    codes: jax.Array
    scales: jax.Array
    zero_points: jax.Array
    bits: int
    group_size: int
    asymmetric: bool

    # pytree protocol -------------------------------------------------------
    def tree_flatten(self):
        children = (self.codes, self.scales, self.zero_points)
        aux = (self.bits, self.group_size, self.asymmetric)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scales, zps = children
        bits, group_size, asymmetric = aux
        return cls(codes, scales, zps, bits, group_size, asymmetric)

    @property
    def shape(self):
        return self.codes.shape

    @property
    def nbytes_weights(self) -> float:
        """Logical storage in bytes at the *logical* bit-width."""
        import numpy as np

        n_codes = float(np.prod(self.codes.shape))
        n_groups = float(np.prod(self.scales.shape))
        # fp16 scale + b-bit zero point per group
        return n_codes * self.bits / 8 + n_groups * (2 + self.bits / 8)

    def dequantize(self) -> jax.Array:
        return dequantize(self)


def _group_reshape(w: jax.Array, group_size: int) -> jax.Array:
    *lead, K, N = w.shape
    if K % group_size != 0:
        raise ValueError(f"K={K} not divisible by group_size={group_size}")
    return w.reshape(*lead, K // group_size, group_size, N)


@partial(jax.jit, static_argnames=("bits", "group_size", "asymmetric"))
def quantize(
    w: jax.Array,
    *,
    bits: int = 8,
    group_size: int = 32,
    asymmetric: bool = True,
) -> QuantizedTensor:
    """Group-quantize ``w`` along its second-to-last dimension."""
    wg = _group_reshape(w.astype(jnp.float32), group_size)
    if asymmetric:
        # Range always includes zero (standard affine-quant convention):
        # keeps the integer zero-point in range, bounding the roundtrip
        # error by one quantization step even for one-sided distributions.
        wmin = jnp.minimum(jnp.min(wg, axis=-2, keepdims=True), 0.0)
        wmax = jnp.maximum(jnp.max(wg, axis=-2, keepdims=True), 0.0)
        qmax = 2**bits - 1
        scale = (wmax - wmin) / qmax
        scale = jnp.where(scale <= 0, 1.0, scale)
        zp = jnp.clip(jnp.round(-wmin / scale), 0, qmax)
        q = jnp.clip(jnp.round(wg / scale) + zp, 0, qmax)
        codes = q.reshape(w.shape).astype(jnp.uint8)
        scales = jnp.squeeze(scale, axis=-2).astype(jnp.float32)
        zps = jnp.squeeze(zp, axis=-2).astype(jnp.uint8)
    else:
        amax = jnp.max(jnp.abs(wg), axis=-2, keepdims=True)
        qmax = 2 ** (bits - 1) - 1
        scale = amax / qmax
        scale = jnp.where(scale <= 0, 1.0, scale)
        q = jnp.clip(jnp.round(wg / scale), -(qmax + 1), qmax)
        codes = q.reshape(w.shape).astype(jnp.int8)
        scales = jnp.squeeze(scale, axis=-2).astype(jnp.float32)
        zps = jnp.zeros(scales.shape, jnp.uint8)
    return QuantizedTensor(codes, scales, zps, bits, group_size, asymmetric)


@jax.jit
def dequantize(qt: QuantizedTensor) -> jax.Array:
    codes = qt.codes
    *lead, K, N = codes.shape
    G = K // qt.group_size
    cg = codes.reshape(*lead, G, qt.group_size, N)
    scales = qt.scales[..., :, None, :]
    if qt.asymmetric:
        zps = qt.zero_points[..., :, None, :].astype(jnp.float32)
        w = (cg.astype(jnp.float32) - zps) * scales
    else:
        w = cg.astype(jnp.float32) * scales
    return w.reshape(*lead, K, N)


def quantization_error(w: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """Relative RMS error of a quantized tensor vs the original."""
    d = dequantize(qt) - w.astype(jnp.float32)
    return jnp.sqrt(jnp.mean(d * d)) / (jnp.sqrt(jnp.mean(w * w)) + 1e-12)
