"""CLI: ``python -m repro.analysis [paths...]``.

Exit codes: 0 clean (or all findings baselined), 1 new findings (or a
stale baseline with ``--strict-baseline``), 2 usage error.

Typical use::

    python -m repro.analysis src/repro               # lint the tree
    python -m repro.analysis src/repro --list-rules  # rule catalogue
    python -m repro.analysis src/repro --write-baseline
    python -m repro.analysis src/repro --rule purity --no-baseline
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import all_rules
from .core import Baseline, lint_paths

DEFAULT_BASELINE = ".slicelint.json"


def find_root(start: Path) -> Path:
    """Repo root: nearest ancestor holding pyproject.toml (or .git)."""
    for p in [start] + list(start.parents):
        if (p / "pyproject.toml").exists() or (p / ".git").exists():
            return p
    return start


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="slicelint: charge-path static analysis "
                    "(purity, clone, ledger, knobs)")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or directories to lint "
                         "(default: src/repro)")
    ap.add_argument("--rule", action="append", dest="rules", metavar="ID",
                    help="run only this rule (repeatable)")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help=f"baseline file (default: <root>/{DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="freeze current findings into the baseline "
                         "file and exit 0")
    ap.add_argument("--strict-baseline", action="store_true",
                    help="fail when the baseline holds stale entries "
                         "that no longer match any finding")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    ap.add_argument("--root", default=None, metavar="DIR",
                    help="repo root for relative paths/baseline "
                         "(default: auto-detected)")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            head = (rule.doc.strip().splitlines() or [""])[0]
            print(f"{rule.id:8s} {head}")
        return 0

    paths = [Path(p) for p in args.paths]
    for p in paths:
        if not p.exists():
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2
    root = Path(args.root).resolve() if args.root \
        else find_root(paths[0].resolve())
    baseline_path = Path(args.baseline) if args.baseline \
        else root / DEFAULT_BASELINE

    try:
        findings = lint_paths(paths, root, rules=args.rules)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2

    if args.write_baseline:
        bl = Baseline({f.key: f.message for f in findings})
        bl.save(baseline_path)
        print(f"wrote {len(findings)} finding(s) to {baseline_path}")
        return 0

    bl = Baseline() if args.no_baseline else Baseline.load(baseline_path)
    new, baselined, stale = bl.split(findings)

    for f in new:
        print(f.render())
    status = (f"slicelint: {len(new)} new finding(s), "
              f"{len(baselined)} baselined, {len(stale)} stale "
              f"baseline entr{'y' if len(stale) == 1 else 'ies'}")
    print(status)
    if stale:
        for key in stale:
            print(f"  stale: {key}  (fixed? remove it from "
                  f"{baseline_path.name})")
    if new:
        return 1
    if stale and args.strict_baseline:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
