"""Rule ``purity``: charge-path modules are pure functions of the trace.

Replay fidelity (live ≡ replay, bit-identical miss counts and charge
sequences) holds only if every decision the engine charges for is
computed from trace-visible state.  This rule bans, inside the
charge-path modules:

* wall-clock reads (``time.time``, ``perf_counter``, ``monotonic``,
  ``datetime.now``, ...) — a charge keyed on wall time can never replay;
* process-global / unseeded RNG (``random.*`` module calls,
  ``np.random.<fn>`` legacy global state, ``default_rng()`` /
  ``random.Random()`` *without* a seed argument) — seeded generators
  owned by a component are fine;
* environment reads (``os.environ``, ``os.getenv``) — config must flow
  through ``EngineConfig`` so it lands in ``TraceMeta``;
* ``id()`` outside ``__hash__`` — identity is fresh per process, so any
  decision keyed on it diverges between live and replay;
* *iterating* a ``set`` (for-loop, comprehension, ``list()``/``tuple()``
  materialization) — set order is insertion/hash dependent; membership
  tests are fine, iterate ``sorted(...)`` instead.
"""

from __future__ import annotations

import ast
from typing import List, Sequence

from .core import Finding, SourceFile, dotted_name, register

RULE = "purity"

# Modules whose code runs on the charge path (matched as path suffixes).
CHARGE_PATH_SUFFIXES = (
    "core/engine.py",
    "core/cache.py",
    "core/shard.py",
    "core/prefetch.py",
    "core/placement.py",
    "core/warmup.py",
    "hw/energy.py",
)
CHARGE_PATH_DIR_SUFFIXES = ("control/",)

WALL_CLOCK = {
    "time.time", "time.time_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "time.monotonic", "time.monotonic_ns",
    "time.process_time", "time.process_time_ns",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "date.today", "datetime.date.today",
}

ENV_READS = {"os.getenv", "os.environ.get"}

# np.random legacy global-state functions (always hidden global state).
NP_GLOBAL_RANDOM = {
    "rand", "randn", "randint", "random", "random_sample", "ranf",
    "sample", "choice", "shuffle", "permutation", "uniform", "normal",
    "seed", "standard_normal", "binomial", "poisson",
}

SET_OPS = (ast.Sub, ast.BitOr, ast.BitAnd, ast.BitXor)


def is_charge_path(rel: str) -> bool:
    if rel.endswith(CHARGE_PATH_SUFFIXES):
        return True
    parent = rel.rsplit("/", 1)[0] + "/"
    return any(parent.endswith(d) for d in CHARGE_PATH_DIR_SUFFIXES)


def _call_seeded(call: ast.Call) -> bool:
    """True if the constructor call passes any seed-like argument."""
    return bool(call.args) or bool(call.keywords)


class _FuncScope(ast.NodeVisitor):
    """Names bound to set-valued expressions within one function body."""

    def __init__(self) -> None:
        self.set_names: set = set()

    def visit_Assign(self, node: ast.Assign) -> None:
        if _is_set_expr(node.value, self.set_names):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self.set_names.add(t.id)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None and \
                _is_set_expr(node.value, self.set_names) and \
                isinstance(node.target, ast.Name):
            self.set_names.add(node.target.id)
        self.generic_visit(node)

    # Do not descend into nested functions: their scopes are separate.
    def visit_FunctionDef(self, node):  # noqa: D102
        pass

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef


def _is_set_expr(node: ast.AST, set_names: set) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    if isinstance(node, ast.Name) and node.id in set_names:
        return True
    if isinstance(node, ast.BinOp) and isinstance(node.op, SET_OPS):
        return _is_set_expr(node.left, set_names) or \
            _is_set_expr(node.right, set_names)
    return False


def _enclosing_functions(tree: ast.Module):
    """Yield (qualname, func) for every function, including methods."""

    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                yield qual, child
                yield from walk(child, qual + ".")
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.")

    yield from walk(tree, "")


def _check_file(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []

    def emit(line: int, ident: str, message: str) -> None:
        findings.append(Finding(RULE, sf.rel, line, ident, message))

    for qual, func in _enclosing_functions(sf.tree):
        in_hash = qual.endswith("__hash__")
        scope = _FuncScope()
        for stmt in func.body:
            scope.visit(stmt)

        for node in ast.walk(func):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not func:
                continue  # handled under its own qualname

            if isinstance(node, ast.Call):
                name = dotted_name(node.func)
                if name in WALL_CLOCK:
                    emit(node.lineno, f"{qual}:wall-clock:{name}",
                         f"{qual} reads the wall clock via {name}(); "
                         "charges keyed on wall time cannot replay — "
                         "derive timing from ChannelTimeline clocks")
                elif name in ENV_READS:
                    emit(node.lineno, f"{qual}:env:{name}",
                         f"{qual} reads the environment via {name}(); "
                         "config must flow through EngineConfig so it "
                         "is captured in TraceMeta")
                elif name and name.startswith("random.") \
                        and name.count(".") == 1 and name != "random.Random":
                    emit(node.lineno, f"{qual}:global-rng:{name}",
                         f"{qual} uses process-global RNG {name}(); use "
                         "a seeded generator owned by the component")
                elif name == "random.Random" and not _call_seeded(node):
                    emit(node.lineno, f"{qual}:unseeded-rng:{name}",
                         f"{qual} constructs random.Random() without a "
                         "seed; replay cannot reproduce its stream")
                elif name and (name.startswith("np.random.")
                               or name.startswith("numpy.random.")):
                    leaf = name.rsplit(".", 1)[1]
                    if leaf in NP_GLOBAL_RANDOM:
                        emit(node.lineno, f"{qual}:global-rng:{name}",
                             f"{qual} uses numpy's global RNG {name}(); "
                             "use np.random.default_rng(seed) owned by "
                             "the component")
                    elif leaf in ("default_rng", "Generator",
                                  "SeedSequence") and not _call_seeded(node):
                        emit(node.lineno, f"{qual}:unseeded-rng:{name}",
                             f"{qual} constructs {name}() without a seed; "
                             "replay cannot reproduce its stream")
                elif name == "id" and not in_hash:
                    emit(node.lineno, f"{qual}:id-call",
                         f"{qual} calls id(); object identity is fresh "
                         "per process, so decisions keyed on it diverge "
                         "between live and replay (allowed only in "
                         "__hash__)")
                elif name in ("list", "tuple") and node.args and \
                        _is_set_expr(node.args[0], scope.set_names):
                    emit(node.lineno,
                         f"{qual}:set-order:{ast.unparse(node.args[0])}",
                         f"{qual} materializes a set into an ordered "
                         f"sequence ({ast.unparse(node)[:60]}); set order "
                         "is hash-dependent — use sorted(...)")

            elif isinstance(node, ast.Attribute):
                if dotted_name(node) == "os.environ":
                    emit(node.lineno, f"{qual}:env:os.environ",
                         f"{qual} touches os.environ; config must flow "
                         "through EngineConfig so it is captured in "
                         "TraceMeta")

            elif isinstance(node, ast.For):
                if _is_set_expr(node.iter, scope.set_names):
                    emit(node.iter.lineno,
                         f"{qual}:set-order:{ast.unparse(node.iter)}",
                         f"{qual} iterates a set "
                         f"({ast.unparse(node.iter)[:60]}); iteration "
                         "order is hash-dependent and can reorder "
                         "charges — iterate sorted(...) instead")

            elif isinstance(node, (ast.ListComp, ast.GeneratorExp)):
                for gen in node.generators:
                    if _is_set_expr(gen.iter, scope.set_names):
                        emit(gen.iter.lineno,
                             f"{qual}:set-order:{ast.unparse(gen.iter)}",
                             f"{qual} iterates a set in a comprehension "
                             f"({ast.unparse(gen.iter)[:60]}); order is "
                             "hash-dependent — iterate sorted(...)")

    return findings


@register(RULE, __doc__ or "")
def check(files: Sequence[SourceFile]) -> List[Finding]:
    out: List[Finding] = []
    for sf in files:
        if is_charge_path(sf.rel):
            out.extend(_check_file(sf))
    return out
