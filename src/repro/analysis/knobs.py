"""Rule ``knobs``: every EngineConfig knob round-trips everywhere.

Replay can only reproduce a live run if every knob that shaped the run
is (a) recorded in the trace header, (b) settable from the serving CLI,
and (c) consumed when a trace is replayed/swept.  PR 4's original sin —
a knob added to ``EngineConfig`` but not to ``TraceMeta`` silently
replays at its default — is exactly the drift this rule freezes out.

Cross-checked surfaces (all parsed statically, nothing imported):

* **fields** — ``EngineConfig`` dataclass fields in ``core/engine.py``;
* **meta** — keys of the ``engine={...}`` dict built by
  ``engine_meta()`` in ``sim/trace.py`` (the trace header);
* **cli** — keys of ``DEFAULT_KNOBS`` *and* of the dict returned by
  ``cli_engine_knobs()`` in ``launch/serve.py`` (a key present in one
  but not the other is its own finding);
* **replay** — string keys read (``e[...]``, ``.get(...)``,
  ``.setdefault(...)``) inside ``engine_config_from_meta()`` in
  ``sim/replay.py``.  This is also the autotune sweep surface: sweep
  overrides are validated against exactly these keys.

Composite fields map through ``ALIASES`` (``mat`` serializes as
``high_bits``/``low_bits``; ``policy`` as ``policy_kind``/``slice_mode``
/``theta``/``fetch_lsb_on_miss``).  Fields that legitimately do not
round-trip carry an ``ALLOWLIST`` entry with a justification.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .core import Finding, SourceFile, register

RULE = "knobs"

ENGINE_FILE = "core/engine.py"
TRACE_FILE = "sim/trace.py"
SERVE_FILE = "launch/serve.py"
REPLAY_FILE = "sim/replay.py"

# EngineConfig field -> the flat knob names it serializes as.
ALIASES: Dict[str, Set[str]] = {
    "mat": {"high_bits", "low_bits"},
    "policy": {"policy_kind", "slice_mode", "theta", "fetch_lsb_on_miss"},
}

# Fields that deliberately do not round-trip, with the reason.
ALLOWLIST: Dict[str, str] = {
    # Live-model KV/sequence capacity. Not a charge-path knob: replay
    # derives step structure from the recorded trace itself, and the
    # serving CLI sizes sequences via --prompt-len/--max-new.
    "max_seq": "model capacity bound, not a charge-path knob",
}


def _file(files: Sequence[SourceFile], suffix: str) -> Optional[SourceFile]:
    for sf in files:
        if sf.rel.endswith(suffix):
            return sf
    return None


def _engine_fields(sf: SourceFile) -> Dict[str, int]:
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.ClassDef) and node.name == "EngineConfig":
            return {
                n.target.id: n.lineno
                for n in node.body
                if isinstance(n, ast.AnnAssign)
                and isinstance(n.target, ast.Name)
                and not n.target.id.startswith("_")
            }
    return {}


def _meta_keys(sf: SourceFile) -> Dict[str, int]:
    """Keys of the ``engine={...}`` dict literal inside engine_meta()."""
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.FunctionDef) and node.name == "engine_meta":
            for call in ast.walk(node):
                if not isinstance(call, ast.Call):
                    continue
                for kw in call.keywords:
                    if kw.arg == "engine" and isinstance(kw.value, ast.Dict):
                        return {
                            k.value: k.lineno
                            for k in kw.value.keys
                            if isinstance(k, ast.Constant)
                            and isinstance(k.value, str)
                        }
    return {}


def _dict_literal_keys(node: ast.AST) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for d in ast.walk(node):
        if isinstance(d, ast.Dict):
            for k in d.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    out.setdefault(k.value, k.lineno)
    return out


def _cli_surfaces(sf: SourceFile) -> Tuple[Dict[str, int], Dict[str, int]]:
    defaults: Dict[str, int] = {}
    knobs: Dict[str, int] = {}
    for node in sf.tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "DEFAULT_KNOBS":
                    defaults = _dict_literal_keys(node.value)
        elif isinstance(node, ast.FunctionDef) and \
                node.name == "cli_engine_knobs":
            knobs = _dict_literal_keys(node)
    return defaults, knobs


def _replay_keys(sf: SourceFile) -> Dict[str, int]:
    """String keys consumed by engine_config_from_meta()."""
    out: Dict[str, int] = {}
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.FunctionDef)
                and node.name == "engine_config_from_meta"):
            continue
        for sub in ast.walk(node):
            if isinstance(sub, ast.Subscript) and \
                    isinstance(sub.slice, ast.Constant) and \
                    isinstance(sub.slice.value, str):
                out.setdefault(sub.slice.value, sub.lineno)
            elif isinstance(sub, ast.Call) and \
                    isinstance(sub.func, ast.Attribute) and \
                    sub.func.attr in ("get", "setdefault", "pop") and \
                    sub.args and isinstance(sub.args[0], ast.Constant) and \
                    isinstance(sub.args[0].value, str):
                out.setdefault(sub.args[0].value, sub.lineno)
    return out


@register(RULE, __doc__ or "")
def check(files: Sequence[SourceFile]) -> List[Finding]:
    engine = _file(files, ENGINE_FILE)
    trace = _file(files, TRACE_FILE)
    serve = _file(files, SERVE_FILE)
    replay = _file(files, REPLAY_FILE)
    if engine is None:
        return []  # nothing to check outside the main tree
    fields = _engine_fields(engine)
    if not fields:
        return []

    findings: List[Finding] = []
    surfaces = []
    if trace is not None:
        surfaces.append(("TraceMeta engine dict (sim/trace.py "
                         "engine_meta)", _meta_keys(trace)))
    if serve is not None:
        defaults, knobs = _cli_surfaces(serve)
        surfaces.append(("serve.py DEFAULT_KNOBS", defaults))
        surfaces.append(("serve.py cli_engine_knobs", knobs))
        # The two CLI dicts must agree with each other.
        for k in sorted(set(defaults) ^ set(knobs)):
            where = "DEFAULT_KNOBS" if k in defaults else "cli_engine_knobs"
            line = defaults.get(k) or knobs.get(k)
            findings.append(Finding(
                RULE, serve.rel, line, f"cli-skew:{k}",
                f"knob '{k}' appears in {where} but not its counterpart; "
                "DEFAULT_KNOBS and cli_engine_knobs must stay in sync"))
    if replay is not None:
        surfaces.append(("replay/autotune consumption (sim/replay.py "
                         "engine_config_from_meta)", _replay_keys(replay)))

    # Forward: every EngineConfig field reaches every surface.
    known_flat: Set[str] = set()
    for field, lineno in sorted(fields.items()):
        flat = ALIASES.get(field, {field})
        known_flat |= flat
        if field in ALLOWLIST:
            continue
        for label, keys in surfaces:
            missing = sorted(flat - set(keys))
            if missing:
                findings.append(Finding(
                    RULE, engine.rel, lineno,
                    f"{field}:missing-from:{label.split(' ')[0]}",
                    f"EngineConfig.{field} (serialized as "
                    f"{', '.join(sorted(flat))}) is missing "
                    f"{', '.join(missing)} in {label}; a run configured "
                    "through that surface silently drops the knob — add "
                    "it or allowlist it with a justification"))

    # Reverse: no surface invents knobs EngineConfig doesn't have.
    allow_flat = set().union(*(ALIASES.get(f, {f}) for f in ALLOWLIST)) \
        if ALLOWLIST else set()
    for label, keys in surfaces:
        sf_for = {"TraceMeta": trace, "serve.py": serve}.get(
            label.split(" ")[0], replay)
        for k, line in sorted(keys.items()):
            if k not in known_flat and k not in allow_flat:
                findings.append(Finding(
                    RULE, (sf_for or engine).rel, line, f"orphan:{label.split(' ')[0]}:{k}",
                    f"{label} carries knob '{k}' that maps to no "
                    "EngineConfig field — dead serialization or a "
                    "missing ALIASES entry in repro/analysis/knobs.py"))
    return findings
