"""Rule framework for slicelint: findings, registry, suppressions, baseline.

Design notes
------------
* **Stdlib-only.**  The CI ``lint`` job runs this without jax/numpy, so
  nothing here (or in the rule modules) may import outside the standard
  library.  Rules inspect *source text* with :mod:`ast`; they never
  import the code under analysis.
* **Findings are stable.**  A finding's identity for baseline purposes
  is ``(rule, path, ident)`` where ``ident`` is a rule-chosen stable
  name (e.g. ``ClassName.attr`` or ``func:pattern``) — *not* the line
  number, which churns on unrelated edits.  Line numbers are reported
  for humans but do not participate in baseline matching.
* **Baseline freezes debt.**  ``--write-baseline`` records the current
  findings; later runs subtract baselined identities and fail only on
  *new* violations.  Stale baseline entries (entries that no longer
  match any finding) are reported so the baseline shrinks over time.
* **Inline suppressions.**  A line containing ``# slicelint: ignore[rule]``
  (or ``ignore[*]``) suppresses findings reported on that line.  Use
  sparingly, with a justification comment; prefer fixing or baselining.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence

SUPPRESS_RE = re.compile(r"#\s*slicelint:\s*ignore\[([\w*,\s-]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a specific site."""

    rule: str           # rule id, e.g. "purity"
    path: str           # repo-relative posix path
    line: int           # 1-based line (informational, not identity)
    ident: str          # stable identity within (rule, path)
    message: str        # human explanation: what + why it matters

    @property
    def key(self) -> str:
        """Baseline identity — deliberately line-number free."""
        return f"{self.rule}::{self.path}::{self.ident}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class SourceFile:
    """A parsed source file handed to every rule."""

    path: Path          # absolute
    rel: str            # repo-relative posix path
    text: str
    tree: ast.Module
    suppressions: Dict[int, set]  # line -> set of rule ids (or {"*"})

    @classmethod
    def load(cls, path: Path, root: Path) -> "SourceFile":
        text = path.read_text()
        tree = ast.parse(text, filename=str(path))
        sup: Dict[int, set] = {}
        for i, line in enumerate(text.splitlines(), start=1):
            m = SUPPRESS_RE.search(line)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                sup[i] = rules
        rel = path.relative_to(root).as_posix()
        return cls(path=path, rel=rel, text=text, tree=tree, suppressions=sup)

    def suppressed(self, rule: str, line: int) -> bool:
        rules = self.suppressions.get(line)
        return bool(rules) and (rule in rules or "*" in rules)


@dataclasses.dataclass
class Rule:
    """A registered rule: a checker over the whole file set.

    Rules see *all* files at once (``check(files)``) because two of the
    four shipped rules are cross-file (knob parity spans four modules).
    """

    id: str
    doc: str
    check: Callable[[Sequence[SourceFile]], List[Finding]]


_REGISTRY: Dict[str, Rule] = {}


def register(rule_id: str, doc: str):
    """Decorator registering ``check(files) -> [Finding]`` under ``rule_id``."""

    def deco(fn: Callable[[Sequence[SourceFile]], List[Finding]]) -> Rule:
        if rule_id in _REGISTRY:
            raise ValueError(f"duplicate rule id: {rule_id}")
        rule = Rule(id=rule_id, doc=doc, check=fn)
        _REGISTRY[rule_id] = rule
        return rule

    return deco


def all_rules() -> List[Rule]:
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


def get_rule(rule_id: str) -> Rule:
    try:
        return _REGISTRY[rule_id]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown rule {rule_id!r} (known: {known})") from None


class Baseline:
    """Committed ledger of frozen (pre-existing) findings.

    File format: JSON ``{"version": 1, "findings": {key: message}}``.
    The message is stored for human review only; matching is by key.
    """

    VERSION = 1

    def __init__(self, entries: Optional[Dict[str, str]] = None) -> None:
        self.entries: Dict[str, str] = dict(entries or {})

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        if not path.exists():
            return cls()
        data = json.loads(path.read_text())
        if data.get("version") != cls.VERSION:
            raise ValueError(
                f"{path}: unsupported baseline version {data.get('version')!r}"
            )
        return cls(data.get("findings", {}))

    def save(self, path: Path) -> None:
        data = {
            "version": self.VERSION,
            "findings": {k: self.entries[k] for k in sorted(self.entries)},
        }
        path.write_text(json.dumps(data, indent=2) + "\n")

    def split(self, findings: Sequence[Finding]):
        """Partition findings into (new, baselined); also return stale keys."""
        new: List[Finding] = []
        baselined: List[Finding] = []
        seen = set()
        for f in findings:
            seen.add(f.key)
            (baselined if f.key in self.entries else new).append(f)
        stale = sorted(set(self.entries) - seen)
        return new, baselined, stale


def collect_files(paths: Iterable[Path], root: Path) -> List[SourceFile]:
    """Expand files/dirs into parsed SourceFiles, sorted for determinism."""
    out: Dict[str, SourceFile] = {}
    for p in paths:
        p = p.resolve()
        candidates = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for c in candidates:
            if c.suffix != ".py":
                continue
            sf = SourceFile.load(c, root)
            out[sf.rel] = sf
    return [out[k] for k in sorted(out)]


def lint_paths(
    paths: Sequence[Path],
    root: Path,
    rules: Optional[Sequence[str]] = None,
) -> List[Finding]:
    """Run the selected rules over ``paths``; suppressions applied."""
    files = collect_files(paths, root)
    selected = [get_rule(r) for r in rules] if rules else all_rules()
    by_rel = {f.rel: f for f in files}
    findings: List[Finding] = []
    for rule in selected:
        for f in rule.check(files):
            sf = by_rel.get(f.path)
            if sf is not None and sf.suppressed(f.rule, f.line):
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.ident))
    return findings


# ---------------------------------------------------------------------------
# Shared AST helpers used by the rule modules.
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """Best-effort dotted name of a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def find_classes(tree: ast.Module) -> List[ast.ClassDef]:
    return [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]


def class_method(cls: ast.ClassDef, name: str) -> Optional[ast.FunctionDef]:
    for n in cls.body:
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and n.name == name:
            return n
    return None


def string_constants(node: ast.AST) -> set:
    """All string literals anywhere under ``node``."""
    return {
        n.value
        for n in ast.walk(node)
        if isinstance(n, ast.Constant) and isinstance(n.value, str)
    }
