"""slicelint: repo-specific static analysis for the SliceMoE charge path.

An AST-based rule framework plus four rules that prove, at lint time,
the invariants the dynamic test suites (golden traces, clone-isolation,
event conservation, knob round-trips) can only sample:

``purity``
    charge-path modules must not read wall clocks, unseeded RNG state,
    environment variables, or iterate unordered sets / ``id()`` keys on
    a decision path — replay fidelity requires charges to be pure
    functions of the trace.
``clone``
    every class defining ``clone()`` must fork each mutable attribute
    assigned in ``__init__``/``__post_init__``.
``ledger``
    every :class:`~repro.hw.energy.CostLedger` event method must pair a
    channel charge with a byte/op accumulator and an event counter, all
    covered by ``snapshot()``/``reset()``; call sites must use the known
    ledger API.
``knobs``
    every ``EngineConfig`` field must round-trip through ``TraceMeta``
    serialization, the ``serve.py`` CLI, and replay consumption, or be
    explicitly allowlisted.

Usage::

    python -m repro.analysis src/repro                  # lint
    python -m repro.analysis src/repro --write-baseline # freeze debt

The package is stdlib-only on purpose: the CI ``lint`` job runs it
without installing jax/numpy.
"""

from .core import (  # noqa: F401
    Baseline,
    Finding,
    Rule,
    all_rules,
    get_rule,
    lint_paths,
    register,
)

# Importing the rule modules registers them with the framework.
from . import purity, clones, ledger, knobs  # noqa: F401,E402

__all__ = [
    "Baseline",
    "Finding",
    "Rule",
    "all_rules",
    "get_rule",
    "lint_paths",
    "register",
]
