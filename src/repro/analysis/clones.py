"""Rule ``clone``: every ``clone()`` forks every mutable attribute.

The autotuner, the speculative controller probes, and the replay
fidelity gates all rely on ``clone()`` producing a fully isolated fork:
a single shared mutable attribute lets a probe run contaminate its
parent and breaks live ≡ replay (the bug class PR 7's clone-isolation
tests hunt at runtime, one instance at a time).

For each class defining ``clone()`` this rule cross-references the
mutable attributes assigned in ``__init__`` / ``__post_init__`` against
those handled in the clone body and flags misses.

A clone body "handles" everything when it deep-copies ``self``
(``copy.deepcopy(self)``).  Otherwise an attribute ``x`` counts as
handled when the clone body contains an attribute store ``<obj>.x = ...``,
reads ``self.x`` (fork-from patterns like ``new.x = self.x.clone()``),
or mentions ``"x"`` as a string literal (``setattr`` loops over literal
name tuples, as in ``ReplayEngine.clone``).

Only *known-mutable* initializers are demanded: container literals and
comprehensions, ``list/dict/set/bytearray/deque/OrderedDict/defaultdict
/Counter()`` calls, and numpy array constructors.  Attributes assigned
from parameters or arbitrary expressions are out of scope (they may be
immutable or intentionally shared).
"""

from __future__ import annotations

import ast
from typing import Dict, List, Sequence

from .core import (
    Finding,
    SourceFile,
    class_method,
    dotted_name,
    find_classes,
    register,
    string_constants,
)

RULE = "clone"

MUTABLE_CALLS = {
    "list", "dict", "set", "bytearray",
    "deque", "OrderedDict", "defaultdict", "Counter",
}
# numpy constructors returning fresh mutable arrays (leaf attribute name).
NP_ARRAY_CALLS = {
    "zeros", "ones", "full", "empty", "array", "arange", "copy",
    "zeros_like", "ones_like", "full_like", "empty_like",
}


def _is_mutable_value(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        name = dotted_name(node.func)
        if name is None:
            return False
        leaf = name.rsplit(".", 1)[-1]
        if leaf in MUTABLE_CALLS:
            return True
        if name.startswith(("np.", "numpy.")) and leaf in NP_ARRAY_CALLS:
            return True
    return False


def _init_mutable_attrs(cls: ast.ClassDef) -> Dict[str, int]:
    """Mutable ``self.x = ...`` assignments in __init__/__post_init__."""
    attrs: Dict[str, int] = {}
    for meth_name in ("__init__", "__post_init__"):
        meth = class_method(cls, meth_name)
        if meth is None:
            continue
        for node in ast.walk(meth):
            targets = []
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = [node.target], node.value
            else:
                continue
            if not _is_mutable_value(value):
                continue
            for t in targets:
                if isinstance(t, ast.Attribute) and \
                        isinstance(t.value, ast.Name) and \
                        t.value.id == "self":
                    attrs.setdefault(t.attr, node.lineno)
    return attrs


def _deepcopies_self(clone: ast.FunctionDef) -> bool:
    for node in ast.walk(clone):
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if name in ("copy.deepcopy", "deepcopy") and node.args and \
                    isinstance(node.args[0], ast.Name) and \
                    node.args[0].id == "self":
                return True
    return False


def _handled_attrs(clone: ast.FunctionDef) -> set:
    handled = set(string_constants(clone))
    for node in ast.walk(clone):
        if isinstance(node, ast.Attribute):
            if isinstance(node.ctx, ast.Store):
                handled.add(node.attr)
            elif isinstance(node.value, ast.Name) and \
                    node.value.id == "self":
                handled.add(node.attr)
    return handled


def _check_class(sf: SourceFile, cls: ast.ClassDef) -> List[Finding]:
    clone = class_method(cls, "clone")
    if clone is None:
        return []
    if _deepcopies_self(clone):
        return []
    mutable = _init_mutable_attrs(cls)
    if not mutable:
        return []
    handled = _handled_attrs(clone)
    findings = []
    for attr in sorted(set(mutable) - handled):
        findings.append(Finding(
            RULE, sf.rel, mutable[attr], f"{cls.name}.{attr}",
            f"{cls.name}.__init__ assigns mutable attribute "
            f"'{attr}' but {cls.name}.clone() never forks it; a "
            "clone sharing it corrupts its parent on first mutation "
            "(copy it in clone(), or deepcopy self)"))
    return findings


@register(RULE, __doc__ or "")
def check(files: Sequence[SourceFile]) -> List[Finding]:
    out: List[Finding] = []
    for sf in files:
        for cls in find_classes(sf.tree):
            out.extend(_check_class(sf, cls))
    return out
