"""Rule ``ledger``: CostLedger channel discipline, statically.

``tests/test_obs.py::test_event_conservation`` checks *empirically* that
every traced event reconciles with the ledger's counters.  This rule
proves the structural half at lint time:

* **Event methods pair their counters.**  Every :class:`CostLedger`
  method that issues a channel span (calls ``<channel>.issue(...)``)
  must — directly or via the methods it calls — increment at least one
  event counter (``n_*``) and at least one traffic accumulator
  (``*_bytes`` / ``*_ops``).  A charge without a counter is invisible
  to event conservation and to the controller's sliding windows.
* **Snapshot/reset cover every counter.**  Every counter/accumulator
  field declared on ``CostLedger`` (``n_*``, ``*_bytes``, ``*_ops``,
  ``*_energy_j``) must appear as a key in ``snapshot()`` and be zeroed
  in ``reset()`` — otherwise ``delta_since`` windows silently miss it.
* **Call sites use the known channel API.**  Any ``*_at`` / serialized
  charge call on a ledger-ish receiver (name mentions ``led``/``ledger``)
  must be a method actually defined on ``CostLedger`` or
  ``ShardedCostLedger`` — catching drift when a charge method is renamed
  but a call site (e.g. in an engine branch rarely exercised) is not.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set

from .core import Finding, SourceFile, class_method, register, string_constants

RULE = "ledger"

LEDGER_FILE_SUFFIX = "hw/energy.py"
LEDGER_CLASSES = ("CostLedger", "ShardedCostLedger")

SERIALIZED_CHARGES = {
    "miss_fill", "flash_stream", "dram_read", "matmul",
    "ici_transfer", "migrate", "mark_prefetch_wasted",
}


def _is_counter(name: str) -> bool:
    return name.startswith("n_")


def _is_accumulator(name: str) -> bool:
    return name.endswith(("_bytes", "_ops"))


def _is_tracked_field(name: str) -> bool:
    return _is_counter(name) or _is_accumulator(name) \
        or name.endswith("_energy_j")


def _find_class(files: Sequence[SourceFile], name: str):
    for sf in files:
        if sf.rel.endswith(LEDGER_FILE_SUFFIX):
            for node in sf.tree.body:
                if isinstance(node, ast.ClassDef) and node.name == name:
                    return sf, node
    return None, None


def _method_map(cls: ast.ClassDef) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _direct_issues(meth: ast.FunctionDef) -> bool:
    for node in ast.walk(meth):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "issue":
            return True
    return False


def _direct_increments(meth: ast.FunctionDef) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(meth):
        if isinstance(node, ast.AugAssign) and \
                isinstance(node.target, ast.Attribute) and \
                isinstance(node.target.value, ast.Name) and \
                node.target.value.id == "self":
            out.add(node.target.attr)
    return out


def _self_calls(meth: ast.FunctionDef, methods: Dict) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(meth):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                isinstance(node.func.value, ast.Name) and \
                node.func.value.id == "self" and \
                node.func.attr in methods:
            out.add(node.func.attr)
    return out


def _effective_increments(name: str, methods: Dict,
                          memo: Dict[str, Set[str]],
                          stack: Optional[Set[str]] = None) -> Set[str]:
    if name in memo:
        return memo[name]
    stack = stack or set()
    if name in stack:
        return set()
    stack = stack | {name}
    eff = set(_direct_increments(methods[name]))
    for callee in _self_calls(methods[name], methods):
        eff |= _effective_increments(callee, methods, memo, stack)
    memo[name] = eff
    return eff


def _reset_fields(meth: ast.FunctionDef) -> Set[str]:
    """Fields zeroed in reset(): plain self.x = targets plus any string
    literal (setattr loops over literal field-name tuples)."""
    out = set(string_constants(meth))
    for node in ast.walk(meth):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Attribute) and \
                        isinstance(t.value, ast.Name) and t.value.id == "self":
                    out.add(t.attr)
    return out


def _check_definition(sf: SourceFile, cls: ast.ClassDef) -> List[Finding]:
    findings: List[Finding] = []
    methods = _method_map(cls)
    memo: Dict[str, Set[str]] = {}

    # 1. Every direct channel-issuing method pairs counter + accumulator.
    for name, meth in methods.items():
        if not _direct_issues(meth):
            continue
        eff = _effective_increments(name, methods, memo)
        if not any(_is_counter(f) for f in eff):
            findings.append(Finding(
                RULE, sf.rel, meth.lineno, f"{cls.name}.{name}:no-counter",
                f"{cls.name}.{name} issues a channel event but never "
                "increments an n_* event counter; the charge is invisible "
                "to event conservation and delta windows"))
        if not any(_is_accumulator(f) for f in eff):
            findings.append(Finding(
                RULE, sf.rel, meth.lineno,
                f"{cls.name}.{name}:no-accumulator",
                f"{cls.name}.{name} issues a channel event but never "
                "adds to a *_bytes/*_ops traffic accumulator"))

    # 2. snapshot()/reset() cover every tracked field.
    fields = {
        (n.target.id, n.lineno)
        for n in cls.body
        if isinstance(n, ast.AnnAssign) and isinstance(n.target, ast.Name)
        and _is_tracked_field(n.target.id)
    }
    snap = class_method(cls, "snapshot")
    reset = class_method(cls, "reset")
    snap_keys = string_constants(snap) if snap else set()
    reset_keys = _reset_fields(reset) if reset else set()
    for fname, lineno in sorted(fields):
        if snap is not None and fname not in snap_keys:
            findings.append(Finding(
                RULE, sf.rel, lineno, f"{cls.name}.{fname}:not-in-snapshot",
                f"{cls.name} counter field '{fname}' is missing from "
                "snapshot(); delta_since windows will never see it"))
        if reset is not None and fname not in reset_keys:
            findings.append(Finding(
                RULE, sf.rel, lineno, f"{cls.name}.{fname}:not-in-reset",
                f"{cls.name} counter field '{fname}' is not zeroed in "
                "reset(); it leaks across epochs"))
    return findings


def _ledger_api(files: Sequence[SourceFile]) -> Set[str]:
    api: Set[str] = set()
    for cname in LEDGER_CLASSES:
        _, cls = _find_class(files, cname)
        if cls is not None:
            api |= set(_method_map(cls))
    return api


def _looks_ledgerish(recv: ast.AST) -> bool:
    try:
        text = ast.unparse(recv)
    except Exception:  # pragma: no cover - unparse failure
        return False
    return "led" in text.lower()


def _check_call_sites(files: Sequence[SourceFile],
                      api: Set[str]) -> List[Finding]:
    findings: List[Finding] = []
    charge_like = SERIALIZED_CHARGES
    for sf in files:
        if sf.rel.endswith(LEDGER_FILE_SUFFIX):
            continue  # definitions, checked above
        for node in ast.walk(sf.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)):
                continue
            meth = node.func.attr
            if not (meth.endswith("_at") or meth in charge_like):
                continue
            if not _looks_ledgerish(node.func.value):
                continue
            if meth not in api:
                recv = ast.unparse(node.func.value)[:40]
                findings.append(Finding(
                    RULE, sf.rel, node.lineno, f"call:{recv}.{meth}",
                    f"call site {recv}.{meth}(...) does not match any "
                    "method on CostLedger/ShardedCostLedger — unknown "
                    "charge channel (renamed API? typo?)"))
    return findings


@register(RULE, __doc__ or "")
def check(files: Sequence[SourceFile]) -> List[Finding]:
    findings: List[Finding] = []
    api = _ledger_api(files)
    for cname in LEDGER_CLASSES:
        sf, cls = _find_class(files, cname)
        if cls is not None:
            findings.extend(_check_definition(sf, cls))
    if api:  # only meaningful when the definitions are in the file set
        findings.extend(_check_call_sites(files, api))
    return findings
