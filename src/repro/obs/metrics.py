"""Low-overhead metrics registry: counters, gauges, histograms.

One :class:`MetricsRegistry` per serving run.  Instruments are created
lazily (``registry.counter("tokens_total")``) and cached by name +
labels, so the hot path is attribute arithmetic on a resolved object —
no dict lookups per event once the caller holds the instrument.

Two export formats, both schema-stable:

* **JSONL time series** — :meth:`MetricsRegistry.sample` appends one
  flat row (every scalar instrument, histograms as ``_count``/``_sum``)
  per decode step; :meth:`MetricsRegistry.to_jsonl` writes the series.
* **Prometheus text exposition** — :meth:`MetricsRegistry.prometheus_text`
  renders the current values with ``# HELP`` / ``# TYPE`` headers and
  cumulative histogram buckets, scrape-ready.

:class:`MetricsSampler` is the serving-stack glue: a
:class:`~repro.serving.telemetry.FleetTelemetry` listener that folds
each :class:`~repro.serving.telemetry.StepRecord` into the registry and
samples engine-side state (cache occupancy, ledger traffic, prefetch
outcomes, controller actuation, shard balance) per decode step —
replacing ad-hoc per-consumer snapshot plumbing with one catalog (see
docs/observability.md).
"""

from __future__ import annotations

import json
import math
from typing import Dict, List, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "MetricsSampler", "DEFAULT_BUCKETS"]

#: Default histogram buckets (seconds-flavored, log-ish spacing).
DEFAULT_BUCKETS = (1e-6, 2.5e-6, 5e-6, 1e-5, 2.5e-5, 5e-5,
                   1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
                   1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 0.5, 1.0)


def _label_key(name: str, labels: Dict[str, str]) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically non-decreasing accumulator."""

    __slots__ = ("name", "labels", "value")
    kind = "counter"

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name}: negative inc {v}")
        self.value += v

    def set_to(self, v: float) -> None:
        """Monotonic set from a cumulative upstream total (e.g. a ledger
        accumulator) — refuses to go backwards."""
        if v < self.value:
            raise ValueError(
                f"counter {self.name}: set_to({v}) < current {self.value}")
        self.value = v


class Gauge:
    """Point-in-time value (may move in either direction)."""

    __slots__ = ("name", "labels", "value")
    kind = "gauge"

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Histogram:
    """Fixed-bucket histogram with ``sum``/``count`` (Prometheus model)."""

    __slots__ = ("name", "labels", "buckets", "counts", "sum", "count")
    kind = "histogram"

    def __init__(self, name: str, labels: Dict[str, str],
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.labels = labels
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * len(self.buckets)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        if math.isnan(v):
            return
        self.sum += v
        self.count += 1
        for i, le in enumerate(self.buckets):
            if v <= le:
                self.counts[i] += 1
                break

    def cumulative(self) -> List[Tuple[float, int]]:
        """``(le, cumulative_count)`` rows, exposition-ready."""
        out, acc = [], 0
        for le, c in zip(self.buckets, self.counts):
            acc += c
            out.append((le, acc))
        return out


class MetricsRegistry:
    """Name → instrument registry with a sampled JSONL time series."""

    def __init__(self) -> None:
        self._metrics: Dict[str, object] = {}
        self._families: Dict[str, str] = {}   # family name -> kind
        self._help: Dict[str, str] = {}
        self.series: List[dict] = []

    # ------------------------------------------------------------ create
    def _get(self, cls, name: str, help: str, labels: Dict[str, str],
             **kw):
        key = _label_key(name, labels)
        inst = self._metrics.get(key)
        if inst is None:
            kind = self._families.setdefault(name, cls.kind)
            if kind != cls.kind:
                raise TypeError(
                    f"metric {name!r} already registered as {kind}")
            if help:
                self._help.setdefault(name, help)
            inst = cls(name, labels, **kw)
            self._metrics[key] = inst
        elif not isinstance(inst, cls):
            raise TypeError(f"metric {key!r} is a {inst.kind}, "
                            f"not a {cls.kind}")
        return inst

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    # ------------------------------------------------------------ export
    def snapshot(self) -> dict:
        """Flat ``{key: value}`` view of every instrument right now
        (histograms contribute ``_count`` and ``_sum``)."""
        out = {}
        for key, m in self._metrics.items():
            if isinstance(m, Histogram):
                out[key + "_count"] = m.count
                out[key + "_sum"] = m.sum
            else:
                out[key] = m.value
        return out

    def sample(self, *, t: float, step: int) -> dict:
        """Append (and return) one time-series row at sim-time ``t``."""
        row = {"t": t, "step": step}
        row.update(self.snapshot())
        self.series.append(row)
        return row

    def to_jsonl(self, path: str) -> int:
        """Write the sampled series, one JSON object per line; returns
        the number of rows written."""
        with open(path, "w") as fh:
            for row in self.series:
                fh.write(json.dumps(row, sort_keys=True))
                fh.write("\n")
        return len(self.series)

    def prometheus_text(self) -> str:
        """Current values in the Prometheus text exposition format."""
        by_family: Dict[str, List[object]] = {}
        for m in self._metrics.values():
            by_family.setdefault(m.name, []).append(m)
        lines: List[str] = []
        for name in sorted(by_family):
            help_ = self._help.get(name, "")
            if help_:
                lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} {self._families[name]}")
            for m in sorted(by_family[name],
                            key=lambda m: sorted(m.labels.items())):
                if isinstance(m, Histogram):
                    for le, acc in m.cumulative():
                        lab = dict(m.labels, le=repr(le))
                        lines.append(f"{_label_key(name + '_bucket', lab)}"
                                     f" {acc}")
                    lab = dict(m.labels, le="+Inf")
                    lines.append(
                        f"{_label_key(name + '_bucket', lab)} {m.count}")
                    lines.append(f"{_label_key(name + '_sum', m.labels)}"
                                 f" {m.sum}")
                    lines.append(f"{_label_key(name + '_count', m.labels)}"
                                 f" {m.count}")
                else:
                    lines.append(f"{_label_key(name, m.labels)} {m.value}")
        return "\n".join(lines) + "\n"


# --------------------------------------------------------------------------
# Serving-stack sampler
# --------------------------------------------------------------------------
class MetricsSampler:
    """FleetTelemetry listener that feeds a :class:`MetricsRegistry`.

    Registered via ``scheduler.attach_metrics(registry)``; per decode
    step it folds the :class:`StepRecord` into counters/histograms,
    reads cumulative engine-side state (ledger traffic via monotonic
    ``set_to``, cache occupancy, prefetch outcomes, controller
    actuation, shard balance) and appends one time-series row.
    """

    def __init__(self, registry: MetricsRegistry, engine=None):
        self.registry = registry
        self.engine = engine
        self._steps = 0
        # Last-seen values of upstream windows that may reset (the
        # cache stats window is wiped at request boundaries).
        self._prev: Dict[str, float] = {}
        r = registry
        self._c_steps = r.counter(
            "decode_steps_total", "decode steps executed")
        self._c_tokens = r.counter(
            "tokens_total", "tokens generated across the fleet")
        self._c_requests = r.counter(
            "requests_submitted_total", "requests submitted")
        self._c_first = r.counter(
            "requests_first_token_total", "requests that produced a token")
        self._c_energy = r.counter(
            "energy_joules_total", "modeled energy spent")
        self._c_latency = r.counter(
            "sim_latency_seconds_total", "simulated decode time spent")
        self._c_stall = r.counter(
            "io_stall_seconds_total", "compute idle time waiting on data")
        self._c_overlap = r.counter(
            "overlap_saved_seconds_total", "latency hidden by overlap")
        self._g_miss = r.gauge(
            "step_miss_rate", "cache miss rate of the last decode step")
        self._g_active = r.gauge(
            "batch_occupancy", "active sequences in the last decode step")
        self._h_step = r.histogram(
            "step_latency_seconds", "simulated decode-step latency")
        self._h_ttft = r.histogram(
            "ttft_seconds", "time to first token")

    # --------------------------------------------- telemetry callbacks
    def on_submit(self, record) -> None:
        self._c_requests.inc()

    def on_first_token(self, record) -> None:
        self._c_first.inc()
        self._h_ttft.observe(record.ttft)

    def on_step(self, step) -> None:
        r = self.registry
        self._steps += 1
        self._c_steps.inc()
        self._c_tokens.inc(step.n_active)
        self._c_energy.inc(max(0.0, step.energy_j))
        self._c_latency.inc(max(0.0, step.latency_s))
        self._c_stall.inc(max(0.0, step.io_stall_s))
        self._c_overlap.inc(max(0.0, step.overlap_saved_s))
        self._g_miss.set(step.miss_rate)
        self._g_active.set(step.n_active)
        self._h_step.observe(step.latency_s)
        for tenant, row in (step.per_tenant or {}).items():
            r.counter("tenant_tokens_total", "tokens per tenant",
                      tenant=tenant).inc(row.get("tokens", 0))
            r.gauge("tenant_step_miss_rate", "per-tenant step miss rate",
                    tenant=tenant).set(
                        row.get("misses", 0)
                        / max(row.get("accesses", 0), 1))
        if self.engine is not None:
            self._sample_engine(r)
        r.sample(t=step.t, step=self._steps - 1)

    # --------------------------------------------- engine-side sampling
    def _fold_window(self, counter: Counter, key: str, cur: float) -> None:
        """Accumulate an upstream counter that may reset to 0 between
        samples (Prometheus counter-reset semantics): on a drop, the
        current value counts from the reset, not from our last sample."""
        prev = self._prev.get(key, 0.0)
        counter.inc(cur - prev if cur >= prev else cur)
        self._prev[key] = cur

    def _sample_engine(self, r: MetricsRegistry) -> None:
        eng = self.engine
        cache = eng.cache
        u = cache.usage()
        r.gauge("cache_capacity_bytes",
                "slice-cache capacity").set(u["capacity_bytes"])
        r.gauge("cache_used_bytes",
                "resident slice bytes").set(u["used_bytes"])
        r.gauge("cache_resident_slices",
                "resident slice count").set(u["n_slices"])
        r.gauge("cache_occupancy",
                "used/capacity byte fraction").set(u["occupancy"])
        # usage() folds archived epochs in, but the serving engine also
        # hard-resets the open stats window at each prefill->decode
        # transition — fold deltas with counter-reset semantics.
        self._fold_window(r.counter("cache_accesses_total",
                                    "slice-cache accesses"),
                          "cache_accesses", u["accesses"])
        self._fold_window(r.counter("cache_misses_total",
                                    "slice-cache misses"),
                          "cache_misses", u["misses"])
        seg = getattr(cache, "segment_summary", None)
        if callable(seg):
            for tenant, row in seg().items():
                r.gauge("tenant_resident_bytes",
                        "resident bytes per tenant partition",
                        tenant=tenant).set(row["used_bytes"])
        per_shard = getattr(cache, "per_shard_counts", None)
        if callable(per_shard):
            counts = per_shard()
            accs = [a for a, _m in counts]
            if accs and max(accs) > 0:
                mean = sum(accs) / len(accs)
                r.gauge("shard_imbalance",
                        "max/mean shard access ratio").set(
                            max(accs) / mean if mean else 0.0)
        led = eng.ledger.snapshot()
        for key, name in (("flash_bytes", "flash_bytes_total"),
                          ("dram_bytes", "dram_bytes_total"),
                          ("ici_bytes", "ici_bytes_total"),
                          ("migration_bytes", "migration_bytes_total"),
                          ("prefetch_flash_bytes",
                           "prefetch_flash_bytes_total")):
            r.counter(name, f"ledger {key}").set_to(led[key])
        pf = getattr(eng, "prefetcher", None)
        if pf is not None:
            s = pf.summary()
            for key in ("issued", "useful", "late", "wasted"):
                r.counter(f"prefetch_{key}_total",
                          "prefetch outcome").set_to(s[key])
        ctl = getattr(eng, "slo_controller", None)
        if ctl is not None:
            r.counter("controller_actions_total",
                      "controller actuations").set_to(len(ctl.actions))
            for tenant, frac in ctl.admit_fracs.items():
                r.gauge("tenant_admit_frac", "admission fraction",
                        tenant=tenant).set(frac)
            for tenant, lvl in ctl.levels.items():
                r.gauge("tenant_bit_level", "controller bit level",
                        tenant=tenant).set(lvl)
            r.gauge("low_bit_fraction",
                    "fraction of tenants demoted below full bits").set(
                        ctl.low_bit_fraction())
