"""Per-channel stall/overlap/waste analysis of an exported trace.

Operates on the Chrome-trace JSON produced by
:func:`repro.obs.timeline.chrome_trace` (stdlib-only: the CLI in
``scripts/trace_report.py`` is a thin wrapper), so a trace exported
from any run — live, replay, CI artifact — can be summarized without
the engine that produced it.

Per ``(process, thread)`` channel track it reports busy time, idle
time inside the track's own active window, utilization against the
overall makespan, bytes moved and event count; per process it reports
the overlap saved (sum of channel busy time minus the process
makespan — what a fully serialized replay would have added).  The
speculative prefetch lane (``flash_bg``) is summarized separately as
*waste-at-risk*: bytes moved on spec that demand traffic never had to
wait for.
"""

from __future__ import annotations

import json
from typing import Dict, List


def _tracks(data: dict) -> Dict[tuple, dict]:
    """Group complete events by (pid, tid); resolve metadata names."""
    pnames: Dict[int, str] = {}
    tnames: Dict[tuple, str] = {}
    tracks: Dict[tuple, dict] = {}
    for ev in data.get("traceEvents", []):
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") == "process_name":
                pnames[ev["pid"]] = ev["args"]["name"]
            elif ev.get("name") == "thread_name":
                tnames[(ev["pid"], ev.get("tid", 0))] = ev["args"]["name"]
            continue
        if ph != "X":
            continue
        key = (ev["pid"], ev.get("tid", 0))
        tr = tracks.setdefault(key, {
            "events": 0, "busy_us": 0.0, "bytes": 0.0, "ops": 0.0,
            "first_us": float("inf"), "last_us": 0.0,
        })
        ts, dur = ev["ts"], ev.get("dur", 0.0)
        tr["events"] += 1
        tr["busy_us"] += dur
        tr["first_us"] = min(tr["first_us"], ts)
        tr["last_us"] = max(tr["last_us"], ts + dur)
        args = ev.get("args", {})
        tr["bytes"] += args.get("nbytes", 0.0)
        tr["ops"] += args.get("ops", 0.0)
    for key, tr in tracks.items():
        tr["process"] = pnames.get(key[0], f"pid {key[0]}")
        tr["channel"] = tnames.get(key, f"tid {key[1]}")
    return tracks


def trace_report(data: dict) -> dict:
    """Summarize an exported Chrome trace.

    Returns ``{"makespan_us", "channels": [...], "processes": [...]}``
    where each channel row carries busy/idle/utilization/bytes and each
    process row the overlap saved across its channels.
    """
    tracks = _tracks(data)
    hw = {k: t for k, t in tracks.items() if t["process"] != "requests"}
    makespan = max((t["last_us"] for k, t in hw.items()
                    if t["channel"] != "flash_bg"), default=0.0)
    channels: List[dict] = []
    for (pid, tid), t in sorted(hw.items()):
        window = t["last_us"] - min(t["first_us"], t["last_us"])
        channels.append({
            "process": t["process"], "channel": t["channel"],
            "events": t["events"], "busy_us": t["busy_us"],
            "bytes": t["bytes"], "ops": t["ops"],
            "stall_us": max(0.0, window - t["busy_us"]),
            "util_vs_makespan": (t["busy_us"] / makespan
                                 if makespan else 0.0),
        })
    processes: List[dict] = []
    by_proc: Dict[str, List[dict]] = {}
    for (pid, tid), t in hw.items():
        by_proc.setdefault(t["process"], []).append(t)
    for proc in sorted(by_proc):
        rows = [t for t in by_proc[proc] if t["channel"] != "flash_bg"]
        spec = [t for t in by_proc[proc] if t["channel"] == "flash_bg"]
        serial = sum(t["busy_us"] for t in rows)
        span = max((t["last_us"] for t in rows), default=0.0)
        processes.append({
            "process": proc,
            "serial_us": serial,
            "makespan_us": span,
            "overlap_saved_us": max(0.0, serial - span),
            "speculative_bytes": sum(t["bytes"] for t in spec),
            "speculative_events": sum(t["events"] for t in spec),
        })
    return {"makespan_us": makespan, "channels": channels,
            "processes": processes}


def format_trace_report(rep: dict) -> str:
    """Human-readable table of a :func:`trace_report` result."""
    lines = [f"makespan: {rep['makespan_us']:.1f} us", "",
             f"{'process':<14}{'channel':<10}{'events':>8}"
             f"{'busy_us':>12}{'stall_us':>12}{'util':>8}"
             f"{'bytes':>14}"]
    for row in rep["channels"]:
        lines.append(
            f"{row['process']:<14}{row['channel']:<10}"
            f"{row['events']:>8}{row['busy_us']:>12.1f}"
            f"{row['stall_us']:>12.1f}{row['util_vs_makespan']:>8.1%}"
            f"{row['bytes']:>14.0f}")
    lines.append("")
    lines.append(f"{'process':<14}{'serial_us':>12}{'makespan_us':>14}"
                 f"{'overlap_us':>12}{'spec_bytes':>12}")
    for row in rep["processes"]:
        lines.append(
            f"{row['process']:<14}{row['serial_us']:>12.1f}"
            f"{row['makespan_us']:>14.1f}"
            f"{row['overlap_saved_us']:>12.1f}"
            f"{row['speculative_bytes']:>12.0f}")
    return "\n".join(lines)


def load_trace(path: str) -> dict:
    with open(path) as fh:
        return json.load(fh)
