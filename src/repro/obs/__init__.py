"""Observability for the SliceMoE serving stack.

* :mod:`repro.obs.timeline` — charge-path event tracing and
  Chrome-trace/Perfetto export (attach with
  ``engine.attach_tracer(TimelineTracer())``);
* :mod:`repro.obs.metrics` — counter/gauge/histogram registry with
  JSONL time series + Prometheus text exposition, sampled per decode
  step via ``scheduler.attach_metrics(MetricsRegistry())``;
* :mod:`repro.obs.report` — stall/overlap/waste analysis of an
  exported trace (CLI: ``scripts/trace_report.py``).

See docs/observability.md for the trace schema, span model and
metrics catalog.
"""

from repro.obs.timeline import (TimelineTracer, TraceEvent, chrome_trace,
                                events_equal, export_chrome_trace,
                                first_divergence)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               MetricsSampler)
from repro.obs.report import format_trace_report, load_trace, trace_report

__all__ = [
    "TimelineTracer", "TraceEvent", "chrome_trace", "export_chrome_trace",
    "events_equal", "first_divergence",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "MetricsSampler",
    "trace_report", "format_trace_report", "load_trace",
]
