"""Timeline tracing: per-channel event capture + Chrome-trace export.

The cost model is an event timeline (:mod:`repro.hw.energy`) — every
fill / dram_read / matmul / prefetch_fill / a2a / migrate charge issues
a ``(start, end)`` span on one hardware channel.  A
:class:`TimelineTracer` attached to the ledger captures exactly one
:class:`TraceEvent` per charge, stamped with the attribution context
the engine maintains while charging (layer, expert, slice kind, bits,
phase, decode-step index).  Because the tracer hangs off the shared
charge path, a record→replay run of the same trace emits an identical
event stream — live≡replay observability is by construction, not by a
second implementation.

The capture is export-agnostic; :func:`chrome_trace` renders the event
list (plus scheduler-emitted request spans) as Chrome-trace JSON that
loads directly in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``.  See docs/observability.md for the schema.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Optional, Tuple

#: Stable thread-id per hardware channel inside a shard's process track.
CHANNEL_TIDS = {"flash": 0, "flash_bg": 1, "dram": 2, "compute": 3, "ici": 4}

#: Synthetic pids for the non-shard tracks in the Chrome export.
INTERCONNECT_PID = 900     # shared ici sub-ledger (shard id < 0)
REQUESTS_PID = 1000        # scheduler-emitted request / step spans

#: Event kinds a ledger can emit (the trace schema's closed vocabulary).
EVENT_KINDS = ("fill", "prefetch_fill", "dram_read", "matmul", "a2a",
               "migrate")


@dataclasses.dataclass
class TraceEvent:
    """One charge on one hardware channel.

    ``kind`` is one of :data:`EVENT_KINDS`; ``channel`` names the
    :class:`~repro.hw.energy.ChannelTimeline` the span occupies
    (``flash``/``flash_bg``/``dram``/``compute``/``ici``); ``shard`` is
    the owning shard's index (``-1`` for the shared interconnect
    sub-ledger).  ``layer``/``expert``/``slice_kind``/``bits`` carry the
    attribution the engine set when it issued the charge (``-1``/empty
    for unattributed traffic such as the shared resident-weight
    stream); ``phase`` is ``prefill`` or ``decode`` and ``step`` the
    decode-step index (``-1`` before the first decode step).
    """

    kind: str
    channel: str
    shard: int
    start: float
    end: float
    nbytes: float = 0.0
    ops: float = 0.0
    bits: int = 0
    layer: int = -1
    expert: int = -1
    slice_kind: str = ""
    phase: str = ""
    step: int = -1

    def key(self) -> tuple:
        """Total-order comparison key (used by the equivalence gate)."""
        return (self.kind, self.channel, self.shard, self.start, self.end,
                self.nbytes, self.ops, self.bits, self.layer, self.expert,
                self.slice_kind, self.phase, self.step)


class TimelineTracer:
    """Event sink + attribution context for one engine's ledger(s).

    The ledger calls :meth:`emit` once per charge; the engine moves the
    attribution context (:meth:`begin_step` / :meth:`begin_prefill` /
    :meth:`set_attr`) as it walks layers and experts, so every emitted
    event is stamped with what the charge was *for*.  The scheduler adds
    request-lifecycle spans via :meth:`span`.  Overhead when no tracer
    is attached is a single ``is None`` test per charge.
    """

    def __init__(self) -> None:
        self.events: List[TraceEvent] = []
        self.spans: List[dict] = []
        # mutable attribution context (engine-owned)
        self.phase = ""
        self.step = -1
        self.layer = -1
        self.expert = -1
        self.slice_kind = ""
        self.bits = 0

    # ------------------------------------------------------------ context
    def begin_step(self) -> int:
        """Enter the next decode step: bump the step index and clear the
        per-expert attribution.  Returns the new step index."""
        self.step += 1
        self.phase = "decode"
        self.layer = -1
        self.expert = -1
        self.slice_kind = ""
        self.bits = 0
        return self.step

    def begin_prefill(self) -> None:
        """Enter a prefill charge (attribution cleared, step unchanged)."""
        self.phase = "prefill"
        self.layer = -1
        self.expert = -1
        self.slice_kind = ""
        self.bits = 0

    def set_attr(self, layer: int = -1, expert: int = -1,
                 slice_kind: str = "", bits: int = 0) -> None:
        """Point the context at what is being charged next."""
        self.layer = layer
        self.expert = expert
        self.slice_kind = slice_kind
        self.bits = bits

    # ------------------------------------------------------------ capture
    def emit(self, kind: str, channel: str, shard: int,
             start: float, end: float, *, nbytes: float = 0.0,
             ops: float = 0.0, bits: Optional[int] = None) -> None:
        """Record one charge (called by the ledger, context pre-set)."""
        self.events.append(TraceEvent(
            kind, channel, shard, start, end, nbytes, ops,
            self.bits if bits is None else bits,
            self.layer, self.expert, self.slice_kind,
            self.phase, self.step))

    def span(self, name: str, track: str, start: float, end: float,
             **args) -> None:
        """Record one scheduler-level span (queue/prefill/decode/step)
        on a named track of the ``requests`` process."""
        self.spans.append({"name": name, "track": track,
                           "start": float(start), "end": float(end),
                           "args": dict(args)})

    def clear(self) -> None:
        self.events.clear()
        self.spans.clear()
        self.phase = ""
        self.step = -1
        self.layer = -1
        self.expert = -1
        self.slice_kind = ""
        self.bits = 0

    # ------------------------------------------------------------ queries
    def channel_makespans(self) -> Dict[Tuple[int, str], float]:
        """Latest event end per ``(shard, channel)`` — must equal that
        channel's ``busy_until`` clock (the makespan gate)."""
        out: Dict[Tuple[int, str], float] = {}
        for e in self.events:
            k = (e.shard, e.channel)
            if e.end > out.get(k, 0.0):
                out[k] = e.end
        return out

    def makespan(self) -> float:
        """Overall makespan over the demand channels (the background
        prefetch lane is excluded, mirroring ``CostLedger.now``)."""
        return max((e.end for e in self.events
                    if e.channel != "flash_bg"), default=0.0)


def events_equal(a: Iterable[TraceEvent], b: Iterable[TraceEvent]) -> bool:
    """Exact event-stream equality (the live≡replay gate)."""
    ka = [e.key() for e in a]
    kb = [e.key() for e in b]
    return ka == kb


def first_divergence(a: List[TraceEvent],
                     b: List[TraceEvent]) -> Optional[int]:
    """Index of the first differing event, or ``None`` if identical
    (length mismatch reports the shorter length)."""
    n = min(len(a), len(b))
    for i in range(n):
        if a[i].key() != b[i].key():
            return i
    if len(a) != len(b):
        return n
    return None


# ---------------------------------------------------------------- export
def _event_name(e: TraceEvent) -> str:
    who = "shared" if e.layer < 0 else (
        f"L{e.layer}" if e.expert < 0 else f"L{e.layer}.E{e.expert}")
    if e.slice_kind:
        who += f".{e.slice_kind}"
    if e.kind == "matmul":
        return f"matmul {e.bits}b {who}"
    return f"{e.kind} {who}"


def _event_pid(e: TraceEvent) -> int:
    return INTERCONNECT_PID if e.shard < 0 else e.shard


def chrome_trace(tracer: TimelineTracer) -> dict:
    """Render the captured events + spans as a Chrome-trace JSON dict.

    Layout: one process per shard (threads = hardware channels, the
    background prefetch lane on its own ``flash_bg`` thread so it is
    visually distinct from demand fills), one process for the shared
    interconnect, and one ``requests`` process whose threads are the
    scheduler's span tracks.  Timestamps are microseconds (Chrome-trace
    convention); all events are complete (``ph: "X"``) spans.
    """
    trace_events: List[dict] = []
    pids_seen: Dict[int, str] = {}
    tids_seen: Dict[Tuple[int, int], str] = {}

    for e in tracer.events:
        pid = _event_pid(e)
        tid = CHANNEL_TIDS[e.channel]
        pids_seen.setdefault(
            pid, "interconnect" if e.shard < 0 else f"shard {e.shard}")
        tids_seen.setdefault((pid, tid), e.channel)
        args = {"phase": e.phase, "step": e.step, "shard": e.shard}
        if e.nbytes:
            args["nbytes"] = e.nbytes
        if e.ops:
            args["ops"] = e.ops
        if e.bits:
            args["bits"] = e.bits
        if e.layer >= 0:
            args["layer"] = e.layer
        if e.expert >= 0:
            args["expert"] = e.expert
        if e.slice_kind:
            args["slice"] = e.slice_kind
        trace_events.append({
            "name": _event_name(e), "cat": e.kind, "ph": "X",
            "ts": e.start * 1e6, "dur": (e.end - e.start) * 1e6,
            "pid": pid, "tid": tid, "args": args,
        })

    span_tids: Dict[str, int] = {}
    for s in tracer.spans:
        tid = span_tids.setdefault(s["track"], len(span_tids))
        pids_seen.setdefault(REQUESTS_PID, "requests")
        tids_seen.setdefault((REQUESTS_PID, tid), s["track"])
        trace_events.append({
            "name": s["name"], "cat": "span", "ph": "X",
            "ts": s["start"] * 1e6,
            "dur": (s["end"] - s["start"]) * 1e6,
            "pid": REQUESTS_PID, "tid": tid, "args": s["args"],
        })

    meta: List[dict] = []
    for pid, pname in sorted(pids_seen.items()):
        meta.append({"name": "process_name", "ph": "M", "pid": pid,
                     "args": {"name": pname}})
    for (pid, tid), tname in sorted(tids_seen.items()):
        meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                     "tid": tid, "args": {"name": tname}})
    return {"traceEvents": meta + trace_events, "displayTimeUnit": "ms"}


def export_chrome_trace(tracer: TimelineTracer, path: str) -> dict:
    """Write the Chrome-trace JSON for ``tracer`` to ``path``; returns
    the exported dict (handy for asserting on what was written)."""
    data = chrome_trace(tracer)
    with open(path, "w") as fh:
        json.dump(data, fh)
        fh.write("\n")
    return data
