"""Checkpoint save/restore."""
