"""Pytree checkpointing: msgpack manifest + raw .npy payloads.

No orbax offline, so this is a small self-contained implementation:

* ``save(path, tree)``   — writes ``manifest.msgpack`` (treedef as nested
  lists/dicts with dtype/shape leaves) + one ``.npy`` per leaf.
* ``restore(path)``      — reads them back, preserving dtypes (including
  bfloat16, stored as uint16 view) and the tree structure.
* ``save_sharded`` adds a per-process suffix so multi-host jobs don't
  collide; the dry-run container is single-process so this is exercised
  with n_process=1 in tests.

Leaves may be jax or numpy arrays; restored leaves are numpy (callers
``device_put`` with the right sharding).
"""

from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_BF16 = "bfloat16"


def _leaf_meta(x) -> dict:
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


def _to_numpy(x):
    x = np.asarray(x)
    if x.dtype == jnp.bfloat16:
        return x.view(np.uint16), _BF16
    return x, str(x.dtype)


def _from_numpy(x: np.ndarray, dtype: str):
    if dtype == _BF16:
        return x.view(jnp.bfloat16)
    return x.astype(dtype) if str(x.dtype) != dtype else x


def save(path: str, tree: Any, *, step: int | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    metas = []
    for i, leaf in enumerate(leaves):
        arr, dtype = _to_numpy(leaf)
        np.save(os.path.join(path, f"leaf_{i}.npy"), arr)
        metas.append({"shape": list(arr.shape), "dtype": dtype})
    manifest = {
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "metas": metas,
        "step": step,
    }
    # treedef round-trip: store the structure via tree_structure of a
    # token-filled tree using tree_map on indices
    idx_tree = jax.tree_util.tree_unflatten(treedef, list(range(len(leaves))))
    manifest["structure"] = _encode_structure(idx_tree)
    with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))


def _encode_structure(node):
    if isinstance(node, dict):
        return {"__kind__": "dict",
                "items": {k: _encode_structure(v) for k, v in node.items()}}
    if isinstance(node, (list, tuple)):
        return {"__kind__": type(node).__name__,
                "items": [_encode_structure(v) for v in node]}
    return {"__kind__": "leaf", "index": int(node)}


def _decode_structure(node, leaves):
    kind = node["__kind__"]
    if kind == "dict":
        return {k: _decode_structure(v, leaves)
                for k, v in node["items"].items()}
    if kind == "list":
        return [_decode_structure(v, leaves) for v in node["items"]]
    if kind == "tuple":
        return tuple(_decode_structure(v, leaves) for v in node["items"])
    return leaves[node["index"]]


def restore(path: str) -> Any:
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    leaves = []
    for i, meta in enumerate(manifest["metas"]):
        arr = np.load(os.path.join(path, f"leaf_{i}.npy"))
        leaves.append(_from_numpy(arr, meta["dtype"]))
    return _decode_structure(manifest["structure"], leaves)


def restore_step(path: str) -> int | None:
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read()).get("step")


def save_sharded(path: str, tree: Any, process_idx: int,
                 *, step: int | None = None) -> None:
    save(os.path.join(path, f"proc_{process_idx:05d}"), tree, step=step)


def restore_sharded(path: str, process_idx: int) -> Any:
    return restore(os.path.join(path, f"proc_{process_idx:05d}"))
