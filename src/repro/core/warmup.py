"""Predictive Cache Warmup — PCW (paper §4.3).

During prefill the engine accumulates per-(layer, expert) access frequency
("prefill hotness").  At the prefill→decode transition PCW reshapes the
unified cache into a hotness-aligned state:

  1. evict LSB slices of experts whose hotness is below the critical
     quantile (they contribute least to accuracy — paper: "starting from
     LSB slices"),
  2. evict MSB slices with low prefill access frequency next,
  3. re-order the LRU recency of what remains by hotness, so the first
     decode evictions hit the coldest slices,
  4. (optionally) pre-install hot MSB slices that prefill's layer-by-layer
     streaming already paid to load — the "reshape, don't refill" step.

The ratio of experts retaining their LSB (i.e. staying high-bit) is tied to
the DBSC single-head threshold: on average fewer than one expert per token
is critical, so only the hottest ``lsb_keep_frac`` keep their LSBs.

Baseline initial states for Fig. 10: ``empty``, ``last_layer``, ``random``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.cache import SliceCache
from repro.core.slices import ExpertSliceStore, SliceKey


@dataclasses.dataclass
class HotnessTracker:
    """Per-(layer, expert) EMA of selection frequency, gate-mass weighted."""

    n_layers: int
    n_experts: int
    decay: float = 0.95

    def __post_init__(self):
        self.counts = np.zeros((self.n_layers, self.n_experts))
        self.gate_mass = np.zeros((self.n_layers, self.n_experts))

    def observe(self, layer: int, expert_ids: np.ndarray,
                gates: np.ndarray) -> None:
        """expert_ids/gates: [T, k] for the tokens routed this call.

        Out-of-range ids are dropped, not counted: ``mask_routing``
        redirects padding slots to the sentinel id ``n_experts``, which
        used to raise IndexError from ``np.add.at`` when a caller passed
        unfiltered routing arrays.
        """
        ids = np.asarray(expert_ids).reshape(-1)
        g = np.asarray(gates).reshape(-1)
        valid = (ids >= 0) & (ids < self.n_experts)
        if not valid.all():
            ids, g = ids[valid], g[valid]
        np.add.at(self.counts[layer], ids, 1.0)
        np.add.at(self.gate_mass[layer], ids, g)

    def step_decay(self) -> None:
        self.counts *= self.decay
        self.gate_mass *= self.decay

    def begin_request(self, decay: float = 0.5) -> None:
        """Age accumulated hotness at a request boundary.

        The persistent engine keeps one tracker across requests so PCW can
        reshape from *accumulated* traffic rather than only the current
        prompt's prefill; the boundary decay keeps old requests from
        permanently pinning the ranking when the workload mix drifts.
        """
        self.counts *= decay
        self.gate_mass *= decay

    def clone(self) -> "HotnessTracker":
        """Deep copy (counts + gate mass) for forked replay simulations."""
        import copy

        return copy.deepcopy(self)

    def hotness(self) -> np.ndarray:
        """[L, E] combined score: frequency + gate mass."""
        c = self.counts / max(self.counts.max(), 1e-9)
        g = self.gate_mass / max(self.gate_mass.max(), 1e-9)
        return 0.5 * c + 0.5 * g


def pcw_reshape(cache: SliceCache, store: ExpertSliceStore,
                tracker: HotnessTracker, *,
                lsb_keep_frac: float = 0.125,
                msb_keep_frac: float = 1.0) -> dict:
    """Apply the PCW transition reshape.  Returns an action summary."""
    hot = tracker.hotness()
    L, E = hot.shape

    flat = hot.reshape(-1)
    lsb_thresh = float(np.quantile(flat, 1.0 - lsb_keep_frac)) \
        if lsb_keep_frac < 1.0 else -1.0
    msb_thresh = float(np.quantile(flat, 1.0 - msb_keep_frac)) \
        if msb_keep_frac < 1.0 else -1.0

    # 1) drop cold LSBs, 2) drop cold MSBs.
    evicted_lsb = cache.evict_where(
        lambda k: k.kind == "lsb" and hot[k.layer, k.expert] < lsb_thresh)
    evicted_msb = cache.evict_where(
        lambda k: k.kind == "msb" and hot[k.layer, k.expert] < msb_thresh)

    # 3) fill freed space with the hottest missing MSB slices (these bytes
    # were already streamed through DRAM during prefill; reshaping keeps
    # them instead of dropping them — no extra Flash traffic is charged).
    # Every MSB slice is the same size, so the first one that doesn't fit
    # marks its shard full; the scan ends once every shard is full (for
    # the single-device cache that is the first non-fit, as before).
    order = np.argsort(-flat)
    installed = 0
    nb = store.msb_bytes_per_expert
    full_shards: set = set()
    for idx in order:
        if len(full_shards) >= cache.n_shards:
            break
        lidx, e = divmod(int(idx), E)
        key = SliceKey(lidx, e, "msb")
        sid = cache.shard_index(key)
        if sid in full_shards:
            continue
        if not cache.can_fit(key, nb):
            full_shards.add(sid)
            continue
        if key in cache:
            continue
        cache.insert(key, nb)
        installed += 1

    # 4) hotness-aligned recency over the FULL final population —
    # survivors and installs together.  Re-ranking must run *after* the
    # install loop: inserting into an already-reordered cache appended
    # every installed slice at the recency tail, so installs (added
    # hottest-first, hottest nearest the LRU head) outranked every
    # survivor regardless of hotness.
    ranking: Dict[SliceKey, float] = {
        k: float(hot[k.layer, k.expert]) for k in cache.resident_keys()}
    cache.reorder_by(ranking)

    return {
        "evicted_lsb": len(evicted_lsb),
        "evicted_msb": len(evicted_msb),
        "installed_msb": installed,
        "resident": len(cache),
    }


# --------------------------------------------------------------------------
# Baseline initial states (paper Fig. 10)
# --------------------------------------------------------------------------
def init_empty(cache: SliceCache, *_args, **_kw) -> None:
    cache.clear()


def init_last_layer(cache: SliceCache, store: ExpertSliceStore,
                    *_args, **_kw) -> None:
    """Keep only the last prefill layer's experts (naive leftover state)."""
    cache.clear()
    last = max(store.layers.keys())
    for e in range(store.n_experts):
        for kind in ("msb", "lsb"):
            key = SliceKey(last, e, kind)
            nb = store.slice_bytes(key)
            if cache.can_fit(key, nb):
                cache.insert(key, nb)


def init_random(cache: SliceCache, store: ExpertSliceStore, *,
                seed: int = 0, **_kw) -> None:
    cache.clear()
    rng = np.random.default_rng(seed)
    keys = list(store.all_keys())
    rng.shuffle(keys)
    for key in keys:
        nb = store.slice_bytes(key)
        if not cache.can_fit(key, nb):
            if cache.n_shards == 1:
                break
            continue
        cache.insert(key, nb)


INIT_STATES = {
    "empty": init_empty,
    "last_layer": init_last_layer,
    "random": init_random,
}
