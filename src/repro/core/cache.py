"""Slice-granular DRAM cache simulator (paper §4.1, §6.1-3).

Deterministic model of the DRAM expert cache sitting between Flash and the
XPU.  Keys are :class:`~repro.core.slices.SliceKey`; capacity is in bytes.

Policy (DBSC heterogeneous management):
  * **MSB slices** — standard LRU.
  * **LSB slices** — lowest priority: they live in a separate segment that
    is evicted *before* any MSB slice is touched ("aggressively evicted
    after initial access").

Setting ``slice_aware=False`` collapses both segments into one LRU — the
paper's baseline cache (used with whole-expert keys for high-bit /
uniform-low-bit baselines).

Every miss/hit is charged to a :class:`~repro.hw.energy.CostLedger` by the
caller (the engine), keeping the cache purely a state machine.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

from repro.core.slices import SliceKey


class SliceTooLargeError(ValueError):
    """A slice bigger than the whole cache was offered for insertion.

    Raised by :meth:`SliceCache.insert` so the caller can't confuse
    "dropped" with "already resident" (both used to return ``[]``):
    a dropped fill never lands in DRAM, so the ledger must charge a
    direct Flash→XPU stream instead of a fill + DRAM read.
    """

    def __init__(self, key: SliceKey, nbytes: float, capacity: float):
        super().__init__(
            f"slice {key} ({nbytes:.0f} B) exceeds cache capacity "
            f"({capacity:.0f} B); fill dropped")
        self.key = key
        self.nbytes = nbytes
        self.capacity = capacity


@dataclasses.dataclass
class CacheStats:
    msb_hits: int = 0
    msb_misses: int = 0
    lsb_hits: int = 0
    lsb_misses: int = 0
    n_dropped: int = 0     # fills dropped because the slice outsizes the cache

    def record(self, kind: str, hit: bool) -> None:
        f = f"{kind}_{'hits' if hit else 'misses'}"
        setattr(self, f, getattr(self, f) + 1)

    @property
    def accesses(self) -> int:
        return self.msb_hits + self.msb_misses + self.lsb_hits + self.lsb_misses

    @property
    def misses(self) -> int:
        return self.msb_misses + self.lsb_misses

    @property
    def miss_rate(self) -> float:
        return self.misses / max(self.accesses, 1)

    @property
    def msb_miss_rate(self) -> float:
        return self.msb_misses / max(self.msb_hits + self.msb_misses, 1)

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)

    def reset(self) -> None:
        self.msb_hits = self.msb_misses = 0
        self.lsb_hits = self.lsb_misses = 0
        self.n_dropped = 0


class SliceCache:
    """Byte-capacity cache with the DBSC two-segment policy."""

    # Single-device cache: one shard holding every expert.  The
    # expert-parallel wrapper (repro.core.shard.ShardedSliceCache)
    # overrides these so shard-agnostic callers (PCW reshape, the init
    # states) can ask "does this slice's *owning* shard have room"
    # without knowing whether the cache is partitioned.
    n_shards: int = 1

    def shard_index(self, key: SliceKey) -> int:
        return 0

    def can_fit(self, key: SliceKey, nbytes: float) -> bool:
        """Whether ``key`` fits in its owning shard without eviction."""
        return self.used + nbytes <= self.capacity

    def set_active_tenant(self, tenant) -> None:
        """Tenant-attribution hint for fills.  No-op here: the flat cache
        has no per-tenant segments.  The engine calls this unconditionally
        on its charge path; :class:`repro.control.partition.
        TenantPartitionedCache` overrides it to route fills."""

    def __init__(self, capacity_bytes: float, *, slice_aware: bool = True):
        self.capacity = float(capacity_bytes)
        self.slice_aware = slice_aware
        self._msb: "OrderedDict[SliceKey, float]" = OrderedDict()
        self._lsb: "OrderedDict[SliceKey, float]" = OrderedDict()
        self.used = 0.0
        self.stats = CacheStats()
        # In-flight fill state: completion time (timeline seconds) of a
        # resident entry whose Flash→DRAM transfer is still landing.  A
        # consumer arriving before ``ready_time`` must wait for it; an
        # entry with no record is fully landed (ready at any time).
        self._ready_at: Dict[SliceKey, float] = {}
        # Cross-request stats epochs: each served request gets its own
        # hit/miss window while cache *contents* persist, so a warm-vs-cold
        # miss-rate curve can be read off epoch-by-epoch.
        self.epochs: List[Tuple[str, dict]] = []
        self._epoch_label: Optional[str] = None

    # ------------------------------------------------------------- epochs
    def begin_epoch(self, label: str) -> None:
        """Archive the current stats window under its label, start a new one.

        Contents (and therefore warmth) are untouched — only the counters
        roll over.  Used by the persistent engine at request boundaries.
        """
        self.end_epoch()
        self._epoch_label = label
        self.stats = CacheStats()

    def end_epoch(self) -> None:
        """Archive the open epoch (no-op when none is open)."""
        if self._epoch_label is None:
            return
        self.epochs.append((self._epoch_label, self.stats.snapshot()))
        self._epoch_label = None
        self.stats = CacheStats()

    def epoch_miss_rates(self) -> List[Tuple[str, float]]:
        """[(label, miss_rate)] over archived epochs — the warm-up curve."""
        return [(label, CacheStats(**snap).miss_rate)
                for label, snap in self.epochs]

    def epoch_counts(self) -> List[Tuple[str, int, int]]:
        """[(label, accesses, misses)] over archived epochs.

        The raw integer counts behind :meth:`epoch_miss_rates` — what the
        trace-replay fidelity gate compares exactly (rates alone can
        agree by coincidence while the underlying counts differ).
        """
        return [(label, CacheStats(**snap).accesses,
                 CacheStats(**snap).misses)
                for label, snap in self.epochs]

    def usage(self) -> dict:
        """Point-in-time occupancy plus *lifetime* access counts.

        ``stats`` resets at every epoch boundary (request boundaries
        under persistent serving), so a monotonic consumer — the
        metrics registry (repro.obs) — must read the archived epochs
        folded back in, not the open window alone.
        """
        acc = self.stats.accesses
        miss = self.stats.misses
        for _, snap in self.epochs:
            st = CacheStats(**snap)
            acc += st.accesses
            miss += st.misses
        return {
            "capacity_bytes": self.capacity,
            "used_bytes": self.used,
            "n_slices": len(self),
            "occupancy": self.used / self.capacity if self.capacity
            else 0.0,
            "accesses": acc,
            "misses": miss,
        }

    def clone(self) -> "SliceCache":
        """Deep copy of the full cache state (contents, recency order,
        stats windows, in-flight fills).  Used by the replay simulator to
        fork a simulation mid-trace without disturbing the original."""
        import copy

        return copy.deepcopy(self)

    # ----------------------------------------------------------- internals
    def _segment(self, key: SliceKey) -> "OrderedDict[SliceKey, float]":
        if not self.slice_aware:
            return self._msb
        return self._lsb if key.kind == "lsb" else self._msb

    def _evict_one(self) -> Optional[Tuple[SliceKey, float]]:
        """Evict the lowest-priority entry: LSB segment first, then MSB LRU."""
        if self._lsb:
            key, nb = self._lsb.popitem(last=False)
        elif self._msb:
            key, nb = self._msb.popitem(last=False)
        else:
            return None
        self.used -= nb
        self._ready_at.pop(key, None)
        return key, nb

    def _make_room(self, nbytes: float) -> List[SliceKey]:
        evicted = []
        while self.used + nbytes > self.capacity:
            e = self._evict_one()
            if e is None:
                break
            evicted.append(e[0])
        return evicted

    # ----------------------------------------------------------------- api
    def __contains__(self, key: SliceKey) -> bool:
        return key in self._msb or key in self._lsb

    def __len__(self) -> int:
        return len(self._msb) + len(self._lsb)

    def contains(self, key: SliceKey) -> bool:
        return key in self

    def access(self, key: SliceKey, nbytes: float,
               *, fill_on_miss: bool = True) -> bool:
        """Touch ``key``; returns True on hit.  Fills (with eviction) on miss.

        An oversized fill (``nbytes > capacity``) is *dropped*, counted in
        ``stats.n_dropped``, and the miss is reported as usual — callers
        that need to distinguish a landed fill from a drop check
        ``key in cache`` after a missed access (see the engine's charge
        path) or call :meth:`insert` directly and catch
        :class:`SliceTooLargeError`.
        """
        seg = self._segment(key)
        hit = key in seg
        self.stats.record(key.kind, hit)
        if hit:
            if key.kind == "msb" or not self.slice_aware:
                seg.move_to_end(key)      # LRU bump; LSBs stay low priority
            return True
        if fill_on_miss:
            try:
                self.insert(key, nbytes)
            except SliceTooLargeError:
                self.stats.n_dropped += 1
        return False

    def insert(self, key: SliceKey, nbytes: float) -> List[SliceKey]:
        """Install ``key``, evicting low-priority entries to make room.

        Returns the evicted keys.  Raises :class:`SliceTooLargeError`
        when the slice cannot fit even in an empty cache — previously
        this silently returned ``[]``, indistinguishable from "already
        resident", so callers charged the ledger for fills that never
        happened.
        """
        if nbytes > self.capacity:
            raise SliceTooLargeError(key, nbytes, self.capacity)
        seg = self._segment(key)
        if key in seg:
            seg.move_to_end(key)
            return []
        evicted = self._make_room(nbytes)
        seg[key] = nbytes
        self.used += nbytes
        return evicted

    # --------------------------------------------------- in-flight fills
    def mark_inflight(self, key: SliceKey, ready_t: float) -> None:
        """Record that ``key``'s fill (already inserted) lands at
        ``ready_t`` on the simulation timeline.  Used by the async decode
        replay so a consumer arriving earlier stalls until the transfer
        completes instead of re-issuing it."""
        if key in self:
            self._ready_at[key] = ready_t

    def ready_time(self, key: SliceKey, default: float = 0.0) -> float:
        """Timeline second at which ``key`` is usable (``default`` when
        no fill is in flight for it)."""
        return self._ready_at.get(key, default)

    def settle(self, now: float) -> None:
        """Forget in-flight records that have landed by ``now``."""
        self._ready_at = {k: t for k, t in self._ready_at.items()
                          if t > now}

    def nbytes_of(self, key: SliceKey, default: float = 0.0) -> float:
        """Resident size of ``key`` (``default`` when not resident).
        Used by placement migration to move slices at their true size."""
        for seg in (self._msb, self._lsb):
            if key in seg:
                return seg[key]
        return default

    def evict(self, key: SliceKey) -> bool:
        for seg in (self._msb, self._lsb):
            if key in seg:
                self.used -= seg.pop(key)
                self._ready_at.pop(key, None)
                return True
        return False

    def resident_keys(self) -> List[SliceKey]:
        return list(self._msb.keys()) + list(self._lsb.keys())

    def residency(self, n_layers: int, n_experts: int):
        """Dense bool arrays (msb[L,E], lsb[L,E]) for jit-input masks."""
        import numpy as np

        msb = np.zeros((n_layers, n_experts), bool)
        lsb = np.zeros((n_layers, n_experts), bool)
        for k in self._msb:
            if k.kind == "msb":
                msb[k.layer, k.expert] = True
            else:  # slice_aware=False stores everything in _msb
                lsb[k.layer, k.expert] = True
        for k in self._lsb:
            lsb[k.layer, k.expert] = True
        return msb, lsb

    # ------------------------------------------------------- PCW interface
    def reorder_by(self, ranking: Dict[SliceKey, float]) -> None:
        """Rebuild recency so higher-ranked keys are evicted last."""
        for seg in (self._msb, self._lsb):
            items = sorted(seg.items(), key=lambda kv: ranking.get(kv[0], 0.0))
            seg.clear()
            for k, v in items:
                seg[k] = v

    def evict_where(self, pred) -> List[SliceKey]:
        out = []
        for seg in (self._msb, self._lsb):
            for k in [k for k in seg if pred(k)]:
                self.used -= seg.pop(k)
                self._ready_at.pop(k, None)
                out.append(k)
        return out

    def clear(self) -> None:
        self._msb.clear()
        self._lsb.clear()
        self._ready_at.clear()
        self.used = 0.0
