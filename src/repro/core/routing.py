"""Cache-aware routing policies (paper §2.1, §4.1) — all jittable.

* ``topk_routing``        — vanilla top-k (locality-insensitive baseline).
* ``cumsum_routing``      — cumulative-threshold expert selection [14]:
  take experts in descending probability until the cumulative mass exceeds
  ``tau`` (capped at ``k_max``).  Strong accuracy, terrible locality.
* ``cache_prior_routing`` — Cache-Prior [14]: boost the gating scores of
  DRAM-resident experts by ``alpha`` before top-k, pulling selection
  toward the cache.  ``alpha`` is the knob the miss-rate-constraint
  controller actuates.
* ``criticality``         — DBSC's dynamic single-head test (paper §4.1,
  citing [31]): an expert is *critical* for a token iff its renormalized
  gate exceeds ``theta``.  Critical experts want MSB+LSB (high-bit);
  the rest run MSB-only.  Token-wise this yields 0..k critical experts,
  matching the paper's Fig. 4 observation.

All functions take ``probs`` — the router softmax output ``[T, E]`` — and
return ``(gates [T, k], ids [T, k])`` plus policy-specific extras.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("k",))
def topk_routing(probs: jax.Array, k: int):
    gates, ids = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, ids


@partial(jax.jit, static_argnames=("k_max",))
def cumsum_routing(probs: jax.Array, tau: float, k_max: int):
    """Select experts until cumulative prob >= tau (at most k_max).

    Returns (gates [T, k_max], ids [T, k_max], active [T, k_max] bool).
    Inactive slots have zero gates.
    """
    p_sorted, ids = jax.lax.top_k(probs, k_max)
    csum = jnp.cumsum(p_sorted, axis=-1)
    # slot j is active if the mass *before* it hasn't reached tau yet
    active = jnp.concatenate(
        [jnp.ones_like(csum[:, :1], bool), csum[:, :-1] < tau], axis=-1)
    gates = p_sorted * active
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, ids, active


@partial(jax.jit, static_argnames=("k",))
def cache_prior_routing(probs: jax.Array, cached: jax.Array, alpha,
                        k: int):
    """Boost cached experts' scores: p' ∝ p * (1 + alpha * cached).

    ``cached``: [E] (or [T, E]) bool/0-1 mask of DRAM-resident experts.
    ``alpha >= 0``; alpha=0 recovers vanilla top-k.
    """
    boost = 1.0 + alpha * cached.astype(probs.dtype)
    boosted = probs * boost
    gates_b, ids = jax.lax.top_k(boosted, k)
    # Gate values come from the *original* probabilities (the boost only
    # reorders selection, it must not change mixture weights).
    gates = jnp.take_along_axis(probs, ids, axis=-1)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, ids


@partial(jax.jit, static_argnames=("k",))
def buddy_routing(probs: jax.Array, cached: jax.Array,
                  buddies: jax.Array, k: int):
    """BuddyMoE [15]: substitute a missed expert with its cached "buddy".

    ``buddies``: [E] int — the offline-calibrated most-interchangeable
    expert for each expert (here: nearest neighbour in expert-weight
    cosine similarity; BuddyMoE calibrates on routing overlap).
    Selection is vanilla top-k; each selected-but-uncached expert is
    replaced by its buddy iff the buddy IS cached (otherwise the miss
    stands).  Gates keep the original expert's probability — the buddy
    is acting as its stand-in.
    """
    gates, ids = topk_routing(probs, k)
    buddy_ids = buddies[ids]
    use_buddy = (~cached[ids]) & cached[buddy_ids]
    new_ids = jnp.where(use_buddy, buddy_ids, ids)
    return gates, new_ids


def compute_buddies(flat_weights: jax.Array) -> jax.Array:
    """Offline buddy calibration: nearest expert by weight cosine sim.

    flat_weights: [E, D_flat] — per-expert flattened weights.
    """
    w = flat_weights.astype(jnp.float32)
    w = w / (jnp.linalg.norm(w, axis=-1, keepdims=True) + 1e-9)
    sim = w @ w.T
    sim = sim - 2.0 * jnp.eye(sim.shape[0])   # exclude self
    return jnp.argmax(sim, axis=-1).astype(jnp.int32)


def criticality(gates: jax.Array, theta: float = 0.5):
    """DBSC single-head test on renormalized top-k gates [T, k].

    Returns bool [T, k]: slot needs high-bit (MSB+LSB) precision.
    ``theta=0.5`` means an expert is critical when it carries at least
    half of the routed mass — the "single head" of the distribution.
    """
    return gates >= theta


def expert_demand(ids: jax.Array, critical: jax.Array, n_experts: int):
    """Aggregate per-token selections into per-expert slice demand.

    Returns (msb_needed [E] bool, lsb_needed [E] bool): MSB is needed by
    any selection; LSB only by critical selections.
    """
    sel = jax.nn.one_hot(ids, n_experts, dtype=jnp.bool_)      # [T, k, E]
    msb = jnp.any(sel, axis=(0, 1))
    lsb = jnp.any(sel & critical[..., None], axis=(0, 1))
    return msb, lsb


class MissRateController:
    """Proportional-integral controller on the Cache-Prior boost ``alpha``.

    Enforces the paper's miss-rate constraint (Fig. 1b): measure the rolling
    slice miss rate over recent decode steps; if above the target, increase
    alpha (pull routing toward the cache), else relax toward zero so
    accuracy recovers.  Activates after ``warmup_steps`` (paper: 10).
    """

    def __init__(self, target_miss_rate: float, *, kp: float = 40.0,
                 ki: float = 4.0, alpha_max: float = 50.0,
                 warmup_steps: int = 10, window: int = 16):
        self.target = target_miss_rate
        self.kp, self.ki = kp, ki
        self.alpha_max = alpha_max
        self.warmup_steps = warmup_steps
        self.window = window
        self.alpha = 0.0
        self._integral = 0.0
        self._history: list[float] = []
        self._step = 0

    def update(self, step_miss_rate: float) -> float:
        self._step += 1
        self._history.append(step_miss_rate)
        if len(self._history) > self.window:
            self._history.pop(0)
        if self._step <= self.warmup_steps:
            return self.alpha
        rolling = sum(self._history) / len(self._history)
        err = rolling - self.target
        self._integral = max(0.0, self._integral + err)
        self.alpha = float(min(self.alpha_max,
                               max(0.0, self.kp * err + self.ki * self._integral)))
        return self.alpha

    @property
    def active(self) -> bool:
        return self._step > self.warmup_steps
