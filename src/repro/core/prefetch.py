"""Predictive expert prefetching: the Markov baseline and its replacement.

Two predictors live here:

* :class:`TransitionPrefetcher` — the single-step layer-transition
  model (Pre-gated-MoE / ProMoE style) the paper's §2.1 argues against.
  Kept as the measured baseline: the serving benchmark shows it at 0%
  accuracy (0 useful / 21 late / 75 wasted of 96 fills) because a fill
  issued one layer ahead almost never lands before the consuming layer
  routes in the I/O-bound decode regime.

* :class:`RequestPrefetcher` over :class:`ActivationPredictor` — the
  sparsity-aware, request-level activation model (MoE-Infinity, arXiv
  2401.14361): per-request expert-activation matrices accumulated across
  layers from prefill routing onward, multi-layer-ahead candidate
  scoring (decayed request-level activation blended with the global
  transition prior), slice-granular issuance ranked by expected benefit
  per Flash byte, and confidence gating so low-evidence layers issue
  nothing.  Crucially it predicts *across decode-step boundaries*
  (cyclic layer targets), which buys a fill an entire step of slack —
  the only distance at which a prefetch can land before its consumer in
  a 99.5%-I/O-stalled pipeline.

Paper §2.1: "Predictive schemes such as prefetching and speculative
caching [17-20] improve locality but become increasingly unreliable in
modern MoE … strong router regularization leads to stochastic routing
patterns and frequent prefetch failures."

We implement the standard layer-transition predictor (Pre-gated-MoE /
ProMoE style): an online co-occurrence model
``P(expert_j at layer l+1 | expert_i at layer l)`` trained on observed
routing traces, used during decode to pull the top-m predicted experts
of the next layer into DRAM before that layer executes.  Mispredictions
cost real Flash reads (charged to the ledger) without saving future
misses — exactly the failure mode the paper describes for
diversity-regularized routers.

On the asynchronous decode timeline (``EngineConfig.async_io``) each
prediction becomes a fill issued on the Flash channel *behind* the
current layer's demand fills; the engine classifies every issued
prefetch into one of three outcomes:

* **useful** — the predicted slice was demanded by its consuming layer
  and its transfer landed before that layer started;
* **late** — demanded, but the transfer was still in flight when the
  layer needed it (the layer stalls on the tail of the transfer; some
  latency is still hidden, but the paper's "before the layer starts"
  usefulness bar is missed);
* **wasted** — never demanded: pure Flash/DRAM energy burned
  (``CostLedger.prefetch_wasted_energy_j``).

Outcomes are judged against the *predicted consuming layer* (the next
layer of the current step), the paper's §2.1 usefulness bar.  A
"wasted" fill whose slice survives in the cache and serves a *later*
step's demand shows up as an ordinary demand hit — that residual
benefit is credited to the cache, not to the prefetcher, and its fill
energy stays attributed as prefetch waste.

Two fixes over the original implementation (both regression-tested):

1. ``predict`` takes an optional **residency mask** — predicting an
   expert that is already cached wastes a prefetch slot on a guaranteed
   no-op, crowding out predictions that could actually save a miss;
2. ties are broken by a **seeded random permutation** instead of
   ``argsort``'s index order.  Under the uniform smoothing prior a cold
   predictor used to emit experts ``0..m-1`` every time, systematically
   (and invisibly) favoring low-numbered experts.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.slices import SliceKey


@dataclasses.dataclass
class TransitionPrefetcher:
    kind = "transition"

    n_layers: int
    n_experts: int
    top_m: int = 4
    smoothing: float = 0.1
    seed: int = 0
    # Confidence floor: a layer transition must have been observed at
    # least this many times before predict() issues for it.  With 0 the
    # cold predictor guesses from the uniform smoothing prior — near-
    # random fills that burn Flash energy with ~no chance of saving a
    # miss (the paper's §2.1 "frequent prefetch failures").
    min_transitions: int = 0

    def __post_init__(self):
        # counts[l, i, j]: expert i used at layer l, expert j at layer l+1
        self.counts = np.full(
            (max(self.n_layers - 1, 1), self.n_experts, self.n_experts),
            self.smoothing)
        # obs[l]: observed (layer l -> l+1) transition events — the
        # confidence-floor denominator (smoothing prior excluded).
        self.obs = np.zeros(max(self.n_layers - 1, 1), np.int64)
        self._rng = np.random.default_rng(self.seed)
        self.issued = 0
        self.useful = 0
        self.late = 0
        self.wasted = 0

    def _valid_ids(self, experts: np.ndarray) -> np.ndarray:
        """Unique in-range expert ids.  ``mask_routing`` emits the
        sentinel id ``n_experts`` for padding slots; indexing the
        transition counts with it used to raise IndexError, so masked
        slots are dropped here instead."""
        ids = np.unique(np.asarray(experts).reshape(-1))
        return ids[(ids >= 0) & (ids < self.n_experts)]

    # --------------------------------------------------------------- learn
    def observe(self, layer: int, prev_experts: np.ndarray,
                cur_experts: np.ndarray) -> None:
        """Record a (layer-1 -> layer) transition from a routing trace."""
        if layer <= 0 or layer > self.counts.shape[0]:
            return
        pe = self._valid_ids(prev_experts)
        ce = self._valid_ids(cur_experts)
        if pe.size == 0 or ce.size == 0:
            return
        self.counts[layer - 1][np.ix_(pe, ce)] += 1.0
        self.obs[layer - 1] += 1

    # -------------------------------------------------------------- predict
    def predict(self, layer: int, cur_experts: np.ndarray,
                resident: Optional[np.ndarray] = None) -> np.ndarray:
        """Top-m predicted experts for ``layer + 1``.

        ``resident``: optional ``[n_experts]`` bool mask of experts whose
        target slice is already cached — they are excluded so every
        returned prediction corresponds to a fill that could save a miss.
        Score ties are broken by a seeded random permutation (drawn per
        call, deterministic for a given construction seed and call
        sequence), not by expert index.
        """
        # n_layers - 1, not counts.shape[0]: the counts buffer is floored
        # to one transition matrix, so a 1-layer model would otherwise
        # "predict" for a layer that does not exist.
        if layer < 0 or layer >= self.n_layers - 1:
            return np.empty(0, np.int64)
        # Confidence floor: stay silent until this transition has enough
        # real observations that the scores are no longer the prior.
        if self.obs[layer] < self.min_transitions:
            return np.empty(0, np.int64)
        ce = self._valid_ids(cur_experts)
        if ce.size == 0:
            return np.empty(0, np.int64)
        scores = self.counts[layer][ce].sum(axis=0)
        candidates = np.arange(self.n_experts)
        if resident is not None:
            keep = ~np.asarray(resident, bool)
            candidates = candidates[keep]
            scores = scores[keep]
        if candidates.size == 0:
            return np.empty(0, np.int64)
        perm = self._rng.permutation(candidates.size)
        order = perm[np.argsort(-scores[perm], kind="stable")]
        return candidates[order[: self.top_m]].astype(np.int64)

    def clone(self) -> "TransitionPrefetcher":
        """Deep copy (transition counts, rng state, outcome counters) so a
        forked replay simulation keeps an independent predictor whose tie
        -break stream continues deterministically from the fork point."""
        import copy

        return copy.deepcopy(self)

    # ------------------------------------------------------ interface shims
    # The engine drives both predictor kinds through one surface; the
    # request-level hooks are no-ops on the transition baseline, so old
    # recorded traces replay bit-identically.
    def begin_request(self, decay: float) -> None:
        pass

    def observe_prefill(self, layer: int, ids: np.ndarray,
                        gates: np.ndarray,
                        n_tokens: Optional[int] = None) -> None:
        pass

    @property
    def in_flight(self) -> int:
        """Issued fills not yet judged.  The transition baseline only
        targets the next layer of the same step, which always judges
        before the step ends — so this is 0 between steps."""
        return self.issued - self.useful - self.late - self.wasted

    # ---------------------------------------------------------- accounting
    def mark_issued(self, n: int = 1) -> None:
        self.issued += n

    def mark_useful(self, n: int = 1) -> None:
        self.useful += n

    def mark_late(self, n: int = 1) -> None:
        self.late += n

    def mark_wasted(self, n: int = 1) -> None:
        self.wasted += n

    @property
    def accuracy(self) -> float:
        return self.useful / max(self.issued, 1)

    def summary(self) -> dict:
        return {
            "kind": self.kind,
            "issued": self.issued,
            "useful": self.useful,
            "late": self.late,
            "wasted": self.wasted,
            "in_flight": self.in_flight,
            "accuracy": self.accuracy,
            "min_transitions": self.min_transitions,
            "observed_transitions": int(self.obs.sum()),
        }


# --------------------------------------------------------------------------
# Request-level activation prediction (MoE-Infinity style)
# --------------------------------------------------------------------------

def _valid_unique(experts: np.ndarray, n_experts: int) -> np.ndarray:
    """Unique in-range ids; drops the ``n_experts`` padding sentinel."""
    ids = np.unique(np.asarray(experts).reshape(-1))
    return ids[(ids >= 0) & (ids < n_experts)]


@dataclasses.dataclass
class ActivationPredictor:
    """Request-level expert-activation model over the flat MoE layers.

    State (all ``[n_layers, n_experts]`` unless noted):

    * ``act`` — decayed gate-mass per (layer, expert): seeded from
      prefill routing, EMA-updated each decode observation, aged by
      ``begin_request`` at request boundaries so the matrix tracks the
      *current* request mix rather than the all-time average — the
      "activation matrix" of MoE-Infinity.
    * ``freq`` — decayed per-step demand *indicator* EMA per (layer,
      expert): unlike ``act`` (a share of gate mass), this estimates
      ``P(expert demanded at the layer's next execution)`` directly,
      which is the probability a prefetch outcome is judged on.  An
      expert the batch touches every step scores ~1 here even when its
      gate share is small — exactly the slice worth re-filling after
      an eviction.
    * ``trans`` — global cyclic transition prior ``[n_layers, E, E]``:
      ``trans[l]`` counts expert co-occurrence from layer ``l`` to the
      *next observed* layer ``(l+1) % n_layers`` — the wrap row learns
      the cross-step transition the Markov baseline cannot express.
      Never decayed (it is a property of the router, not the request).
    * ``sel`` / ``crit`` — per-expert selection and critical-selection
      mass, aged with ``act``; their ratio estimates how often an
      expert's selection is critical, i.e. whether its LSB slice is
      worth prefetching (DBSC demand prediction).
    * ``obs`` ``[n_layers]`` — cumulative observation count per layer,
      the confidence-gate denominator (never decayed, mirroring the
      transition baseline's ``min_transitions`` semantics).

    The predictor is deliberately *aggregate* across concurrent
    requests: decode steps are batched, so per-slot attribution does not
    exist in the charge path — the matrix models the in-flight request
    mix, aged at admission boundaries.
    """

    n_layers: int
    n_experts: int
    ema: float = 0.3            # within-request EMA weight per observation
    request_weight: float = 0.7  # blend: request activation share ...
    prior_weight: float = 0.3    # ... vs global transition-prior share
    smoothing: float = 0.1       # transition-prior Laplace smoothing
    seed: int = 0

    def __post_init__(self):
        L, E = self.n_layers, self.n_experts
        self.act = np.zeros((L, E))
        self.freq = np.zeros((L, E))
        self.pfrac = np.zeros((L, E))   # most recent admission's prefill frac
        self.sel = np.zeros((L, E))
        self.crit = np.zeros((L, E))
        self.trans = np.full((L, E, E), self.smoothing)
        self.obs = np.zeros(L, np.int64)
        self._prev: Optional[tuple] = None   # (layer, ids) last observed
        self._rng = np.random.default_rng(self.seed)

    # --------------------------------------------------------------- learn
    def begin_request(self, decay: float) -> None:
        """Age the request-level state at a request boundary (same decay
        the engine applies to cache hotness): the new request inherits a
        faded picture of the in-flight mix, not a blank slate."""
        self.act *= decay
        self.freq *= decay
        self.sel *= decay
        self.crit *= decay
        self.pfrac[:] = 0.0      # admission-time signal is per-request only
        self._prev = None        # don't learn transitions across requests

    def _mass(self, ids: np.ndarray, gates: np.ndarray) -> np.ndarray:
        """Per-expert gate mass of one layer's routing, L1-normalised so
        a layer's activation row is a share distribution regardless of
        batch occupancy."""
        m = np.zeros(self.n_experts)
        ids = np.asarray(ids).reshape(-1)
        gates = np.asarray(gates, np.float64).reshape(-1)
        ok = (ids >= 0) & (ids < self.n_experts)
        np.add.at(m, ids[ok], np.abs(gates[ok]))
        tot = m.sum()
        return m / tot if tot > 0 else m

    def observe_prefill(self, layer: int, ids: np.ndarray,
                        gates: np.ndarray,
                        n_tokens: Optional[int] = None) -> None:
        """Seed the activation matrix from prompt routing — the signal
        MoE-Infinity shows is already predictive of the whole request's
        decode routing.  The demand-frequency row is seeded with each
        expert's *per-token* selection fraction, not a whole-prompt
        indicator: nearly every expert appears somewhere in a long
        prompt, but only per-token rates transfer to per-decode-step
        demand probability."""
        if not (0 <= layer < self.n_layers):
            return
        mass = self._mass(ids, gates)
        if mass.sum() == 0:
            return
        self.act[layer] = 0.5 * self.act[layer] + 0.5 * mass
        ids_flat = np.asarray(ids).reshape(-1)
        ids_flat = ids_flat[(ids_flat >= 0) & (ids_flat < self.n_experts)]
        if n_tokens is None:
            n_tokens = ids_flat.size
        cnt = np.bincount(ids_flat, minlength=self.n_experts)
        frac = np.clip(cnt / max(int(n_tokens), 1), 0.0, 1.0)
        self.freq[layer] = 0.5 * self.freq[layer] + 0.5 * frac
        self.pfrac[layer] = frac
        self.sel[layer] += mass
        self.obs[layer] += 1

    def observe(self, layer: int, ids: np.ndarray, gates: np.ndarray,
                crit_ids: Optional[Sequence[int]] = None) -> None:
        """One decode step's routing at ``layer``: EMA the activation
        row, count the cyclic transition from the previously observed
        layer, and accumulate critical-selection mass (``crit_ids`` —
        the experts whose LSB slice the layer demanded)."""
        if not (0 <= layer < self.n_layers):
            return
        mass = self._mass(ids, gates)
        used = _valid_unique(ids, self.n_experts)
        if mass.sum() > 0:
            self.act[layer] = (1 - self.ema) * self.act[layer] \
                + self.ema * mass
            self.freq[layer] = (1 - self.ema) * self.freq[layer]
            self.freq[layer][used] += self.ema
            self.obs[layer] += 1
        self.sel[layer][used] += 1.0
        if crit_ids is not None:
            ce = _valid_unique(np.asarray(list(crit_ids), np.int64),
                               self.n_experts)
            self.crit[layer][ce] += 1.0
        if self._prev is not None:
            pl, pe = self._prev
            if (pl + 1) % self.n_layers == layer and pe.size \
                    and used.size:
                self.trans[pl][np.ix_(pe, used)] += 1.0
        self._prev = (layer, used)

    # ------------------------------------------------------------- predict
    def _prior_chain(self, from_layer: int, from_ids: np.ndarray,
                     distance: int) -> np.ndarray:
        """Propagate the current layer's expert set ``distance`` hops
        through the cyclic transition prior; returns an ``[E]`` share
        distribution over experts at layer
        ``(from_layer + distance) % n_layers``."""
        v = np.zeros(self.n_experts)
        ids = _valid_unique(from_ids, self.n_experts)
        if ids.size == 0:
            return v
        v[ids] = 1.0 / ids.size
        for h in range(distance):
            mat = self.trans[(from_layer + h) % self.n_layers]
            v = v @ mat
            tot = v.sum()
            if tot <= 0:
                return np.zeros(self.n_experts)
            v /= tot
        return v

    def scores(self, from_layer: int, from_ids: np.ndarray,
               distance: int) -> np.ndarray:
        """Blended ``[E]`` candidate scores for the layer ``distance``
        hops ahead (cyclically — distances ≥ the remaining layers of
        this step target the *next* decode step).  The request component
        is the demand-frequency EMA (≈ P(demanded at the target's next
        execution) — what outcomes are judged on); the prior component
        is the propagated transition share.  Scores live in [0, 1], so
        one ``min_score`` threshold is meaningful across layers."""
        target = (from_layer + distance) % self.n_layers
        prior = self._prior_chain(from_layer, from_ids, distance)
        return self.request_weight * self.freq[target] \
            + self.prior_weight * prior

    def crit_frac(self, layer: int) -> np.ndarray:
        """[E] estimate of P(selection is critical) per expert — the
        LSB-demand predictor (a controller-demoted fleet stops demanding
        LSBs, so this decays toward 0 and LSB prefetch dries up)."""
        return self.crit[layer] / np.maximum(self.sel[layer], 1e-12)

    def clone(self) -> "ActivationPredictor":
        import copy

        return copy.deepcopy(self)


@dataclasses.dataclass
class RequestPrefetcher:
    """Issuance policy + outcome accounting over an
    :class:`ActivationPredictor`.

    ``plan`` returns at most ``top_m`` :class:`SliceKey` candidates per
    call, ranked by **expected benefit per Flash byte**:

    ``score(e, target) x P(useful | distance) / slice_bytes``

    where ``score`` is the predictor's blended activation share and
    ``P(useful | distance)`` is learned online from this run's own
    outcome history (Laplace-smoothed useful/issued per lookahead
    distance) — a near-target fill that keeps landing late stops being
    issued without any hand-tuned timing model.

    Gates, in order:

    * confidence — a target layer with fewer than ``min_obs``
      observations issues nothing (generalises the transition
      baseline's ``prefetch_min_obs``);
    * ``min_score`` — activation-share floor, so the cold/uniform tail
      never burns Flash energy (the paper's §2.1 failure mode);
    * residency + in-flight — a candidate already cached or already
      pending is a guaranteed no-op and is skipped *before* the budget
      is spent;
    * LSB candidates only when the caller allows them (DBSC mode,
      un-demoted) and the expert's learned critical fraction clears
      ``lsb_crit_frac``.
    """

    n_layers: int
    n_experts: int
    top_m: int = 4
    lookahead: int = 2
    min_obs: int = 0
    min_score: float = 0.02
    lsb_crit_frac: float = 0.5
    ema: float = 0.3
    request_weight: float = 0.7
    prior_weight: float = 0.3
    seed: int = 0

    kind = "request"

    def __post_init__(self):
        self.predictor = ActivationPredictor(
            self.n_layers, self.n_experts, ema=self.ema,
            request_weight=self.request_weight,
            prior_weight=self.prior_weight, seed=self.seed)
        self._rng = np.random.default_rng(self.seed + 1)
        # outcome counters + per-distance usefulness (Laplace prior 1/2)
        self.issued = 0
        self.useful = 0
        self.late = 0
        self.wasted = 0
        self.in_flight = 0
        # Distance buckets: index 0 is the prefill-seeded (admission-time)
        # bucket, 1..lookahead are decode-time issuance distances.
        d = max(self.lookahead, 1)
        self.dist_issued = np.zeros(d + 1, np.int64)
        self.dist_useful = np.zeros(d + 1, np.int64)

    # --------------------------------------------------------------- learn
    def begin_request(self, decay: float) -> None:
        self.predictor.begin_request(decay)

    def observe_prefill(self, layer: int, ids: np.ndarray,
                        gates: np.ndarray,
                        n_tokens: Optional[int] = None) -> None:
        self.predictor.observe_prefill(layer, ids, gates,
                                       n_tokens=n_tokens)

    def observe(self, layer: int, ids: np.ndarray, gates: np.ndarray,
                crit_ids: Optional[Sequence[int]] = None) -> None:
        self.predictor.observe(layer, ids, gates, crit_ids=crit_ids)

    # ---------------------------------------------------------------- plan
    def _p_useful(self, distance: int) -> float:
        """Learned P(useful | lookahead distance), Laplace-smoothed with
        an optimistic prior so every distance gets explored before the
        outcome history can demote it.  Distance 0 is the prefill-seeded
        bucket."""
        i = self.dist_issued[min(distance, len(self.dist_issued) - 1)]
        u = self.dist_useful[min(distance, len(self.dist_useful) - 1)]
        return float((u + 1.0) / (i + 2.0))

    def _gate(self, score: float, p_use: float) -> bool:
        """Confidence-weighted admission floor.  The raw score is scaled
        by ``(p_useful / 0.5)**2`` (squared deviation from the Laplace
        prior), so a cold distance is gated on score alone while a
        distance whose fills keep landing late or wasted needs a
        rapidly stronger score to keep issuing — structurally-always-
        late distances throttle themselves off within a few fills."""
        return score * (p_use / 0.5) ** 2 >= self.min_score

    def plan(self, from_layer: int, from_ids: np.ndarray, *,
             is_resident: Callable[[SliceKey], bool],
             slice_bytes: Callable[[SliceKey], float],
             pending: Sequence[SliceKey] = (),
             lsb_allowed: bool = False) -> List[tuple]:
        """Rank prefetch candidates after ``from_layer`` routed.

        Returns ``[(SliceKey, distance), ...]`` (≤ ``top_m``), best
        expected-benefit-per-byte first.  The caller charges the fills
        (capacity permitting) and reports issuance via ``mark_issued``.
        """
        pend = set(pending)
        cands: List[tuple] = []   # (benefit_per_byte, jitter, key, dist)
        pred = self.predictor
        for d in range(1, max(self.lookahead, 1) + 1):
            target = (from_layer + d) % self.n_layers
            if d > 1 and target == (from_layer + 1) % self.n_layers:
                break            # n_layers == 1: distances alias
            if pred.obs[target] < self.min_obs:
                continue         # confidence gate: not enough evidence
            scores = pred.scores(from_layer, from_ids, d)
            p_use = self._p_useful(d)
            crit = pred.crit_frac(target) if lsb_allowed else None
            for e in np.nonzero(scores > 0)[0]:
                e = int(e)
                if not self._gate(scores[e], p_use):
                    continue
                key = SliceKey(target, e, "msb")
                if key not in pend and not is_resident(key):
                    nb = max(slice_bytes(key), 1e-12)
                    cands.append((scores[e] * p_use / nb,
                                  self._rng.random(), key, d))
                if crit is not None and crit[e] >= self.lsb_crit_frac:
                    lkey = SliceKey(target, e, "lsb")
                    if lkey not in pend and not is_resident(lkey):
                        lnb = max(slice_bytes(lkey), 1e-12)
                        cands.append(
                            (scores[e] * crit[e] * p_use / lnb,
                             self._rng.random(), lkey, d))
        cands.sort(key=lambda c: (-c[0], c[1]))
        return [(key, d) for _, _, key, d in cands[: self.top_m]]

    def plan_prefill(self, *, is_resident: Callable[[SliceKey], bool],
                     slice_bytes: Callable[[SliceKey], float],
                     pending: Sequence[SliceKey] = (),
                     budget: Optional[int] = None) -> List[tuple]:
        """Admission-time issuance from the freshly seeded activation
        matrix, called once per request after the prefill charge and the
        warmup reshape have settled residency.

        A request's prompt routing is already predictive of its decode
        routing (MoE-Infinity's key observation; measured here at
        P(demanded within 3 steps) ≈ 0.8 for per-token selection
        fractions ≥ 0.15), and the warmup reshape keeps *globally* hot
        experts — evicting exactly the request-specific experts this
        request will re-demand.  Candidates are scored by the *fresh*
        per-token selection fraction of the admission's own prompt
        (``pfrac`` — not the cross-request ``freq`` EMA, whose stale
        mass from departed tenants is exactly the wasted-fill tail)
        across **all** layers at once (distance bucket 0), ranked by
        expected benefit per Flash byte, with a per-request budget of
        ``top_m x n_layers`` fills.

        Returns ``[(SliceKey, 0), ...]`` like :meth:`plan`.
        """
        pend = set(pending)
        pred = self.predictor
        p_use = self._p_useful(0)
        cands: List[tuple] = []
        for layer in range(self.n_layers):
            if pred.obs[layer] < self.min_obs:
                continue
            scores = self.request_weight * pred.pfrac[layer]
            for e in np.nonzero(scores > 0)[0]:
                e = int(e)
                if not self._gate(scores[e], p_use):
                    continue
                key = SliceKey(layer, e, "msb")
                if key not in pend and not is_resident(key):
                    nb = max(slice_bytes(key), 1e-12)
                    cands.append((scores[e] * p_use / nb,
                                  self._rng.random(), key))
        cands.sort(key=lambda c: (-c[0], c[1]))
        if budget is None:
            budget = self.top_m * self.n_layers
        return [(key, 0) for _, _, key in cands[:budget]]

    # ---------------------------------------------------------- accounting
    def mark_issued(self, n: int = 1, distance: int = 1) -> None:
        self.issued += n
        self.in_flight += n
        self.dist_issued[min(distance, len(self.dist_issued) - 1)] += n

    def mark_useful(self, n: int = 1, distance: int = 1) -> None:
        self.useful += n
        self.in_flight -= n
        self.dist_useful[min(distance, len(self.dist_useful) - 1)] += n

    def mark_late(self, n: int = 1, distance: int = 1) -> None:
        self.late += n
        self.in_flight -= n

    def mark_wasted(self, n: int = 1, distance: int = 1) -> None:
        self.wasted += n
        self.in_flight -= n

    @property
    def accuracy(self) -> float:
        return self.useful / max(self.issued, 1)

    def clone(self) -> "RequestPrefetcher":
        """Deep copy: predictor matrices, rng streams, outcome counters.
        A forked replay's predictor evolves independently from the fork
        point (asserted by the invariant suite)."""
        import copy

        return copy.deepcopy(self)

    def summary(self) -> dict:
        return {
            "kind": self.kind,
            "issued": self.issued,
            "useful": self.useful,
            "late": self.late,
            "wasted": self.wasted,
            "in_flight": self.in_flight,
            "accuracy": self.accuracy,
            "min_obs": self.min_obs,
            "lookahead": self.lookahead,
            "min_score": self.min_score,
            "observed_layers": int(self.predictor.obs.sum()),
            # index 0: prefill-seeded (admission-time) fills; 1..lookahead:
            # decode-time issuance distances.
            "p_useful_by_distance": [
                round(self._p_useful(d), 4)
                for d in range(len(self.dist_issued))],
        }
