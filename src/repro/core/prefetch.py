"""Predictive expert prefetching — the baseline the paper argues against.

Paper §2.1: "Predictive schemes such as prefetching and speculative
caching [17-20] improve locality but become increasingly unreliable in
modern MoE … strong router regularization leads to stochastic routing
patterns and frequent prefetch failures."

We implement the standard layer-transition predictor (Pre-gated-MoE /
ProMoE style): an online co-occurrence model
``P(expert_j at layer l+1 | expert_i at layer l)`` trained on observed
routing traces, used during decode to pull the top-m predicted experts
of the next layer into DRAM before that layer executes.  Mispredictions
cost real Flash reads (charged to the ledger) without saving future
misses — exactly the failure mode the paper describes for
diversity-regularized routers.

On the asynchronous decode timeline (``EngineConfig.async_io``) each
prediction becomes a fill issued on the Flash channel *behind* the
current layer's demand fills; the engine classifies every issued
prefetch into one of three outcomes:

* **useful** — the predicted slice was demanded by its consuming layer
  and its transfer landed before that layer started;
* **late** — demanded, but the transfer was still in flight when the
  layer needed it (the layer stalls on the tail of the transfer; some
  latency is still hidden, but the paper's "before the layer starts"
  usefulness bar is missed);
* **wasted** — never demanded: pure Flash/DRAM energy burned
  (``CostLedger.prefetch_wasted_energy_j``).

Outcomes are judged against the *predicted consuming layer* (the next
layer of the current step), the paper's §2.1 usefulness bar.  A
"wasted" fill whose slice survives in the cache and serves a *later*
step's demand shows up as an ordinary demand hit — that residual
benefit is credited to the cache, not to the prefetcher, and its fill
energy stays attributed as prefetch waste.

Two fixes over the original implementation (both regression-tested):

1. ``predict`` takes an optional **residency mask** — predicting an
   expert that is already cached wastes a prefetch slot on a guaranteed
   no-op, crowding out predictions that could actually save a miss;
2. ties are broken by a **seeded random permutation** instead of
   ``argsort``'s index order.  Under the uniform smoothing prior a cold
   predictor used to emit experts ``0..m-1`` every time, systematically
   (and invisibly) favoring low-numbered experts.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class TransitionPrefetcher:
    n_layers: int
    n_experts: int
    top_m: int = 4
    smoothing: float = 0.1
    seed: int = 0
    # Confidence floor: a layer transition must have been observed at
    # least this many times before predict() issues for it.  With 0 the
    # cold predictor guesses from the uniform smoothing prior — near-
    # random fills that burn Flash energy with ~no chance of saving a
    # miss (the paper's §2.1 "frequent prefetch failures").
    min_transitions: int = 0

    def __post_init__(self):
        # counts[l, i, j]: expert i used at layer l, expert j at layer l+1
        self.counts = np.full(
            (max(self.n_layers - 1, 1), self.n_experts, self.n_experts),
            self.smoothing)
        # obs[l]: observed (layer l -> l+1) transition events — the
        # confidence-floor denominator (smoothing prior excluded).
        self.obs = np.zeros(max(self.n_layers - 1, 1), np.int64)
        self._rng = np.random.default_rng(self.seed)
        self.issued = 0
        self.useful = 0
        self.late = 0
        self.wasted = 0

    def _valid_ids(self, experts: np.ndarray) -> np.ndarray:
        """Unique in-range expert ids.  ``mask_routing`` emits the
        sentinel id ``n_experts`` for padding slots; indexing the
        transition counts with it used to raise IndexError, so masked
        slots are dropped here instead."""
        ids = np.unique(np.asarray(experts).reshape(-1))
        return ids[(ids >= 0) & (ids < self.n_experts)]

    # --------------------------------------------------------------- learn
    def observe(self, layer: int, prev_experts: np.ndarray,
                cur_experts: np.ndarray) -> None:
        """Record a (layer-1 -> layer) transition from a routing trace."""
        if layer <= 0 or layer > self.counts.shape[0]:
            return
        pe = self._valid_ids(prev_experts)
        ce = self._valid_ids(cur_experts)
        if pe.size == 0 or ce.size == 0:
            return
        self.counts[layer - 1][np.ix_(pe, ce)] += 1.0
        self.obs[layer - 1] += 1

    # -------------------------------------------------------------- predict
    def predict(self, layer: int, cur_experts: np.ndarray,
                resident: Optional[np.ndarray] = None) -> np.ndarray:
        """Top-m predicted experts for ``layer + 1``.

        ``resident``: optional ``[n_experts]`` bool mask of experts whose
        target slice is already cached — they are excluded so every
        returned prediction corresponds to a fill that could save a miss.
        Score ties are broken by a seeded random permutation (drawn per
        call, deterministic for a given construction seed and call
        sequence), not by expert index.
        """
        # n_layers - 1, not counts.shape[0]: the counts buffer is floored
        # to one transition matrix, so a 1-layer model would otherwise
        # "predict" for a layer that does not exist.
        if layer < 0 or layer >= self.n_layers - 1:
            return np.empty(0, np.int64)
        # Confidence floor: stay silent until this transition has enough
        # real observations that the scores are no longer the prior.
        if self.obs[layer] < self.min_transitions:
            return np.empty(0, np.int64)
        ce = self._valid_ids(cur_experts)
        if ce.size == 0:
            return np.empty(0, np.int64)
        scores = self.counts[layer][ce].sum(axis=0)
        candidates = np.arange(self.n_experts)
        if resident is not None:
            keep = ~np.asarray(resident, bool)
            candidates = candidates[keep]
            scores = scores[keep]
        if candidates.size == 0:
            return np.empty(0, np.int64)
        perm = self._rng.permutation(candidates.size)
        order = perm[np.argsort(-scores[perm], kind="stable")]
        return candidates[order[: self.top_m]].astype(np.int64)

    def clone(self) -> "TransitionPrefetcher":
        """Deep copy (transition counts, rng state, outcome counters) so a
        forked replay simulation keeps an independent predictor whose tie
        -break stream continues deterministically from the fork point."""
        import copy

        return copy.deepcopy(self)

    # ---------------------------------------------------------- accounting
    def mark_issued(self, n: int = 1) -> None:
        self.issued += n

    def mark_useful(self, n: int = 1) -> None:
        self.useful += n

    def mark_late(self, n: int = 1) -> None:
        self.late += n

    def mark_wasted(self, n: int = 1) -> None:
        self.wasted += n

    @property
    def accuracy(self) -> float:
        return self.useful / max(self.issued, 1)

    def summary(self) -> dict:
        return {
            "issued": self.issued,
            "useful": self.useful,
            "late": self.late,
            "wasted": self.wasted,
            "accuracy": self.accuracy,
            "min_transitions": self.min_transitions,
            "observed_transitions": int(self.obs.sum()),
        }
