"""Predictive expert prefetching — the baseline the paper argues against.

Paper §2.1: "Predictive schemes such as prefetching and speculative
caching [17-20] improve locality but become increasingly unreliable in
modern MoE … strong router regularization leads to stochastic routing
patterns and frequent prefetch failures."

We implement the standard layer-transition predictor (Pre-gated-MoE /
ProMoE style): an online co-occurrence model
``P(expert_j at layer l+1 | expert_i at layer l)`` trained on observed
routing traces, used during decode to pull the top-m predicted experts
of the next layer into DRAM before that layer executes.  Mispredictions
cost real Flash reads (charged to the ledger) without saving future
misses — exactly the failure mode the paper describes for
diversity-regularized routers.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TransitionPrefetcher:
    n_layers: int
    n_experts: int
    top_m: int = 4
    smoothing: float = 0.1

    def __post_init__(self):
        # counts[l, i, j]: expert i used at layer l, expert j at layer l+1
        self.counts = np.full(
            (max(self.n_layers - 1, 1), self.n_experts, self.n_experts),
            self.smoothing)
        self.issued = 0
        self.useful = 0

    # --------------------------------------------------------------- learn
    def observe(self, layer: int, prev_experts: np.ndarray,
                cur_experts: np.ndarray) -> None:
        """Record a (layer-1 -> layer) transition from a routing trace."""
        if layer <= 0 or layer > self.counts.shape[0]:
            return
        pe = np.unique(prev_experts.reshape(-1))
        ce = np.unique(cur_experts.reshape(-1))
        self.counts[layer - 1][np.ix_(pe, ce)] += 1.0

    # -------------------------------------------------------------- predict
    def predict(self, layer: int, cur_experts: np.ndarray) -> np.ndarray:
        """Top-m predicted experts for ``layer + 1``."""
        if layer >= self.counts.shape[0]:
            return np.empty(0, np.int64)
        ce = np.unique(cur_experts.reshape(-1))
        scores = self.counts[layer][ce].sum(axis=0)
        return np.argsort(-scores)[: self.top_m]

    def mark_issued(self, n: int = 1) -> None:
        self.issued += n

    def mark_useful(self, n: int = 1) -> None:
        self.useful += n

    @property
    def accuracy(self) -> float:
        return self.useful / max(self.issued, 1)
