"""Expert placement as a first-class policy (ROADMAP item 3).

PR 5's expert parallelism hardcoded ownership as ``expert % ep_shards``
— a pure modulo consumed verbatim by the charge paths, the ledger,
replay, and telemetry.  Round-robin is blind to the Zipf-like expert
hotness that dominates real MoE activation traces (MoE-Infinity, arXiv
2401.14361), so at ep=4 per-shard miss rates span 0.14–0.23: hot shards
thrash while cold shards idle.

This module makes the ownership decision a *table*, not a formula:

* :class:`PlacementMap` — an explicit ``[L, E] -> shard`` owner table
  plus a ``[L, E]`` replication mask.  Everything downstream
  (``ShardedSliceCache`` key routing, the engine's per-expert ledger
  dispatch, all-to-all accounting, telemetry) keys off this map.
* :class:`RoundRobinPlacement` — reproduces the pre-refactor modulo
  bit-identically and never migrates.
* :class:`HotnessPlacement` — greedy balanced bin-packing of
  hotness-ranked experts, recomputed periodically by the engine with
  migration bytes charged on the ``ici`` interconnect channel; with
  ``replicate_k > 0`` the k globally hottest (layer, expert) pairs are
  replicated on *every* shard so dispatch resolves to the token's home
  shard and all-to-all volume drops.

Determinism / replay fidelity: policies are pure functions of the
hotness array handed to :meth:`PlacementPolicy.replace`.  The engine
feeds them charge-path hotness (``HotnessTracker``) at decode-step
boundaries only, so a trace replay — which drives the identical charge
path — reproduces every placement decision and migration bit-for-bit
(same argument as the PR 6 controller).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

__all__ = [
    "PlacementMap",
    "PlacementPolicy",
    "RoundRobinPlacement",
    "HotnessPlacement",
    "parse_placement_spec",
    "build_placement_policy",
]


@dataclasses.dataclass(frozen=True)
class PlacementMap:
    """Explicit expert→shard ownership table.

    ``owner[l, e]`` is the home shard of expert ``e`` at MoE layer
    ``l``; ``replicated[l, e]`` marks experts that additionally hold a
    replica on every shard (dispatch then resolves to the token's home
    shard, so the access never crosses the interconnect).
    """

    owner: np.ndarray        # [L, E] int, values in [0, n_shards)
    replicated: np.ndarray   # [L, E] bool
    n_shards: int

    def __post_init__(self):
        owner = np.asarray(self.owner, dtype=np.int64)
        rep = np.asarray(self.replicated, dtype=bool)
        if owner.shape != rep.shape or owner.ndim != 2:
            raise ValueError(
                f"owner {owner.shape} / replicated {rep.shape} must be "
                "matching [n_layers, n_experts] tables")
        if owner.size and (owner.min() < 0 or owner.max() >= self.n_shards):
            raise ValueError(
                f"owner table references shard outside [0, {self.n_shards})")
        object.__setattr__(self, "owner", owner)
        object.__setattr__(self, "replicated", rep)

    # ------------------------------------------------------------ queries
    @property
    def n_layers(self) -> int:
        return int(self.owner.shape[0])

    @property
    def n_experts(self) -> int:
        return int(self.owner.shape[1])

    def owner_of(self, layer: int, expert: int) -> int:
        return int(self.owner[layer, expert])

    def is_replicated(self, layer: int, expert: int) -> bool:
        return bool(self.replicated[layer, expert])

    def shards_of(self, layer: int, expert: int) -> Tuple[int, ...]:
        """Every shard holding (a replica of) the expert, owner first."""
        o = self.owner_of(layer, expert)
        if not self.is_replicated(layer, expert):
            return (o,)
        return (o,) + tuple(s for s in range(self.n_shards) if s != o)

    def owner_row(self, layer: int) -> np.ndarray:
        """``[E]`` owner shard per expert at ``layer`` (read-only view)."""
        return self.owner[layer]

    def replicated_row(self, layer: int) -> np.ndarray:
        return self.replicated[layer]

    def experts_of_shard(self, layer: int, shard: int) -> List[int]:
        """Experts resident on ``shard`` at ``layer`` (owned or replica)."""
        own = np.nonzero((self.owner[layer] == shard)
                         | self.replicated[layer])[0]
        return [int(e) for e in own]

    def __eq__(self, other) -> bool:
        if not isinstance(other, PlacementMap):
            return NotImplemented
        return (self.n_shards == other.n_shards
                and np.array_equal(self.owner, other.owner)
                and np.array_equal(self.replicated, other.replicated))

    def __hash__(self):  # frozen dataclass with arrays: identity hash
        return id(self)

    # ------------------------------------------------------ constructors
    @classmethod
    def round_robin(cls, n_layers: int, n_experts: int,
                    n_shards: int) -> "PlacementMap":
        """The pre-refactor modulo, as a table: ``owner[l, e] = e % S``."""
        owner = np.tile(np.arange(n_experts, dtype=np.int64) % n_shards,
                        (n_layers, 1))
        return cls(owner=owner,
                   replicated=np.zeros((n_layers, n_experts), bool),
                   n_shards=n_shards)


class PlacementPolicy:
    """Decides the :class:`PlacementMap`; the engine owns *when* to ask.

    ``migrates`` tells the engine whether periodic re-placement is ever
    worth triggering (round_robin never changes, so the engine skips the
    hotness snapshot entirely and stays bit-identical to pre-refactor).
    """

    name: str = "base"
    migrates: bool = False

    def __init__(self, n_layers: int, n_experts: int, n_shards: int):
        self.n_layers = int(n_layers)
        self.n_experts = int(n_experts)
        self.n_shards = int(n_shards)

    def initial(self) -> PlacementMap:
        """Placement before any hotness has been observed."""
        return self.replace(np.zeros((self.n_layers, self.n_experts)))

    def replace(self, hotness: np.ndarray) -> PlacementMap:
        raise NotImplementedError


class RoundRobinPlacement(PlacementPolicy):
    """Today's behavior, bit-identical: ``owner[l, e] = e % S``, never
    re-placed, nothing replicated."""

    name = "round_robin"
    migrates = False

    def replace(self, hotness: np.ndarray) -> PlacementMap:
        return PlacementMap.round_robin(
            self.n_layers, self.n_experts, self.n_shards)


class HotnessPlacement(PlacementPolicy):
    """Greedy balanced bin-packing of hotness-ranked experts.

    Per layer, experts are visited in descending hotness (ties: lower
    expert id first) and each is assigned to the shard with the least
    accumulated hotness load — ties broken by fewest experts assigned,
    then lowest shard id.  The count tie-break makes the zero-hotness
    degenerate case collapse *exactly* to round-robin, so a cold engine
    starts from the pre-refactor placement and only diverges once the
    tracker has observed real traffic.

    With ``replicate_k > 0`` the k hottest (layer, expert) pairs across
    the whole model (ties: lower layer, then lower expert) are marked
    replicated: each shard keeps its own copy, charged against its own
    DRAM budget, and dispatch resolves to the token's home shard.
    """

    migrates = True

    def __init__(self, n_layers: int, n_experts: int, n_shards: int,
                 *, replicate_k: int = 0):
        super().__init__(n_layers, n_experts, n_shards)
        self.replicate_k = int(replicate_k)
        if self.replicate_k < 0:
            raise ValueError(f"replicate_k must be >= 0, got {replicate_k}")
        self.name = ("hotness" if not self.replicate_k
                     else f"hotness+replicate:{self.replicate_k}")

    def replace(self, hotness: np.ndarray) -> PlacementMap:
        hot = np.asarray(hotness, dtype=np.float64)
        if hot.shape != (self.n_layers, self.n_experts):
            raise ValueError(
                f"hotness shape {hot.shape} != "
                f"({self.n_layers}, {self.n_experts})")
        L, E, S = self.n_layers, self.n_experts, self.n_shards
        owner = np.zeros((L, E), dtype=np.int64)
        for l in range(L):
            # Descending hotness; np.lexsort's last key dominates, ties
            # fall through to ascending expert id for determinism.
            order = np.lexsort((np.arange(E), -hot[l]))
            load = [0.0] * S
            count = [0] * S
            for e in order:
                sid = min(range(S), key=lambda s: (load[s], count[s], s))
                owner[l, e] = sid
                load[sid] += float(hot[l, e])
                count[sid] += 1
        replicated = np.zeros((L, E), bool)
        if self.replicate_k > 0 and S > 1:
            flat = hot.reshape(-1)
            # Hottest first; ties resolve to lower (layer, expert).
            order = np.lexsort((np.arange(flat.size), -flat))
            for idx in order[: self.replicate_k]:
                replicated.reshape(-1)[idx] = True
        return PlacementMap(owner=owner, replicated=replicated, n_shards=S)


def parse_placement_spec(spec: str) -> Tuple[str, int]:
    """``"round_robin" | "hotness" | "hotness+replicate:K"`` →
    ``(policy_name, replicate_k)``.  Raises ``ValueError`` on junk."""
    s = (spec or "round_robin").strip()
    if s == "round_robin":
        return "round_robin", 0
    if s == "hotness":
        return "hotness", 0
    if s.startswith("hotness+replicate:"):
        try:
            k = int(s.split(":", 1)[1])
        except ValueError:
            raise ValueError(f"bad replicate count in placement spec {spec!r}")
        if k <= 0:
            raise ValueError(
                f"replicate count must be positive in placement spec {spec!r}")
        return "hotness", k
    raise ValueError(
        f"unknown placement spec {spec!r} (expected 'round_robin', "
        "'hotness', or 'hotness+replicate:K')")


def build_placement_policy(spec: str, n_layers: int, n_experts: int,
                           n_shards: int, *,
                           replicate_k: Optional[int] = None
                           ) -> PlacementPolicy:
    """Factory: spec string (+ optional explicit replicate_k override)
    → policy instance.  ``replicate_k`` passed separately wins over a
    ``+replicate:K`` suffix so the engine-config knob stays scalar."""
    name, spec_k = parse_placement_spec(spec)
    k = spec_k if replicate_k is None else int(replicate_k)
    if name == "round_robin":
        if k:
            raise ValueError(
                "replicate_k > 0 requires the hotness placement policy")
        return RoundRobinPlacement(n_layers, n_experts, n_shards)
    return HotnessPlacement(n_layers, n_experts, n_shards, replicate_k=k)
