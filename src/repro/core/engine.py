"""SliceMoE inference engine (paper §5-6): the orchestrator.

Runs a *real* JAX MoE model token-by-token while simulating the
DRAM/Flash offload hierarchy.  Per decode step:

  1. the jitted ``decode_step`` runs with the current cache residency
     masks, the static :class:`RoutingPolicy` and the Cache-Prior boost
     ``alpha`` — it returns next-token logits plus per-layer traces
     (selected experts, gates, criticality, slice demand);
  2. the Python-side :class:`SliceCache` replays the slice demand
     (MSB always; LSB per DBSC criticality), records hits/misses and
     charges the :class:`CostLedger` (Flash fill on miss, DRAM read on
     use, XPU matmul energy at the computed precision);
  3. the :class:`MissRateController` updates ``alpha`` from the rolling
     miss rate (activating after the paper's 10-step warmup window).

Prefill runs once, layer-parallel, collecting the hotness statistics PCW
needs; the prefill→decode transition applies the selected cache
initialization (``pcw`` or one of the Fig. 10 baselines).

State is split into two tiers so one engine can serve many requests
(the continuous-batching scheduler in :mod:`repro.serving.scheduler`):

* :class:`PersistentEngine` — *shared* state: the jitted prefill/decode
  functions, the quantized slice store, the :class:`SliceCache`, the
  :class:`HotnessTracker` and the :class:`CostLedger`.  These survive
  across requests: a warm cache turns later requests' expert fetches
  into hits, and PCW reshapes from *accumulated* hotness rather than
  only the current prompt's prefill.
* per-request state — the KV cache, the step counter and the
  miss-rate-controller ``alpha``.  The scheduler keeps one of each per
  active sequence; :class:`SliceMoEEngine` (the original single-request
  API) keeps exactly one.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.amat import MatConfig
from repro.core.cache import SliceCache
from repro.core.routing import MissRateController
from repro.core.shard import (ShardedSliceCache, expert_placement,
                              home_shard_of_token, remote_selection_mask,
                              shard_of_expert)
from repro.core.slices import SliceKey, quantize_moe_params
from repro.core.warmup import (HotnessTracker, INIT_STATES, pcw_reshape)
from repro.hw.energy import CostLedger, ShardedCostLedger
from repro.hw.specs import SYSTEM_PROFILES
from repro.models.moe import RoutingPolicy
from repro.models import model as MDL


@dataclasses.dataclass
class EngineConfig:
    mat: MatConfig = dataclasses.field(
        default_factory=lambda: MatConfig(8, 4))
    cache_bytes: float = 64e6
    policy: RoutingPolicy = dataclasses.field(default_factory=RoutingPolicy)
    miss_rate_target: Optional[float] = None      # e.g. 0.05
    warmup: str = "pcw"        # 'pcw' | 'empty' | 'last_layer' | 'random'
    lsb_keep_frac: float = 0.125
    system: str = "mobile_soc"
    max_seq: int = 256
    # Whole-expert caching (high-bit baseline): both slices move together.
    fused_slices: bool = False
    # Layer-transition expert prefetching (the paper's §2.1 baseline):
    # pull the top-m predicted next-layer experts into DRAM per layer.
    # None disables.
    prefetch_top_m: Optional[int] = None
    # Asynchronous slice-I/O timeline: replay decode as a per-expert
    # fill -> DRAM-read -> matmul pipeline over the ledger's channel
    # clocks (Flash / DRAM / XPU), with prefetch fills issued behind
    # demand fills on the Flash channel.  False reproduces the
    # serialized (paper Figs. 9-10) accounting exactly.
    async_io: bool = False
    # Cross-request hotness aging applied at each request boundary by the
    # persistent engine (1.0 = never forget, 0.0 = per-request hotness).
    hotness_request_decay: float = 0.5
    # Expert-parallel sharding: partition the experts of every MoE layer
    # across this many shards (round-robin on the expert id, the mesh
    # `model` axis placement).  Each shard owns its own slice cache
    # segment (cache_bytes / ep_shards — iso aggregate DRAM) and its own
    # Flash/DRAM/XPU channel clocks; token dispatch to remote experts is
    # charged on the interconnect channel.  1 = the single-device model.
    ep_shards: int = 1
    # Prefetch confidence floor: a target layer must have been observed
    # at least this many times before the prefetcher issues fills for it
    # (0 = issue immediately).  Suppresses cold-start blind fills that
    # burn Flash energy.  Applies to both predictor kinds (the
    # transition baseline reads it as its min_transitions).
    prefetch_min_obs: int = 0
    # Which predictor drives prefetch_top_m:
    #   'request'    — request-level activation matrices with cyclic
    #                  multi-layer-ahead targets (MoE-Infinity style;
    #                  the only kind that can land fills in time in the
    #                  I/O-bound decode regime);
    #   'transition' — the single-step Markov baseline (paper §2.1).
    prefetch_kind: str = "request"
    # Request predictor: how many layers ahead plan() may target
    # (cyclic — distances past the end of the step wrap to the next
    # decode step, which is where the real slack is).
    prefetch_lookahead: int = 2
    # Request predictor: activation-share floor below which a candidate
    # is never issued (shares sum to <= 1 across experts).
    prefetch_min_score: float = 0.02
    # Online SLO controller (repro.control.controller.ControllerConfig):
    # per-tenant closed-loop bit-plan / cache-partition / admission
    # adaptation.  None = static policy (everything above as configured).
    controller: Optional["ControllerConfig"] = None
    # Expert placement policy across EP shards (repro.core.placement):
    #   'round_robin'          — the pre-refactor expert % ep modulo,
    #                            bit-identical, never migrates;
    #   'hotness'              — greedy balanced bin-packing of hotness-
    #                            ranked experts, re-placed every
    #                            placement_period decode steps with
    #                            migration bytes charged on the ici
    #                            channel;
    #   'hotness+replicate:K'  — hotness plus the K globally hottest
    #                            experts replicated on every shard
    #                            (dispatch resolves to the token's home
    #                            shard; replicas charge each shard's own
    #                            DRAM budget).
    # Ignored (after validation) when ep_shards == 1.
    placement: str = "round_robin"
    # Decode steps between hotness re-placements (migration cadence).
    placement_period: int = 64
    # Replication count for the hotness policy (scalar alternative to
    # the '+replicate:K' spec suffix; the explicit knob wins).  Requires
    # placement='hotness'.
    replicate_k: int = 0

    def cache(self, *, placement=None):
        slice_aware = self.policy.slice_mode == "dbsc" and not self.fused_slices
        if self.controller is not None and self.controller.partition:
            if self.ep_shards > 1:
                raise ValueError(
                    "controller cache partitioning and ep_shards > 1 are "
                    "mutually exclusive: the DRAM budget cannot be split "
                    "along both the tenant and the placement axis")
            from repro.control.partition import TenantPartitionedCache
            return TenantPartitionedCache(
                self.cache_bytes, sorted(self.controller.slos),
                shared_frac=self.controller.shared_frac,
                slice_aware=slice_aware)
        if self.ep_shards > 1:
            return ShardedSliceCache(self.cache_bytes, self.ep_shards,
                                     slice_aware=slice_aware,
                                     placement=placement)
        return SliceCache(self.cache_bytes, slice_aware=slice_aware)

    def ledger(self):
        system = SYSTEM_PROFILES[self.system]
        if self.ep_shards > 1:
            return ShardedCostLedger(system, self.ep_shards)
        return CostLedger(system=system)

    def build_prefetcher(self, n_layers: int, n_experts: int):
        """The configured predictor (or None) — one factory shared by
        the live engine and the trace-replay engine so a sweep toggling
        ``prefetch_kind`` exercises the identical construction."""
        if not self.prefetch_top_m:
            return None
        if self.prefetch_kind == "transition":
            from repro.core.prefetch import TransitionPrefetcher
            return TransitionPrefetcher(
                n_layers, n_experts, top_m=self.prefetch_top_m,
                min_transitions=self.prefetch_min_obs)
        if self.prefetch_kind == "request":
            from repro.core.prefetch import RequestPrefetcher
            return RequestPrefetcher(
                n_layers, n_experts, top_m=self.prefetch_top_m,
                lookahead=self.prefetch_lookahead,
                min_obs=self.prefetch_min_obs,
                min_score=self.prefetch_min_score)
        raise ValueError(
            f"unknown prefetch_kind {self.prefetch_kind!r}; "
            "expected 'request' or 'transition'")

    def build_placement_policy(self, n_layers: int, n_experts: int):
        """The configured placement policy, or None on a single device.

        Shared by the live engine and the trace-replay engine (like
        :meth:`build_prefetcher`) so a sweep toggling ``placement``
        exercises the identical construction.  The spec is validated
        even at ``ep_shards == 1`` — a bad placement string fails fast
        rather than only once sharding is turned on.
        """
        from repro.core.placement import build_placement_policy
        pol = build_placement_policy(
            self.placement, n_layers, n_experts, max(self.ep_shards, 1),
            replicate_k=self.replicate_k if self.replicate_k else None)
        return pol if self.ep_shards > 1 else None


@dataclasses.dataclass
class StepCharge:
    """Result of replaying one decode step into the cache + ledger."""

    miss_rate: float                      # fleet expert-level miss rate
    accesses: int
    misses: int
    per_slot_miss: np.ndarray             # [B] selection-weighted miss rate
    ledger_delta: dict                    # cost delta for this step
    # Per-tenant charge-path counters {tenant: {tokens, accesses, misses,
    # critical, critical_low}} — the SLO controller's input signal.  None
    # unless slot tenants were supplied or a controller is attached.
    per_tenant: Optional[dict] = None


@dataclasses.dataclass
class _StepTrace:
    """One decode step's routing trace + mutable replay counters.

    Hoisted out of the jit aux once per step so the sync and async replay
    paths share identical demand inputs and miss bookkeeping.
    """

    ids: np.ndarray                       # [P, npos, T, k]
    gates: np.ndarray
    active: np.ndarray
    critical: np.ndarray
    slot_mask: np.ndarray                 # [T] bool
    slot_accesses: np.ndarray             # [T] int64 (mutated during replay)
    slot_misses: np.ndarray
    accesses: int = 0
    misses: int = 0
    # Tenant attribution: [T] tenant names (None entries = unattributed
    # slots).  Recorded into traces; drives the controller's per-tenant
    # signals and the partitioned cache's fill routing.
    slot_tenants: Optional[list] = None
    # Controller bit plan for this step: [T] int8, 0 = full AMAT plan,
    # 1 = demoted to MSB-only.  Set by the engine *after* the recorder
    # sees the trace (the plan is recomputed on replay, never recorded).
    slot_bit_level: Optional[np.ndarray] = None
    # Accuracy-proxy counters (mutated during replay, controller-only):
    # per-slot critical selections, and those served at low precision.
    slot_critical: Optional[np.ndarray] = None
    slot_critical_low: Optional[np.ndarray] = None

    @property
    def P(self) -> int:
        return self.ids.shape[0]

    @classmethod
    def from_aux(cls, aux, slot_active: Optional[np.ndarray],
                 slot_tenants: Optional[list] = None) -> "_StepTrace":
        ids = np.asarray(aux["moe"]["ids"])            # [P, npos, T, k]
        T = ids.shape[2]
        slot_mask = np.ones(T, bool) if slot_active is None \
            else np.asarray(slot_active, bool)
        return cls(
            ids=ids,
            gates=np.asarray(aux["moe"]["gates"]).astype(np.float64),
            active=np.asarray(aux["moe"]["active"]),
            critical=np.asarray(aux["moe"]["critical"]),
            slot_mask=slot_mask,
            slot_accesses=np.zeros(T, np.int64),
            slot_misses=np.zeros(T, np.int64),
            slot_tenants=slot_tenants,
        )


class PersistentEngine:
    """Shared-state engine: one instance serves many requests.

    Holds everything that must survive across requests (jitted fns, slice
    store, :class:`SliceCache`, :class:`HotnessTracker`,
    :class:`CostLedger`) and exposes stateless-per-request entry points:
    ``run_prefill`` produces a fresh KV cache against the *warm* shared
    cache, ``decode_batch`` advances a batch of sequences one token.
    """

    def __init__(self, cfg: ModelConfig, params: dict, ecfg: EngineConfig):
        if not cfg.has_moe:
            raise ValueError(f"{cfg.name} has no MoE layers; SliceMoE "
                             "expert caching is inapplicable (see DESIGN.md)")
        self.cfg = cfg
        self.ecfg = ecfg
        self.qparams, self.store, self.layer_map = quantize_moe_params(
            params, cfg, ecfg.mat,
            quant_execution=ecfg.policy.quant_execution)
        self.float_params = params
        self.n_moe_layers = len(self.layer_map)
        self.n_experts = cfg.moe.n_experts

        # Expert placement across EP shards: the policy decides the
        # [L, E] -> shard ownership table; the cache routes keys by it.
        # None on a single device (and the legacy modulo inside
        # ShardedSliceCache remains for direct constructions).
        self.placement_policy = ecfg.build_placement_policy(
            self.n_moe_layers, self.n_experts)
        self.placement = (self.placement_policy.initial()
                          if self.placement_policy is not None else None)
        # Placement re-packing bookkeeping: decode-step counter driving
        # the migration cadence, and the executed migration events
        # [{step, moved, bytes}] — the replay fidelity gate compares
        # this sequence exactly.
        self._decode_steps = 0
        self.migration_events: List[dict] = []

        self.cache = ecfg.cache(placement=self.placement)
        self.ledger = ecfg.ledger()
        self.tracker = HotnessTracker(self.n_moe_layers, self.n_experts)
        self.requests_served = 0
        # Optional routing-trace recorder (repro.sim.trace.TraceRecorder):
        # when attached, every prefill's and decode step's routing arrays
        # are captured so the run can be replayed offline without a model.
        self.recorder = None
        # Optional timeline tracer (repro.obs.timeline.TimelineTracer):
        # when attached via attach_tracer, every ledger charge emits one
        # attributed TraceEvent (see docs/observability.md).
        self.tracer = None

        # moe pattern positions in order (matches aux stacking order)
        self.moe_positions = [i for i, s in enumerate(cfg.block_pattern)
                              if s.ffn == "moe"]

        self.prefetcher = ecfg.build_prefetcher(
            self.n_moe_layers, self.n_experts)
        # Prefetches in flight across decode steps: target flat layer ->
        # {SliceKey: (ready_t, nbytes, distance)}.  The request
        # predictor's cyclic targets judge at the *next* execution of
        # the target layer, which may be next step — state must outlive
        # a single charge_step_trace call.
        self._pf_pending: dict = {}

        # Online SLO controller: closed-loop bit-plan / cache-partition
        # adaptation.  Named slo_controller (not controller) because the
        # per-request MissRateController occupies that name on the
        # single-request engine and the replay simulator.
        self.slo_controller = None
        if ecfg.controller is not None:
            from repro.control.controller import SLOController
            self.slo_controller = SLOController(
                ecfg.controller, cache_bytes=ecfg.cache_bytes)

        # BuddyMoE offline calibration (policy.kind == 'buddy'): nearest
        # expert by weight cosine similarity, per (position, period).
        self.buddies = None
        if ecfg.policy.kind == "buddy":
            from repro.core.routing import compute_buddies
            self.buddies = {}
            for i in self.moe_positions:
                wi = params["blocks"][f"pos{i}"]["moe"]["experts"]["wi"]
                P, E = wi.shape[0], wi.shape[1]
                flat = wi.reshape(P, E, -1)
                self.buddies[f"pos{i}"] = jnp.stack(
                    [compute_buddies(flat[p]) for p in range(P)])

        # Both jitted fns run the expert FFN on packed AMAT codes when
        # the policy selects quantized execution (prefill carries no
        # policy, so the flag is threaded explicitly; prefill computes
        # every expert high-bit — use_lsb defaults to all-ones inside
        # the kernel path).
        qe = ecfg.policy.quant_execution
        # Prefill routing follows the configured policy when it is
        # state-free (cumsum): cumulative-threshold selection deactivates
        # most of the k_max slots, and the charge path must see that
        # `active` mask or it over-charges fills and skews PCW hotness.
        # Compute stays high-bit either way (the paper's prefill
        # discipline); stateful kinds (cache_prior, buddy) need residency
        # masks that don't exist yet at prefill and keep natural top-k.
        prefill_policy = ecfg.policy if ecfg.policy.kind == "cumsum" \
            else None
        self._jit_prefill = jax.jit(partial(
            MDL.prefill, cfg=cfg, max_seq=ecfg.max_seq, collect_trace=True,
            mat=ecfg.mat, quant_execution=qe, policy=prefill_policy))
        self._jit_decode = jax.jit(partial(
            MDL.decode_step, cfg=cfg, collect_trace=True,
            policy=ecfg.policy, mat=ecfg.mat, quant_execution=qe))

        # Non-expert resident weight bytes touched per decode step (INT8
        # per the paper's G128 non-expert quantization).
        total = MDL.count_params(params)
        import numpy as _np
        expert_total = 0
        for i in self.moe_positions:
            e = params["blocks"][f"pos{i}"]["moe"]["experts"]
            expert_total += sum(int(_np.prod(x.shape)) for x in e.values())
        self.resident_bytes = float(total - expert_total)  # int8: 1 B/param

        # per-expert matmul dims for cost accounting
        m = cfg.moe
        wi_cols = 2 * m.d_ff if m.mlp_type in ("swiglu", "geglu") else m.d_ff
        self.expert_macs_per_token = cfg.d_model * wi_cols + m.d_ff * cfg.d_model

    # ------------------------------------------------------- introspection
    def expert_weight_bytes_per_step(self, *,
                                     quant_execution: Optional[bool] = None
                                     ) -> float:
        """Analytic HBM expert-weight traffic of one decode step.

        The batched expert FFN touches every expert's weights each step
        (inactive experts multiply zero rows).  Dense-dequant reads the
        packed codes, writes the dense tensor *at the model dtype's
        width* and reads it back into the matmul; quantized execution
        streams only the packed codes.  Shared accounting lives in
        :func:`repro.hw.energy.expert_weight_step_bytes`.
        """
        from repro.hw.energy import expert_weight_step_bytes

        if quant_execution is None:
            quant_execution = self.ecfg.policy.quant_execution
        import numpy as _np
        n_codes = n_groups = 0.0
        for le in self.store.layers.values():
            for q in (le.wi_q, le.wo_q):
                n_codes += float(_np.prod(q.codes.shape))
                n_groups += float(_np.prod(q.scales.shape))
        return expert_weight_step_bytes(
            n_codes, n_groups, quant_execution=quant_execution,
            dense_itemsize=jnp.dtype(self.cfg.dtype).itemsize)

    def shard_breakdown(self) -> Optional[List[dict]]:
        """Per-shard serving breakdown (None on a single-device engine).

        One row per shard: lifetime cache accesses/misses (archived
        epochs + the open window), Flash/DRAM traffic, energy and the
        shard's timeline makespan — the numbers the EP telemetry and the
        serving benchmark report.
        """
        if not isinstance(self.ledger, ShardedCostLedger) \
                or not isinstance(self.cache, ShardedSliceCache):
            return None
        rows = []
        counts = self.cache.per_shard_counts()
        if self.placement is not None:
            # Ownership can differ per layer under the hotness policy;
            # the row reports the first MoE layer's assignment as the
            # representative (identical across layers for round_robin).
            owner0 = self.placement.owner_row(0)
        else:
            owner0 = expert_placement(self.n_experts, self.ledger.n_shards)
        for sid, led in enumerate(self.ledger.shards):
            acc, miss = counts[sid]
            rows.append({
                "shard": sid,
                "experts": np.nonzero(owner0 == sid)[0].tolist(),
                "accesses": acc,
                "misses": miss,
                "miss_rate": miss / max(acc, 1),
                "flash_bytes": led.flash_bytes,
                "dram_bytes": led.dram_bytes,
                "energy_j": led.total_energy_j,
                "makespan_s": led.now,
            })
        return rows

    def placement_summary(self) -> Optional[dict]:
        """Placement policy + migration accounting (None unsharded)."""
        if self.placement is None:
            return None
        return {
            "policy": self.placement_policy.name,
            "period": int(self.ecfg.placement_period),
            "replicated_pairs": int(np.count_nonzero(
                self.placement.replicated)),
            "n_migration_events": len(self.migration_events),
            "migrated_slices": sum(e["moved"]
                                   for e in self.migration_events),
            "migration_bytes": float(
                getattr(self.ledger, "migration_bytes", 0.0)),
        }

    # --------------------------------------------------- per-request state
    def new_controller(self) -> Optional[MissRateController]:
        """Fresh per-request miss-rate controller (None if unconstrained)."""
        if self.ecfg.miss_rate_target is None:
            return None
        return MissRateController(self.ecfg.miss_rate_target)

    def init_batch_cache(self, max_batch: int) -> dict:
        """Batched KV-cache pytree with per-sequence positions."""
        cache = MDL.init_cache(self.cfg, max_batch, self.ecfg.max_seq)
        cache["pos"] = jnp.zeros((max_batch,), jnp.int32)
        return cache

    @staticmethod
    def install_slot(batch_cache: dict, request_cache: dict,
                     slot: int) -> dict:
        """Scatter a batch-1 prefill cache into ``slot`` of a batched cache.

        Leaves are ``[n_periods, B, ...]``; the prefill cache has B=1.
        Returns a new pytree (functional update).
        """
        out = {}
        for key, entry in batch_cache.items():
            if key == "pos":
                continue
            out[key] = {name: leaf.at[:, slot].set(
                request_cache[key][name][:, 0].astype(leaf.dtype))
                for name, leaf in entry.items()}
        out["pos"] = batch_cache["pos"].at[slot].set(
            jnp.asarray(request_cache["pos"], jnp.int32))
        return out

    @staticmethod
    def clear_slot(batch_cache: dict, slot: int) -> dict:
        """Retire ``slot``: reset its position (KV rows become dead)."""
        out = dict(batch_cache)
        out["pos"] = batch_cache["pos"].at[slot].set(0)
        return out

    # ------------------------------------------------------------- prefill
    def run_prefill(self, tokens: jax.Array, *,
                    label: Optional[str] = None, inflight: int = 0,
                    tenant: str = "default",
                    **model_kwargs):
        """Prefill one request against the warm shared cache.

        Simulates layer-streaming cache fills (hits on already-resident
        slices cost no Flash traffic — the cross-request win), applies the
        configured warmup transition from *accumulated* hotness, and
        returns ``(logits, kv_cache, info)`` without mutating any
        per-request state on the engine.

        ``label``: when set, the request's prefill hit/miss counters are
        archived as a cache stats epoch under ``{label}/prefill`` and a
        fresh window is opened for its decode phase.

        ``inflight``: sequences currently decoding.  The boundary decay
        exponent is scaled by ``1/(1+inflight)`` so that under concurrent
        batching — where admissions arrive many per request *completed* —
        accumulated hotness doesn't collapse with arrival rate.

        ``tenant``: attribution for this request's cache fills (prefill
        streaming *and* the warmup reshape installs) under a
        tenant-partitioned cache; ignored otherwise.
        """
        self._begin_request(label, inflight, tenant=tenant)

        logits, kv_cache, aux = self._jit_prefill(
            self.qparams, tokens=tokens, **model_kwargs)

        ids = np.asarray(aux["moe"]["ids"])      # [n_periods, n_moe_pos, T, k]
        gates = np.asarray(aux["moe"]["gates"]).astype(np.float64)
        # `active` exists when prefill ran a routing policy (cumsum):
        # deactivated slots must not charge fills or count as hotness.
        # An all-True mask carries no information — normalize it to None
        # so recorded traces don't serialize a redundant bool array per
        # prompt (replay semantics are identical).
        active = (np.asarray(aux["moe"]["active"], bool)
                  if "active" in aux["moe"] else None)
        if active is not None and active.all():
            active = None
        if self.recorder is not None:
            self.recorder.on_prefill(ids, gates, active=active,
                                     label=label, inflight=inflight,
                                     tenant=tenant)
        self._charge_prefill(ids, gates, active)
        info = self._finish_prefill(label)
        return logits, kv_cache, info

    # The three pieces below are the *model-free* half of prefill: they
    # consume only routing arrays plus cache/ledger/tracker state, so the
    # trace-replay simulator (repro.sim.replay) can drive them from a
    # recorded or synthetic trace with zero JAX involvement while staying
    # bit-identical to the live path above.
    def _begin_request(self, label: Optional[str], inflight: int,
                       tenant: str = "default") -> None:
        """Request-boundary bookkeeping: hotness aging + stats epoch.

        Also points the cache's fill attribution at the admitting
        request's tenant (sticky until the next request / decode-step
        override) — prefill fills and the PCW reshape land in that
        tenant's segment under a partitioned cache."""
        self.cache.set_active_tenant(tenant)
        if self.requests_served > 0:
            decay = self.ecfg.hotness_request_decay \
                ** (1.0 / (1.0 + max(inflight, 0)))
            self.tracker.begin_request(decay)
            if self.prefetcher is not None:
                # Request-level predictor state ages on the same
                # schedule as cache hotness (no-op on the transition
                # baseline, so pre-existing traces replay unchanged).
                self.prefetcher.begin_request(decay)
        self.requests_served += 1
        if label is not None:
            self.cache.begin_epoch(f"{label}/prefill")

    def _charge_prefill(self, ids: np.ndarray, gates: np.ndarray,
                        active: Optional[np.ndarray] = None) -> None:
        """Replay one prompt's layer-streaming fills + compute charges.

        ``ids``/``gates``/``active``: the prefill routing trace
        ``[n_periods, n_moe_pos, T, k]``.  ``active`` (None = all slots)
        masks deactivated selections — under cumsum routing most of the
        ``k_max`` slots carry zero gates and must charge neither fills
        nor hotness (mirrors ``_layer_demand``'s ``act2d`` handling).
        """
        if active is None:
            active = np.ones(ids.shape, bool)
        trc = self.tracer
        if trc is not None:
            trc.begin_prefill()
        # Layer-order streaming: for each flat moe layer (in execution
        # order), every expert *actively* selected by >=1 token is loaded
        # high-bit.
        for period in range(ids.shape[0]):
            for pidx, pos in enumerate(self.moe_positions):
                lidx = self.layer_map[(pos, period)]
                if trc is not None:
                    trc.set_attr(layer=lidx)
                a2d = active[period, pidx]                       # [T, k]
                sel_ids = ids[period, pidx][a2d]
                sel_gates = gates[period, pidx][a2d]
                self.tracker.observe(lidx, sel_ids, sel_gates)
                if self.prefetcher is not None:
                    # Seed the request-level activation matrix from
                    # prompt routing (MoE-Infinity's key observation);
                    # no-op on the transition baseline.
                    self.prefetcher.observe_prefill(
                        lidx, sel_ids, sel_gates,
                        n_tokens=int(a2d.any(axis=1).sum()))
                # All-to-all: prompt tokens live round-robin across
                # shards; selections landing on remote experts pay
                # dispatch + combine bytes (zero on a single device).
                nb_a2a, _ = self._a2a_layer_demand(lidx, a2d,
                                                   ids[period, pidx])
                if nb_a2a > 0:
                    self.ledger.ici_transfer(nb_a2a)
                rep = self._replica_targets(lidx, a2d, ids[period, pidx])
                used = np.unique(sel_ids)
                for e in used:
                    e = int(e)
                    # A replicated expert streams into every shard whose
                    # tokens selected it (each replica charged against
                    # that shard's cache + channels); everything else
                    # fills the owning shard as before.
                    if e in rep:
                        segs = [(self.cache.shards[sid],
                                 self.ledger.shards[sid])
                                for sid, _ in rep[e]]
                    else:
                        segs = [(self.cache, self._ledger_for(lidx, e))]
                    for cache_seg, led in segs:
                        for kind in ("msb", "lsb"):   # prefill is high-bit
                            if trc is not None:
                                trc.set_attr(layer=lidx, expert=e,
                                             slice_kind=kind,
                                             bits=self._slice_bits(kind))
                            key = SliceKey(lidx, e, kind)
                            nb = self.store.slice_bytes(key)
                            hit = cache_seg.access(key, nb)
                            if hit or key in cache_seg:
                                if not hit:           # fill landed
                                    led.miss_fill(nb)
                                led.dram_read(nb)
                            else:                     # dropped: direct stream
                                led.flash_stream(nb)
                # prefill compute: all actively routed tokens, high
                # precision, split over the shards *executing* the
                # selections (the owner; the token's home shard for a
                # replicated expert)
                exec_sh = None if self._n_shards() == 1 else \
                    self._selection_exec_shards(lidx, a2d, ids[period, pidx])
                if trc is not None:
                    trc.set_attr(layer=lidx)
                for sid, led in enumerate(self._shard_ledgers()):
                    t_s = sel_ids.size if exec_sh is None else \
                        int(np.count_nonzero(exec_sh == sid))
                    led.matmul(t_s, self.cfg.d_model,
                               self.expert_macs_per_token // self.cfg.d_model,
                               self.ecfg.mat.high_bits)

    def _finish_prefill(self, label: Optional[str]) -> dict:
        """Prefill→decode transition: warmup reshape + epoch rollover."""
        # Transition: PCW or a baseline init state.
        if self.ecfg.warmup == "pcw":
            warmup_summary = pcw_reshape(
                self.cache, self.store, self.tracker,
                lsb_keep_frac=self.ecfg.lsb_keep_frac)
        else:
            INIT_STATES[self.ecfg.warmup](self.cache, self.store)
            warmup_summary = {"init": self.ecfg.warmup}
        # Admission-time prefetch: issue from the prompt-seeded activation
        # matrix now that the reshape has settled residency (no-op for
        # the transition baseline and with prefetch off).
        self._prefetch_issue_prefill()
        snapshot = self.ledger.snapshot()
        if label is not None:
            self.cache.begin_epoch(f"{label}/decode")
        else:
            self.cache.stats.reset()
        return {"warmup": warmup_summary, "snapshot": snapshot}

    # -------------------------------------------------------------- decode
    def _policy_state(self):
        msb, lsb = self.cache.residency(self.n_moe_layers, self.n_experts)
        n_periods = self.cfg.n_periods
        state = {}
        for pos in self.moe_positions:
            cm = np.zeros((n_periods, self.n_experts), bool)
            cl = np.zeros((n_periods, self.n_experts), bool)
            for period in range(n_periods):
                lidx = self.layer_map[(pos, period)]
                cm[period] = msb[lidx]
                cl[period] = lsb[lidx]
            state[f"pos{pos}"] = {
                "cached_msb": jnp.asarray(cm),
                "cached_lsb": jnp.asarray(cl),
            }
            if self.buddies is not None:
                state[f"pos{pos}"]["buddies"] = self.buddies[f"pos{pos}"]
        return state

    def decode_batch(self, token: jax.Array, kv_cache: dict, *,
                     alpha: float = 0.0,
                     slot_active: Optional[np.ndarray] = None,
                     slot_tenants: Optional[list] = None,
                     **model_kwargs):
        """One batched decode step for the scheduler.

        ``token``: [B] int32 (padding slots carry an arbitrary token);
        ``slot_active``: [B] bool — padding slots are masked out of MoE
        routing inside the jitted step (no expert capacity consumed, no
        trace entries) and excluded from cache/cost accounting;
        ``slot_tenants``: [B] tenant names (None entries allowed) for
        per-tenant charge attribution and the SLO controller's signals.

        Returns ``(logits [B, V], kv_cache, StepCharge)``.
        """
        ps = self._policy_state()
        mask = None if slot_active is None \
            else jnp.asarray(np.asarray(slot_active, bool))
        logits, kv_cache, aux = self._jit_decode(
            self.qparams, token=token, cache=kv_cache,
            policy_state=ps, alpha=jnp.float32(alpha),
            token_mask=mask, **model_kwargs)
        charge = self.charge_decode_step(aux, slot_active=slot_active,
                                         slot_tenants=slot_tenants)
        return logits, kv_cache, charge

    def charge_decode_step(self, aux,
                           slot_active: Optional[np.ndarray] = None,
                           slot_tenants: Optional[list] = None
                           ) -> StepCharge:
        """Replay one decode step's slice demand into cache + ledger.

        Per-expert accounting matches the single-request engine exactly
        when every slot is active.  Additionally attributes each slice
        miss to the slots that selected the missing expert, yielding the
        per-sequence miss-rate signal the per-request controllers consume.

        Two replay disciplines share the same demand derivation, energy
        model and hit/miss bookkeeping and differ only in *when* each
        transfer occupies its hardware channel:

        * ``async_io=False`` — serialized issue: every Flash fill, DRAM
          read and matmul blocks the timeline (the pre-timeline scalar
          accounting, reproduced exactly);
        * ``async_io=True`` — a double-buffered layer pipeline: each
          expert's fill → DRAM read → matmul chain is issued with real
          data dependencies on the per-channel clocks, prefetch fills
          ride the Flash channel behind demand fills, and only the layer
          that actually consumes a late slice stalls.
        """
        return self.charge_step_trace(
            _StepTrace.from_aux(aux, slot_active, slot_tenants))

    def charge_step_trace(self, tr: "_StepTrace") -> StepCharge:
        """Charge an already-assembled :class:`_StepTrace`.

        This is the model-free entry point shared by the live engine
        (which builds the trace from the jit aux) and the trace-replay
        simulator (which builds it from a recorded or synthetic routing
        trace) — both run the *identical* cache/ledger replay below.

        The SLO controller is applied entirely inside this function —
        plan the step's bit levels after the recorder captures the raw
        trace, observe/actuate after the charge — and consumes only
        charge-path counters, so a recorded run replays through the same
        controller decisions bit-identically (the fidelity gate in
        benchmarks/controller_soak.py).
        """
        if self.recorder is not None:
            self.recorder.on_decode(tr)
        if self.tracer is not None:
            # One trace step per charge call, live or replay — the step
            # index correlates channel events with scheduler spans.
            self.tracer.begin_step()
        # Placement re-packing runs after the recorder (so the raw trace
        # is captured) and before any charging: it consumes only
        # charge-path state (the hotness tracker + the decode-step
        # counter), so a replay of the recorded trace recomputes the
        # identical migration sequence.
        self._maybe_migrate()
        ctl = self.slo_controller
        T = tr.slot_mask.shape[0]
        if ctl is not None:
            tr.slot_bit_level = ctl.plan_bits(tr.slot_tenants, T)
        # Accuracy-proxy counters run controller or not, so a *static*
        # config's low-bit exposure is measurable on the same accounting
        # the controller is judged by (benchmarks/controller_soak.py).
        tr.slot_critical = np.zeros(T, np.int64)
        tr.slot_critical_low = np.zeros(T, np.int64)
        replay = self._charge_async if self.ecfg.async_io \
            else self._charge_sync
        charge = replay(tr)
        if ctl is not None:
            actions = ctl.observe_step(charge.per_tenant or {},
                                       charge.ledger_delta)
            budgets = actions.get("budgets")
            if budgets and hasattr(self.cache, "set_budgets"):
                self.cache.set_budgets(budgets)
        return charge

    # ---------------------------------------------------- observability
    def attach_tracer(self, tracer):
        """Attach a :class:`repro.obs.timeline.TimelineTracer` (or
        ``None`` to detach): every subsequent ledger charge emits one
        attributed timeline event.  Because events hang off the shared
        charge path, a replay of a recorded trace through
        :class:`repro.sim.replay.ReplayEngine` emits the identical
        stream.  Returns the tracer for chaining."""
        self.tracer = tracer
        led = self.ledger
        if isinstance(led, ShardedCostLedger):
            led.attach_tracer(tracer)
        else:
            led.tracer = tracer
        return tracer

    def export_trace(self, path: str) -> dict:
        """Write the attached tracer's capture as Chrome-trace JSON
        (loadable in Perfetto); returns the exported dict."""
        if self.tracer is None:
            raise ValueError(
                "no tracer attached; call attach_tracer() before the run")
        from repro.obs.timeline import export_chrome_trace
        return export_chrome_trace(self.tracer, path)

    def _slice_bits(self, kind: str) -> int:
        """Nominal bit-width a slice contributes (trace attribution)."""
        mat = self.ecfg.mat
        return mat.low_bits if kind == "msb" \
            else mat.high_bits - mat.low_bits

    # -------------------------------------------------- shard routing bits
    # All four helpers dispatch on the *ledger object*, not on the
    # config, so a test/benchmark can swap sharded components onto an
    # engine (force-sharded at ep=1) and exercise the identical path.
    def _shard_ledgers(self) -> List[CostLedger]:
        led = self.ledger
        if isinstance(led, ShardedCostLedger):
            return led.shards
        return [led]

    def _n_shards(self) -> int:
        led = self.ledger
        return led.n_shards if isinstance(led, ShardedCostLedger) else 1

    def _owner_shard(self, lidx: int, expert: int) -> int:
        """Owning shard of ``expert`` at MoE layer ``lidx`` under the
        active placement map (legacy round-robin modulo without one)."""
        if self.placement is not None:
            return self.placement.owner_of(lidx, expert)
        return shard_of_expert(expert, self._n_shards())

    def _ledger_for(self, lidx: int, expert: int) -> CostLedger:
        """The cost ledger owning ``expert``'s slices at ``lidx``."""
        led = self.ledger
        if isinstance(led, ShardedCostLedger):
            return led.shards[self._owner_shard(lidx, int(expert))]
        return led

    def _compute_frontier(self) -> float:
        led = self.ledger
        if isinstance(led, ShardedCostLedger):
            return led.compute_frontier()
        return led.compute_ch.busy_until

    def _segment_capacity(self, key: SliceKey) -> float:
        """Capacity of the cache segment that would hold ``key`` — the
        owning shard's slice of the budget under EP, the currently
        targeted tenant segment under partitioning, the whole cache
        otherwise (the "would this fill be dropped" bound)."""
        if isinstance(self.cache, ShardedSliceCache):
            return self.cache.shard(key).capacity
        if self._partitioned:
            return self.cache.fill_capacity()
        return self.cache.capacity

    @property
    def _partitioned(self) -> bool:
        """Whether the cache routes fills into per-tenant segments."""
        return hasattr(self.cache, "set_budgets")

    def _expert_owner(self, tr: "_StepTrace", period: int, pidx: int):
        """expert id -> tenant whose segment a miss fill charges: the
        first active slot (in slot-index order — deterministic, so replay
        agrees) selecting that expert.  None when fills are unattributed
        (no tenants, or cache not partitioned)."""
        if tr.slot_tenants is None or not self._partitioned:
            return None
        owner: dict = {}
        act2d = tr.active[period, pidx] & tr.slot_mask[:, None]
        for b in np.nonzero(tr.slot_mask)[0]:
            t = tr.slot_tenants[b]
            if t is None:
                continue
            for e in tr.ids[period, pidx][b][act2d[b]]:
                owner.setdefault(int(e), t)
        return owner

    def _placement_rows(self, lidx: int):
        """(owner_row, replicated_row) for ``lidx`` — (None, None) when
        no placement map is active (legacy modulo ownership)."""
        if self.placement is None:
            return None, None
        return (self.placement.owner_row(lidx),
                self.placement.replicated_row(lidx))

    def _a2a_layer_demand(self, lidx: int, act2d: np.ndarray,
                          ids2d: np.ndarray):
        """All-to-all demand for one layer's ``[T, k]`` routing:
        ``(bytes, remote_experts)``.  Each active selection whose expert
        lives on a different shard than its token moves its activation
        out and the partial result back; ``remote_experts`` is the set
        of experts with at least one such selection (their matmuls wait
        on the dispatch).  Selections of *replicated* experts are never
        remote — the token's home shard serves them from its own
        replica, which is exactly how replication buys its all-to-all
        reduction.  ``(0.0, frozenset())`` on a single device — the
        common path skips the index arithmetic entirely."""
        n = self._n_shards()
        if n == 1:
            return 0.0, frozenset()
        rows, _ = np.nonzero(act2d)
        sel = ids2d[act2d]
        orow, rrow = self._placement_rows(lidx)
        remote = remote_selection_mask(rows, sel, n,
                                       owner_row=orow, replicated_row=rrow)
        if not remote.any():
            return 0.0, frozenset()
        return (2.0 * self.cfg.d_model * float(np.count_nonzero(remote)),
                frozenset(int(e) for e in np.unique(sel[remote])))

    def _layer_a2a_demand(self, tr: "_StepTrace", period: int, pidx: int,
                          lidx: int):
        if self._n_shards() == 1:
            return 0.0, frozenset()
        return self._a2a_layer_demand(
            lidx,
            tr.active[period, pidx] & tr.slot_mask[:, None],
            tr.ids[period, pidx])

    def _replica_targets(self, lidx: int, act2d: np.ndarray,
                         ids2d: np.ndarray) -> dict:
        """Replica dispatch plan for one layer: ``{expert: [(shard,
        n_tokens), ...]}`` over the *replicated* experts with at least
        one active selection, splitting each expert's tokens by their
        home shard.  Empty unless a placement map with replication is
        active — the round_robin/hotness paths never pay this scan."""
        if self.placement is None:
            return {}
        rrow = self.placement.replicated_row(lidx)
        if not rrow.any():
            return {}
        n = self._n_shards()
        rows, _ = np.nonzero(act2d)
        sel = ids2d[act2d]
        mask = rrow[sel]
        out: dict = {}
        for tok, e in zip(rows[mask], sel[mask]):
            d = out.setdefault(int(e), {})
            sid = home_shard_of_token(int(tok), n)
            d[sid] = d.get(sid, 0) + 1
        return {e: sorted(d.items()) for e, d in out.items()}

    def _selection_exec_shards(self, lidx: int, act2d: np.ndarray,
                               ids2d: np.ndarray) -> np.ndarray:
        """Shard executing each active selection's expert matmul: the
        owner, except replicated experts run on the token's home shard."""
        n = self._n_shards()
        rows, _ = np.nonzero(act2d)
        sel = ids2d[act2d]
        if self.placement is None:
            return shard_of_expert(sel, n)
        owner = self.placement.owner_row(lidx)[sel]
        rep = self.placement.replicated_row(lidx)[sel]
        if rep.any():
            owner = np.where(rep, home_shard_of_token(rows, n), owner)
        return owner

    def _maybe_migrate(self) -> None:
        """Periodic hotness re-placement at decode-step granularity.

        Deterministic in charge-path state only (the hotness tracker and
        the step counter), so record→replay reproduces the identical
        placement maps, migration moves and interconnect charges.  Each
        moved slice's bytes are charged on the ici channel via
        :meth:`~repro.hw.energy.CostLedger.migrate` — re-packing is not
        free, and the benchmark judges the policy net of this cost.
        """
        pol = self.placement_policy
        if pol is None or not pol.migrates or self._n_shards() <= 1:
            return
        self._decode_steps += 1
        period = max(int(self.ecfg.placement_period), 1)
        if self._decode_steps % period:
            return
        new_map = pol.replace(self.tracker.hotness())
        if new_map == self.placement:
            return
        moves = self.cache.apply_placement(new_map)
        self.placement = new_map
        trc = self.tracer
        for key, nb, _frm, _to in moves:
            if trc is not None:
                trc.set_attr(layer=key.layer, expert=key.expert,
                             slice_kind=key.kind)
            self.ledger.migrate(nb)
        if trc is not None and moves:
            trc.set_attr()
        self.migration_events.append({
            "step": self._decode_steps,
            "moved": len(moves),
            "bytes": float(sum(m[1] for m in moves)),
        })

    # -------------------------------------------------- shared replay bits
    def _slice_nbytes(self, key: SliceKey) -> float:
        if self.ecfg.fused_slices:
            return self.store.highbit_expert_bytes()
        return self.store.slice_bytes(key)

    def _layer_demand(self, tr: "_StepTrace", period: int, pidx: int):
        """Demand for one (period, position) layer over *active* slots.

        For a full batch this reproduces the jit-side msb_needed /
        lsb_needed exactly; padding slots are excluded.
        """
        mode = self.ecfg.policy.slice_mode
        act2d = tr.active[period, pidx] & tr.slot_mask[:, None]   # [T, k]
        flat_ids = tr.ids[period, pidx][act2d]
        flat_gates = tr.gates[period, pidx][act2d]
        msb_demand = np.unique(flat_ids)
        crit2d = act2d & tr.critical[period, pidx]
        demoted = None if tr.slot_bit_level is None \
            else tr.slot_bit_level > 0                            # [T]
        if mode == "highbit":
            lsb_wanted = set(int(e) for e in msb_demand)
        elif mode in ("lowbit", "amat_static"):
            lsb_wanted = set()
        else:   # dbsc — a controller-demoted slot stops demanding LSBs
            # (AMAT truncation: its MSB slice is already a valid low-bit
            # tensor).  An expert critically selected by *any* kept slot
            # still wants its LSB.
            kept2d = crit2d if demoted is None \
                else crit2d & ~demoted[:, None]
            crit_ids = tr.ids[period, pidx][kept2d]
            lsb_wanted = set(int(e) for e in np.unique(crit_ids))
        if tr.slot_critical is not None:
            # Accuracy proxy, plan-level: a demoted slot's critical
            # selections all count as served-low even when another slot
            # kept the expert's LSB resident (conservative overcount —
            # the guard promotes slightly early, never late).
            tr.slot_critical += crit2d.sum(axis=1)
            if mode in ("lowbit", "amat_static"):
                tr.slot_critical_low += crit2d.sum(axis=1)
            elif mode == "dbsc" and demoted is not None:
                tr.slot_critical_low += \
                    (crit2d & demoted[:, None]).sum(axis=1)
        tok_per_e = np.bincount(flat_ids, minlength=self.n_experts)
        return flat_ids, flat_gates, msb_demand, lsb_wanted, tok_per_e

    def _expert_bits(self, lsb_available: bool) -> int:
        """Matmul bit-width from the *slot-masked* demand (padding slots
        must not promote an expert to high-bit in the cost model; the
        jit-side use_lsb can't distinguish)."""
        mat = self.ecfg.mat
        mode = self.ecfg.policy.slice_mode
        if self.ecfg.fused_slices or mode == "highbit":
            return mat.high_bits
        if mode in ("lowbit", "amat_static"):
            return mat.low_bits
        return mat.high_bits if lsb_available else mat.low_bits  # dbsc

    def _msb_resident_row(self, lidx: int) -> np.ndarray:
        """[E] bool: experts whose MSB slice for ``lidx`` is cached."""
        row = np.zeros(self.n_experts, bool)
        for e in range(self.n_experts):
            row[e] = SliceKey(lidx, e, "msb") in self.cache
        return row

    # ------------------------------------------- request-kind prefetch bits
    def _pf_pending_keys(self) -> set:
        keys: set = set()
        for m in self._pf_pending.values():
            keys.update(m)
        return keys

    def _lsb_prefetch_allowed(self, tr: "_StepTrace") -> bool:
        """Whether LSB slices are worth prefetching this step: DBSC mode
        only (other modes never demand LSBs separately), and not when
        the controller has demoted every active slot to MSB-only — a
        demoted fleet's LSB fills would be wasted by construction."""
        if self.ecfg.policy.slice_mode != "dbsc" or self.ecfg.fused_slices:
            return False
        demoted = tr.slot_bit_level
        if demoted is not None and tr.slot_mask.any() \
                and bool((demoted[tr.slot_mask] > 0).all()):
            return False
        return True

    def _prefetch_judge(self, lidx: int, msb_demand: np.ndarray,
                        lsb_wanted: set, t_route: float) -> None:
        """Judge pending prefetches targeting ``lidx`` against the
        layer's actual demand, *before* demand charging mutates the
        cache.  Kind-aware: an LSB fill is useful only if the layer
        wanted that expert's LSB.  ``t_route`` is the usefulness bar
        (serialized replay passes 0.0 — fills land instantly there).

        Waste is judged on *energy truth*, not a fixed horizon: a fill's
        cost is repaid iff the slice serves at least one demand before
        leaving the cache, so a pending entry survives un-demanded as
        long as it stays resident.  The wasted verdict lands when the
        slice is evicted unused (it can no longer repay its fill) or is
        still unused when the run flushes (:meth:`_prefetch_flush`).
        The single-next-execution verdict of the transition baseline is
        an artifact of its one-step horizon.  Conservation
        ``issued == useful + late + wasted + in_flight`` holds
        throughout, with surviving entries counted in ``in_flight``."""
        pf = self.prefetcher
        demanded = set(int(e) for e in msb_demand)
        survivors = {}
        for key, (ready_t, p_nb, d) in \
                self._pf_pending.pop(lidx, {}).items():
            if key not in self.cache:        # evicted before use
                pf.mark_wasted(distance=d)
                self._ledger_for(key.layer,
                                 key.expert).mark_prefetch_wasted(p_nb)
            elif (key.expert in demanded if key.kind == "msb"
                  else key.expert in lsb_wanted):
                if ready_t <= t_route:
                    pf.mark_useful(distance=d)
                else:
                    pf.mark_late(distance=d)
            else:                            # resident, un-demanded: wait
                survivors[key] = (ready_t, p_nb, d)
        if survivors:
            self._pf_pending[lidx] = survivors

    def _prefetch_flush(self) -> None:
        """End-of-run settlement for the request-kind predictor: any
        pending fill still unused is energy spent that will never be
        repaid — wasted, exactly like an eviction before use.  After the
        flush ``issued == useful + late + wasted`` and ``in_flight`` is
        zero, which is what the invariant suite asserts on finished
        engines."""
        pf = self.prefetcher
        if pf is None or pf.kind != "request":
            return
        for m in self._pf_pending.values():
            for key, (ready_t, p_nb, d) in m.items():
                pf.mark_wasted(distance=d)
                self._ledger_for(key.layer,
                                 key.expert).mark_prefetch_wasted(p_nb)
        self._pf_pending.clear()

    def _prefetch_issue(self, lidx: int, flat_ids: np.ndarray,
                        t_issue: float, tr: "_StepTrace", *,
                        timeline: bool) -> None:
        """Plan + enqueue request-predictor fills after ``lidx`` routed.

        Fills ride the owning shard's Flash channel behind the layer's
        demand fills (``timeline=True``) or charge the serialized
        accounting (``timeline=False``).  Capacity-skipped candidates
        never count as issued — they moved no bytes.  Under EP sharding
        ``_ledger_for``/``ShardedSliceCache`` route every fill to the
        shard owning the expert, so a shard never fills a
        remote-placement slice (asserted by the cross-feature tests).
        """
        pf = self.prefetcher
        if self._partitioned:    # speculative fills: shared segment
            self.cache.set_active_tenant(None)
        cands = pf.plan(
            lidx, flat_ids,
            is_resident=lambda k: k in self.cache,
            slice_bytes=self._slice_nbytes,
            pending=self._pf_pending_keys(),
            lsb_allowed=self._lsb_prefetch_allowed(tr))
        for key, d in cands:
            nb = self._slice_nbytes(key)
            if key in self.cache or nb > self._segment_capacity(key):
                continue
            if self.tracer is not None:
                self.tracer.set_attr(layer=key.layer, expert=key.expert,
                                     slice_kind=key.kind,
                                     bits=self._slice_bits(key.kind))
            led = self._ledger_for(key.layer, key.expert)
            if timeline:
                # Background-priority lane: speculative fills never
                # delay the demand queue (demand preempts), unlike the
                # transition baseline's FIFO fills.
                _, end = led.prefetch_fill_at(t_issue, nb)
                self.cache.insert(key, nb)
                self.cache.mark_inflight(key, end)
            else:
                led.prefetch_fill_at(None, nb)
                self.cache.insert(key, nb)
                end = 0.0
            self._pf_pending.setdefault(key.layer, {})[key] = \
                (end, nb, d)
            pf.mark_issued(distance=d)

    def _prefetch_issue_prefill(self) -> None:
        """Admission-time issuance: once per request, after the prefill
        charge seeded the activation matrix and the warmup reshape
        settled residency (the reshape keeps globally hot experts,
        evicting exactly the request-specific slices this request is
        about to re-demand).  Fills charge the serialized accounting —
        prefill is off the decode timeline in both engine modes, and the
        transfer genuinely completes during the (long) prefill charge,
        so ``ready_t = 0.0`` at the first decode judge."""
        pf = self.prefetcher
        if pf is None or pf.kind != "request" or not pf.top_m:
            return
        if self._partitioned:    # speculative fills: shared segment
            self.cache.set_active_tenant(None)
        cands = pf.plan_prefill(
            is_resident=lambda k: k in self.cache,
            slice_bytes=self._slice_nbytes,
            pending=self._pf_pending_keys())
        for key, d in cands:
            nb = self._slice_nbytes(key)
            if key in self.cache or nb > self._segment_capacity(key):
                continue
            if self.tracer is not None:
                self.tracer.set_attr(layer=key.layer, expert=key.expert,
                                     slice_kind=key.kind,
                                     bits=self._slice_bits(key.kind))
            _, end = self._ledger_for(key.layer,
                                      key.expert).prefetch_fill_at(None, nb)
            self.cache.insert(key, nb)
            if not self.ecfg.async_io:
                end = 0.0    # serialized judge bar is t_route == 0.0
            self._pf_pending.setdefault(key.layer, {})[key] = \
                (end, nb, d)
            pf.mark_issued(distance=d)

    def _attribute_slot_misses(self, tr: "_StepTrace", period: int,
                               pidx: int, missed_expert: np.ndarray,
                               missed_rep: Optional[dict] = None) -> None:
        """Per-slot miss attribution: a slot is charged for every
        selection that landed on an expert whose slice(s) missed this
        layer-step.  ``missed_rep`` (``{expert: {shards that missed}}``)
        scopes a *replicated* expert's miss to the slots homed on the
        shards whose replica actually missed — the other shards' tokens
        were served by their own resident copy."""
        n = self._n_shards()
        for b in np.nonzero(tr.slot_mask)[0]:
            sel = tr.ids[period, pidx][b][tr.active[period, pidx][b]]
            tr.slot_accesses[b] += sel.size
            miss = int(missed_expert[sel].sum())
            if missed_rep:
                home = home_shard_of_token(int(b), n)
                miss += sum(1 for e in sel
                            if home in missed_rep.get(int(e), ()))
            tr.slot_misses[b] += miss

    def _per_tenant_counts(self, tr: "_StepTrace") -> Optional[dict]:
        """Aggregate the per-slot replay counters by tenant (slots with
        no tenant fall under "default")."""
        if tr.slot_tenants is None and self.slo_controller is None:
            return None
        out: dict = {}
        for b in np.nonzero(tr.slot_mask)[0]:
            t = "default"
            if tr.slot_tenants is not None \
                    and tr.slot_tenants[b] is not None:
                t = tr.slot_tenants[b]
            row = out.setdefault(t, {"tokens": 0, "accesses": 0,
                                     "misses": 0, "critical": 0,
                                     "critical_low": 0})
            row["tokens"] += 1
            row["accesses"] += int(tr.slot_accesses[b])
            row["misses"] += int(tr.slot_misses[b])
            if tr.slot_critical is not None:
                row["critical"] += int(tr.slot_critical[b])
                row["critical_low"] += int(tr.slot_critical_low[b])
        return out

    def _step_charge(self, tr: "_StepTrace", base: dict) -> StepCharge:
        return StepCharge(
            miss_rate=tr.misses / max(tr.accesses, 1),
            accesses=tr.accesses,
            misses=tr.misses,
            per_slot_miss=tr.slot_misses / np.maximum(tr.slot_accesses, 1),
            ledger_delta=self.ledger.delta_since(base),
            per_tenant=self._per_tenant_counts(tr),
        )

    # ----------------------------------------- per-expert charge kernels
    # Both kernels take the cache segment + ledger they charge against
    # explicitly: the owner pair for a normally-placed expert (via
    # ``self.cache`` routing + ``_ledger_for``), or one (shard cache,
    # shard ledger) pair per home shard for a replicated expert.  The
    # charging sequence is byte-for-byte the pre-refactor inline code, so
    # the non-replicated path stays bit-identical.

    def _charge_expert_sync(self, tr: "_StepTrace", lidx: int, e: int,
                            cache_seg, led: CostLedger, ntok: int,
                            lsb_wanted: set) -> bool:
        """Serialized-issue slice demand + matmul for one expert on one
        cache segment.  Returns whether any of its slices missed."""
        missed = False
        trc = self.tracer
        if trc is not None:
            trc.set_attr(layer=lidx, expert=e, slice_kind="msb",
                         bits=self._slice_bits("msb"))
        key = SliceKey(lidx, e, "msb")
        nb = self._slice_nbytes(key)
        hit = cache_seg.access(key, nb)
        tr.accesses += 1
        if not hit:
            tr.misses += 1
            missed = True
            if key in cache_seg:       # fill landed
                led.miss_fill(nb)
            else:                      # dropped: direct stream
                led.flash_stream(nb)
        if hit or key in cache_seg:
            led.dram_read(nb)
        wants_lsb = e in lsb_wanted and not self.ecfg.fused_slices
        lsb_available = False
        if wants_lsb:
            if trc is not None:
                trc.set_attr(layer=lidx, expert=e, slice_kind="lsb",
                             bits=self._slice_bits("lsb"))
            lkey = SliceKey(lidx, e, "lsb")
            lnb = self.store.slice_bytes(lkey)
            lhit = cache_seg.access(
                lkey, lnb,
                fill_on_miss=self.ecfg.policy.fetch_lsb_on_miss)
            tr.accesses += 1
            if not lhit:
                tr.misses += 1
                missed = True
                if self.ecfg.policy.fetch_lsb_on_miss:
                    if lkey in cache_seg:
                        led.miss_fill(lnb)
                    else:
                        led.flash_stream(lnb)
            if lhit or self.ecfg.policy.fetch_lsb_on_miss:
                if lhit or lkey in cache_seg:
                    led.dram_read(lnb)
                lsb_available = True
        if trc is not None:
            trc.set_attr(layer=lidx, expert=e)
        led.matmul(
            ntok, self.cfg.d_model,
            self.expert_macs_per_token // self.cfg.d_model,
            self._expert_bits(lsb_available))
        return missed

    def _charge_expert_async(self, tr: "_StepTrace", lidx: int, e: int,
                             cache_seg, led: CostLedger, ntok: int,
                             lsb_wanted: set, t_route: float,
                             t_disp: Optional[float] = None) -> bool:
        """Event-timeline fill → read → matmul chain for one expert on
        one cache segment.  ``t_disp``: all-to-all completion the matmul
        must additionally wait for (remote experts only; replicated
        experts run home-local and never pass one).  Returns whether any
        of its slices missed."""
        missed = False
        trc = self.tracer
        if trc is not None:
            trc.set_attr(layer=lidx, expert=e, slice_kind="msb",
                         bits=self._slice_bits("msb"))
        key = SliceKey(lidx, e, "msb")
        nb = self._slice_nbytes(key)
        hit = cache_seg.access(key, nb)
        tr.accesses += 1
        if hit:
            # wait out an in-flight (prefetched) transfer
            t_data = max(t_route, cache_seg.ready_time(key))
            _, t_data = led.dram_read_at(t_data, nb)
        else:
            tr.misses += 1
            missed = True
            if key in cache_seg:        # fill landed
                _, fill_end = led.fill_at(t_route, nb)
                cache_seg.mark_inflight(key, fill_end)
                _, t_data = led.dram_read_at(fill_end, nb)
            else:                       # dropped: direct stream
                _, t_data = led.flash_stream_at(t_route, nb)
        wants_lsb = e in lsb_wanted and not self.ecfg.fused_slices
        lsb_available = False
        if wants_lsb:
            if trc is not None:
                trc.set_attr(layer=lidx, expert=e, slice_kind="lsb",
                             bits=self._slice_bits("lsb"))
            lkey = SliceKey(lidx, e, "lsb")
            lnb = self.store.slice_bytes(lkey)
            lhit = cache_seg.access(
                lkey, lnb,
                fill_on_miss=self.ecfg.policy.fetch_lsb_on_miss)
            tr.accesses += 1
            if lhit:
                t_lsb = max(t_route, cache_seg.ready_time(lkey))
                _, t_lsb = led.dram_read_at(t_lsb, lnb)
                t_data = max(t_data, t_lsb)
                lsb_available = True
            else:
                tr.misses += 1
                missed = True
                if self.ecfg.policy.fetch_lsb_on_miss:
                    if lkey in cache_seg:
                        _, lf_end = led.fill_at(t_route, lnb)
                        cache_seg.mark_inflight(lkey, lf_end)
                        _, t_lsb = led.dram_read_at(lf_end, lnb)
                    else:
                        _, t_lsb = led.flash_stream_at(t_route, lnb)
                    t_data = max(t_data, t_lsb)
                    lsb_available = True
        if trc is not None:
            trc.set_attr(layer=lidx, expert=e)
        led.matmul_at(
            t_data if t_disp is None else max(t_data, t_disp),
            ntok, self.cfg.d_model,
            self.expert_macs_per_token // self.cfg.d_model,
            self._expert_bits(lsb_available))
        return missed

    # -------------------------------------------- serialized (sync) replay
    def _charge_sync(self, tr: "_StepTrace") -> StepCharge:
        base = self.ledger.snapshot()
        trc = self.tracer
        pf = self.prefetcher
        pf_req = pf is not None and pf.kind == "request"
        prev_used = None
        for period in range(tr.P):
            for pidx, pos in enumerate(self.moe_positions):
                lidx = self.layer_map[(pos, period)]
                # --- prefetch (paper §2.1 baseline): before this layer
                # runs, the predictor has pulled its guesses into DRAM.
                # Residency-filtered, so every prediction is a real fill.
                issued = None
                if pf is not None and not pf_req \
                        and prev_used is not None:
                    if self._partitioned:   # speculative: shared segment
                        self.cache.set_active_tenant(None)
                    predicted = pf.predict(
                        lidx - 1, prev_used,
                        resident=self._msb_resident_row(lidx))
                    # Only fills actually enqueued count as issued — a
                    # capacity-skipped prediction moved no bytes and can
                    # never save a miss (matches the async accounting).
                    issued = set()
                    for e in predicted:
                        key = SliceKey(lidx, int(e), "msb")
                        nb = self._slice_nbytes(key)
                        if key not in self.cache \
                                and nb <= self._segment_capacity(key):
                            if trc is not None:
                                trc.set_attr(layer=lidx, expert=int(e),
                                             slice_kind="msb",
                                             bits=self._slice_bits("msb"))
                            self._ledger_for(lidx, int(e)).miss_fill(
                                nb, prefetch=True)
                            self.cache.insert(key, nb)
                            issued.add(int(e))
                    pf.mark_issued(len(issued))
                flat_ids, flat_gates, msb_demand, lsb_wanted, tok_per_e = \
                    self._layer_demand(tr, period, pidx)
                self.tracker.observe(lidx, flat_ids, flat_gates)
                # All-to-all token dispatch to remote experts (EP only).
                nb_a2a, _ = self._layer_a2a_demand(tr, period, pidx, lidx)
                if nb_a2a > 0:
                    if trc is not None:
                        trc.set_attr(layer=lidx)
                    self.ledger.ici_transfer(nb_a2a)
                if pf_req:
                    # Serialized fills land instantly, so a correct
                    # prediction that survived until its target layer is
                    # useful by definition (bar t_route=0).
                    self._prefetch_judge(lidx, msb_demand, lsb_wanted, 0.0)
                elif pf is not None:
                    if prev_used is not None:
                        pf.observe(lidx, prev_used, flat_ids)
                        demanded = set(int(e) for e in msb_demand)
                        pf.mark_useful(len(demanded & issued))
                        for e in sorted(issued - demanded):
                            pf.mark_wasted()
                            self._ledger_for(lidx, e).mark_prefetch_wasted(
                                self._slice_nbytes(SliceKey(lidx, e, "msb")))
                    prev_used = flat_ids

                owner = self._expert_owner(tr, period, pidx)
                rep = self._replica_targets(
                    lidx, tr.active[period, pidx] & tr.slot_mask[:, None],
                    tr.ids[period, pidx])
                missed_expert = np.zeros(self.n_experts, bool)
                missed_rep: dict = {}
                for e in msb_demand:
                    e = int(e)
                    if owner is not None:
                        self.cache.set_active_tenant(owner.get(e))
                    if e in rep:
                        # Replicated expert: each shard with tokens for
                        # it runs against its *own* replica + channels.
                        for sid, ntok in rep[e]:
                            if self._charge_expert_sync(
                                    tr, lidx, e, self.cache.shards[sid],
                                    self.ledger.shards[sid], ntok,
                                    lsb_wanted):
                                missed_rep.setdefault(e, set()).add(sid)
                    elif self._charge_expert_sync(
                            tr, lidx, e, self.cache,
                            self._ledger_for(lidx, e),
                            int(tok_per_e[e]), lsb_wanted):
                        missed_expert[e] = True
                # --- learn + issue for future layers (request kind):
                # plan() sees post-demand residency, so every candidate
                # is a fill that could save a future miss.
                if pf_req:
                    pf.observe(lidx, flat_ids, flat_gates,
                               crit_ids=lsb_wanted)
                    self._prefetch_issue(lidx, flat_ids, 0.0, tr,
                                         timeline=False)
                self._attribute_slot_misses(tr, period, pidx, missed_expert,
                                            missed_rep or None)
        # Non-expert resident weights: one pass per decode step per shard
        # (replicated dense weights), the batch's active tokens split
        # data-parallel across shards.
        self._charge_resident_sync(tr)
        return self._step_charge(tr, base)

    def _resident_token_share(self, tr: "_StepTrace", sid: int) -> int:
        """Active tokens shard ``sid`` runs the dense (non-expert) layers
        for: slots are data-parallel round-robin across shards."""
        n = self._n_shards()
        if n == 1:
            return int(tr.slot_mask.sum())
        active_slots = np.nonzero(tr.slot_mask)[0]
        return int(np.count_nonzero(
            home_shard_of_token(active_slots, n) == sid))

    def _charge_resident_sync(self, tr: "_StepTrace") -> None:
        n = self._n_shards()
        if self.tracer is not None:
            self.tracer.set_attr(bits=8)   # shared (non-expert) weights
        for sid, led in enumerate(self._shard_ledgers()):
            share = self._resident_token_share(tr, sid)
            if n == 1:
                share = max(share, 1)   # legacy single-device floor
            elif share == 0:
                continue    # no tokens homed here: no dense pass to run
            led.dram_read(self.resident_bytes)
            led.matmul(share, self.cfg.d_model,
                       int(self.resident_bytes / self.cfg.d_model) + 1, 8)

    # ------------------------------------------- pipelined (async) replay
    def _charge_async(self, tr: "_StepTrace") -> StepCharge:
        """Event-timeline replay: the double-buffered layer pipeline.

        Per flat layer (execution order):

        1. the layer's routing is known once the previous layer's compute
           drains (``t_route``); demand fills issue on the Flash channel
           at that instant and each expert's DRAM read / matmul chain
           follows its own data dependencies — expert ``e+1``'s fill
           overlaps expert ``e``'s read and compute;
        2. prefetch fills for the *next* layer (predicted from this
           layer's routing, residency-filtered) are enqueued on the Flash
           channel behind this layer's demand fills and marked in-flight
           in the cache; a consumer that arrives before a prefetched
           transfer lands stalls only for the remaining tail;
        3. a prediction is **useful** iff its transfer landed before its
           consuming layer started, **late** if demanded but still in
           flight, **wasted** if never demanded (its Flash/DRAM energy is
           attributed to ``prefetch_wasted_energy_j``).

        The resident (non-expert) weight stream for the step is issued
        once behind the expert reads and overlaps expert compute — the
        double-buffering win the serialized model cannot express.

        Under expert parallelism every per-expert chain issues on the
        *owning shard's* channel clocks, so the shards' expert pipelines
        progress independently and the step's latency is the max over
        shard timelines plus the all-to-all dispatch: routing at
        ``t_route`` first pays the layer's dispatch bytes on the shared
        interconnect channel, and each remote expert's matmul waits for
        both its slice data and the dispatched activations.
        """
        base = self.ledger.snapshot()
        trc = self.tracer
        t_step = self._compute_frontier()
        pf = self.prefetcher
        pf_req = pf is not None and pf.kind == "request"
        prev_used = None
        # Transition-kind prefetches in flight: key -> (ready_t, nbytes)
        # per target layer.  Step-local: the Markov baseline only ever
        # targets the next layer of the same step.  The request kind
        # uses the engine-level ``_pf_pending`` instead (cyclic targets
        # cross the step boundary).
        pending: dict = {}
        for period in range(tr.P):
            for pidx, pos in enumerate(self.moe_positions):
                lidx = self.layer_map[(pos, period)]
                t_route = max(t_step, self._compute_frontier())
                flat_ids, flat_gates, msb_demand, lsb_wanted, tok_per_e = \
                    self._layer_demand(tr, period, pidx)
                self.tracker.observe(lidx, flat_ids, flat_gates)
                # All-to-all token dispatch for this layer, issued the
                # moment routing is known; only experts that actually
                # receive remote tokens additionally wait for it
                # (t_disp) — purely local expert chains do not.
                nb_a2a, remote_experts = self._layer_a2a_demand(
                    tr, period, pidx, lidx)
                t_disp = t_route
                if nb_a2a > 0:
                    if trc is not None:
                        trc.set_attr(layer=lidx)
                    _, t_disp = self.ledger.ici_transfer_at(t_route,
                                                            nb_a2a)

                # --- prefetch usefulness for THIS layer (issued at l-1),
                # judged before demand charging mutates the cache.  The
                # bar is t_route — when the consuming layer starts; a
                # transfer still in flight then is late even though the
                # consumer only waits out its tail.  A prediction whose
                # slice was evicted before use saved nothing: wasted.
                demanded = set(int(e) for e in msb_demand)
                if pf_req:
                    self._prefetch_judge(lidx, msb_demand, lsb_wanted,
                                         t_route)
                else:
                    for key, (ready_t, p_nb) in \
                            pending.pop(lidx, {}).items():
                        if key not in self.cache:  # evicted before use
                            self.prefetcher.mark_wasted()
                            self._ledger_for(
                                key.layer,
                                key.expert).mark_prefetch_wasted(p_nb)
                        elif key.expert in demanded:
                            if ready_t <= t_route:
                                self.prefetcher.mark_useful()
                            else:
                                self.prefetcher.mark_late()
                        else:
                            self.prefetcher.mark_wasted()
                            self._ledger_for(
                                key.layer,
                                key.expert).mark_prefetch_wasted(p_nb)

                owner = self._expert_owner(tr, period, pidx)
                rep = self._replica_targets(
                    lidx, tr.active[period, pidx] & tr.slot_mask[:, None],
                    tr.ids[period, pidx])
                missed_expert = np.zeros(self.n_experts, bool)
                missed_rep: dict = {}
                for e in msb_demand:
                    e = int(e)
                    if owner is not None:
                        self.cache.set_active_tenant(owner.get(e))
                    if e in rep:
                        # Replicated expert: each shard with tokens for
                        # it chains against its *own* replica + channels
                        # and never waits on the dispatch.
                        for sid, ntok in rep[e]:
                            if self._charge_expert_async(
                                    tr, lidx, e, self.cache.shards[sid],
                                    self.ledger.shards[sid], ntok,
                                    lsb_wanted, t_route):
                                missed_rep.setdefault(e, set()).add(sid)
                    elif self._charge_expert_async(
                            tr, lidx, e, self.cache,
                            self._ledger_for(lidx, e), int(tok_per_e[e]),
                            lsb_wanted, t_route,
                            t_disp if e in remote_experts else None):
                        missed_expert[e] = True
                # --- learn + issue prefetch for future layers, behind
                # this layer's demand fills on each shard's Flash channel.
                if pf_req:
                    pf.observe(lidx, flat_ids, flat_gates,
                               crit_ids=lsb_wanted)
                    self._prefetch_issue(lidx, flat_ids, t_route, tr,
                                         timeline=True)
                elif pf is not None:
                    if prev_used is not None:
                        pf.observe(lidx, prev_used, flat_ids)
                    prev_used = flat_ids
                    if lidx + 1 < self.n_moe_layers:
                        if self._partitioned:   # speculative: shared seg
                            self.cache.set_active_tenant(None)
                        predicted = pf.predict(
                            lidx, flat_ids,
                            resident=self._msb_resident_row(lidx + 1))
                        n_issued = 0
                        for e in predicted:
                            key = SliceKey(lidx + 1, int(e), "msb")
                            nb = self._slice_nbytes(key)
                            if key in self.cache \
                                    or nb > self._segment_capacity(key):
                                continue
                            if trc is not None:
                                trc.set_attr(layer=lidx + 1, expert=int(e),
                                             slice_kind="msb",
                                             bits=self._slice_bits("msb"))
                            _, end = self._ledger_for(lidx + 1, int(e)).fill_at(
                                t_route, nb, prefetch=True)
                            self.cache.insert(key, nb)
                            self.cache.mark_inflight(key, end)
                            pending.setdefault(lidx + 1, {})[key] = (end, nb)
                            n_issued += 1
                        pf.mark_issued(n_issued)
                self._attribute_slot_misses(tr, period, pidx, missed_expert,
                                            missed_rep or None)
        # Transition-kind prefetch targets lidx+1 (< n_moe_layers), which
        # always runs later in the same step and pops its pending entries
        # — so issued == useful + late + wasted holds per step.  Request-
        # kind entries live in self._pf_pending (judged at the target
        # layer's next execution; unjudged ones count as in_flight).
        assert not pending, f"unconsumed prefetch bookkeeping: {pending}"
        # Resident (non-expert) weights stream behind the expert reads
        # and overlap expert compute; the dense step compute waits on
        # them.  Replicated per shard, tokens split data-parallel; a
        # shard with no tokens homed on it runs no dense pass this step.
        n_sh = self._n_shards()
        if trc is not None:
            trc.set_attr(bits=8)   # shared (non-expert) weights
        for sid, led in enumerate(self._shard_ledgers()):
            share = self._resident_token_share(tr, sid)
            if n_sh == 1:
                share = max(share, 1)   # legacy single-device floor
            elif share == 0:
                continue
            _, res_ready = led.dram_read_at(t_step, self.resident_bytes)
            led.matmul_at(res_ready, share, self.cfg.d_model,
                          int(self.resident_bytes / self.cfg.d_model) + 1,
                          8)
        self.cache.settle(self.ledger.now)
        return self._step_charge(tr, base)


class SliceMoEEngine(PersistentEngine):
    """Single-request convenience API (the paper's Fig. 1a deployment).

    Adds exactly one request's worth of per-request state — ``kv_cache``,
    the step counter and the controller ``alpha`` — on top of the shared
    :class:`PersistentEngine`.
    """

    def __init__(self, cfg: ModelConfig, params: dict, ecfg: EngineConfig):
        super().__init__(cfg, params, ecfg)
        self.controller = self.new_controller()
        self.alpha = 0.0

    # ------------------------------------------------------------- prefill
    def prefill(self, tokens: jax.Array, **model_kwargs):
        """Run prefill; simulate layer-streaming cache fills; apply warmup."""
        logits, self.kv_cache, info = self.run_prefill(
            tokens, **model_kwargs)
        self.warmup_summary = info["warmup"]
        self.prefill_snapshot = info["snapshot"]
        return logits

    # -------------------------------------------------------------- decode
    def decode(self, first_token: jax.Array, n_steps: int,
               **model_kwargs):
        """Greedy decode ``n_steps`` tokens with full offload simulation.

        Returns (tokens [B, n_steps], metrics dict).
        """
        token = first_token
        tokens_out = []
        step_metrics = []

        for step in range(n_steps):
            ps = self._policy_state()
            logits, self.kv_cache, aux = self._jit_decode(
                self.qparams, token=token, cache=self.kv_cache,
                policy_state=ps, alpha=jnp.float32(self.alpha),
                **model_kwargs)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            tokens_out.append(token)

            charge = self.charge_decode_step(aux)
            step_miss = charge.miss_rate
            if self.controller is not None:
                self.alpha = self.controller.update(step_miss)
            step_metrics.append({
                "miss_rate": step_miss,
                "alpha": self.alpha,
                **charge.ledger_delta,
            })

        metrics = {
            "per_step": step_metrics,
            "cache_stats": self.cache.stats.snapshot(),
            "decode_totals": self.ledger.delta_since(self.prefill_snapshot),
        }
        return jnp.stack(tokens_out, axis=1), metrics

    def _charge_step(self, aux) -> float:
        """Back-compat shim: replay one step, return the fleet miss rate."""
        return self.charge_decode_step(aux).miss_rate
