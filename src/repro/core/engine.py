"""SliceMoE inference engine (paper §5-6): the orchestrator.

Runs a *real* JAX MoE model token-by-token while simulating the
DRAM/Flash offload hierarchy.  Per decode step:

  1. the jitted ``decode_step`` runs with the current cache residency
     masks, the static :class:`RoutingPolicy` and the Cache-Prior boost
     ``alpha`` — it returns next-token logits plus per-layer traces
     (selected experts, gates, criticality, slice demand);
  2. the Python-side :class:`SliceCache` replays the slice demand
     (MSB always; LSB per DBSC criticality), records hits/misses and
     charges the :class:`CostLedger` (Flash fill on miss, DRAM read on
     use, XPU matmul energy at the computed precision);
  3. the :class:`MissRateController` updates ``alpha`` from the rolling
     miss rate (activating after the paper's 10-step warmup window).

Prefill runs once, layer-parallel, collecting the hotness statistics PCW
needs; the prefill→decode transition applies the selected cache
initialization (``pcw`` or one of the Fig. 10 baselines).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.amat import MatConfig
from repro.core.cache import SliceCache
from repro.core.routing import MissRateController
from repro.core.slices import ExpertSliceStore, SliceKey, quantize_moe_params
from repro.core.warmup import (HotnessTracker, INIT_STATES, pcw_reshape)
from repro.hw.energy import CostLedger
from repro.hw.specs import SYSTEM_PROFILES
from repro.models.moe import RoutingPolicy
from repro.models import model as MDL


@dataclasses.dataclass
class EngineConfig:
    mat: MatConfig = dataclasses.field(
        default_factory=lambda: MatConfig(8, 4))
    cache_bytes: float = 64e6
    policy: RoutingPolicy = dataclasses.field(default_factory=RoutingPolicy)
    miss_rate_target: Optional[float] = None      # e.g. 0.05
    warmup: str = "pcw"        # 'pcw' | 'empty' | 'last_layer' | 'random'
    lsb_keep_frac: float = 0.125
    system: str = "mobile_soc"
    max_seq: int = 256
    # Whole-expert caching (high-bit baseline): both slices move together.
    fused_slices: bool = False
    # Layer-transition expert prefetching (the paper's §2.1 baseline):
    # pull the top-m predicted next-layer experts into DRAM per layer.
    # None disables.
    prefetch_top_m: Optional[int] = None

    def cache(self) -> SliceCache:
        slice_aware = self.policy.slice_mode == "dbsc" and not self.fused_slices
        return SliceCache(self.cache_bytes, slice_aware=slice_aware)


class SliceMoEEngine:
    def __init__(self, cfg: ModelConfig, params: dict, ecfg: EngineConfig):
        if not cfg.has_moe:
            raise ValueError(f"{cfg.name} has no MoE layers; SliceMoE "
                             "expert caching is inapplicable (see DESIGN.md)")
        self.cfg = cfg
        self.ecfg = ecfg
        self.qparams, self.store, self.layer_map = quantize_moe_params(
            params, cfg, ecfg.mat)
        self.float_params = params
        self.n_moe_layers = len(self.layer_map)
        self.n_experts = cfg.moe.n_experts

        self.cache = ecfg.cache()
        self.ledger = CostLedger(system=SYSTEM_PROFILES[ecfg.system])
        self.tracker = HotnessTracker(self.n_moe_layers, self.n_experts)
        self.controller = MissRateController(ecfg.miss_rate_target) \
            if ecfg.miss_rate_target is not None else None
        self.alpha = 0.0

        # moe pattern positions in order (matches aux stacking order)
        self.moe_positions = [i for i, s in enumerate(cfg.block_pattern)
                              if s.ffn == "moe"]

        self.prefetcher = None
        if ecfg.prefetch_top_m:
            from repro.core.prefetch import TransitionPrefetcher
            self.prefetcher = TransitionPrefetcher(
                self.n_moe_layers, self.n_experts,
                top_m=ecfg.prefetch_top_m)

        # BuddyMoE offline calibration (policy.kind == 'buddy'): nearest
        # expert by weight cosine similarity, per (position, period).
        self.buddies = None
        if ecfg.policy.kind == "buddy":
            from repro.core.routing import compute_buddies
            self.buddies = {}
            for i in self.moe_positions:
                wi = params["blocks"][f"pos{i}"]["moe"]["experts"]["wi"]
                P, E = wi.shape[0], wi.shape[1]
                flat = wi.reshape(P, E, -1)
                self.buddies[f"pos{i}"] = jnp.stack(
                    [compute_buddies(flat[p]) for p in range(P)])

        self._jit_prefill = jax.jit(partial(
            MDL.prefill, cfg=cfg, max_seq=ecfg.max_seq, collect_trace=True,
            mat=ecfg.mat))
        self._jit_decode = jax.jit(partial(
            MDL.decode_step, cfg=cfg, collect_trace=True,
            policy=ecfg.policy, mat=ecfg.mat))

        # Non-expert resident weight bytes touched per decode step (INT8
        # per the paper's G128 non-expert quantization).
        total = MDL.count_params(params)
        import numpy as _np
        expert_total = 0
        for i in self.moe_positions:
            e = params["blocks"][f"pos{i}"]["moe"]["experts"]
            expert_total += sum(int(_np.prod(x.shape)) for x in e.values())
        self.resident_bytes = float(total - expert_total)  # int8: 1 B/param

        # per-expert matmul dims for cost accounting
        m = cfg.moe
        wi_cols = 2 * m.d_ff if m.mlp_type in ("swiglu", "geglu") else m.d_ff
        self.expert_macs_per_token = cfg.d_model * wi_cols + m.d_ff * cfg.d_model

    # ------------------------------------------------------------- prefill
    def prefill(self, tokens: jax.Array, **model_kwargs):
        """Run prefill; simulate layer-streaming cache fills; apply warmup."""
        logits, kv_cache, aux = self._jit_prefill(
            self.qparams, tokens=tokens, **model_kwargs)
        self.kv_cache = kv_cache

        ids = np.asarray(aux["moe"]["ids"])      # [n_periods, n_moe_pos, T, k]
        gates = np.asarray(aux["moe"]["gates"]).astype(np.float64)

        # Layer-order streaming: for each flat moe layer (in execution
        # order), every expert selected by >=1 token is loaded high-bit.
        for period in range(ids.shape[0]):
            for pidx, pos in enumerate(self.moe_positions):
                lidx = self.layer_map[(pos, period)]
                l_ids, l_gates = ids[period, pidx], gates[period, pidx]
                self.tracker.observe(lidx, l_ids, l_gates)
                used = np.unique(l_ids.reshape(-1))
                for e in used:
                    for kind in ("msb", "lsb"):   # prefill is high-bit
                        key = SliceKey(lidx, int(e), kind)
                        nb = self.store.slice_bytes(key)
                        hit = self.cache.access(key, nb)
                        if not hit:
                            self.ledger.miss_fill(nb)
                        self.ledger.dram_read(nb)
                # prefill compute: all routed tokens, high precision
                t_routed = l_ids.size
                self.ledger.matmul(t_routed, self.cfg.d_model,
                                   self.expert_macs_per_token // self.cfg.d_model,
                                   self.ecfg.mat.high_bits)

        # Transition: PCW or a baseline init state.
        if self.ecfg.warmup == "pcw":
            self.warmup_summary = pcw_reshape(
                self.cache, self.store, self.tracker,
                lsb_keep_frac=self.ecfg.lsb_keep_frac)
        else:
            INIT_STATES[self.ecfg.warmup](self.cache, self.store)
            self.warmup_summary = {"init": self.ecfg.warmup}
        self.prefill_snapshot = self.ledger.snapshot()
        self.cache.stats.reset()
        return logits

    # -------------------------------------------------------------- decode
    def _policy_state(self):
        msb, lsb = self.cache.residency(self.n_moe_layers, self.n_experts)
        n_periods = self.cfg.n_periods
        state = {}
        for pos in self.moe_positions:
            cm = np.zeros((n_periods, self.n_experts), bool)
            cl = np.zeros((n_periods, self.n_experts), bool)
            for period in range(n_periods):
                lidx = self.layer_map[(pos, period)]
                cm[period] = msb[lidx]
                cl[period] = lsb[lidx]
            state[f"pos{pos}"] = {
                "cached_msb": jnp.asarray(cm),
                "cached_lsb": jnp.asarray(cl),
            }
            if self.buddies is not None:
                state[f"pos{pos}"]["buddies"] = self.buddies[f"pos{pos}"]
        return state

    def decode(self, first_token: jax.Array, n_steps: int,
               **model_kwargs):
        """Greedy decode ``n_steps`` tokens with full offload simulation.

        Returns (tokens [B, n_steps], metrics dict).
        """
        token = first_token
        tokens_out = []
        step_metrics = []
        base = self.ledger.snapshot()

        for step in range(n_steps):
            ps = self._policy_state()
            logits, self.kv_cache, aux = self._jit_decode(
                self.qparams, token=token, cache=self.kv_cache,
                policy_state=ps, alpha=jnp.float32(self.alpha),
                **model_kwargs)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            tokens_out.append(token)

            step_miss = self._charge_step(aux)
            if self.controller is not None:
                self.alpha = self.controller.update(step_miss)
            step_metrics.append({
                "miss_rate": step_miss,
                "alpha": self.alpha,
                **self.ledger.delta_since(base),
            })
            base = self.ledger.snapshot()

        metrics = {
            "per_step": step_metrics,
            "cache_stats": self.cache.stats.snapshot(),
            "decode_totals": self.ledger.delta_since(self.prefill_snapshot),
        }
        return jnp.stack(tokens_out, axis=1), metrics

    def _charge_step(self, aux) -> float:
        """Replay one decode step's slice demand into cache + ledger."""
        ids = np.asarray(aux["moe"]["ids"])            # [P, npos, T, k]
        msb_needed = np.asarray(aux["moe"]["msb_needed"])  # [P, npos, E]
        lsb_needed = np.asarray(aux["moe"]["lsb_needed"])
        use_lsb = np.asarray(aux["moe"]["use_lsb"])
        gates = np.asarray(aux["moe"]["gates"]).astype(np.float64)
        active = np.asarray(aux["moe"]["active"])

        accesses = misses = 0
        mat = self.ecfg.mat
        prev_used = None
        for period in range(ids.shape[0]):
            for pidx, pos in enumerate(self.moe_positions):
                lidx = self.layer_map[(pos, period)]
                # --- prefetch (paper §2.1 baseline): before this layer
                # runs, the predictor has pulled its guesses into DRAM.
                if self.prefetcher is not None and prev_used is not None:
                    predicted = self.prefetcher.predict(lidx - 1, prev_used)
                    self.prefetcher.mark_issued(len(predicted))
                    for e in predicted:
                        key = SliceKey(lidx, int(e), "msb")
                        nb = self.store.slice_bytes(key)
                        if self.ecfg.fused_slices:
                            nb = self.store.highbit_expert_bytes()
                        if key not in self.cache:
                            self.ledger.miss_fill(nb)
                            self.cache.insert(key, nb)
                act = active[period, pidx].reshape(-1)
                flat_ids = ids[period, pidx].reshape(-1)[act]
                flat_gates = gates[period, pidx].reshape(-1)[act]
                self.tracker.observe(lidx, flat_ids, flat_gates)
                if self.prefetcher is not None:
                    if prev_used is not None:
                        self.prefetcher.observe(lidx, prev_used, flat_ids)
                        hits = set(np.unique(flat_ids)) & set(
                            int(e) for e in
                            self.prefetcher.predict(lidx - 1, prev_used))
                        self.prefetcher.mark_useful(len(hits))
                    prev_used = flat_ids
                # token count per expert (for compute cost)
                tok_per_e = np.bincount(flat_ids, minlength=self.n_experts)
                for e in np.nonzero(msb_needed[period, pidx])[0]:
                    e = int(e)
                    key = SliceKey(lidx, e, "msb")
                    nb = self.store.slice_bytes(key)
                    if self.ecfg.fused_slices:
                        nb = self.store.highbit_expert_bytes()
                    hit = self.cache.access(key, nb)
                    accesses += 1
                    if not hit:
                        misses += 1
                        self.ledger.miss_fill(nb)
                    self.ledger.dram_read(nb)
                    wants_lsb = bool(lsb_needed[period, pidx, e]) \
                        and not self.ecfg.fused_slices
                    if wants_lsb:
                        lkey = SliceKey(lidx, e, "lsb")
                        lnb = self.store.slice_bytes(lkey)
                        lhit = self.cache.access(
                            lkey, lnb,
                            fill_on_miss=self.ecfg.policy.fetch_lsb_on_miss)
                        accesses += 1
                        if not lhit:
                            misses += 1
                            if self.ecfg.policy.fetch_lsb_on_miss:
                                self.ledger.miss_fill(lnb)
                        if lhit or self.ecfg.policy.fetch_lsb_on_miss:
                            self.ledger.dram_read(lnb)
                    bits = mat.high_bits if bool(use_lsb[period, pidx, e]) \
                        else mat.low_bits
                    if self.ecfg.fused_slices:
                        bits = mat.high_bits
                    self.ledger.matmul(
                        int(tok_per_e[e]), self.cfg.d_model,
                        self.expert_macs_per_token // self.cfg.d_model,
                        bits)
        # Non-expert resident weights: one pass per decode step.
        self.ledger.dram_read(self.resident_bytes)
        self.ledger.matmul(ids.shape[-2], self.cfg.d_model,
                           int(self.resident_bytes / self.cfg.d_model) + 1, 8)
        return misses / max(accesses, 1)
