"""Calibration-Free Asymmetric Matryoshka Quantization (AMAT) — paper §4.2.

One high-bit asymmetric group-quantized tensor stores *both* precisions.
The low-bit representation is obtained by truncating the code **and** the
zero-point by the same bit offset::

    shift   = b_high - b_low
    q_low   = floor(q_high / 2**shift)      # MSB slice
    zp_low  = floor(zp_high / 2**shift)
    s_low   = s_high * 2**shift             # implied by the bit offset

so ``(q_low - zp_low) * s_low`` re-centers the low-bit range on the
asymmetric weight distribution.  The LSB slice ``q_high & (2**shift - 1)``
is the *upgrade* payload: caching it alongside the MSB slice losslessly
reconstructs the high-bit code via ``(msb << shift) | lsb``.

Baselines reproduced for Table 1:

* ``base``   — independent low-bit quantization (the quality ceiling).
* ``trunc``  — *naive* truncation: the code is shifted but the metadata
  (scale, zero-point) is left at its high-bit values.  Under symmetric
  quant this shrinks every weight by ``2**shift``; under asymmetric quant
  the un-truncated zero-point wrecks the dequant entirely (paper: PPL
  1e6-1e10 / nan).
* ``amat``   — joint code+zp truncation (ours / the paper's).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.quant.groupquant import QuantizedTensor, quantize, dequantize


@dataclasses.dataclass(frozen=True)
class MatConfig:
    """A Matryoshka MAT(h, l) configuration, e.g. MAT84 = (8, 4)."""

    high_bits: int
    low_bits: int
    group_size: int = 32

    @property
    def shift(self) -> int:
        return self.high_bits - self.low_bits

    @property
    def name(self) -> str:
        return f"MAT{self.high_bits}{self.low_bits}"


MAT42 = MatConfig(4, 2)
MAT63 = MatConfig(6, 3)
MAT84 = MatConfig(8, 4)
PAPER_CONFIGS = (MAT42, MAT63, MAT84)


# --------------------------------------------------------------------------
# AMAT construction
# --------------------------------------------------------------------------
def amat_quantize(w: jax.Array, cfg: MatConfig) -> QuantizedTensor:
    """Quantize ``w`` at the *high* bit-width; the low-bit view is free."""
    return quantize(w, bits=cfg.high_bits, group_size=cfg.group_size,
                    asymmetric=True)


@partial(jax.jit, static_argnames=("low_bits", "truncate_zp", "rescale"))
def truncate(
    qt: QuantizedTensor,
    *,
    low_bits: int,
    truncate_zp: bool = True,
    rescale: bool = True,
) -> QuantizedTensor:
    """Derive a low-bit QuantizedTensor from a high-bit one by truncation.

    ``truncate_zp=True, rescale=True``  -> AMAT (the paper's scheme).
    ``truncate_zp=False, rescale=False`` -> naive truncation baseline.
    """
    shift = qt.bits - low_bits
    if shift < 0:
        raise ValueError(f"cannot truncate {qt.bits}b -> {low_bits}b")
    if shift == 0:
        return qt
    if qt.asymmetric:
        codes = (qt.codes >> shift).astype(jnp.uint8)
        zps = (qt.zero_points >> shift) if truncate_zp else qt.zero_points
    else:
        # arithmetic shift == floor division for int8
        codes = (qt.codes.astype(jnp.int8) >> shift).astype(jnp.int8)
        zps = qt.zero_points
    scales = qt.scales * (2.0**shift) if rescale else qt.scales
    return QuantizedTensor(codes, scales, zps, low_bits, qt.group_size,
                           qt.asymmetric)


# --------------------------------------------------------------------------
# Bit-slice views (DBSC's storage primitive)
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("shift",))
def msb_slice(codes: jax.Array, shift: int) -> jax.Array:
    """Top ``bits - shift`` bits of each code (the low-precision payload)."""
    return (codes >> shift).astype(codes.dtype)


@partial(jax.jit, static_argnames=("shift",))
def lsb_slice(codes: jax.Array, shift: int) -> jax.Array:
    """Bottom ``shift`` bits of each code (the precision-upgrade payload)."""
    mask = (1 << shift) - 1
    return (codes & mask).astype(codes.dtype)


@partial(jax.jit, static_argnames=("shift",))
def reconstruct(msb: jax.Array, lsb: jax.Array, shift: int) -> jax.Array:
    """Lossless high-bit code from its two slices."""
    return ((msb << shift) | lsb).astype(msb.dtype)


# --------------------------------------------------------------------------
# Dequantization paths
# --------------------------------------------------------------------------
def dequant_high(qt: QuantizedTensor) -> jax.Array:
    """Full-precision path (MSB+LSB both resident)."""
    return dequantize(qt)


def dequant_low(qt: QuantizedTensor, cfg: MatConfig) -> jax.Array:
    """MSB-only path (AMAT truncation)."""
    return dequantize(truncate(qt, low_bits=cfg.low_bits))


@partial(jax.jit, static_argnames=("shift",))
def dequant_mixed(qt: QuantizedTensor, use_lsb: jax.Array, shift: int) -> jax.Array:
    """Per-leading-index mixed dequantization.

    ``use_lsb`` has shape ``qt.codes.shape[:use_lsb.ndim]`` (typically
    ``(E,)`` for per-expert precision) and selects, per expert, the
    high-bit (MSB+LSB) or the AMAT low-bit (MSB-only) dequantization.
    This is the jittable compute path behind DBSC: a slice miss on the LSB
    simply flips the corresponding ``use_lsb`` bit.
    """
    codes = qt.codes
    *lead, K, N = codes.shape
    G = K // qt.group_size
    cg = codes.reshape(*lead, G, qt.group_size, N).astype(jnp.float32)
    zp = qt.zero_points[..., :, None, :].astype(jnp.float32)
    s = qt.scales[..., :, None, :]

    w_hi = (cg - zp) * s
    cl = jnp.floor(cg / (2.0**shift))
    zl = jnp.floor(zp / (2.0**shift))
    w_lo = (cl - zl) * (s * (2.0**shift))

    sel = use_lsb.reshape(use_lsb.shape + (1,) * (w_hi.ndim - use_lsb.ndim))
    w = jnp.where(sel, w_hi, w_lo)
    return w.reshape(*lead, K, N)


def slice_nbytes(shape, bits: int, group_size: int, *, which: str,
                 shift: int) -> float:
    """Storage cost of one slice of a quantized weight of ``shape``.

    MSB slice carries the (bits - shift)-bit codes plus all group metadata
    (scale fp16 + truncated zp); the LSB slice is codes-only (`shift` bits
    per element) — its metadata is derived by shifting the MSB's.
    """
    import numpy as np

    n = float(np.prod(shape))
    n_groups = n / group_size
    if which == "msb":
        code_bits = bits - shift
        return n * code_bits / 8 + n_groups * (2 + code_bits / 8)
    if which == "lsb":
        return n * shift / 8
    raise ValueError(which)
