"""Bit-sliced expert weight store (DBSC's storage layer, paper §4.1).

One AMAT high-bit code buffer per (layer, expert) weight matrix; the MSB
and LSB *slices* are views of that buffer (shift / mask), so supporting
mixed precision costs **zero** extra weight memory — the point of AMAT.

The store serves two consumers:

* the **cache simulator** asks for slice byte sizes and identities
  (:class:`SliceKey`) to manage the DRAM budget, and
* the **jitted model** receives stacked ``QuantizedTensor`` expert weights
  plus a per-expert ``use_lsb`` mask assembled from cache state.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amat import MatConfig, amat_quantize, slice_nbytes
from repro.quant.groupquant import QuantizedTensor


class SliceKey(NamedTuple):
    layer: int
    expert: int
    kind: str          # 'msb' | 'lsb'


@dataclasses.dataclass
class LayerExperts:
    """Stacked AMAT-quantized expert weights for one MoE layer."""

    wi_q: QuantizedTensor          # codes [E, d, F(|2F)]
    wo_q: QuantizedTensor          # codes [E, F, d]

    @property
    def n_experts(self) -> int:
        return self.wi_q.codes.shape[0]


@dataclasses.dataclass
class ExpertSliceStore:
    """All MoE layers' expert weights in AMAT form + slice-size metadata."""

    mat: MatConfig
    layers: Dict[int, LayerExperts]
    msb_bytes_per_expert: float = 0.0
    lsb_bytes_per_expert: float = 0.0

    @classmethod
    def from_float(cls, expert_weights: Dict[int, dict],
                   mat: MatConfig) -> "ExpertSliceStore":
        """expert_weights: {layer: {'wi': [E,d,F], 'wo': [E,F,d]}} floats."""
        layers = {}
        msb_b = lsb_b = 0.0
        for lidx, w in expert_weights.items():
            le = LayerExperts(
                wi_q=amat_quantize(w["wi"], mat),
                wo_q=amat_quantize(w["wo"], mat),
            )
            layers[lidx] = le
            msb_b = sum(
                slice_nbytes(q.codes.shape[1:], mat.high_bits,
                             mat.group_size, which="msb", shift=mat.shift)
                for q in (le.wi_q, le.wo_q))
            lsb_b = sum(
                slice_nbytes(q.codes.shape[1:], mat.high_bits,
                             mat.group_size, which="lsb", shift=mat.shift)
                for q in (le.wi_q, le.wo_q))
        return cls(mat=mat, layers=layers,
                   msb_bytes_per_expert=msb_b, lsb_bytes_per_expert=lsb_b)

    # ------------------------------------------------------------ metadata
    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def n_experts(self) -> int:
        return next(iter(self.layers.values())).n_experts

    def slice_bytes(self, key: SliceKey) -> float:
        return (self.msb_bytes_per_expert if key.kind == "msb"
                else self.lsb_bytes_per_expert)

    def highbit_expert_bytes(self) -> float:
        return self.msb_bytes_per_expert + self.lsb_bytes_per_expert

    def total_bytes(self) -> float:
        return self.highbit_expert_bytes() * self.n_layers * self.n_experts

    def all_keys(self):
        for lidx in self.layers:
            for e in range(self.n_experts):
                yield SliceKey(lidx, e, "msb")
                yield SliceKey(lidx, e, "lsb")

    # ------------------------------------------------------- compute views
    def layer_weights(self, layer: int) -> LayerExperts:
        return self.layers[layer]

    def use_lsb_mask(self, layer: int, resident_lsb: np.ndarray) -> jax.Array:
        """Build the jit-input mask from the cache's LSB residency row."""
        return jnp.asarray(resident_lsb, bool)


def quantize_moe_params(params: dict, cfg, mat: MatConfig, *,
                        quant_execution: bool = False):
    """Replace float expert weights in a model param tree by AMAT tensors.

    Returns (new_params, store).  The param tree keeps QuantizedTensor
    leaves (a registered pytree) under ``experts/{wi_q,wo_q}``; the store
    indexes the same tensors by *flat layer index* for the cache sim.

    ``quant_execution``: additionally store the ``wo`` codes transposed
    to the output-major ``[..., d_model, d_ff]`` layout under
    ``experts/wo_codes_t`` — the layout the transposed batched-expert
    kernel consumes (so the hot path never transposes at step time).
    """
    pattern = cfg.block_pattern
    new_blocks = dict(params["blocks"])
    expert_weights: Dict[int, dict] = {}
    store_layers: Dict[int, LayerExperts] = {}

    flat_idx = 0
    layer_map = {}   # (pos, period) -> flat moe layer index
    for period in range(cfg.n_periods):
        for i, spec in enumerate(pattern):
            if spec.ffn == "moe":
                layer_map[(i, period)] = flat_idx
                flat_idx += 1

    msb_b = lsb_b = 0.0
    for i, spec in enumerate(pattern):
        if spec.ffn != "moe":
            continue
        blk = dict(new_blocks[f"pos{i}"])
        experts = blk["moe"]["experts"]
        wi = experts["wi"].astype(jnp.float32)   # [n_periods, E, d, F]
        wo = experts["wo"].astype(jnp.float32)
        wi_q = amat_quantize(wi, mat)
        wo_q = amat_quantize(wo, mat)
        moe_p = dict(blk["moe"])
        moe_p["experts"] = {"wi_q": wi_q, "wo_q": wo_q}
        if quant_execution:
            moe_p["experts"]["wo_codes_t"] = jnp.swapaxes(
                wo_q.codes, -1, -2)
        blk["moe"] = moe_p
        new_blocks[f"pos{i}"] = blk
        for period in range(cfg.n_periods):
            lidx = layer_map[(i, period)]
            le = LayerExperts(
                wi_q=_index_qt(wi_q, period), wo_q=_index_qt(wo_q, period))
            store_layers[lidx] = le
            msb_b = sum(
                slice_nbytes(q.codes.shape[1:], mat.high_bits,
                             mat.group_size, which="msb", shift=mat.shift)
                for q in (le.wi_q, le.wo_q))
            lsb_b = sum(
                slice_nbytes(q.codes.shape[1:], mat.high_bits,
                             mat.group_size, which="lsb", shift=mat.shift)
                for q in (le.wi_q, le.wo_q))

    new_params = dict(params)
    new_params["blocks"] = new_blocks
    store = ExpertSliceStore(
        mat=mat, layers=store_layers,
        msb_bytes_per_expert=msb_b, lsb_bytes_per_expert=lsb_b)
    return new_params, store, layer_map


def _index_qt(qt: QuantizedTensor, i: int) -> QuantizedTensor:
    return QuantizedTensor(qt.codes[i], qt.scales[i], qt.zero_points[i],
                           qt.bits, qt.group_size, qt.asymmetric)
