"""Expert-parallel sharding: placement + the per-shard slice cache set.

Expert parallelism partitions the experts of every MoE layer across
``ep`` shards along the mesh ``model`` axis; each shard owns the DRAM
slice cache (and, in :mod:`repro.hw.energy`, the Flash/DRAM channel
clocks) for its experts.  Placement is a **pure function of the expert
id** — round-robin ``expert % ep`` — so:

* a routing trace recorded on a single device replays under *any*
  ``ep_shards`` (the trace stores expert ids, never device ids), which
  is what makes EP a sweepable axis in :mod:`repro.sim.autotune`;
* every layer spreads its experts evenly across shards (contiguous
  blocks would, too, but round-robin also balances the common
  low-id-biased synthetic streams);
* the live engine, the replay simulator and the telemetry all agree on
  ownership without exchanging any state.

:class:`ShardedSliceCache` wraps ``ep`` independent
:class:`~repro.core.cache.SliceCache` instances (each holding
``capacity_bytes / ep`` — the aggregate DRAM budget is *iso* with the
single-device run, split in proportion to each shard's expert
population) behind the exact :class:`SliceCache` surface the engine's
charge path, PCW reshape and the policy-state builder consume.  Routing
is by key; stats/epochs aggregate across shards on read while the
per-shard windows stay addressable for the EP fidelity gate and the
serving telemetry breakdown.

Tokens also have a home shard (the dense, non-expert half of the model
runs data-parallel over the same devices): decode slot ``b`` and
prefill position ``t`` live on shard ``b % ep`` / ``t % ep``.  A
selection whose expert lives elsewhere pays all-to-all dispatch bytes —
charged by the engine on the interconnect channel, computed here by
:func:`all_to_all_bytes`.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.cache import CacheStats, SliceCache
from repro.core.slices import SliceKey

__all__ = ["shard_of_expert", "expert_placement", "home_shard_of_token",
           "remote_selection_mask", "all_to_all_bytes",
           "ShardedSliceCache"]


def shard_of_expert(expert, n_shards: int):
    """Owning shard of ``expert``: round-robin, pure in the expert id.

    Accepts a scalar (returns int) or an id ndarray (returns the
    elementwise placement) — every ownership decision in the engine,
    replay and telemetry goes through this one function.
    """
    if isinstance(expert, np.ndarray):
        return expert % int(n_shards)
    return int(expert) % int(n_shards)


def expert_placement(n_experts: int, n_shards: int) -> np.ndarray:
    """[E] int array mapping every expert id to its owning shard."""
    return shard_of_expert(np.arange(n_experts, dtype=np.int64), n_shards)


def home_shard_of_token(token_idx, n_shards: int):
    """Home shard of a decode slot / prompt position: the dense
    (non-expert) half of the model runs data-parallel round-robin over
    the same shards.  Scalar or ndarray, like :func:`shard_of_expert`."""
    return shard_of_expert(token_idx, n_shards)


def remote_selection_mask(token_idx: np.ndarray, expert_ids: np.ndarray,
                          n_shards: int) -> np.ndarray:
    """Bool mask over flat parallel (token, expert) selections: True
    where the token's home shard (``token_idx % n_shards``) differs
    from the expert's owner, i.e. the selection pays all-to-all."""
    if n_shards <= 1 or token_idx.size == 0:
        return np.zeros(token_idx.shape, bool)
    return home_shard_of_token(token_idx, n_shards) \
        != shard_of_expert(expert_ids, n_shards)


def all_to_all_bytes(token_idx: np.ndarray, expert_ids: np.ndarray,
                     d_model: int, n_shards: int,
                     itemsize: float = 1.0) -> float:
    """Dispatch + combine bytes for one layer's routed selections.

    ``token_idx``/``expert_ids``: flat parallel arrays, one entry per
    *active* (token, slot) selection.  Each remote selection (see
    :func:`remote_selection_mask`) moves its ``d_model`` activation to
    the expert's shard and the result back (2x).  Activations travel at
    ``itemsize`` bytes/element (int8 by default, matching the engine's
    INT8 non-expert traffic convention).
    """
    remote = remote_selection_mask(token_idx, expert_ids, n_shards)
    return 2.0 * d_model * itemsize * float(np.count_nonzero(remote))


class _AggregateStats:
    """Read/reset view over the per-shard :class:`CacheStats` windows.

    Mirrors the pieces of ``CacheStats`` the engine, scheduler and
    benchmarks touch on ``cache.stats`` (snapshot / reset / the derived
    counters); mutation happens inside each shard's own ``access``.
    """

    def __init__(self, shards: List[SliceCache]):
        self._shards = shards

    def snapshot(self) -> dict:
        out = self._shards[0].stats.snapshot()
        for s in self._shards[1:]:
            snap = s.stats.snapshot()
            for k in out:
                out[k] += snap[k]
        return out

    def reset(self) -> None:
        for s in self._shards:
            s.stats.reset()

    def __getattr__(self, name):
        # Derived counters (accesses, misses, miss_rate, msb_misses, ...)
        # come from a summed CacheStats built on demand.
        return getattr(CacheStats(**self.snapshot()), name)


class ShardedSliceCache:
    """``ep`` per-shard :class:`SliceCache` instances behind one surface.

    Every key-addressed operation routes to the owning shard
    (:func:`shard_of_expert` on ``key.expert``); aggregate reads
    (``used``, ``residency``, ``stats``, ``epochs``) combine shards.
    Capacity is split evenly: each shard holds ``capacity_bytes /
    n_shards`` and only ever sees keys it owns, so LRU/eviction pressure
    is strictly shard-local — exactly the deployment question EP poses
    (a hot shard cannot borrow a cold shard's DRAM).
    """

    def __init__(self, capacity_bytes: float, n_shards: int, *,
                 slice_aware: bool = True):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = int(n_shards)
        self.slice_aware = slice_aware
        self.shards: List[SliceCache] = [
            SliceCache(capacity_bytes / self.n_shards,
                       slice_aware=slice_aware)
            for _ in range(self.n_shards)]

    # ------------------------------------------------------------ routing
    def shard_index(self, key: SliceKey) -> int:
        return shard_of_expert(key.expert, self.n_shards)

    def shard(self, key: SliceKey) -> SliceCache:
        return self.shards[self.shard_index(key)]

    # ----------------------------------------------------- aggregate state
    @property
    def capacity(self) -> float:
        return sum(s.capacity for s in self.shards)

    @property
    def used(self) -> float:
        return sum(s.used for s in self.shards)

    @property
    def stats(self) -> _AggregateStats:
        return _AggregateStats(self.shards)

    def __contains__(self, key: SliceKey) -> bool:
        return key in self.shard(key)

    def __len__(self) -> int:
        return sum(len(s) for s in self.shards)

    def contains(self, key: SliceKey) -> bool:
        return key in self

    def can_fit(self, key: SliceKey, nbytes: float) -> bool:
        s = self.shard(key)
        return s.used + nbytes <= s.capacity

    def set_active_tenant(self, tenant) -> None:
        """No-op (see :meth:`SliceCache.set_active_tenant`)."""

    # ------------------------------------------------------------- mutate
    def access(self, key: SliceKey, nbytes: float,
               *, fill_on_miss: bool = True) -> bool:
        return self.shard(key).access(key, nbytes,
                                      fill_on_miss=fill_on_miss)

    def insert(self, key: SliceKey, nbytes: float) -> List[SliceKey]:
        return self.shard(key).insert(key, nbytes)

    def evict(self, key: SliceKey) -> bool:
        return self.shard(key).evict(key)

    def evict_where(self, pred) -> List[SliceKey]:
        out: List[SliceKey] = []
        for s in self.shards:
            out.extend(s.evict_where(pred))
        return out

    def reorder_by(self, ranking) -> None:
        for s in self.shards:
            s.reorder_by(ranking)

    def clear(self) -> None:
        for s in self.shards:
            s.clear()

    # --------------------------------------------------- in-flight fills
    def mark_inflight(self, key: SliceKey, ready_t: float) -> None:
        self.shard(key).mark_inflight(key, ready_t)

    def ready_time(self, key: SliceKey, default: float = 0.0) -> float:
        return self.shard(key).ready_time(key, default)

    def settle(self, now: float) -> None:
        for s in self.shards:
            s.settle(now)

    # ------------------------------------------------------------- reads
    def resident_keys(self) -> List[SliceKey]:
        out: List[SliceKey] = []
        for s in self.shards:
            out.extend(s.resident_keys())
        return out

    def residency(self, n_layers: int, n_experts: int):
        msb = np.zeros((n_layers, n_experts), bool)
        lsb = np.zeros((n_layers, n_experts), bool)
        for s in self.shards:
            m, l = s.residency(n_layers, n_experts)
            msb |= m
            lsb |= l
        return msb, lsb

    # ------------------------------------------------------------- epochs
    # begin/end fan out so every shard's counter window rolls over at the
    # same request boundary; per-label aggregation sums the windows.
    def begin_epoch(self, label: str) -> None:
        for s in self.shards:
            s.begin_epoch(label)

    def end_epoch(self) -> None:
        for s in self.shards:
            s.end_epoch()

    @property
    def epochs(self) -> List[Tuple[str, dict]]:
        """Aggregated ``[(label, summed stats dict)]`` across shards."""
        if not self.shards[0].epochs:
            return []
        out: List[Tuple[str, dict]] = []
        for i, (label, snap) in enumerate(self.shards[0].epochs):
            agg = dict(snap)
            for s in self.shards[1:]:
                other_label, other = s.epochs[i]
                assert other_label == label, \
                    f"shard epoch skew: {other_label!r} != {label!r}"
                for k in agg:
                    agg[k] += other[k]
            out.append((label, agg))
        return out

    def epoch_miss_rates(self) -> List[Tuple[str, float]]:
        return [(label, CacheStats(**snap).miss_rate)
                for label, snap in self.epochs]

    def epoch_counts(self) -> List[Tuple[str, int, int]]:
        return [(label, CacheStats(**snap).accesses,
                 CacheStats(**snap).misses)
                for label, snap in self.epochs]

    def per_shard_epoch_counts(self) -> List[List[Tuple[str, int, int]]]:
        """Per-shard ``epoch_counts`` — the EP fidelity gate's unit."""
        return [s.epoch_counts() for s in self.shards]

    def per_shard_counts(self) -> List[Tuple[int, int]]:
        """Lifetime (accesses, misses) per shard: archived epochs plus
        the open window."""
        out = []
        for s in self.shards:
            acc = s.stats.accesses
            miss = s.stats.misses
            for _, snap in s.epochs:
                st = CacheStats(**snap)
                acc += st.accesses
                miss += st.misses
            out.append((acc, miss))
        return out

    def clone(self) -> "ShardedSliceCache":
        import copy

        return copy.deepcopy(self)
