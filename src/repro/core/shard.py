"""Expert-parallel sharding: placement + the per-shard slice cache set.

Expert parallelism partitions the experts of every MoE layer across
``ep`` shards along the mesh ``model`` axis; each shard owns the DRAM
slice cache (and, in :mod:`repro.hw.energy`, the Flash/DRAM channel
clocks) for its experts.  Ownership is decided by a
:class:`~repro.core.placement.PlacementMap` — an explicit ``[L, E] →
shard`` table (plus a replication mask) chosen by a placement policy:

* ``round_robin`` reproduces the original pure-modulo ``expert % ep``
  bit-identically (and is what the legacy :func:`shard_of_expert`
  helper still computes for placement-agnostic callers);
* ``hotness`` re-packs hotness-ranked experts onto shards for balance,
  with migrations applied through :meth:`ShardedSliceCache.
  apply_placement`;
* ``hotness+replicate:k`` additionally keeps the k hottest experts
  resident on every shard so dispatch resolves locally.

A routing trace recorded on a single device still replays under *any*
``ep_shards`` and *any* placement (the trace stores expert ids, never
device ids), which is what makes both EP and placement sweepable axes
in :mod:`repro.sim.autotune`; the live engine, the replay simulator and
the telemetry all agree on ownership because placement decisions are
pure functions of charge-path hotness.

:class:`ShardedSliceCache` wraps ``ep`` independent
:class:`~repro.core.cache.SliceCache` instances (each holding
``capacity_bytes / ep`` — the aggregate DRAM budget is *iso* with the
single-device run, split in proportion to each shard's expert
population) behind the exact :class:`SliceCache` surface the engine's
charge path, PCW reshape and the policy-state builder consume.  Routing
is by key; stats/epochs aggregate across shards on read while the
per-shard windows stay addressable for the EP fidelity gate and the
serving telemetry breakdown.

Tokens also have a home shard (the dense, non-expert half of the model
runs data-parallel over the same devices): decode slot ``b`` and
prefill position ``t`` live on shard ``b % ep`` / ``t % ep``.  A
selection whose expert lives elsewhere pays all-to-all dispatch bytes —
charged by the engine on the interconnect channel, computed here by
:func:`all_to_all_bytes`.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.cache import CacheStats, SliceCache
from repro.core.placement import PlacementMap
from repro.core.slices import SliceKey

__all__ = ["shard_of_expert", "expert_placement", "home_shard_of_token",
           "remote_selection_mask", "all_to_all_bytes",
           "ShardedSliceCache"]


def shard_of_expert(expert, n_shards: int):
    """Owning shard of ``expert``: round-robin, pure in the expert id.

    Accepts a scalar (returns int) or an id ndarray (returns the
    elementwise placement) — every ownership decision in the engine,
    replay and telemetry goes through this one function.
    """
    if isinstance(expert, np.ndarray):
        return expert % int(n_shards)
    return int(expert) % int(n_shards)


def expert_placement(n_experts: int, n_shards: int) -> np.ndarray:
    """[E] int array mapping every expert id to its owning shard."""
    return shard_of_expert(np.arange(n_experts, dtype=np.int64), n_shards)


def home_shard_of_token(token_idx, n_shards: int):
    """Home shard of a decode slot / prompt position: the dense
    (non-expert) half of the model runs data-parallel round-robin over
    the same shards.  Scalar or ndarray, like :func:`shard_of_expert`."""
    return shard_of_expert(token_idx, n_shards)


def remote_selection_mask(token_idx: np.ndarray, expert_ids: np.ndarray,
                          n_shards: int, *,
                          owner_row: Optional[np.ndarray] = None,
                          replicated_row: Optional[np.ndarray] = None
                          ) -> np.ndarray:
    """Bool mask over flat parallel (token, expert) selections: True
    where the token's home shard (``token_idx % n_shards``) differs
    from the expert's owner, i.e. the selection pays all-to-all.

    ``owner_row`` (an ``[E]`` shard table, one placement-map layer row)
    replaces the legacy modulo owner when given; selections of experts
    marked in ``replicated_row`` are never remote — the token's home
    shard holds its own replica, so dispatch resolves locally.
    """
    if n_shards <= 1 or token_idx.size == 0:
        return np.zeros(token_idx.shape, bool)
    if owner_row is None:
        owner = shard_of_expert(expert_ids, n_shards)
    else:
        owner = np.asarray(owner_row)[expert_ids]
    remote = home_shard_of_token(token_idx, n_shards) != owner
    if replicated_row is not None:
        remote &= ~np.asarray(replicated_row, bool)[expert_ids]
    return remote


def all_to_all_bytes(token_idx: np.ndarray, expert_ids: np.ndarray,
                     d_model: int, n_shards: int,
                     itemsize: float = 1.0, *,
                     owner_row: Optional[np.ndarray] = None,
                     replicated_row: Optional[np.ndarray] = None) -> float:
    """Dispatch + combine bytes for one layer's routed selections.

    ``token_idx``/``expert_ids``: flat parallel arrays, one entry per
    *active* (token, slot) selection.  Each remote selection (see
    :func:`remote_selection_mask`) moves its ``d_model`` activation to
    the expert's shard and the result back (2x).  Activations travel at
    ``itemsize`` bytes/element (int8 by default, matching the engine's
    INT8 non-expert traffic convention).  ``owner_row`` /
    ``replicated_row`` carry the placement map's layer row through to
    the remoteness test.
    """
    remote = remote_selection_mask(token_idx, expert_ids, n_shards,
                                   owner_row=owner_row,
                                   replicated_row=replicated_row)
    return 2.0 * d_model * itemsize * float(np.count_nonzero(remote))


class _AggregateStats:
    """Read/reset view over the per-shard :class:`CacheStats` windows.

    Mirrors the pieces of ``CacheStats`` the engine, scheduler and
    benchmarks touch on ``cache.stats`` (snapshot / reset / the derived
    counters); mutation happens inside each shard's own ``access``.
    """

    def __init__(self, shards: List[SliceCache]):
        self._shards = shards

    def combined(self) -> CacheStats:
        """One summed :class:`CacheStats` over the shards.

        Callers reading several counters should grab this once per read
        batch instead of touching attributes on the aggregate view —
        each attribute read re-sums (the old path additionally built a
        full snapshot dict *per attribute*, an O(shards) dict merge for
        every counter; this sums the five raw fields directly).
        """
        c = CacheStats()
        for sh in self._shards:
            st = sh.stats
            c.msb_hits += st.msb_hits
            c.msb_misses += st.msb_misses
            c.lsb_hits += st.lsb_hits
            c.lsb_misses += st.lsb_misses
            c.n_dropped += st.n_dropped
        return c

    def snapshot(self) -> dict:
        return self.combined().snapshot()

    def reset(self) -> None:
        for s in self._shards:
            s.stats.reset()

    def __getattr__(self, name):
        # Derived counters (accesses, misses, miss_rate, msb_misses, ...)
        # resolve against one combined CacheStats.
        return getattr(self.combined(), name)


class ShardedSliceCache:
    """``ep`` per-shard :class:`SliceCache` instances behind one surface.

    Every key-addressed operation routes to the owning shard — decided
    by the :class:`~repro.core.placement.PlacementMap` when one is set,
    or the legacy round-robin modulo otherwise (direct constructions in
    tests and the modulo path are bit-identical to ``round_robin``
    placement by design).  Aggregate reads (``used``, ``residency``,
    ``stats``, ``epochs``) combine shards.  Capacity is split evenly:
    each shard holds ``capacity_bytes / n_shards``, so LRU/eviction
    pressure is strictly shard-local — exactly the deployment question
    EP poses (a hot shard cannot borrow a cold shard's DRAM).  Replicas
    of experts marked in the placement map live in *other* shards'
    segments too, inserted there by the engine's replica dispatch and
    charged against those shards' budgets; key-routed operations here
    always address the owner's copy.
    """

    def __init__(self, capacity_bytes: float, n_shards: int, *,
                 slice_aware: bool = True,
                 placement: Optional[PlacementMap] = None):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if placement is not None and placement.n_shards != int(n_shards):
            raise ValueError(
                f"placement map is for {placement.n_shards} shards, "
                f"cache has {n_shards}")
        self.n_shards = int(n_shards)
        self.slice_aware = slice_aware
        self.placement = placement
        self.shards: List[SliceCache] = [
            SliceCache(capacity_bytes / self.n_shards,
                       slice_aware=slice_aware)
            for _ in range(self.n_shards)]

    # ------------------------------------------------------------ routing
    def shard_index(self, key: SliceKey) -> int:
        if self.placement is not None:
            return self.placement.owner_of(key.layer, key.expert)
        return shard_of_expert(key.expert, self.n_shards)

    def shard(self, key: SliceKey) -> SliceCache:
        return self.shards[self.shard_index(key)]

    # ----------------------------------------------------- aggregate state
    @property
    def capacity(self) -> float:
        return sum(s.capacity for s in self.shards)

    @property
    def used(self) -> float:
        return sum(s.used for s in self.shards)

    @property
    def stats(self) -> _AggregateStats:
        return _AggregateStats(self.shards)

    def __contains__(self, key: SliceKey) -> bool:
        if (self.placement is not None
                and self.placement.is_replicated(key.layer, key.expert)):
            return any(key in s for s in self.shards)
        return key in self.shard(key)

    def __len__(self) -> int:
        return sum(len(s) for s in self.shards)

    def contains(self, key: SliceKey) -> bool:
        return key in self

    def can_fit(self, key: SliceKey, nbytes: float) -> bool:
        s = self.shard(key)
        return s.used + nbytes <= s.capacity

    def set_active_tenant(self, tenant) -> None:
        """No-op (see :meth:`SliceCache.set_active_tenant`)."""

    # ------------------------------------------------------------- mutate
    def access(self, key: SliceKey, nbytes: float,
               *, fill_on_miss: bool = True) -> bool:
        return self.shard(key).access(key, nbytes,
                                      fill_on_miss=fill_on_miss)

    def insert(self, key: SliceKey, nbytes: float) -> List[SliceKey]:
        return self.shard(key).insert(key, nbytes)

    def evict(self, key: SliceKey) -> bool:
        return self.shard(key).evict(key)

    def evict_where(self, pred) -> List[SliceKey]:
        out: List[SliceKey] = []
        for s in self.shards:
            out.extend(s.evict_where(pred))
        return out

    def reorder_by(self, ranking) -> None:
        for s in self.shards:
            s.reorder_by(ranking)

    def clear(self) -> None:
        for s in self.shards:
            s.clear()

    # ---------------------------------------------------------- migration
    def apply_placement(self, new_map: PlacementMap
                        ) -> List[Tuple[SliceKey, float, int, int]]:
        """Adopt ``new_map``, physically moving displaced resident slices.

        A resident slice stays put if its shard is still the owner under
        the new map, or if the slice is replicated (replicas are valid
        on any shard).  Everything else is evicted from its old shard
        and inserted into the new owner (which may LRU-evict locally to
        make room — honest capacity pressure on the receiving side).

        Returns the executed moves ``[(key, nbytes, from, to)]`` in a
        deterministic (layer, expert, kind, source-shard) order; the
        caller (the engine) charges ``sum(nbytes)`` on the interconnect
        channel.  A slice whose new owner already holds a copy is simply
        freed — no bytes cross the interconnect for it.
        """
        plan: List[Tuple[int, int, str, int, SliceKey]] = []
        for sid, sh in enumerate(self.shards):
            for key in sh.resident_keys():
                keep = (sid == new_map.owner_of(key.layer, key.expert)
                        or new_map.is_replicated(key.layer, key.expert))
                if not keep:
                    plan.append((key.layer, key.expert, key.kind, sid, key))
        plan.sort(key=lambda t: t[:4])
        moves: List[Tuple[SliceKey, float, int, int]] = []
        for lidx, e, _kind, sid, key in plan:
            src = self.shards[sid]
            if key not in src:      # displaced by an earlier move's insert
                continue
            nb = src.nbytes_of(key)
            ready = src.ready_time(key, 0.0)
            src.evict(key)
            dst_sid = new_map.owner_of(lidx, e)
            dst = self.shards[dst_sid]
            if key in dst or nb > dst.capacity:
                continue            # freed (copy exists) or unfittable
            dst.insert(key, nb)
            if ready > 0.0:
                dst.mark_inflight(key, ready)
            moves.append((key, nb, sid, dst_sid))
        self.placement = new_map
        return moves

    # --------------------------------------------------- in-flight fills
    def mark_inflight(self, key: SliceKey, ready_t: float) -> None:
        self.shard(key).mark_inflight(key, ready_t)

    def ready_time(self, key: SliceKey, default: float = 0.0) -> float:
        return self.shard(key).ready_time(key, default)

    def settle(self, now: float) -> None:
        for s in self.shards:
            s.settle(now)

    # ------------------------------------------------------------- reads
    def resident_keys(self) -> List[SliceKey]:
        out: List[SliceKey] = []
        for s in self.shards:
            out.extend(s.resident_keys())
        return out

    def residency(self, n_layers: int, n_experts: int):
        msb = np.zeros((n_layers, n_experts), bool)
        lsb = np.zeros((n_layers, n_experts), bool)
        for s in self.shards:
            m, l = s.residency(n_layers, n_experts)
            msb |= m
            lsb |= l
        return msb, lsb

    # ------------------------------------------------------------- epochs
    # begin/end fan out so every shard's counter window rolls over at the
    # same request boundary; per-label aggregation sums the windows.
    def begin_epoch(self, label: str) -> None:
        for s in self.shards:
            s.begin_epoch(label)

    def end_epoch(self) -> None:
        for s in self.shards:
            s.end_epoch()

    @property
    def epochs(self) -> List[Tuple[str, dict]]:
        """Aggregated ``[(label, summed stats dict)]`` across shards."""
        if not self.shards[0].epochs:
            return []
        out: List[Tuple[str, dict]] = []
        for i, (label, snap) in enumerate(self.shards[0].epochs):
            agg = dict(snap)
            for s in self.shards[1:]:
                other_label, other = s.epochs[i]
                if other_label != label:
                    # Not an assert: those vanish under ``python -O``,
                    # and silently mis-summing epochs would corrupt the
                    # warm-up curve and the EP fidelity gate.
                    raise RuntimeError(
                        f"shard epoch skew: {other_label!r} != {label!r}")
                for k in agg:
                    agg[k] += other[k]
            out.append((label, agg))
        return out

    def epoch_miss_rates(self) -> List[Tuple[str, float]]:
        return [(label, CacheStats(**snap).miss_rate)
                for label, snap in self.epochs]

    def epoch_counts(self) -> List[Tuple[str, int, int]]:
        return [(label, CacheStats(**snap).accesses,
                 CacheStats(**snap).misses)
                for label, snap in self.epochs]

    def per_shard_epoch_counts(self) -> List[List[Tuple[str, int, int]]]:
        """Per-shard ``epoch_counts`` — the EP fidelity gate's unit."""
        return [s.epoch_counts() for s in self.shards]

    def per_shard_counts(self) -> List[Tuple[int, int]]:
        """Lifetime (accesses, misses) per shard: archived epochs plus
        the open window."""
        out = []
        for s in self.shards:
            acc = s.stats.accesses
            miss = s.stats.misses
            for _, snap in s.epochs:
                st = CacheStats(**snap)
                acc += st.accesses
                miss += st.misses
            out.append((acc, miss))
        return out

    def usage(self) -> dict:
        """Shard-summed occupancy + lifetime counts, same shape as
        :meth:`SliceCache.usage` (the metrics-registry view)."""
        rows = [s.usage() for s in self.shards]
        cap = sum(r["capacity_bytes"] for r in rows)
        used = sum(r["used_bytes"] for r in rows)
        return {
            "capacity_bytes": cap,
            "used_bytes": used,
            "n_slices": sum(r["n_slices"] for r in rows),
            "occupancy": used / cap if cap else 0.0,
            "accesses": sum(r["accesses"] for r in rows),
            "misses": sum(r["misses"] for r in rows),
        }

    def clone(self) -> "ShardedSliceCache":
        import copy

        return copy.deepcopy(self)
