"""Mixture-of-Experts layer with capacity-based dispatch.

Two expert-weight representations share the same dispatch/combine path:

* ``float``     — plain bf16/f32 expert weights, used for training and the
                  train/prefill dry-runs.
* ``quantized`` — AMAT (G32 asymmetric) codes + scales + zero-points, with a
                  per-expert ``use_lsb`` mask selecting MSB+LSB (high-bit) or
                  MSB-only (low-bit) dequantization.  This is the jittable
                  compute path behind DBSC: the cache simulator flips
                  ``use_lsb`` bits; the math stays pure.

Dispatch is the classic capacity-based scheme (Switch/GShard): per-k-slot
one-hot position ranking, scatter into an ``[E, C, d]`` buffer, batched
expert matmuls, gather+combine.  Experts shard over the ``model`` mesh axis;
the scatter/gather lower to all-to-all under GSPMD.

The router also exposes the *raw* probabilities so the SliceMoE engine can
apply cache-aware policies (Cache-Prior boost, Cumsum, DBSC criticality)
outside or inside the jitted step.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.amat import MatConfig
from repro.quant.groupquant import QuantizedTensor


@dataclasses.dataclass(frozen=True)
class RoutingPolicy:
    """Static cache-aware routing policy (SliceMoE engine; paper §2.1/§4.1).

    kind:        'topk' | 'cache_prior' | 'cumsum'
    slice_mode:  'dbsc'       — per-token dynamic precision (DBSC)
                 'highbit'    — every selected expert computes MSB+LSB
                 'lowbit'     — MSB-only for everyone
                 'amat_static'— MSB-only during decode (high-bit prefill)
    fetch_lsb_on_miss: if False, an LSB miss degrades the expert to
                 MSB-only compute instead of fetching (needs cached_lsb).
    quant_execution: run the expert FFN *directly on packed AMAT codes*
                 via the batched-expert Pallas kernel (per-expert
                 ``use_lsb`` becomes a per-expert dequant shift inside
                 the kernel) instead of materializing dense f32/bf16
                 expert weights each step.  Numerically equivalent to
                 the dense-dequant path; see docs/kernels.md.
    """

    kind: str = "topk"
    slice_mode: str = "dbsc"
    theta: float = 0.5
    cumsum_tau: float = 0.9
    cumsum_kmax: int = 8
    fetch_lsb_on_miss: bool = True
    quant_execution: bool = False


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert FFN width
    n_shared_experts: int = 0
    d_ff_shared: int = 0           # total shared-expert width
    capacity_factor: float = 1.25
    mlp_type: str = "swiglu"
    router_noise: float = 0.0      # jitter for load-balance during training
    aux_loss_weight: float = 0.01


# --------------------------------------------------------------------------
# Routing
# --------------------------------------------------------------------------
def router_probs(x: jax.Array, w_router: jax.Array) -> jax.Array:
    """[T, d] @ [d, E] -> softmax probs [T, E] (f32)."""
    logits = x.astype(jnp.float32) @ w_router.astype(jnp.float32)
    return jax.nn.softmax(logits, axis=-1)


def topk_select(probs: jax.Array, k: int, *, renormalize: bool = True):
    """Top-k routing: returns (gates [T,k], ids [T,k])."""
    gates, ids = jax.lax.top_k(probs, k)
    if renormalize:
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, ids


def load_balance_loss(probs: jax.Array, ids: jax.Array, n_experts: int):
    """Switch-style auxiliary loss: E * <f_e> . <p_e>."""
    sel = jax.nn.one_hot(ids, n_experts, dtype=jnp.float32)   # [T, k, E]
    frac_tokens = jnp.mean(jnp.sum(sel, axis=1), axis=0)      # [E]
    mean_probs = jnp.mean(probs, axis=0)                      # [E]
    return n_experts * jnp.sum(frac_tokens * mean_probs)


# --------------------------------------------------------------------------
# Dispatch / combine
# --------------------------------------------------------------------------
def capacity(n_tokens: int, k: int, n_experts: int, factor: float) -> int:
    c = int(n_tokens * k * factor / n_experts) + 1
    # keep the MXU happy and bound the tiny-T case
    return max(8, min(c, n_tokens))


def dispatch_indices(ids: jax.Array, gates: jax.Array, n_experts: int,
                     cap: int):
    """Compute per-(token, slot) expert positions under a capacity limit.

    Returns (positions [T,k] int32, keep [T,k] bool).  Slot priority follows
    k order (top-1 assignments never dropped before top-2's), matching
    GShard semantics.
    """
    T, k = ids.shape
    positions = []
    keeps = []
    counts = jnp.zeros((n_experts,), jnp.int32)
    for kk in range(k):
        onehot = jax.nn.one_hot(ids[:, kk], n_experts, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(onehot, axis=0) - 1 + counts[None, :]
        pos = jnp.sum(pos_in_e * onehot, axis=-1)
        keep = pos < cap
        positions.append(pos)
        keeps.append(keep)
        counts = counts + jnp.sum(onehot * keep[:, None].astype(jnp.int32),
                                  axis=0)
    return jnp.stack(positions, 1), jnp.stack(keeps, 1)


def dispatch(x: jax.Array, ids: jax.Array, positions: jax.Array,
             keep: jax.Array, n_experts: int, cap: int) -> jax.Array:
    """Scatter tokens into the [E, C, d] expert buffer."""
    T, k = ids.shape
    d = x.shape[-1]
    flat_ids = ids.reshape(-1)
    flat_pos = jnp.where(keep.reshape(-1), positions.reshape(-1), cap)
    xk = jnp.broadcast_to(x[:, None, :], (T, k, d)).reshape(-1, d)
    buf = jnp.zeros((n_experts, cap + 1, d), x.dtype)
    buf = buf.at[flat_ids, flat_pos].add(xk, mode="drop",
                                         unique_indices=False)
    return buf[:, :cap]


def combine(y_buf: jax.Array, ids: jax.Array, positions: jax.Array,
            keep: jax.Array, gates: jax.Array) -> jax.Array:
    """Gather expert outputs back to tokens and mix with gates."""
    T, k = ids.shape
    flat_ids = ids.reshape(-1)
    flat_pos = jnp.clip(positions.reshape(-1), 0, y_buf.shape[1] - 1)
    y = y_buf[flat_ids, flat_pos].reshape(T, k, -1)
    w = (gates * keep.astype(gates.dtype))[..., None]
    return jnp.sum(y * w.astype(y.dtype), axis=1)


# --------------------------------------------------------------------------
# Expert compute
# --------------------------------------------------------------------------
def _ffn_activation(h: jax.Array, mlp_type: str, dtype) -> jax.Array:
    """The FFN nonlinearity in f32, result cast to ``dtype``."""
    if mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if mlp_type == "swiglu" else \
            (lambda u: jax.nn.gelu(u, approximate=True))
        g, u = jnp.split(h, 2, axis=-1)
        return act(g.astype(jnp.float32)).astype(dtype) * u
    if mlp_type == "relu2":
        return jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(dtype)
    if mlp_type == "gelu":
        return jax.nn.gelu(h.astype(jnp.float32),
                           approximate=True).astype(dtype)
    raise ValueError(mlp_type)


def _expert_ffn(xe: jax.Array, wi: jax.Array, wo: jax.Array,
                mlp_type: str) -> jax.Array:
    """Batched per-expert FFN. xe: [E, C, d]; wi: [E, d, F(|2F)]; wo: [E, F, d]."""
    h = jnp.einsum("ecd,edf->ecf", xe, wi.astype(xe.dtype))
    h = _ffn_activation(h, mlp_type, xe.dtype)
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(xe.dtype))


def _expert_ffn_quant(xe: jax.Array, wi_q: QuantizedTensor,
                      wo_q: QuantizedTensor,
                      wo_codes_t: Optional[jax.Array],
                      use_lsb: Optional[jax.Array], shift: int,
                      mlp_type: str) -> jax.Array:
    """Expert FFN computed *directly on packed AMAT codes* (no dense
    weight tensor is ever materialized — the paper's in-front-of-compute
    dequantization, here fused into the Pallas matmul's K loop).

    ``use_lsb`` [E] selects the per-expert dequant shift inside the
    kernel; ``wo_codes_t`` is the pre-transposed (output-major,
    ``[E, d, F]``) wo code buffer — when absent the canonical ``[E, F,
    d]`` codes are used with the K-major kernel.
    """
    from repro.kernels.amat_matmul.ops import (amat_expert_matmul_qt,
                                               amat_expert_matmul_t)

    ul = use_lsb if use_lsb is not None \
        else jnp.ones((xe.shape[0],), bool)
    h = amat_expert_matmul_qt(xe, wi_q, ul, shift=shift).astype(xe.dtype)
    h = _ffn_activation(h, mlp_type, xe.dtype)
    if wo_codes_t is not None:
        y = amat_expert_matmul_t(h, wo_codes_t, wo_q.scales,
                                 wo_q.zero_points, ul, shift=shift,
                                 group_size=wo_q.group_size)
    else:
        y = amat_expert_matmul_qt(h, wo_q, ul, shift=shift)
    return y.astype(xe.dtype)


def _dequant_experts(qt: QuantizedTensor, use_lsb: Optional[jax.Array],
                     shift: int, dtype) -> jax.Array:
    """Dequantize stacked expert weights [E, K, N] with per-expert precision."""
    from repro.core.amat import dequant_mixed
    from repro.quant.groupquant import dequantize

    if use_lsb is None or shift == 0:
        w = dequantize(qt)
    else:
        w = dequant_mixed(qt, use_lsb, shift)
    return w.astype(dtype)


def moe_apply(
    params: dict,
    x: jax.Array,                       # [T, d] flat tokens
    cfg: MoECfg,
    *,
    use_lsb: Optional[jax.Array] = None,   # [E] bool (quantized path only)
    mat: Optional[MatConfig] = None,
    gate_override: Optional[tuple] = None,  # (gates [T,k], ids [T,k])
    policy: Optional[RoutingPolicy] = None,
    policy_state: Optional[dict] = None,   # {'alpha': (), 'cached_msb': [E],
                                           #  'cached_lsb': [E]}
    token_mask: Optional[jax.Array] = None,  # [T] bool; False = padding row
    deterministic: bool = True,
    rng: Optional[jax.Array] = None,
    quant_execution: Optional[bool] = None,  # None -> policy decides
    force_high_bit: bool = False,  # prefill: policy routes, compute hi-bit
):
    """Full MoE layer.  Returns (y [T, d], aux: dict).

    params:
      w_router: [d, E]
      experts:  {'wi': [E, d, F(|2F)] float}  OR
                {'wi_q': QuantizedTensor, 'wo_q': QuantizedTensor}
      shared:   optional dense-MLP params applied to every token

    ``token_mask`` excludes padding rows (retired/empty batch slots in
    continuous-batching decode) from routing entirely: their ids are
    redirected out of range so they occupy no expert capacity, never
    appear in the slice-demand trace, and cannot evict a live token's
    expert assignment under the capacity limit.
    """
    T, d = x.shape
    probs = router_probs(x, params["w_router"])
    active = None
    critical = None

    def mask_routing(gates, ids, active):
        if token_mask is None:
            return gates, ids, active
        tm = token_mask.astype(bool)
        ids = jnp.where(tm[:, None], ids, cfg.n_experts)   # out-of-range
        gates = gates * tm[:, None].astype(gates.dtype)
        active = jnp.broadcast_to(tm[:, None], ids.shape) \
            if active is None else (active & tm[:, None])
        return gates, ids, active

    if gate_override is not None:
        gates, ids = gate_override
        gates, ids, active = mask_routing(gates, ids, active)
        k_eff = ids.shape[-1]
    elif policy is not None:
        from repro.core import routing as R

        if policy.kind == "cache_prior":
            gates, ids = R.cache_prior_routing(
                probs, policy_state["cached_msb"],
                policy_state["alpha"], cfg.top_k)
        elif policy.kind == "buddy":
            gates, ids = R.buddy_routing(
                probs, policy_state["cached_msb"],
                policy_state["buddies"], cfg.top_k)
        elif policy.kind == "cumsum":
            kmax = min(policy.cumsum_kmax, cfg.n_experts)
            gates, ids, active = R.cumsum_routing(
                probs, policy.cumsum_tau, kmax)
        else:
            gates, ids = R.topk_routing(probs, cfg.top_k)
        gates, ids, active = mask_routing(gates, ids, active)
        gates = gates.astype(x.dtype)
        k_eff = ids.shape[-1]

        critical = R.criticality(gates.astype(jnp.float32), policy.theta)
        if active is not None:
            critical = critical & active
        msb_needed, lsb_needed = R.expert_demand(
            ids, critical if active is None else critical & active,
            cfg.n_experts)
        if active is not None:
            sel = jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.bool_)
            msb_needed = jnp.any(sel & active[..., None], axis=(0, 1))
        if policy.slice_mode == "highbit":
            use_lsb = jnp.ones((cfg.n_experts,), bool)
            lsb_needed = msb_needed
        elif policy.slice_mode in ("lowbit", "amat_static"):
            use_lsb = jnp.zeros((cfg.n_experts,), bool)
            lsb_needed = jnp.zeros((cfg.n_experts,), bool)
        else:  # dbsc
            use_lsb = lsb_needed
            # Prefill threads a state-free policy with no policy_state;
            # the residency intersection only applies during decode.
            if not policy.fetch_lsb_on_miss and policy_state is not None:
                use_lsb = lsb_needed & policy_state["cached_lsb"]
        if force_high_bit:
            # Prefill discipline: the configured policy picks *which*
            # experts run (and emits the active/critical trace), but
            # every routed expert computes MSB+LSB.  use_lsb=None takes
            # the exact full-dequant path the policy-free prefill took.
            use_lsb = None
    else:
        p = probs
        if not deterministic and cfg.router_noise > 0 and rng is not None:
            p = p * jax.random.uniform(
                rng, probs.shape, minval=1.0 - cfg.router_noise,
                maxval=1.0 + cfg.router_noise)
        gates, ids = topk_select(p, cfg.top_k)
        gates, ids, active = mask_routing(gates, ids, active)
        gates = gates.astype(x.dtype)
        k_eff = cfg.top_k

    from repro.launch.sharding import shard_hint

    cap = capacity(T, k_eff, cfg.n_experts, cfg.capacity_factor)
    positions, keep = dispatch_indices(ids, gates, cfg.n_experts, cap)
    xe = dispatch(x, ids, positions, keep, cfg.n_experts, cap)
    xe = shard_hint(xe, "model", None, None)   # expert parallelism

    experts = params["experts"]
    quant_exec = quant_execution if quant_execution is not None else \
        (policy.quant_execution if policy is not None else False)
    wi_qt = wo_qt = None
    if "wi_q" in experts:
        assert mat is not None
        wi_qt, wo_qt = experts["wi_q"], experts["wo_q"]
    elif "wi_codes" in experts:
        # flat-dict quantized form (quantized_serve dry-run / serve path)
        assert mat is not None
        wi_qt = QuantizedTensor(experts["wi_codes"], experts["wi_scales"],
                                experts["wi_zps"], mat.high_bits,
                                mat.group_size, True)
        wo_qt = QuantizedTensor(experts["wo_codes"], experts["wo_scales"],
                                experts["wo_zps"], mat.high_bits,
                                mat.group_size, True)

    if wi_qt is not None and quant_exec:
        # Quantized execution: the packed codes ARE the compute format.
        # No dense expert tensor is materialized (and hence no
        # dequant-tile shard_hint workaround is needed — the kernel
        # reads the codes at their native sharding).
        ye = _expert_ffn_quant(xe, wi_qt, wo_qt,
                               experts.get("wo_codes_t"), use_lsb,
                               mat.shift, cfg.mlp_type)
    elif wi_qt is not None:
        # Dense-dequant reference path: materialize per-expert f32/bf16
        # weights each step (gather-then-dequantize).
        wi = _dequant_experts(wi_qt, use_lsb, mat.shift, x.dtype)
        wo = _dequant_experts(wo_qt, use_lsb, mat.shift, x.dtype)
        if "wi_codes" in experts:
            # Pin the dequantized tiles to the codes' sharding: without
            # this GSPMD replicates them (a 66 GB/step all-gather on
            # maverick — EXPERIMENTS.md §Perf hillclimb 1).
            wi = shard_hint(wi, "model", None, "data")
            wo = shard_hint(wo, "model", "data", None)
        ye = _expert_ffn(xe, wi, wo, cfg.mlp_type)
    else:
        ye = _expert_ffn(xe, experts["wi"], experts["wo"], cfg.mlp_type)
    ye = shard_hint(ye, "model", None, None)
    y = combine(ye, ids, positions, keep, gates)
    y = shard_hint(y, ("pod", "data"), None)

    if cfg.n_shared_experts > 0:
        from repro.models.layers import mlp_apply
        y = y + mlp_apply(params["shared"], x, cfg.mlp_type)

    aux = {
        "ids": ids,
        "gates": gates,
        "aux_loss": load_balance_loss(probs, ids, cfg.n_experts),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    if policy is not None:
        aux["critical"] = critical
        aux["msb_needed"] = msb_needed
        aux["lsb_needed"] = lsb_needed
        # force_high_bit clears use_lsb to None for compute; the trace
        # reports what actually ran (all experts high-bit).
        aux["use_lsb"] = use_lsb if use_lsb is not None \
            else jnp.ones((cfg.n_experts,), bool)
        aux["active"] = active if active is not None \
            else jnp.ones(ids.shape, bool)
    return y, aux


def quantize_params_for_serve(params: dict, cfg, mat: MatConfig) -> dict:
    """Replace float expert weights by flat-dict AMAT tensors (serve path).

    The flat-dict form ({wi_codes, wi_scales, wi_zps, ...}) keeps the
    param tree plain-dict so spec builders and sharding-rule path
    matching treat the quantized leaves like any other parameter.
    """
    from repro.core.amat import amat_quantize

    new_blocks = {}
    for pos, blk in params["blocks"].items():
        if "moe" in blk:
            blk = dict(blk)
            moe = dict(blk["moe"])
            e = moe["experts"]
            out = {}
            for name in ("wi", "wo"):
                qt = amat_quantize(e[name].astype(jnp.float32), mat)
                out[f"{name}_codes"] = qt.codes
                out[f"{name}_scales"] = qt.scales
                out[f"{name}_zps"] = qt.zero_points
            moe["experts"] = out
            blk["moe"] = moe
        new_blocks[pos] = blk
    new_params = dict(params)
    new_params["blocks"] = new_blocks
    return new_params


def quantized_expert_shapes(d_model: int, cfg: MoECfg,
                            group_size: int = 32) -> dict:
    wi_cols = 2 * cfg.d_ff if cfg.mlp_type in ("swiglu", "geglu") else cfg.d_ff
    E = cfg.n_experts
    return {
        "wi_codes": (E, d_model, wi_cols),
        "wi_scales": (E, d_model // group_size, wi_cols),
        "wi_zps": (E, d_model // group_size, wi_cols),
        "wo_codes": (E, cfg.d_ff, d_model),
        "wo_scales": (E, cfg.d_ff // group_size, d_model),
        "wo_zps": (E, cfg.d_ff // group_size, d_model),
    }


def moe_param_shapes(d_model: int, cfg: MoECfg) -> dict:
    wi_cols = 2 * cfg.d_ff if cfg.mlp_type in ("swiglu", "geglu") else cfg.d_ff
    shapes = {
        "w_router": (d_model, cfg.n_experts),
        "experts": {
            "wi": (cfg.n_experts, d_model, wi_cols),
            "wo": (cfg.n_experts, cfg.d_ff, d_model),
        },
    }
    if cfg.n_shared_experts > 0:
        from repro.models.layers import mlp_param_shapes
        shapes["shared"] = mlp_param_shapes(
            d_model, cfg.d_ff_shared or cfg.d_ff, cfg.mlp_type)
    return shapes
