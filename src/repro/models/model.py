"""Unified model stack covering all assigned architecture families.

One implementation, six families: dense decoders, MoE decoders, SSM stacks
(Mamba2), hybrid interleaves (Jamba), VLM backbones (embedding-prefix stub)
and encoder-decoder (Whisper, conv-frontend stub).

Layers are organized as ``n_periods`` repetitions of a *block pattern*
(``cfg.block_pattern``); parameters are stacked over periods so the whole
stack is a single ``lax.scan`` — this keeps HLO size (and therefore
dry-run compile time) independent of depth.  Uniform models have a
pattern of length 1; Jamba has length 8.

Public entry points:
  param_shapes / init_params
  forward            — full-sequence forward (train / prefill), optional
                       routing-trace collection for the SliceMoE engine
  lm_loss            — chunked cross-entropy (never materializes [T, V]
                       logits for the full sequence at once)
  init_cache         — decode-state pytree (KV caches / SSM states)
  prefill            — forward + cache population
  decode_step        — single-token step against the cache
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BlockSpec, ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

LOSS_CHUNKS = 16


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ==========================================================================
# Parameter shapes / init
# ==========================================================================
def _attn_shapes(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    sh = {
        "wq": (d, h * hd),
        "wk": (d, kv * hd),
        "wv": (d, kv * hd),
        "wo": (h * hd, d),
        "norm": (d,),
    }
    if cfg.qkv_bias:
        sh["bq"] = (h * hd,)
        sh["bk"] = (kv * hd,)
        sh["bv"] = (kv * hd,)
    if cross:
        sh = {("c_" + k if k != "norm" else "c_norm"): v
              for k, v in sh.items()}
    return sh


def _block_shapes(cfg: ModelConfig, spec: BlockSpec, decoder: bool) -> dict:
    sh: dict = {}
    if spec.mixer == "attn":
        sh.update(_attn_shapes(cfg))
        if decoder and cfg.is_encdec:
            sh.update(_attn_shapes(cfg, cross=True))
    else:
        assert cfg.ssm is not None
        sh["ssm"] = S.ssm_param_shapes(cfg.d_model, cfg.ssm)
        sh["ssm_norm"] = (cfg.d_model,)
    if spec.ffn == "dense":
        sh["mlp"] = L.mlp_param_shapes(cfg.d_model, cfg.d_ff, cfg.mlp_type)
        sh["mlp_norm"] = (cfg.d_model,)
    elif spec.ffn == "moe":
        moe_sh = M.moe_param_shapes(cfg.d_model, cfg.moe)
        if cfg.quantized_serve:
            moe_sh["experts"] = M.quantized_expert_shapes(
                cfg.d_model, cfg.moe)
        sh["moe"] = moe_sh
        sh["moe_norm"] = (cfg.d_model,)
    return sh


def param_shapes(cfg: ModelConfig) -> dict:
    """Nested dict of shape-tuples mirroring the param pytree."""
    def stack(shapes: dict, n: int) -> dict:
        return jax.tree_util.tree_map(
            lambda s: (n,) + s, shapes,
            is_leaf=lambda x: isinstance(x, tuple) and
            all(isinstance(i, int) for i in x))

    blocks = {
        f"pos{i}": stack(_block_shapes(cfg, spec, decoder=True),
                         cfg.n_periods)
        for i, spec in enumerate(cfg.block_pattern)
    }
    v_embed = cfg.padded_vocab if cfg.tie_embeddings else cfg.vocab_size
    sh = {
        "embed": (v_embed, cfg.d_model),
        "blocks": blocks,
        "final_norm": (cfg.d_model,),
    }
    if not cfg.tie_embeddings:
        sh["unembed"] = (cfg.d_model, cfg.padded_vocab)
    if cfg.is_encdec:
        enc_block = _block_shapes(
            cfg, BlockSpec("attn", "dense"), decoder=False)
        sh["encoder"] = {
            "blocks": stack(enc_block, cfg.encoder_layers),
            "final_norm": (cfg.d_model,),
        }
    return sh


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    if cfg.quantized_serve:
        # init the float model, then convert experts to AMAT form
        from repro.core.amat import MatConfig
        from repro.models.moe import quantize_params_for_serve

        base = dataclasses.replace(cfg, quantized_serve=False)
        return quantize_params_for_serve(
            init_params(base, key), cfg, MatConfig(8, 4))
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(
        shapes, is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(i, int) for i in x))
    keys = jax.random.split(key, len(leaves))
    dtype = _dt(cfg)

    def init_one(shape, k):
        if len(shape) == 1 or shape[-1] == 1:
            return jnp.zeros(shape, dtype)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        return (jax.random.normal(k, shape, jnp.float32)
                * (fan_in ** -0.5)).astype(dtype)

    inited = [init_one(s, k) for s, k in zip(leaves, keys)]
    params = jax.tree_util.tree_unflatten(treedef, inited)

    # Non-matrix special inits.
    def fix_blocks(bp: dict):
        for name, blk in bp.items():
            if "ssm" in blk:
                n = blk["ssm"]["A_log"].shape
                blk["ssm"]["A_log"] = jnp.log(
                    jnp.linspace(1.0, 16.0, n[-1], dtype=jnp.float32)
                    * jnp.ones(n, jnp.float32))
                blk["ssm"]["D"] = jnp.ones(n, jnp.float32)
                blk["ssm"]["dt_bias"] = jnp.full(n, -2.0, jnp.float32)
                blk["ssm"]["conv_w"] = (jax.random.normal(
                    jax.random.fold_in(key, hash(name) % 2**31),
                    blk["ssm"]["conv_w"].shape, jnp.float32) * 0.2
                ).astype(dtype)
    fix_blocks(params["blocks"])
    return params


# ==========================================================================
# Blocks
# ==========================================================================
def _attn_qkv(p: dict, x: jax.Array, cfg: ModelConfig, prefix: str = ""):
    b, s, _ = x.shape
    q = x @ p[prefix + "wq"]
    k = x @ p[prefix + "wk"]
    v = x @ p[prefix + "wv"]
    if cfg.qkv_bias:
        q = q + p[prefix + "bq"].astype(q.dtype)
        k = k + p[prefix + "bk"].astype(k.dtype)
        v = v + p[prefix + "bv"].astype(v.dtype)
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def _self_attn_block(p: dict, x: jax.Array, cfg: ModelConfig, *,
                     causal: bool, positions: jax.Array,
                     window: Optional[int]):
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = _attn_qkv(p, h, cfg)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    o = L.attention(q, k, v, causal=causal, sliding_window=window,
                    logit_softcap=cfg.logit_softcap)
    o = o.reshape(x.shape[0], x.shape[1], -1) @ p["wo"]
    return x + o, (k, v)


def _cross_attn_block(p: dict, x: jax.Array, enc_k: jax.Array,
                      enc_v: jax.Array, cfg: ModelConfig):
    h = L.rms_norm(x, p["c_norm"], cfg.norm_eps)
    b, s, _ = h.shape
    q = (h @ p["c_wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    o = L.attention(q, enc_k, enc_v, causal=False,
                    logit_softcap=cfg.logit_softcap)
    o = o.reshape(b, s, -1) @ p["c_wo"]
    return x + o


def _ffn_block(p: dict, x: jax.Array, cfg: ModelConfig, spec: BlockSpec, *,
               collect, use_lsb=None, gate_override=None,
               policy=None, policy_state=None, mat=None, token_mask=None,
               quant_execution=None, force_high_bit=False):
    aux = None
    if spec.ffn == "dense":
        h = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        x = x + L.mlp_apply(p["mlp"], h, cfg.mlp_type)
    elif spec.ffn == "moe":
        h = L.rms_norm(x, p["moe_norm"], cfg.norm_eps)
        b, s, d = h.shape
        y, aux = M.moe_apply(
            p["moe"], h.reshape(-1, d), cfg.moe,
            use_lsb=use_lsb, gate_override=gate_override,
            policy=policy, policy_state=policy_state, mat=mat,
            token_mask=token_mask, quant_execution=quant_execution,
            force_high_bit=force_high_bit)
        x = x + y.reshape(b, s, d)
        if not collect:
            aux = {"aux_loss": aux["aux_loss"],
                   "dropped_frac": aux["dropped_frac"]}
    return x, aux


def _ssm_block(p: dict, x: jax.Array, cfg: ModelConfig):
    h = L.rms_norm(x, p["ssm_norm"], cfg.norm_eps)
    y = S.ssm_forward(p["ssm"], h, cfg.ssm)
    return x + y


# ==========================================================================
# Encoder (whisper)
# ==========================================================================
def _encode(params: dict, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: [B, enc_seq, d_model] — precomputed frontend embeddings."""
    enc = params["encoder"]
    positions = jnp.arange(frames.shape[1])[None, :]

    def body(x, p):
        x, _ = _self_attn_block(p, x, cfg, causal=False,
                                positions=positions, window=None)
        x, _ = _ffn_block(p, x, cfg, BlockSpec("attn", "dense"),
                          collect=False)
        return x, None

    x, _ = jax.lax.scan(body, frames.astype(_dt(cfg)), enc["blocks"])
    return L.rms_norm(x, enc["final_norm"], cfg.norm_eps)


def _enc_kv(p: dict, enc_out: jax.Array, cfg: ModelConfig):
    b, s, _ = enc_out.shape
    k = (enc_out @ p["c_wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ p["c_wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    return k, v


# ==========================================================================
# Full-sequence forward
# ==========================================================================
def embed_inputs(params: dict, cfg: ModelConfig, tokens: jax.Array,
                 prefix_embeds: Optional[jax.Array]) -> jax.Array:
    from repro.launch.sharding import shard_hint

    if cfg.onehot_embed:
        # One-hot matmul lookup: GSPMD partitions a dot over the vocab-
        # sharded table cleanly (plain all-reduce over vocab shards),
        # where a gather triggers involuntary full rematerialization
        # (replicate-then-reshard).  The one-hot fuses into the dot on
        # TPU (iota-compare, never materialized at [T, V]).
        oh = jax.nn.one_hot(tokens, params["embed"].shape[0], dtype=_dt(cfg))
        x = oh @ params["embed"].astype(_dt(cfg))
    else:
        x = params["embed"][tokens].astype(_dt(cfg))
    if cfg.prefix_len and prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(_dt(cfg)), x], axis=1)
    return shard_hint(x, ("pod", "data"), None, None)


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,                       # [B, S_text]
    *,
    prefix_embeds: Optional[jax.Array] = None,   # [B, prefix_len, d]
    encoder_frames: Optional[jax.Array] = None,  # [B, enc_seq, d]
    collect_trace: bool = False,
    use_window: bool = False,
    mat=None,
    quant_execution: Optional[bool] = None,
):
    """Returns (hidden [B, S, d], aux dict with moe traces / losses)."""
    x = embed_inputs(params, cfg, tokens, prefix_embeds)
    b, s, d = x.shape
    positions = jnp.arange(s)[None, :]
    window = cfg.sliding_window if (use_window or cfg.always_swa) else None

    enc_out = None
    if cfg.is_encdec:
        assert encoder_frames is not None
        enc_out = _encode(params, cfg, encoder_frames)

    pattern = cfg.block_pattern

    def period_body(x, period_params):
        if cfg.seq_parallel:
            # Megatron-style sequence parallelism: the residual stream is
            # seq-sharded over the model axis between blocks, turning the
            # per-block all-reduce into reduce-scatter + all-gather and
            # cutting resident activation memory by the model-axis size.
            from repro.launch.sharding import shard_hint
            x = shard_hint(x, ("pod", "data"), "model", None)
        auxes = []
        for i, spec in enumerate(pattern):
            p = period_params[f"pos{i}"]
            if spec.mixer == "attn":
                x, _ = _self_attn_block(
                    p, x, cfg, causal=True, positions=positions,
                    window=window)
                if cfg.is_encdec:
                    ek, ev = _enc_kv(p, enc_out, cfg)
                    x = _cross_attn_block(p, x, ek, ev, cfg)
            else:
                x = _ssm_block(p, x, cfg)
            x, aux = _ffn_block(p, x, cfg, spec, collect=collect_trace,
                                mat=mat, quant_execution=quant_execution)
            if aux is not None:
                auxes.append(aux)
        if auxes:
            stacked = {k: jnp.stack([a[k] for a in auxes])
                       for k in auxes[0]}
        else:
            stacked = {}
        return x, stacked

    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        body = jax.checkpoint(period_body, prevent_cse=False, policy=policy)
    else:
        body = jax.checkpoint(period_body, prevent_cse=False)
    x, aux_stacked = jax.lax.scan(body, x, params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    aux = {}
    if aux_stacked:
        aux["moe"] = aux_stacked                  # leaves [n_periods, n_moe_pos, ...]
        aux["aux_loss"] = jnp.sum(aux_stacked["aux_loss"])
    else:
        aux["aux_loss"] = jnp.zeros((), jnp.float32)
    return x, aux


def unembed(params: dict, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (h @ w.astype(h.dtype)).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask pad columns so softmax / argmax / logsumexp ignore them
        col = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits


def lm_loss(params: dict, cfg: ModelConfig, tokens: jax.Array,
            labels: jax.Array, *, prefix_embeds=None, encoder_frames=None,
            aux_weight: float = 0.01):
    """Chunked cross-entropy over the flattened token stream."""
    h, aux = forward(params, cfg, tokens, prefix_embeds=prefix_embeds,
                     encoder_frames=encoder_frames)
    b, s, d = h.shape
    if cfg.prefix_len and prefix_embeds is not None:
        h = h[:, cfg.prefix_len:]
        s = h.shape[1]
    hf = h.reshape(-1, d)
    lf = labels.reshape(-1)
    T = hf.shape[0]
    n_chunks = LOSS_CHUNKS if T % LOSS_CHUNKS == 0 else 1
    hc = hf.reshape(n_chunks, T // n_chunks, d)
    lc = lf.reshape(n_chunks, T // n_chunks)

    def chunk_loss(carry, xs):
        hx, lx = xs
        logits = unembed(params, cfg, hx)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[:, None], axis=-1)[:, 0]
        return carry + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (hc, lc))
    loss = total / T
    return loss + aux_weight * aux["aux_loss"], aux


# ==========================================================================
# Decode cache
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class CacheDims:
    batch: int
    max_seq: int


def _quant_kv(x: jax.Array):
    """Per-(token, head) dynamic int8 quantization of K/V rows.

    x: [..., hd] -> (codes int8 [..., hd], scales f32 [...]).
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    return codes, scale


def _dequant_kv(codes: jax.Array, scale: jax.Array, dtype):
    return (codes.astype(jnp.float32) * scale[..., None]).astype(dtype)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None) -> dict:
    """Decode-state pytree, stacked over periods per pattern position."""
    dtype = dtype or _dt(cfg)
    np_ = cfg.n_periods
    int8_kv = cfg.kv_dtype == "int8"
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    for i, spec in enumerate(cfg.block_pattern):
        key = f"pos{i}"
        if spec.mixer == "attn":
            kv_shape = (np_, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
            kv_dt = jnp.int8 if int8_kv else dtype
            entry = {"k": jnp.zeros(kv_shape, kv_dt),
                     "v": jnp.zeros(kv_shape, kv_dt)}
            if int8_kv:
                sc_shape = kv_shape[:-1]
                entry["k_scale"] = jnp.zeros(sc_shape, jnp.float32)
                entry["v_scale"] = jnp.zeros(sc_shape, jnp.float32)
            if cfg.is_encdec:
                cs = (np_, batch, cfg.encoder_seq, cfg.n_kv_heads,
                      cfg.head_dim)
                entry["ck"] = jnp.zeros(cs, dtype)
                entry["cv"] = jnp.zeros(cs, dtype)
            cache[key] = entry
        else:
            ssm = cfg.ssm
            di = ssm.d_inner(cfg.d_model)
            h = ssm.n_heads(cfg.d_model)
            cache[key] = {
                "state": jnp.zeros((np_, batch, h, ssm.head_dim,
                                    ssm.d_state), jnp.float32),
                "conv": jnp.zeros((np_, batch, ssm.d_conv - 1,
                                   ssm.conv_channels(cfg.d_model)), dtype),
            }
    return cache


# ==========================================================================
# Prefill
# ==========================================================================
def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array,
            max_seq: int, *, prefix_embeds=None, encoder_frames=None,
            collect_trace: bool = False, use_window: bool = False,
            mat=None, quant_execution: Optional[bool] = None,
            policy=None):
    """Forward over the prompt, returning (last-token logits, cache, aux).

    ``policy``: optional *state-free* RoutingPolicy (e.g. cumsum) to
    route the prompt with — selection and the aux trace (ids/gates/
    active/critical) follow the policy, while compute stays high-bit for
    every routed expert (the engine's prefill discipline).  Stateful
    kinds needing residency masks cannot run here.
    """
    x = embed_inputs(params, cfg, tokens, prefix_embeds)
    b, s, d = x.shape
    positions = jnp.arange(s)[None, :]
    window = cfg.sliding_window if (use_window or cfg.always_swa) else None
    dtype = _dt(cfg)

    enc_out = None
    if cfg.is_encdec:
        assert encoder_frames is not None
        enc_out = _encode(params, cfg, encoder_frames)

    pattern = cfg.block_pattern

    def period_body(x, period_params):
        cache_entries = {}
        auxes = []
        for i, spec in enumerate(pattern):
            p = period_params[f"pos{i}"]
            key = f"pos{i}"
            if spec.mixer == "attn":
                x, (k, v) = _self_attn_block(
                    p, x, cfg, causal=True, positions=positions,
                    window=window)
                pad = max_seq - s
                kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                if cfg.kv_dtype == "int8":
                    kq, ks = _quant_kv(kp)
                    vq, vs = _quant_kv(vp)
                    entry = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
                else:
                    entry = {"k": kp.astype(dtype), "v": vp.astype(dtype)}
                if cfg.is_encdec:
                    ek, ev = _enc_kv(p, enc_out, cfg)
                    x = _cross_attn_block(p, x, ek, ev, cfg)
                    entry["ck"] = ek.astype(dtype)
                    entry["cv"] = ev.astype(dtype)
                cache_entries[key] = entry
            else:
                h = L.rms_norm(x, p["ssm_norm"], cfg.norm_eps)
                y, (state, conv_tail) = S.ssm_forward(
                    p["ssm"], h, cfg.ssm, return_state=True)
                x = x + y
                cache_entries[key] = {"state": state,
                                      "conv": conv_tail.astype(dtype)}
            x, aux = _ffn_block(p, x, cfg, spec, collect=collect_trace,
                                mat=mat, quant_execution=quant_execution,
                                policy=policy,
                                force_high_bit=policy is not None)
            if aux is not None:
                auxes.append(aux)
        stacked = {}
        if auxes:
            stacked = {k: jnp.stack([a[k] for a in auxes])
                       for k in auxes[0]}
        return x, (cache_entries, stacked)

    x, (cache_stacked, aux_stacked) = jax.lax.scan(
        period_body, x, params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, cfg, x[:, -1])

    cache = dict(cache_stacked)
    cache["pos"] = jnp.asarray(s, jnp.int32)
    aux = {"moe": aux_stacked} if aux_stacked else {}
    return logits, cache, aux


# ==========================================================================
# Decode step
# ==========================================================================
def decode_step(params: dict, cfg: ModelConfig, token: jax.Array,
                cache: dict, *, encoder_frames=None,
                collect_trace: bool = False,
                use_lsb: Optional[dict] = None,
                gate_override: Optional[dict] = None,
                policy=None,
                policy_state: Optional[dict] = None,
                alpha=None,
                mat=None,
                token_mask: Optional[jax.Array] = None,
                use_window: bool = False,
                quant_execution: Optional[bool] = None):
    """One decode step.  token: [B] int32.  Returns (logits, cache, aux).

    ``use_lsb`` / ``gate_override`` / ``policy_state`` are optional
    per-(position, period) overrides injected by the SliceMoE engine:
      use_lsb[f"pos{i}"]        : [n_periods, E] bool
      gate_override[f"pos{i}"]  : ([n_periods, B, k] gates, ids)
      policy_state[f"pos{i}"]   : {'cached_msb'/'cached_lsb': [n_periods, E]}
    ``policy`` is a static RoutingPolicy; ``alpha`` a dynamic scalar
    (Cache-Prior boost) broadcast to every MoE layer; ``mat`` the AMAT
    MatConfig when expert weights are quantized.  ``token_mask`` ([B]
    bool) excludes padding rows from MoE routing/capacity (see
    :func:`repro.models.moe.moe_apply`).

    ``cache["pos"]`` may be a scalar (all sequences aligned — the original
    single-request path) or a ``[B]`` vector of per-sequence lengths (the
    continuous-batching path, where each slot was prefilled at a different
    time).  With vector positions every sequence writes its KV row at its
    own offset and attends over its own valid prefix.
    """
    b = token.shape[0]
    pos = cache["pos"]
    vector_pos = getattr(pos, "ndim", 0) == 1      # per-sequence positions
    x = params["embed"][token].astype(_dt(cfg))[:, None, :]   # [B, 1, d]
    if vector_pos:
        positions = pos[:, None].astype(jnp.int32)            # [B, 1]
    else:
        positions = jnp.full((1, 1), pos, jnp.int32)
    window = cfg.sliding_window if (use_window or cfg.always_swa) else None
    pattern = cfg.block_pattern

    def period_body(carry, xs):
        x = carry
        period_params, cache_in, overrides = xs
        cache_out = {}
        auxes = []
        for i, spec in enumerate(pattern):
            key = f"pos{i}"
            p = period_params[key]
            if spec.mixer == "attn":
                h = L.rms_norm(x, p["norm"], cfg.norm_eps)
                q, k, v = _attn_qkv(p, h, cfg)
                q = L.apply_rope(q, positions, cfg.rope_theta)
                k = L.apply_rope(k, positions, cfg.rope_theta)
                S_alloc = cache_in[key]["k"].shape[1]
                ring = cfg.ring_kv
                pos_w = (pos % S_alloc) if ring else pos

                def write_row(buf, val):
                    # val: [B, 1, ...] — the new token's row per sequence.
                    if vector_pos:
                        return buf.at[jnp.arange(b), pos_w].set(
                            val[:, 0].astype(buf.dtype))
                    start = (0, pos_w) + (0,) * (buf.ndim - 2)
                    return jax.lax.dynamic_update_slice(
                        buf, val.astype(buf.dtype), start)

                if cfg.kv_dtype == "int8":
                    kq, ks = _quant_kv(k)
                    vq, vs = _quant_kv(v)
                    kc = write_row(cache_in[key]["k"], kq)
                    vc = write_row(cache_in[key]["v"], vq)
                    ksc = write_row(cache_in[key]["k_scale"], ks)
                    vsc = write_row(cache_in[key]["v_scale"], vs)
                    entry = {"k": kc, "v": vc, "k_scale": ksc,
                             "v_scale": vsc}
                else:
                    kc = write_row(cache_in[key]["k"], k)
                    vc = write_row(cache_in[key]["v"], v)
                    ksc = vsc = None
                    entry = {"k": kc, "v": vc}

                # Sliding-window decode reads only the last `window` cache
                # rows (true O(window) traffic, not a masked full read).
                S_cache = kc.shape[1]
                if vector_pos:
                    # Per-sequence lengths: rows diverge, so the compact
                    # dynamic-slice read doesn't apply — read the full
                    # cache and let the per-row mask in decode_attention
                    # bound each sequence's valid prefix (and window).
                    k_r, v_r = kc, vc
                    ks_r, vs_r = ksc, vsc
                    cur = jnp.minimum(pos + 1, S_cache) if ring else pos + 1
                    win_mask = None if ring else window
                elif ring:
                    # ring buffer: every resident row is within the window;
                    # attention is permutation-invariant so wraparound
                    # order doesn't matter.
                    k_r, v_r = kc, vc
                    ks_r, vs_r = ksc, vsc
                    cur = jnp.minimum(pos + 1, S_cache)
                    win_mask = None
                elif window is not None and S_cache > window:
                    start = jnp.clip(pos + 1 - window, 0, S_cache - window)
                    k_r = jax.lax.dynamic_slice_in_dim(kc, start, window, 1)
                    v_r = jax.lax.dynamic_slice_in_dim(vc, start, window, 1)
                    if ksc is not None:
                        ks_r = jax.lax.dynamic_slice_in_dim(ksc, start,
                                                            window, 1)
                        vs_r = jax.lax.dynamic_slice_in_dim(vsc, start,
                                                            window, 1)
                    cur = pos + 1 - start
                    win_mask = None
                else:
                    k_r, v_r = kc, vc
                    ks_r, vs_r = ksc, vsc
                    cur = pos + 1
                    win_mask = window
                if cfg.kv_dtype == "int8":
                    k_f = _dequant_kv(k_r, ks_r, _dt(cfg))
                    v_f = _dequant_kv(v_r, vs_r, _dt(cfg))
                else:
                    k_f, v_f = k_r, v_r
                o = L.decode_attention(
                    q[:, 0], k_f, v_f, cur, sliding_window=win_mask,
                    logit_softcap=cfg.logit_softcap)
                x = x + (o.reshape(b, -1) @ p["wo"])[:, None, :]
                if cfg.is_encdec:
                    x = _cross_attn_block(
                        p, x, cache_in[key]["ck"], cache_in[key]["cv"], cfg)
                    entry["ck"] = cache_in[key]["ck"]
                    entry["cv"] = cache_in[key]["cv"]
                cache_out[key] = entry
            else:
                h = L.rms_norm(x, p["ssm_norm"], cfg.norm_eps)
                y, st, cb = S.ssm_decode_step(
                    p["ssm"], h[:, 0], cache_in[key]["state"],
                    cache_in[key]["conv"], cfg.ssm)
                x = x + y[:, None, :]
                cache_out[key] = {"state": st, "conv": cb}

            ul = overrides.get("use_lsb", {}).get(key) \
                if overrides else None
            go = overrides.get("gate", {}).get(key) if overrides else None
            ps = overrides.get("policy_state", {}).get(key) \
                if overrides else None
            if ps is not None and alpha is not None:
                ps = dict(ps)
                ps["alpha"] = alpha
            x, aux = _ffn_block(p, x, cfg, spec, collect=collect_trace,
                                use_lsb=ul, gate_override=go,
                                policy=policy, policy_state=ps, mat=mat,
                                token_mask=token_mask,
                                quant_execution=quant_execution)
            if aux is not None:
                auxes.append(aux)
        stacked = {}
        if auxes:
            stacked = {k: jnp.stack([a[k] for a in auxes])
                       for k in auxes[0]}
        return x, (cache_out, stacked)

    overrides = {}
    if use_lsb is not None:
        overrides["use_lsb"] = use_lsb
    if gate_override is not None:
        overrides["gate"] = gate_override
    if policy_state is not None:
        overrides["policy_state"] = policy_state

    layer_cache = {k: v for k, v in cache.items() if k != "pos"}
    xs = (params["blocks"], layer_cache, overrides if overrides else None)
    if overrides:
        x, (new_cache, aux_stacked) = jax.lax.scan(period_body, x, xs)
    else:
        # keep xs structure static when no overrides are present
        def body_no_ov(c, xs2):
            pp, ci = xs2
            return period_body(c, (pp, ci, None))
        x, (new_cache, aux_stacked) = jax.lax.scan(
            body_no_ov, x, (params["blocks"], layer_cache))

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, cfg, x[:, 0])
    new_cache["pos"] = pos + 1
    aux = {"moe": aux_stacked} if aux_stacked else {}
    return logits, new_cache, aux


# ==========================================================================
# Convenience
# ==========================================================================
def count_params(params: dict) -> int:
    return sum(int(np.prod(x.shape))
               for x in jax.tree_util.tree_leaves(params))
