"""Model stack: layers, MoE, SSM, unified multi-arch model."""
