"""Transformer building blocks (pure JAX, shard-friendly).

Everything here is written against stacked-parameter pytrees so the model
stack can ``lax.scan`` over layers, and against explicit shapes so the
dry-run can lower every (arch x input-shape) pair without allocation.

Covers the assigned architecture pool:
  * RMSNorm / LayerNorm
  * RoPE (configurable theta, partial-dim for Mamba-hybrids)
  * GQA attention with optional sliding window and logit soft-capping,
    causal or full (encoder), plus cross-attention (whisper)
  * Blockwise ("flash-style") attention via lax.scan over KV chunks, used
    automatically above a sequence-length threshold so 32k prefill never
    materializes an (S x S) score matrix
  * Single-token decode attention against a KV cache
  * MLP variants: SwiGLU (llama-family), GeGLU (gemma), squared-ReLU
    (nemotron), GELU (starcoder2/whisper)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

# Sequence length above which attention switches to the blockwise
# (online-softmax) implementation.
BLOCKWISE_THRESHOLD = 8192
BLOCK_KV = 1024


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]                     # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv_heads: int
    head_dim: int

    @property
    def q_rep(self) -> int:
        return self.n_heads // self.n_kv_heads


def _soft_cap(scores: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def _expand_kv(k: jax.Array, rep: int) -> jax.Array:
    """[B, S, Hkv, D] -> [B, S, Hkv*rep, D] by repeat (GQA)."""
    if rep == 1:
        return k
    b, s, hkv, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, rep, d)) \
              .reshape(b, s, hkv * rep, d)


def attention(
    q: jax.Array,               # [B, Sq, H, D]
    k: jax.Array,               # [B, Sk, Hkv, D]
    v: jax.Array,               # [B, Sk, Hkv, D]
    *,
    causal: bool,
    q_offset: int | jax.Array = 0,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
) -> jax.Array:
    """Multi-head attention; dispatches to blockwise above the threshold."""
    if k.shape[1] > BLOCKWISE_THRESHOLD:
        return blockwise_attention(
            q, k, v, causal=causal, q_offset=q_offset,
            sliding_window=sliding_window, logit_softcap=logit_softcap)

    dims_rep = q.shape[2] // k.shape[2]
    k = _expand_kv(k, dims_rep)
    v = _expand_kv(v, dims_rep)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = _soft_cap(scores, logit_softcap)

    sq, sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if sliding_window is not None:
        mask &= qpos[:, None] - kpos[None, :] < sliding_window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def blockwise_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool,
    q_offset: int | jax.Array = 0,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    block_kv: int = BLOCK_KV,
) -> jax.Array:
    """Online-softmax attention: lax.scan over KV blocks.

    Never materializes the (Sq x Sk) score matrix — peak memory is
    (Sq x block_kv) per head.  This is flash-attention at the HLO level;
    the Pallas kernel variant lives in repro/kernels.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    rep = h // k.shape[2]
    pad = (-sk) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_blocks = (sk + pad) // block_kv
    kb = k.reshape(b, n_blocks, block_kv, k.shape[2], d)
    vb = v.reshape(b, n_blocks, block_kv, v.shape[2], d)

    scale = d ** -0.5
    qf = q.astype(jnp.float32)
    qpos = jnp.arange(sq) + q_offset

    def body(carry, xs):
        m, l, acc = carry
        blk_idx, kblk, vblk = xs
        kblk = _expand_kv(kblk, rep).astype(jnp.float32)
        vblk = _expand_kv(vblk, rep).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kblk) * scale
        s = _soft_cap(s, logit_softcap)
        kpos = blk_idx * block_kv + jnp.arange(block_kv)
        mask = kpos[None, :] < sk
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if sliding_window is not None:
            mask &= qpos[:, None] - kpos[None, :] < sliding_window
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vblk)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    kb_t = jnp.moveaxis(kb, 1, 0)
    vb_t = jnp.moveaxis(vb, 1, 0)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (jnp.arange(n_blocks), kb_t, vb_t))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)   # [B, Sq, H, D]


def decode_attention(
    q: jax.Array,               # [B, H, D] — one new token per sequence
    k_cache: jax.Array,         # [B, S, Hkv, D]
    v_cache: jax.Array,         # [B, S, Hkv, D]
    cur_pos: jax.Array,         # [] or [B] — number of valid cache entries
    *,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
) -> jax.Array:
    """Single-token attention against a (possibly seq-sharded) KV cache."""
    b, s, hkv, d = k_cache.shape
    h = q.shape[1]
    rep = h // hkv
    scale = d ** -0.5
    qf = q.astype(jnp.float32).reshape(b, hkv, rep, d)
    kf = k_cache.astype(jnp.float32)
    scores = jnp.einsum("bgrd,bsgd->bgrs", qf, kf) * scale
    scores = _soft_cap(scores, logit_softcap)
    kpos = jnp.arange(s)
    cur = jnp.asarray(cur_pos)
    cur_b = jnp.broadcast_to(cur.reshape(-1, *([1] * 0)), (b,)) \
        if cur.ndim <= 1 else cur
    valid = kpos[None, :] < cur_b[:, None]                  # [B, S]
    if sliding_window is not None:
        valid &= kpos[None, :] >= (cur_b[:, None] - sliding_window)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def mlp_apply(params: dict, x: jax.Array, mlp_type: str) -> jax.Array:
    """Dense FFN. params: {'wi': [d, F] or [d, 2F] for gated, 'wo': [F, d]}."""
    dtype = x.dtype
    if mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if mlp_type == "swiglu" else \
            (lambda u: jax.nn.gelu(u, approximate=True))
        gu = x @ params["wi"]
        g, u = jnp.split(gu, 2, axis=-1)
        h = act(g.astype(jnp.float32)).astype(dtype) * u
    elif mlp_type == "relu2":
        h = x @ params["wi"]
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(dtype)
    elif mlp_type == "gelu":
        h = x @ params["wi"]
        h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(dtype)
    else:
        raise ValueError(f"unknown mlp_type {mlp_type}")
    return h @ params["wo"]


def mlp_param_shapes(d_model: int, d_ff: int, mlp_type: str) -> dict:
    wi_cols = 2 * d_ff if mlp_type in ("swiglu", "geglu") else d_ff
    return {"wi": (d_model, wi_cols), "wo": (d_ff, d_model)}
