"""Mamba2 (SSD — state-space duality) mixer, pure JAX.

Implements the chunked SSD algorithm [arXiv:2405.21060] for train/prefill
and the O(1)-per-token recurrent update for decode.  Used by
``mamba2-2.7b`` (pure SSM stack) and ``jamba-v0.1-52b`` (1:7
attention:mamba hybrid — Jamba ships Mamba-1; we adapt it to the SSD form
with its published state size, see DESIGN.md §3 hardware-adaptation notes).

Shapes (single group g=1 for B/C, broadcast over heads):
  u        [B, L, d_model]
  x        [B, L, H, P]      P = head_dim
  dt       [B, L, H]
  B_, C_   [B, L, N]         N = d_state
  state    [B, H, P, N]
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim

    def conv_channels(self, d_model: int) -> int:
        return self.d_inner(d_model) + 2 * self.d_state

    def in_proj_cols(self, d_model: int) -> int:
        # z, x, B, C, dt
        return (2 * self.d_inner(d_model) + 2 * self.d_state
                + self.n_heads(d_model))


def ssm_param_shapes(d_model: int, cfg: SSMCfg) -> dict:
    di = cfg.d_inner(d_model)
    return {
        "in_proj": (d_model, cfg.in_proj_cols(d_model)),
        "conv_w": (cfg.d_conv, cfg.conv_channels(d_model)),
        "conv_b": (cfg.conv_channels(d_model),),
        "A_log": (cfg.n_heads(d_model),),
        "D": (cfg.n_heads(d_model),),
        "dt_bias": (cfg.n_heads(d_model),),
        "norm_scale": (di,),
        "out_proj": (di, d_model),
    }


def _split_proj(proj: jax.Array, d_model: int, cfg: SSMCfg):
    di = cfg.d_inner(d_model)
    n = cfg.d_state
    z, x, B_, C_, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, x, B_, C_, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d.  x: [B, L, C]; w: [K, C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return (out + b).astype(x.dtype)


def _segsum(t: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < s <= i} t[..., s]."""
    L = t.shape[-1]
    c = jnp.cumsum(t, axis=-1)
    out = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B_: jax.Array,
                C_: jax.Array, chunk: int, init_state=None):
    """Chunked SSD scan.

    x [b,l,h,p], dt [b,l,h] (post-softplus), A [h] (negative), B_/C_ [b,l,n].
    Returns (y [b,l,h,p], final_state [b,h,p,n]).
    """
    b, l, h, p = x.shape
    n = B_.shape[-1]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
    L = x.shape[1]
    nc = L // chunk

    xf = x.astype(jnp.float32).reshape(b, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, h)
    Bf = B_.astype(jnp.float32).reshape(b, nc, chunk, n)
    Cf = C_.astype(jnp.float32).reshape(b, nc, chunk, n)

    dA = dtf * A[None, None, None, :]                     # [b,c,q,h]
    dA_cum = jnp.cumsum(dA, axis=2)                       # [b,c,q,h]

    # --- intra-chunk (the "attention-like" quadratic term) -----------------
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dA, 2, 3)))       # [b,c,h,q,q]
    CB = jnp.einsum("bcqn,bckn->bcqk", Cf, Bf)            # [b,c,q,q]
    gate = Lmat * CB[:, :, None]                          # [b,c,h,q,k]
    xdt = xf * dtf[..., None]                             # [b,c,q,h,p]
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", gate, xdt)

    # --- chunk boundary states ---------------------------------------------
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # [b,c,q,h]
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bf,
                        decay_states * dtf, xf)            # [b,c,h,p,n]

    # --- inter-chunk recurrence over chunk states ---------------------------
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])             # [b,c,h]

    def scan_fn(carry, xs):
        st_prev = carry                                     # [b,h,p,n]
        st_c, dec_c = xs                                    # [b,h,p,n], [b,h]
        st_new = st_prev * dec_c[..., None, None] + st_c
        return st_new, st_prev

    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    states_t = jnp.moveaxis(states, 1, 0)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init_state.astype(jnp.float32), (states_t, decay_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # [b,c,h,p,n]

    # --- contribution of previous-chunk states -----------------------------
    state_decay = jnp.exp(dA_cum)                          # [b,c,q,h]
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cf, prev_states,
                       state_decay)

    y = (y_diag + y_off).reshape(b, L, h, p)[:, :l]
    return y.astype(x.dtype), final_state


def ssm_forward(params: dict, u: jax.Array, cfg: SSMCfg,
                init_state=None, init_conv=None, return_state=False):
    """Full Mamba2 mixer forward over a sequence.  u: [B, L, d_model]."""
    b, l, d_model = u.shape
    di = cfg.d_inner(d_model)
    h = cfg.n_heads(d_model)

    proj = u @ params["in_proj"]
    z, xc, Bc, Cc, dt = _split_proj(proj, d_model, cfg)

    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    if init_conv is not None:
        conv_in = jnp.concatenate([init_conv.astype(conv_in.dtype), conv_in],
                                  axis=1)
    conv_out = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    if init_conv is not None:
        conv_out = conv_out[:, init_conv.shape[1]:]
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(u.dtype)
    xc, Bc, Cc = jnp.split(conv_out, [di, di + cfg.d_state], axis=-1)

    x = xc.reshape(b, l, h, cfg.head_dim)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))

    y, state = ssd_chunked(x, dt, A, Bc, Cc, cfg.chunk, init_state)
    y = y + x * params["D"].astype(u.dtype)[None, None, :, None]
    y = y.reshape(b, l, di)

    # gated RMSNorm then out-projection
    g = jax.nn.silu(z.astype(jnp.float32))
    yf = y.astype(jnp.float32) * g
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yn = yf * jax.lax.rsqrt(var + 1e-5) * (1.0 + params["norm_scale"])
    out = yn.astype(u.dtype) @ params["out_proj"]

    if return_state:
        # final conv window for decode continuation
        tail = conv_in[:, -(cfg.d_conv - 1):, :] if l >= cfg.d_conv - 1 else \
            jnp.pad(conv_in, ((0, 0), (cfg.d_conv - 1 - l, 0), (0, 0)))
        return out, (state, tail)
    return out


def ssm_decode_step(params: dict, u: jax.Array, state: jax.Array,
                    conv_buf: jax.Array, cfg: SSMCfg):
    """One-token recurrent update.

    u: [B, d_model]; state: [B, H, P, N] (f32);
    conv_buf: [B, d_conv-1, conv_channels] — trailing conv window.
    Returns (y [B, d_model], new_state, new_conv_buf).
    """
    b, d_model = u.shape
    di = cfg.d_inner(d_model)
    h = cfg.n_heads(d_model)

    proj = u @ params["in_proj"]
    z, xc, Bc, Cc, dt = _split_proj(proj, d_model, cfg)

    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)       # [B, convch]
    window = jnp.concatenate([conv_buf, conv_in[:, None, :]], axis=1)
    conv = jnp.sum(window.astype(jnp.float32)
                   * params["conv_w"].astype(jnp.float32)[None], axis=1) \
        + params["conv_b"]
    conv = jax.nn.silu(conv).astype(u.dtype)
    xc, Bc, Cc = jnp.split(conv, [di, di + cfg.d_state], axis=-1)

    x = xc.reshape(b, h, cfg.head_dim).astype(jnp.float32)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # [B, H]
    da = jnp.exp(dt * A[None, :])                                  # [B, H]

    Bf = Bc.astype(jnp.float32)                                    # [B, N]
    Cf = Cc.astype(jnp.float32)
    state = state * da[..., None, None] \
        + jnp.einsum("bh,bhp,bn->bhpn", dt, x, Bf)
    y = jnp.einsum("bhpn,bn->bhp", state, Cf) \
        + x * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, di)

    g = jax.nn.silu(z.astype(jnp.float32))
    yf = y * g
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yn = yf * jax.lax.rsqrt(var + 1e-5) * (1.0 + params["norm_scale"])
    out = yn.astype(u.dtype) @ params["out_proj"]

    new_buf = window[:, 1:, :]
    return out, state, new_buf
