"""Synthetic data pipelines."""
