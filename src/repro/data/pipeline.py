"""Synthetic LM data pipeline.

No datasets ship offline, so we generate deterministic token streams with
enough structure that (a) training loss goes meaningfully below the
uniform floor and (b) MoE routers develop non-degenerate, input-dependent
routing distributions — which the SliceMoE experiments need (hotness,
single-head sharpness).

Generator: a per-stream zipf-weighted Markov chain over the vocabulary.
Each document draws a "topic" seed that biases the transition matrix rows,
so different documents exercise different token (and therefore expert)
distributions, mimicking the prefill-hotness-carries-to-decode property
the paper exploits (Fig. 3).

The loader is shard-aware: ``global_batch`` is divided over the data axis
of the mesh; each host slices its shard deterministically from the stream
index, so the pipeline is identical on 1 device and 512.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_topics: int = 16
    zipf_a: float = 1.3
    topic_sharpness: float = 4.0


class SyntheticLM:
    """Deterministic zipf-markov token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        # Base zipf unigram distribution.
        ranks = np.arange(1, V + 1, dtype=np.float64)
        base = ranks ** (-cfg.zipf_a)
        self.base = base / base.sum()
        # Topic biases: each topic up-weights a random band of the vocab.
        self.topic_bias = rng.dirichlet(
            np.full(V, 0.5 / np.sqrt(V)) + 1e-3, size=cfg.n_topics)

    def _doc_dist(self, topic: int) -> np.ndarray:
        s = self.cfg.topic_sharpness
        p = self.base * (1.0 + s * self.topic_bias[topic])
        return p / p.sum()

    def sample_batch(self, step: int, batch: int,
                     seq_len: Optional[int] = None) -> np.ndarray:
        """[batch, seq_len+1] tokens; deterministic in (seed, step)."""
        seq_len = seq_len or self.cfg.seq_len
        out = np.empty((batch, seq_len + 1), np.int32)
        for b in range(batch):
            rng = np.random.default_rng(
                (self.cfg.seed, step, b, 0xD00D))
            topic = int(rng.integers(self.cfg.n_topics))
            dist = self._doc_dist(topic)
            # 1st-order structure: with prob q, repeat a recent token.
            toks = rng.choice(self.cfg.vocab_size, size=seq_len + 1, p=dist)
            repeat = rng.random(seq_len + 1) < 0.3
            for t in range(4, seq_len + 1):
                if repeat[t]:
                    toks[t] = toks[t - int(rng.integers(1, 4))]
            out[b] = toks
        return out

    def batches(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            full = self.sample_batch(step, self.cfg.global_batch)
            yield {
                "tokens": full[:, :-1],
                "labels": full[:, 1:],
                "step": step,
            }
            step += 1

    def host_shard(self, step: int, shard_idx: int, n_shards: int) -> dict:
        """Deterministic per-host slice of the global batch."""
        assert self.cfg.global_batch % n_shards == 0
        per = self.cfg.global_batch // n_shards
        full = self.sample_batch(step, self.cfg.global_batch)
        sl = slice(shard_idx * per, (shard_idx + 1) * per)
        return {"tokens": full[sl, :-1], "labels": full[sl, 1:]}
