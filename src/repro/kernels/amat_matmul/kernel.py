"""Pallas TPU kernel: fused AMAT group-dequant + matmul.

The paper's XPU dequantizes bit-sliced experts in fixed-function hardware
in front of the systolic array.  The TPU-native equivalent fuses the
G32 asymmetric dequant into the matmul's K-loop at VMEM-tile granularity:
a ``(bk, bn)`` uint8 code tile is dequantized in VREGs (subtract zp,
scale — and for the MSB-only path, a right-shift on code and zp first)
and immediately fed to the MXU, so the f32 weight tile never exists in
HBM.  Grid: ``(M/bm, N/bn, K/bk)`` with K innermost, accumulating into
the output tile (revisited across the K dimension).

Tiling constraints: ``bk % group_size == 0`` so each K-tile covers whole
quantization groups; bm/bn multiples of (8, 128) keep the MXU aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _amat_matmul_kernel(x_ref, c_ref, s_ref, z_ref, o_ref, acc_ref, *,
                        group_size: int, shift: int, low: bool,
                        n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)              # [bm, bk]
    codes = c_ref[...]                              # [bk, bn] uint8
    s = s_ref[...].astype(jnp.float32)              # [bk//G, bn]
    z = z_ref[...].astype(jnp.float32)              # [bk//G, bn]

    bk, bn = codes.shape
    g = bk // group_size
    c = codes.reshape(g, group_size, bn).astype(jnp.float32)
    zb = z.reshape(g, 1, bn)
    sb = s.reshape(g, 1, bn)
    if low and shift > 0:
        c = jnp.floor(c * (0.5 ** shift))
        zb = jnp.floor(zb * (0.5 ** shift))
        sb = sb * (2.0 ** shift)
    w = ((c - zb) * sb).reshape(bk, bn)             # dequant in VREGs

    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def amat_matmul_pallas(x, codes, scales, zps, *, group_size: int = 32,
                       shift: int = 0, mode: str = "high",
                       bm: int = 128, bn: int = 128, bk: int = 128,
                       interpret: bool = False):
    """x: [M, K]; codes: [K, N] uint8; scales/zps: [K//G, N] -> [M, N] f32."""
    M, K = x.shape
    K2, N = codes.shape
    assert K == K2 and K % group_size == 0
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert bk % group_size == 0, "K tile must cover whole groups"
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        f"pad inputs to block multiples: {(M, N, K)} vs {(bm, bn, bk)}"
    n_k = K // bk
    gs_per_bk = bk // group_size

    kernel = functools.partial(
        _amat_matmul_kernel, group_size=group_size, shift=shift,
        low=(mode == "low"), n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((gs_per_bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((gs_per_bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        # f32 accumulator tile in VMEM, revisited across the K grid dim
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, codes, scales, zps)
