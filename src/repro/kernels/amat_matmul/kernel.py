"""Pallas TPU kernels: fused AMAT group-dequant + matmul.

The paper's XPU dequantizes bit-sliced experts in fixed-function hardware
in front of the systolic array.  The TPU-native equivalent fuses the
G32 asymmetric dequant into the matmul's K-loop at VMEM-tile granularity:
a ``(bk, bn)`` uint8 code tile is dequantized in VREGs (subtract zp,
scale — and for the MSB-only path, a right-shift on code and zp first)
and immediately fed to the MXU, so the f32 weight tile never exists in
HBM.  Grid: ``(M/bm, N/bn, K/bk)`` with K innermost, accumulating into
the output tile (revisited across the K dimension).

Three entry points (see docs/kernels.md for the full grid/BlockSpec map):

* :func:`amat_matmul_pallas` — single weight matrix, static precision
  selection (``mode='high'|'low'``).  Microbenchmark / ablation kernel.
* :func:`amat_batched_matmul_pallas` — batched over an expert axis
  (``[E, K, N]`` codes) with **per-expert** precision selection: the
  ``use_lsb`` vector rides in via scalar prefetch
  (:class:`pltpu.PrefetchScalarGridSpec`), so expert ``e`` flips between
  the MSB+LSB and the MSB-only dequant constants branch-free inside the
  K loop.  This is the quantized-execution path of the expert FFN.
* :func:`amat_batched_matmul_t_pallas` — the transposed variant for the
  ``wo`` projection: codes stored output-major (``[E, N, K]``), the
  tile is transposed in VREGs after the DMA so group metadata stays in
  the canonical ``[E, K//G, N]`` layout.

Tiling constraints: ``bk % group_size == 0`` so each K-tile covers whole
quantization groups; bm/bn multiples of (8, 128) keep the MXU aligned.
All kernels accept ``interpret=True`` so CPU CI executes the same body.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _amat_matmul_kernel(x_ref, c_ref, s_ref, z_ref, o_ref, acc_ref, *,
                        group_size: int, shift: int, low: bool,
                        n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)              # [bm, bk]
    codes = c_ref[...]                              # [bk, bn] uint8
    s = s_ref[...].astype(jnp.float32)              # [bk//G, bn]
    z = z_ref[...].astype(jnp.float32)              # [bk//G, bn]

    bk, bn = codes.shape
    g = bk // group_size
    c = codes.reshape(g, group_size, bn).astype(jnp.float32)
    zb = z.reshape(g, 1, bn)
    sb = s.reshape(g, 1, bn)
    if low and shift > 0:
        c = jnp.floor(c * (0.5 ** shift))
        zb = jnp.floor(zb * (0.5 ** shift))
        sb = sb * (2.0 ** shift)
    w = ((c - zb) * sb).reshape(bk, bn)             # dequant in VREGs

    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def amat_matmul_pallas(x, codes, scales, zps, *, group_size: int = 32,
                       shift: int = 0, mode: str = "high",
                       bm: int = 128, bn: int = 128, bk: int = 128,
                       interpret: bool = False):
    """x: [M, K]; codes: [K, N] uint8; scales/zps: [K//G, N] -> [M, N] f32."""
    M, K = x.shape
    K2, N = codes.shape
    assert K == K2 and K % group_size == 0
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert bk % group_size == 0, "K tile must cover whole groups"
    assert N % bn == 0 and K % bk == 0, \
        f"pad N/K to block multiples: {(N, K)} vs {(bn, bk)}"
    # Decode batches are rarely multiples of bm: pad M internally and
    # slice the result (padded rows hit zeroed x, contributing nothing).
    m_pad = (-M) % bm
    if m_pad:
        x = jnp.pad(x, ((0, m_pad), (0, 0)))
    Mp = M + m_pad
    n_k = K // bk
    gs_per_bk = bk // group_size

    kernel = functools.partial(
        _amat_matmul_kernel, group_size=group_size, shift=shift,
        low=(mode == "low"), n_k=n_k)

    out = pl.pallas_call(
        kernel,
        grid=(Mp // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((gs_per_bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((gs_per_bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), jnp.float32),
        # f32 accumulator tile in VMEM, revisited across the K grid dim
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, codes, scales, zps)
    return out[:M] if m_pad else out


# --------------------------------------------------------------------------
# Batched-expert kernels (the quantized-execution path of the expert FFN)
# --------------------------------------------------------------------------
def _dequant_tile(codes, s, z, use_lsb_e, *, group_size: int, shift: int):
    """Dequantize a [bk, bn] code tile in VREGs with runtime precision.

    ``use_lsb_e`` is a scalar bool (this expert's precision): True keeps
    the full high-bit code; False applies the AMAT truncation (shift on
    code *and* zero-point, rescale) — both paths cost one FMA since the
    select is on the dequant constants, not on the result.
    """
    bk, bn = codes.shape
    g = bk // group_size
    c = codes.reshape(g, group_size, bn).astype(jnp.float32)
    zb = z.astype(jnp.float32).reshape(g, 1, bn)
    sb = s.astype(jnp.float32).reshape(g, 1, bn)
    if shift > 0:
        inv = 0.5 ** shift
        c = jnp.where(use_lsb_e, c, jnp.floor(c * inv))
        zb = jnp.where(use_lsb_e, zb, jnp.floor(zb * inv))
        sb = jnp.where(use_lsb_e, sb, sb * (2.0 ** shift))
    return ((c - zb) * sb).reshape(bk, bn)


def _amat_batched_kernel(u_ref, x_ref, c_ref, s_ref, z_ref, o_ref,
                         acc_ref, *, group_size: int, shift: int,
                         n_k: int, transposed: bool):
    e = pl.program_id(0)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)                # [bm, bk]
    codes = c_ref[0]                                # [bk, bn] | [bn, bk]
    if transposed:
        # output-major wo layout: transpose the code tile in VREGs so the
        # dequant + dot share the K-major path (metadata is K-major).
        codes = codes.T
    hi = u_ref[e] > 0                               # scalar-prefetched flag
    w = _dequant_tile(codes, s_ref[0], z_ref[0], hi,
                      group_size=group_size, shift=shift)

    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def amat_batched_matmul_pallas(x, codes, scales, zps, use_lsb, *,
                               group_size: int = 32, shift: int = 4,
                               bm: int = 128, bn: int = 128, bk: int = 128,
                               transposed: bool = False,
                               interpret: bool = False):
    """Per-expert fused dequant-matmul on packed AMAT codes.

    x: [E, M, K]; codes: [E, K, N] (or [E, N, K] when ``transposed``);
    scales/zps: [E, K//G, N]; use_lsb: [E] (bool/int) — expert ``e``
    computes at high precision iff ``use_lsb[e]``.  Returns [E, M, N] f32.

    ``use_lsb`` travels via scalar prefetch: it is resident in SMEM
    before the grid starts, so per-expert precision selection costs no
    extra DMA and no grid restructuring — DBSC's per-step high/low-bit
    decisions become per-expert dequant shifts inside one kernel launch.
    """
    E, M, K = x.shape
    N = codes.shape[1] if transposed else codes.shape[2]
    assert codes.shape == ((E, N, K) if transposed else (E, K, N))
    assert K % group_size == 0
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert bk % group_size == 0, "K tile must cover whole groups"
    assert N % bn == 0 and K % bk == 0, \
        f"pad N/K to block multiples: {(N, K)} vs {(bn, bk)}"
    m_pad = (-M) % bm
    if m_pad:
        x = jnp.pad(x, ((0, 0), (0, m_pad), (0, 0)))
    Mp = M + m_pad
    n_k = K // bk
    g_bk = bk // group_size
    u = use_lsb.astype(jnp.int32)

    kernel = functools.partial(
        _amat_batched_kernel, group_size=group_size, shift=shift,
        n_k=n_k, transposed=transposed)
    code_spec = (
        pl.BlockSpec((1, bn, bk), lambda e, i, j, k, u_ref: (e, j, k))
        if transposed else
        pl.BlockSpec((1, bk, bn), lambda e, i, j, k, u_ref: (e, k, j)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(E, Mp // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, i, j, k, u_ref: (e, i, k)),
            code_spec,
            pl.BlockSpec((1, g_bk, bn), lambda e, i, j, k, u_ref: (e, k, j)),
            pl.BlockSpec((1, g_bk, bn), lambda e, i, j, k, u_ref: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn),
                               lambda e, i, j, k, u_ref: (e, i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((E, Mp, N), jnp.float32),
        interpret=interpret,
    )(u, x, codes, scales, zps)
    return out[:, :M] if m_pad else out


def amat_batched_matmul_t_pallas(x, codes_t, scales, zps, use_lsb, **kw):
    """Transposed-weight variant: codes_t [E, N, K], metadata [E, K//G, N].

    Used for the ``wo`` projection when its codes are stored output-major
    (``[E, d_model, d_ff]``) so both expert weight matrices share the
    d_model-minor HBM layout; the code tile is transposed in VREGs after
    the DMA — group metadata never changes layout.
    """
    return amat_batched_matmul_pallas(x, codes_t, scales, zps, use_lsb,
                                      transposed=True, **kw)
