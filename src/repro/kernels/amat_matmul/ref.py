"""Pure-jnp oracle for the fused AMAT dequant-matmul kernel.

Computes ``x @ dequant(w_q)`` where ``w_q`` is a G-group asymmetric
AMAT-quantized weight.  ``mode`` selects the precision path:
  'high' — full-precision codes:       (q - zp) * s
  'low'  — AMAT truncated (MSB-only):  (q>>shift - zp>>shift) * s * 2^shift
"""

from __future__ import annotations

import jax.numpy as jnp


def amat_matmul_ref(x, codes, scales, zps, *, group_size: int = 32,
                    shift: int = 0, mode: str = "high"):
    """x: [M, K] float; codes: [K, N] uint8; scales/zps: [K//G, N]."""
    K, N = codes.shape
    G = K // group_size
    c = codes.reshape(G, group_size, N).astype(jnp.float32)
    z = zps.reshape(G, 1, N).astype(jnp.float32)
    s = scales.reshape(G, 1, N).astype(jnp.float32)
    if mode == "low" and shift > 0:
        c = jnp.floor(c / (2.0 ** shift))
        z = jnp.floor(z / (2.0 ** shift))
        s = s * (2.0 ** shift)
    w = ((c - z) * s).reshape(K, N)
    return x.astype(jnp.float32) @ w
