"""Pure-jnp oracle for the fused AMAT dequant-matmul kernel.

Computes ``x @ dequant(w_q)`` where ``w_q`` is a G-group asymmetric
AMAT-quantized weight.  ``mode`` selects the precision path:
  'high' — full-precision codes:       (q - zp) * s
  'low'  — AMAT truncated (MSB-only):  (q>>shift - zp>>shift) * s * 2^shift
"""

from __future__ import annotations

import jax.numpy as jnp


def amat_matmul_ref(x, codes, scales, zps, *, group_size: int = 32,
                    shift: int = 0, mode: str = "high"):
    """x: [M, K] float; codes: [K, N] uint8; scales/zps: [K//G, N]."""
    K, N = codes.shape
    G = K // group_size
    c = codes.reshape(G, group_size, N).astype(jnp.float32)
    z = zps.reshape(G, 1, N).astype(jnp.float32)
    s = scales.reshape(G, 1, N).astype(jnp.float32)
    if mode == "low" and shift > 0:
        c = jnp.floor(c / (2.0 ** shift))
        z = jnp.floor(z / (2.0 ** shift))
        s = s * (2.0 ** shift)
    w = ((c - z) * s).reshape(K, N)
    return x.astype(jnp.float32) @ w


def _dequant_mixed_ref(codes, scales, zps, use_lsb, *, group_size, shift):
    """[E, K, N] codes -> [E, K, N] f32 weights, per-expert precision."""
    E, K, N = codes.shape
    G = K // group_size
    c = codes.reshape(E, G, group_size, N).astype(jnp.float32)
    z = zps.reshape(E, G, 1, N).astype(jnp.float32)
    s = scales.reshape(E, G, 1, N).astype(jnp.float32)
    w_hi = (c - z) * s
    w_lo = (jnp.floor(c / (2.0 ** shift)) - jnp.floor(z / (2.0 ** shift))) \
        * (s * (2.0 ** shift))
    sel = use_lsb.reshape(E, 1, 1, 1).astype(bool)
    return jnp.where(sel, w_hi, w_lo).reshape(E, K, N)


def amat_batched_matmul_ref(x, codes, scales, zps, use_lsb, *,
                            group_size: int = 32, shift: int = 4):
    """x: [E, M, K]; codes: [E, K, N]; scales/zps: [E, K//G, N];
    use_lsb: [E] bool.  Returns [E, M, N] f32."""
    w = _dequant_mixed_ref(codes, scales, zps, use_lsb,
                           group_size=group_size, shift=shift)
    return jnp.einsum("emk,ekn->emn", x.astype(jnp.float32), w)


def amat_batched_matmul_t_ref(x, codes_t, scales, zps, use_lsb, *,
                              group_size: int = 32, shift: int = 4):
    """Transposed-weight oracle: codes_t [E, N, K], metadata [E, K//G, N]."""
    codes = jnp.swapaxes(codes_t, -1, -2)
    return amat_batched_matmul_ref(x, codes, scales, zps, use_lsb,
                                   group_size=group_size, shift=shift)
