"""Jit'd public wrappers for the fused AMAT dequant-matmul kernels.

Handle padding to block multiples, backend detection (interpret=True on
CPU — executes the kernel body in Python for correctness validation; on
TPU the same BlockSpecs drive real VMEM tiling) and the QuantizedTensor
calling convention.  ``amat_expert_matmul`` / ``amat_expert_matmul_t``
are the quantized-execution entry points the MoE layer calls on the
``[E, C, d]`` dispatch buffer (see docs/kernels.md).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.amat_matmul.kernel import (amat_batched_matmul_pallas,
                                              amat_matmul_pallas)
from repro.quant.groupquant import QuantizedTensor


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("group_size", "shift", "mode",
                                   "bm", "bn", "bk", "interpret"))
def amat_matmul(x, codes, scales, zps, *, group_size: int = 32,
                shift: int = 0, mode: str = "high",
                bm: int = 128, bn: int = 128, bk: int = 128,
                interpret: bool | None = None):
    """x [M, K] @ dequant(codes [K, N]) -> [M, N] f32."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    M, K = x.shape
    N = codes.shape[1]
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    bk_ = max(group_size, bk_ - bk_ % group_size)
    # pad to block multiples
    xp = _pad_to(_pad_to(x, bm_, 0), bk_, 1)
    cp = _pad_to(_pad_to(codes, bk_, 0), bn_, 1)
    sp = _pad_to(_pad_to(scales, bk_ // group_size, 0), bn_, 1)
    zp_ = _pad_to(_pad_to(zps, bk_ // group_size, 0), bn_, 1)
    out = amat_matmul_pallas(
        xp, cp, sp, zp_, group_size=group_size, shift=shift, mode=mode,
        bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return out[:M, :N]


def amat_matmul_qt(x, qt: QuantizedTensor, *, shift: int = 0,
                   mode: str = "high", **kw):
    assert qt.asymmetric, "AMAT kernel expects asymmetric group quant"
    return amat_matmul(x, qt.codes, qt.scales,
                       qt.zero_points, group_size=qt.group_size,
                       shift=shift, mode=mode, **kw)


@partial(jax.jit, static_argnames=("group_size", "shift", "transposed",
                                   "bm", "bn", "bk", "interpret"))
def amat_expert_matmul(x, codes, scales, zps, use_lsb, *,
                       group_size: int = 32, shift: int = 4,
                       transposed: bool = False,
                       bm: int = 128, bn: int = 128, bk: int = 128,
                       interpret: bool | None = None):
    """[E, M, K] @ per-expert-dequant([E, K, N] codes) -> [E, M, N] f32.

    ``use_lsb`` [E] selects MSB+LSB (high-bit) vs MSB-only dequant per
    expert inside the kernel.  ``transposed=True`` reads output-major
    codes ([E, N, K]) — the ``wo`` projection layout.  M is padded
    in-kernel; K/N are padded here (zero scales null the pad region).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    E, M, K = x.shape
    N = codes.shape[1] if transposed else codes.shape[2]
    bn_, bk_ = min(bn, N), min(bk, K)
    bk_ = max(group_size, bk_ - bk_ % group_size)
    xp = _pad_to(x, bk_, 2)
    if transposed:
        cp = _pad_to(_pad_to(codes, bn_, 1), bk_, 2)
    else:
        cp = _pad_to(_pad_to(codes, bk_, 1), bn_, 2)
    sp = _pad_to(_pad_to(scales, bk_ // group_size, 1), bn_, 2)
    zp_ = _pad_to(_pad_to(zps, bk_ // group_size, 1), bn_, 2)
    out = amat_batched_matmul_pallas(
        xp, cp, sp, zp_, use_lsb, group_size=group_size, shift=shift,
        bm=min(bm, M), bn=bn_, bk=bk_, transposed=transposed,
        interpret=interpret)
    return out[:, :, :N]


def amat_expert_matmul_qt(x, qt: QuantizedTensor, use_lsb, *, shift: int,
                          **kw):
    """QuantizedTensor convention for the batched expert kernel."""
    assert qt.asymmetric, "AMAT kernel expects asymmetric group quant"
    return amat_expert_matmul(x, qt.codes, qt.scales, qt.zero_points,
                              use_lsb, group_size=qt.group_size,
                              shift=shift, **kw)


def amat_expert_matmul_t(x, codes_t, scales, zps, use_lsb, *, shift: int,
                         group_size: int = 32, **kw):
    """Transposed-weight entry point: codes_t [E, N, K] output-major."""
    return amat_expert_matmul(x, codes_t, scales, zps, use_lsb,
                              group_size=group_size, shift=shift,
                              transposed=True, **kw)
