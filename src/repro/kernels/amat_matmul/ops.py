"""Jit'd public wrapper for the fused AMAT dequant-matmul kernel.

Handles padding to block multiples, backend detection (interpret=True on
CPU — executes the kernel body in Python for correctness validation; on
TPU the same BlockSpecs drive real VMEM tiling) and the QuantizedTensor
calling convention.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.amat_matmul.kernel import amat_matmul_pallas
from repro.quant.groupquant import QuantizedTensor


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("group_size", "shift", "mode",
                                   "bm", "bn", "bk", "interpret"))
def amat_matmul(x, codes, scales, zps, *, group_size: int = 32,
                shift: int = 0, mode: str = "high",
                bm: int = 128, bn: int = 128, bk: int = 128,
                interpret: bool | None = None):
    """x [M, K] @ dequant(codes [K, N]) -> [M, N] f32."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    M, K = x.shape
    N = codes.shape[1]
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    bk_ = max(group_size, bk_ - bk_ % group_size)
    # pad to block multiples
    xp = _pad_to(_pad_to(x, bm_, 0), bk_, 1)
    cp = _pad_to(_pad_to(codes, bk_, 0), bn_, 1)
    sp = _pad_to(_pad_to(scales, bk_ // group_size, 0), bn_, 1)
    zp_ = _pad_to(_pad_to(zps, bk_ // group_size, 0), bn_, 1)
    out = amat_matmul_pallas(
        xp, cp, sp, zp_, group_size=group_size, shift=shift, mode=mode,
        bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return out[:M, :N]


def amat_matmul_qt(x, qt: QuantizedTensor, *, shift: int = 0,
                   mode: str = "high", **kw):
    assert qt.asymmetric, "AMAT kernel expects asymmetric group quant"
    return amat_matmul(x, qt.codes, qt.scales,
                       qt.zero_points, group_size=qt.group_size,
                       shift=shift, mode=mode, **kw)
