"""AMAT bit-sliced matmul Pallas kernel."""
