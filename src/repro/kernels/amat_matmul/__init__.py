"""Fused AMAT group-dequant matmul kernels (single + batched-expert).

The batched variants (:func:`amat_expert_matmul`,
:func:`amat_expert_matmul_t`) are the quantized-execution path of the
expert FFN: packed uint8 codes are dequantized in VREGs inside the
matmul's K loop, with per-expert high/low-bit selection delivered by
scalar prefetch — dense expert weights never exist in HBM.
"""

from repro.kernels.amat_matmul.ops import (amat_expert_matmul,
                                           amat_expert_matmul_qt,
                                           amat_expert_matmul_t,
                                           amat_matmul, amat_matmul_qt)

__all__ = ["amat_expert_matmul", "amat_expert_matmul_qt",
           "amat_expert_matmul_t", "amat_matmul", "amat_matmul_qt"]
