"""Jit'd wrapper for the Pallas flash-attention kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attn.kernel import flash_attention_pallas


@partial(jax.jit, static_argnames=("causal", "sliding_window", "bq", "bk",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, sliding_window=None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return flash_attention_pallas(
        q, k, v, causal=causal, sliding_window=sliding_window,
        bq=bq, bk=bk, interpret=interpret)
