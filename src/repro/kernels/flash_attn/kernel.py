"""Pallas TPU flash attention (causal, GQA, optional sliding window).

Online-softmax over KV tiles with (m, l, acc) carried in VMEM scratch.
Grid: ``(B*Hkv, rep, Sq/bq, Sk/bk)`` — the KV axis is innermost so the
(bq, d) accumulator tile is revisited across KV tiles; one GQA KV head
serves ``rep`` query heads without re-streaming K/V from HBM for each
(the kernel-level reuse a naive per-head loop can't get).

Causality is exploited structurally: a KV tile entirely above the
diagonal contributes nothing, so its work is skipped under ``pl.when``
(on TPU the MXU still schedules the grid step, but no VMEM writes
happen; with a Mosaic grid-skipping hint this becomes a true 2x).

Tiles: ``bq x d`` and ``bk x d`` in VMEM; softmax stats f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, sq: int, sk: int, causal: bool,
                  window, n_k: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk

    # skip KV tiles fully above the causal diagonal
    if causal:
        needed = k_start <= q_start + bq - 1
    else:
        needed = jnp.bool_(True)

    @pl.when(needed)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32)              # [bq, d]
        k = k_ref[0].astype(jnp.float32)                 # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < sk
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           sliding_window=None, bq: int = 128,
                           bk: int = 128, interpret: bool = False):
    """q: [B, Sq, H, D]; k/v: [B, Sk, Hkv, D] -> [B, Sq, H, D] f32.

    H must be a multiple of Hkv (GQA).  Sq/Sk padded to tile multiples.
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    bq_, bk_ = min(bq, sq), min(bk, sk)
    pq = (-sq) % bq_
    pk = (-sk) % bk_
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    sq_p, sk_p = sq + pq, sk + pk
    n_q, n_k = sq_p // bq_, sk_p // bk_

    # [B, S, H, D] -> [B*Hkv, rep, S, D] so one KV head serves rep q-heads
    qr = jnp.moveaxis(q.reshape(b, sq_p, hkv, rep, d), 1, 3) \
        .reshape(b * hkv, rep, sq_p, d)
    kr = jnp.moveaxis(k, 1, 2).reshape(b * hkv, sk_p, d)
    vr = jnp.moveaxis(v, 1, 2).reshape(b * hkv, sk_p, d)

    kernel = functools.partial(
        _flash_kernel, bq=bq_, bk=bk_, sq=sq, sk=sk, causal=causal,
        window=sliding_window, n_k=n_k, scale=d ** -0.5)

    out = pl.pallas_call(
        kernel,
        grid=(b * hkv, rep, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq_, d), lambda g, r, i, j: (g, r, i, 0)),
            pl.BlockSpec((1, bk_, d), lambda g, r, i, j: (g, j, 0)),
            pl.BlockSpec((1, bk_, d), lambda g, r, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, d),
                               lambda g, r, i, j: (g, r, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, rep, sq_p, d),
                                       jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq_,), jnp.float32),      # m
            pltpu.VMEM((bq_,), jnp.float32),      # l
            pltpu.VMEM((bq_, d), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(qr, kr, vr)

    out = out.reshape(b, hkv, rep, sq_p, d)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq_p, h, d)
    return out[:, :sq]
