"""Pure-jnp oracle for the flash-attention kernel (causal, GQA,
optional sliding window)."""

from __future__ import annotations

import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        sliding_window=None):
    """q: [B, Sq, H, D]; k/v: [B, Sk, Hkv, D] -> [B, Sq, H, D] f32."""
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kf) \
        * (d ** -0.5)
    qpos = jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if sliding_window is not None:
        mask &= qpos[:, None] - kpos[None, :] < sliding_window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = p / jnp.sum(p, -1, keepdims=True)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf)
