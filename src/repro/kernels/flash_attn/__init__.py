"""Flash attention Pallas kernel."""
