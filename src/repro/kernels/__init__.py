"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

Every kernel ships as a triple — ``kernel.py`` (the Pallas body +
``pallas_call`` wiring), ``ops.py`` (jit'd public wrapper: padding,
interpret-mode backend detection, ``QuantizedTensor`` convention) and
``ref.py`` (a pure-jnp oracle the tests compare against bit-for-bit).
On CPU the wrappers select ``interpret=True`` so CI executes the same
kernel bodies the TPU runs; see docs/kernels.md for the grid/BlockSpec
and tiling constraints of each kernel.

Subpackages:
  * :mod:`repro.kernels.amat_matmul` — fused AMAT group-dequant matmuls,
    including the batched-expert quantized-execution kernels (per-expert
    ``use_lsb`` via scalar prefetch) used by the MoE decode hot path.
  * :mod:`repro.kernels.expert_matmul` — the original batched per-expert
    sliced dequant matmul (per-expert flag as a VMEM block).
  * :mod:`repro.kernels.flash_attn` — blockwise online-softmax attention.
"""
