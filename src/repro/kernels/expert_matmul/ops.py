"""Jit'd wrapper for the batched sliced expert matmul kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.expert_matmul.kernel import expert_matmul_pallas
from repro.quant.groupquant import QuantizedTensor


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("group_size", "shift",
                                   "bm", "bn", "bk", "interpret"))
def expert_matmul(x, codes, scales, zps, use_lsb, *, group_size: int = 32,
                  shift: int = 4, bm: int = 128, bn: int = 128,
                  bk: int = 128, interpret: bool | None = None):
    """[E, C, K] x [E, K, N] (AMAT codes, per-expert precision) -> [E, C, N]."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    E, C, K = x.shape
    N = codes.shape[2]
    bm_, bn_, bk_ = min(bm, C), min(bn, N), min(bk, K)
    bk_ = max(group_size, bk_ - bk_ % group_size)
    xp = _pad_to(_pad_to(x, bm_, 1), bk_, 2)
    cp = _pad_to(_pad_to(codes, bk_, 1), bn_, 2)
    sp = _pad_to(_pad_to(scales, bk_ // group_size, 1), bn_, 2)
    zp_ = _pad_to(_pad_to(zps, bk_ // group_size, 1), bn_, 2)
    out = expert_matmul_pallas(
        xp, cp, sp, zp_, use_lsb, group_size=group_size, shift=shift,
        bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return out[:, :C, :N]


def expert_matmul_qt(x, qt: QuantizedTensor, use_lsb, *, shift: int,
                     **kw):
    assert qt.asymmetric
    return expert_matmul(x, qt.codes, qt.scales, qt.zero_points, use_lsb,
                         group_size=qt.group_size, shift=shift, **kw)
