"""Pure-jnp oracle for the per-expert sliced dequant matmul.

Batched over experts: ``y[e] = x[e] @ dequant_e(codes[e])`` where expert e
dequantizes at high precision (MSB+LSB) iff ``use_lsb[e]`` — exactly the
DBSC mixed-precision expert FFN (paper §4.1).
"""

from __future__ import annotations

import jax.numpy as jnp


def expert_matmul_ref(x, codes, scales, zps, use_lsb, *,
                      group_size: int = 32, shift: int = 4):
    """x: [E, C, K]; codes: [E, K, N]; scales/zps: [E, K//G, N];
    use_lsb: [E] bool.  Returns [E, C, N] f32."""
    E, K, N = codes.shape
    G = K // group_size
    c = codes.reshape(E, G, group_size, N).astype(jnp.float32)
    z = zps.reshape(E, G, 1, N).astype(jnp.float32)
    s = scales.reshape(E, G, 1, N).astype(jnp.float32)

    w_hi = (c - z) * s
    c_lo = jnp.floor(c / (2.0 ** shift))
    z_lo = jnp.floor(z / (2.0 ** shift))
    w_lo = (c_lo - z_lo) * (s * (2.0 ** shift))

    sel = use_lsb.reshape(E, 1, 1, 1)
    w = jnp.where(sel, w_hi, w_lo).reshape(E, K, N)
    return jnp.einsum("eck,ekn->ecn", x.astype(jnp.float32), w)
