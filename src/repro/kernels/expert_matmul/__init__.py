"""Batched expert matmul Pallas kernel."""
