"""Pallas TPU kernel: batched per-expert sliced dequant matmul (DBSC).

Computes the expert FFN matmul for the ``[E, C, d]`` dispatch buffer with
**per-expert precision selection**: expert ``e`` dequantizes its AMAT
codes at high precision (MSB+LSB) iff ``use_lsb[e]``, else at the
truncated MSB-only precision — all in VMEM, branch-free (the select is a
VREG ``where`` on the dequant constants, so both paths cost one FMA).

Grid: ``(E, C/bm, N/bn, K/bk)``; the per-expert flag rides along as a
``(1, 1)`` VMEM block indexed by the expert grid axis.  On a real v5e
the E axis is sharded over the `model` mesh axis *outside* the kernel
(shard_map/GSPMD) — the kernel sees its local expert shard.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _expert_matmul_kernel(u_ref, x_ref, c_ref, s_ref, z_ref, o_ref,
                          acc_ref, *, group_size: int, shift: int,
                          n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)                # [bm, bk]
    codes = c_ref[0]                                # [bk, bn] uint8
    s = s_ref[0].astype(jnp.float32)                # [bk//G, bn]
    z = z_ref[0].astype(jnp.float32)
    hi = u_ref[0, 0] > 0                            # per-expert flag

    bk, bn = codes.shape
    g = bk // group_size
    c = codes.reshape(g, group_size, bn).astype(jnp.float32)
    zb = z.reshape(g, 1, bn)
    sb = s.reshape(g, 1, bn)

    inv = 0.5 ** shift
    c_lo = jnp.floor(c * inv)
    z_lo = jnp.floor(zb * inv)
    # branch-free select between the two dequant paths
    c_sel = jnp.where(hi, c, c_lo)
    z_sel = jnp.where(hi, zb, z_lo)
    s_sel = jnp.where(hi, sb, sb * (2.0 ** shift))
    w = ((c_sel - z_sel) * s_sel).reshape(bk, bn)

    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def expert_matmul_pallas(x, codes, scales, zps, use_lsb, *,
                         group_size: int = 32, shift: int = 4,
                         bm: int = 128, bn: int = 128, bk: int = 128,
                         interpret: bool = False):
    """x: [E, C, K]; codes: [E, K, N]; use_lsb: [E] -> [E, C, N] f32."""
    E, C, K = x.shape
    N = codes.shape[2]
    bm, bn, bk = min(bm, C), min(bn, N), min(bk, K)
    assert bk % group_size == 0
    assert C % bm == 0 and N % bn == 0 and K % bk == 0
    n_k = K // bk
    g_bk = bk // group_size
    u = use_lsb.astype(jnp.int32).reshape(E, 1)

    kernel = functools.partial(
        _expert_matmul_kernel, group_size=group_size, shift=shift, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(E, C // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((1, 1), lambda e, i, j, k: (e, 0)),
            pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk, bn), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, g_bk, bn), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, g_bk, bn), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(u, x, codes, scales, zps)
