"""Hardware specifications.

Two profiles:

* ``MOBILE_SOC`` — the paper's Fig. 7 system (systolic XPU + LPDDR4 DRAM +
  UFS 3.1 Flash).  Used by the faithful cost-model reproduction of the
  paper's energy / latency figures (Figs. 9-10).
* ``TPU_V5E`` — the target deployment hardware for the JAX framework.  Used
  by the roofline analysis of the compiled dry-runs (EXPERIMENTS.md
  §Roofline).  The DBSC hierarchy maps onto (local HBM ← remote HBM via ICI
  ← host DRAM) as described in DESIGN.md §3.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MemoryTier:
    """One tier of the offload hierarchy.

    ``access_latency_s`` is a fixed per-transfer issue cost (command +
    seek), paid once per transfer on top of the bandwidth term — the
    knob that makes many small slice fills slower than one large fill on
    the event timeline.  Both shipped profiles keep it at 0.0: the
    paper's Fig. 7 bandwidth numbers are *effective* rates with access
    overheads folded in, and the persisted Fig. 9-10 / benchmark
    baselines are calibrated against them.
    """

    name: str
    bandwidth_bytes_per_s: float
    energy_pj_per_bit: float
    capacity_bytes: float
    access_latency_s: float = 0.0

    @property
    def energy_j_per_byte(self) -> float:
        return self.energy_pj_per_bit * 8 * 1e-12

    def transfer_latency_s(self, nbytes: float) -> float:
        return self.access_latency_s + nbytes / self.bandwidth_bytes_per_s

    def transfer_energy_j(self, nbytes: float) -> float:
        return nbytes * self.energy_j_per_byte


@dataclasses.dataclass(frozen=True)
class ComputeSpec:
    """Compute engine spec (the XPU in the paper, a TPU chip for us)."""

    name: str
    peak_ops_per_s: float          # at the native precision below
    ops_per_watt: float            # energy efficiency (paper: 3.18 TOPS/W)
    native_precision_bits: int

    @property
    def energy_j_per_op(self) -> float:
        return 1.0 / self.ops_per_watt

    def compute_latency_s(self, ops: float, utilization: float = 1.0) -> float:
        return ops / (self.peak_ops_per_s * max(utilization, 1e-9))

    def compute_energy_j(self, ops: float) -> float:
        return ops * self.energy_j_per_op


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """A full offload system: compute + fast tier (cache) + slow tier.

    ``interconnect`` models the device-to-device link the expert-parallel
    serving mode charges all-to-all token dispatch on (``None`` keeps the
    cost model single-device; the sharded ledger falls back to the DRAM
    tier's rates if asked anyway).  Its ``capacity_bytes`` is
    meaningless for a link and set to ``inf``.
    """

    name: str
    compute: ComputeSpec
    dram: MemoryTier        # the expert-cache tier
    flash: MemoryTier       # the backing store (miss target)
    interconnect: Optional[MemoryTier] = None   # shard-to-shard link

    @property
    def miss_penalty_ratio_bw(self) -> float:
        return self.dram.bandwidth_bytes_per_s / self.flash.bandwidth_bytes_per_s

    @property
    def miss_penalty_ratio_energy(self) -> float:
        return self.flash.energy_pj_per_bit / self.dram.energy_pj_per_bit


# --- Paper Fig. 7: mobile SoC profile --------------------------------------
# XPU: 1 GHz systolic array, 8192 8-bit PEs -> 16.4 TOPS, 3.18 TOPS/W.
# DRAM: LPDDR4, ~104 Gbps, 8 GB, 1.5 pJ/bit.
# Flash: UFS 3.1, 10 Gbps, 128 GB, 103 pJ/bit.
MOBILE_SOC = SystemSpec(
    name="mobile_soc",
    compute=ComputeSpec(
        name="xpu_systolic_8192pe",
        peak_ops_per_s=16.4e12,
        ops_per_watt=3.18e12,
        native_precision_bits=8,
    ),
    dram=MemoryTier(
        name="lpddr4",
        bandwidth_bytes_per_s=104e9 / 8,   # 104 Gbps -> 13 GB/s
        energy_pj_per_bit=1.5,
        capacity_bytes=8 * 2**30,
    ),
    flash=MemoryTier(
        name="ufs3.1",
        bandwidth_bytes_per_s=10e9 / 8,    # 10 Gbps -> 1.25 GB/s
        energy_pj_per_bit=103.0,
        capacity_bytes=128 * 2**30,
    ),
    # Die-to-die NoC/D2D link for the multi-die expert-parallel variant
    # of the SoC: faster than Flash, slower and costlier per bit than
    # on-die LPDDR (UCIe-class effective rates; a modeling choice, the
    # paper's single-device figures never touch it).
    interconnect=MemoryTier(
        name="d2d_link",
        bandwidth_bytes_per_s=32e9,
        energy_pj_per_bit=2.0,
        capacity_bytes=float("inf"),
    ),
)


# --- TPU v5e profile (roofline constants; see system prompt) ---------------
@dataclasses.dataclass(frozen=True)
class TPUSpec:
    name: str
    peak_flops_bf16: float
    hbm_bytes_per_s: float
    ici_bytes_per_s_per_link: float
    hbm_capacity_bytes: float
    vmem_bytes: float

    def compute_term_s(self, flops: float, chips: int) -> float:
        return flops / (chips * self.peak_flops_bf16)

    def memory_term_s(self, hbm_bytes: float, chips: int) -> float:
        return hbm_bytes / (chips * self.hbm_bytes_per_s)

    def collective_term_s(self, coll_bytes: float, chips: int) -> float:
        return coll_bytes / (chips * self.ici_bytes_per_s_per_link)


TPU_V5E = TPUSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bytes_per_s=819e9,
    ici_bytes_per_s_per_link=50e9,
    hbm_capacity_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
)

# The TPU-native interpretation of the paper's (DRAM, Flash) pair:
# local HBM as the expert cache, host DRAM over PCIe-DMA as the backing
# store.  Used by the "tpu_offload" cost-model profile.
TPU_OFFLOAD = SystemSpec(
    name="tpu_offload",
    compute=ComputeSpec(
        name="tpu_v5e_chip",
        peak_ops_per_s=197e12 * 2,  # int8 ~= 2x bf16 on the MXU
        ops_per_watt=197e12 / 170,  # ~170 W TDP per v5e chip
        native_precision_bits=8,
    ),
    dram=MemoryTier(
        name="hbm",
        bandwidth_bytes_per_s=819e9,
        energy_pj_per_bit=0.5,
        capacity_bytes=16 * 2**30,
    ),
    flash=MemoryTier(
        name="host_dram_dma",
        bandwidth_bytes_per_s=32e9,   # PCIe gen4 x16-ish effective
        energy_pj_per_bit=15.0,
        capacity_bytes=512 * 2**30,
    ),
    # One ICI link per chip (v5e: 50 GB/s/link); all-to-all dispatch in
    # the expert-parallel mode is charged at the per-link rate.
    interconnect=MemoryTier(
        name="ici",
        bandwidth_bytes_per_s=50e9,
        energy_pj_per_bit=0.5,
        capacity_bytes=float("inf"),
    ),
)

SYSTEM_PROFILES = {
    "mobile_soc": MOBILE_SOC,
    "tpu_offload": TPU_OFFLOAD,
}
