"""Hardware cost models (system specs, energy/latency ledger)."""
