"""Deterministic latency / energy accounting for the offload hierarchy.

This is the cost model behind the paper's Figs. 9-10: every expert-slice
transfer (Flash→DRAM on a miss, DRAM→XPU on use) and every expert matmul is
accounted against the active :class:`~repro.hw.specs.SystemSpec`.

The model is an **event timeline**: each hardware channel (Flash, DRAM,
XPU compute) carries its own busy-until clock (:class:`ChannelTimeline`).
An operation issued at time ``t`` starts at ``max(t, channel busy-until)``
and occupies the channel for its transfer/compute duration, so a slice
fill can genuinely overlap an expert matmul — total latency is the
*makespan* ``max(channel busy-untils)``, not a sum of accumulators.

Two issue disciplines feed the timeline:

* the **serialized** (legacy) methods — :meth:`CostLedger.miss_fill`,
  :meth:`CostLedger.dram_read`, :meth:`CostLedger.matmul` — issue every
  event at the current global frontier, so the makespan degenerates to
  the sum of all durations (the paper's decode phase is bandwidth-bound,
  i.e. misses serialize against compute).  With ``overlap_io_compute``
  set, IO events chain only against the IO channels and compute against
  the compute channel, degenerating to ``max(io, compute)`` (prefill).
  Both reproduce the pre-timeline scalar-accumulator totals exactly.
* the **event** methods — :meth:`CostLedger.fill_at`,
  :meth:`CostLedger.dram_read_at`, :meth:`CostLedger.matmul_at` — take an
  explicit data-dependency time, letting the engine pipeline per-expert
  fill → read → matmul chains and issue asynchronous prefetch fills
  behind demand fills on the Flash channel (``prefetch=True`` tags their
  traffic separately).

Energy is time-independent (every byte moved / MAC switched is charged
when the event is recorded), so serialized and pipelined replays of the
same trace agree on energy and disagree only on latency — which is the
point: overlap hides latency, it does not un-spend energy.

Cost conventions (unchanged from the scalar model):

* a *miss* on a slice of ``nbytes`` costs one Flash read (latency +
  energy) plus one DRAM write,
* a *hit* (or post-fill use) costs one DRAM read into the XPU,
* a *dropped* fill (slice larger than the cache — see
  :meth:`~repro.core.cache.SliceCache.insert`) streams Flash→XPU
  directly: Flash latency + energy, no DRAM write
  (:meth:`CostLedger.flash_stream`),
* expert compute costs ``2 * tokens * d_in * d_out`` MAC-ops per matmul
  at the XPU's int8 throughput; low-bit (MSB-only) compute gets a
  throughput multiplier ``8 / bits`` reflecting the bit-serial/sliced PE
  design of the paper's XPU.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.hw.specs import SystemSpec, MOBILE_SOC


def expert_weight_step_bytes(n_codes: float, n_groups: float, *,
                             quant_execution: bool,
                             dense_itemsize: int = 4) -> float:
    """HBM bytes one expert-FFN step moves for its weights (analytic).

    The batched expert FFN touches every expert's weights each step.
    Codes are uint8 (1 B/element); group metadata is an f32 scale + a
    uint8 zero-point (5 B/group), read by both paths.  Dense dequant
    additionally writes and re-reads the materialized dense tensor at
    ``dense_itemsize`` bytes/element (4 for f32, 2 for bf16 — pass the
    model dtype's width); quantized execution streams only the packed
    codes.  This is a *model* of the traffic, shared by the engine and
    the benchmarks so their persisted baselines can't diverge — it is
    not a runtime measurement.
    """
    meta = n_groups * 5.0
    if quant_execution:
        return n_codes * 1.0 + meta
    return n_codes * (1.0 + 2.0 * dense_itemsize) + meta


@dataclasses.dataclass
class ChannelTimeline:
    """Busy-until clock for one hardware channel.

    ``issue`` is the only mutator: an operation ready at ``t_ready``
    starts when the channel frees up (FIFO — no preemption, matching a
    DMA queue / systolic array that drains in issue order) and pushes
    ``busy_until`` to its completion.  ``busy_s`` accumulates occupied
    time, so ``busy_until - busy_s`` is the channel's total idle time.
    """

    name: str
    busy_until: float = 0.0
    busy_s: float = 0.0

    def issue(self, t_ready: float, duration: float) -> Tuple[float, float]:
        start = max(t_ready, self.busy_until)
        end = start + duration
        self.busy_until = end
        self.busy_s += duration
        return start, end

    def reset(self) -> None:
        self.busy_until = 0.0
        self.busy_s = 0.0


@dataclasses.dataclass
class CostLedger:
    """Event-timeline latency + energy ledger over a simulated run."""

    system: SystemSpec = dataclasses.field(default_factory=lambda: MOBILE_SOC)
    overlap_io_compute: bool = False

    # energy / traffic accumulators (time-independent)
    flash_bytes: float = 0.0
    dram_bytes: float = 0.0
    compute_ops: float = 0.0
    flash_latency_s: float = 0.0       # per-channel duration sums (what a
    dram_latency_s: float = 0.0        # fully serialized replay would
    compute_latency_s: float = 0.0     # take; busy_s mirrors these)
    flash_energy_j: float = 0.0
    dram_energy_j: float = 0.0
    compute_energy_j: float = 0.0
    n_flash_transfers: int = 0
    n_dram_transfers: int = 0
    n_matmuls: int = 0

    # timeline state
    flash_ch: ChannelTimeline = dataclasses.field(
        default_factory=lambda: ChannelTimeline("flash"))
    dram_ch: ChannelTimeline = dataclasses.field(
        default_factory=lambda: ChannelTimeline("dram"))
    compute_ch: ChannelTimeline = dataclasses.field(
        default_factory=lambda: ChannelTimeline("compute"))
    # Background-priority Flash lane (see :meth:`prefetch_fill_at`):
    # speculative fills drain here so they never delay demand traffic.
    flash_bg_ch: ChannelTimeline = dataclasses.field(
        default_factory=lambda: ChannelTimeline("flash_bg"))
    io_stall_s: float = 0.0            # compute idle time waiting on data

    # asynchronous-prefetch traffic (a subset of the flash accumulators)
    n_prefetch_fills: int = 0
    prefetch_flash_bytes: float = 0.0
    prefetch_wasted_energy_j: float = 0.0

    # interconnect (all-to-all token dispatch under expert parallelism;
    # zero on every single-device run)
    ici_bytes: float = 0.0
    ici_latency_s: float = 0.0
    ici_energy_j: float = 0.0
    n_ici_transfers: int = 0
    ici_ch: ChannelTimeline = dataclasses.field(
        default_factory=lambda: ChannelTimeline("ici"))

    # expert-migration traffic (placement re-packing moving resident
    # slices shard-to-shard; a tagged subset of the ici accumulators,
    # the way prefetch_flash_bytes is a subset of flash_bytes)
    migration_bytes: float = 0.0
    n_migrations: int = 0

    # optional observability sink (repro.obs.timeline.TimelineTracer):
    # when attached, every charge emits exactly one TraceEvent after its
    # channel span is issued.  shard_id stamps which shard's channels
    # these are (-1 = the shared interconnect sub-ledger).  Detached on
    # clone() — forked hypothetical timelines are untraced.
    tracer: Optional[object] = None
    shard_id: int = 0

    # ------------------------------------------------------------ timeline
    @property
    def now(self) -> float:
        """The timeline frontier: completion time of the latest event."""
        return max(self.flash_ch.busy_until, self.dram_ch.busy_until,
                   self.compute_ch.busy_until, self.ici_ch.busy_until)

    def _io_ready(self) -> float:
        if self.overlap_io_compute:
            return max(self.flash_ch.busy_until, self.dram_ch.busy_until)
        return self.now

    def _compute_ready(self) -> float:
        if self.overlap_io_compute:
            return self.compute_ch.busy_until
        return self.now

    # ------------------------------------------------- event API (timed)
    def fill_at(self, t_ready: float, nbytes: float, *,
                prefetch: bool = False,
                dram_write: bool = True) -> Tuple[float, float]:
        """Flash read issued once the demand (or prediction) is known at
        ``t_ready``.  Returns the (start, end) span on the Flash channel;
        the transferred slice is usable from ``end``.  ``dram_write``
        distinguishes a Flash → DRAM fill (read + DRAM-write energy)
        from a direct Flash → XPU stream (dropped fill, no DRAM write).
        """
        sysspec = self.system
        self.flash_bytes += nbytes
        self.n_flash_transfers += 1
        dur = sysspec.flash.transfer_latency_s(nbytes)
        self.flash_latency_s += dur
        self.flash_energy_j += sysspec.flash.transfer_energy_j(nbytes)
        if dram_write:
            self.dram_energy_j += sysspec.dram.transfer_energy_j(nbytes)
        if prefetch:
            self.n_prefetch_fills += 1
            self.prefetch_flash_bytes += nbytes
        span = self.flash_ch.issue(t_ready, dur)
        if self.tracer is not None:
            self.tracer.emit("prefetch_fill" if prefetch else "fill",
                             "flash", self.shard_id, span[0], span[1],
                             nbytes=nbytes)
        return span

    def prefetch_fill_at(self, t_ready: Optional[float],
                         nbytes: float) -> Tuple[float, float]:
        """Background-priority speculative Flash → DRAM fill.

        Models the standard prefetch-queue discipline: demand fills
        preempt, so speculative traffic *never* delays the demand
        queue — the fill starts only once the demand frontier at issue
        time has drained (it cannot use bandwidth that is already
        spoken for) and occupies a separate background lane whose
        completion does not extend the makespan.  Energy and traffic
        are charged in full (overlap hides latency, it does not
        un-spend joules); the returned ``end`` is the earliest the
        slice is usable, slightly optimistic when demand arrives
        mid-transfer (the paused remainder is not re-queued — slice
        transfers are short relative to a decode step).

        ``t_ready=None`` issues at the serialized IO frontier (the
        blocking-issue discipline's notion of "now").

        The one-step transition baseline keeps issuing through
        :meth:`fill_at`/:meth:`miss_fill` — its fills contend with
        demand in FIFO order, which is part of the measured baseline
        behavior — so only the request-level predictor's fills ride
        this lane.
        """
        if t_ready is None:
            t_ready = self._io_ready()
        sysspec = self.system
        self.flash_bytes += nbytes
        self.n_flash_transfers += 1
        dur = sysspec.flash.transfer_latency_s(nbytes)
        self.flash_latency_s += dur
        self.flash_energy_j += sysspec.flash.transfer_energy_j(nbytes)
        self.dram_energy_j += sysspec.dram.transfer_energy_j(nbytes)
        self.n_prefetch_fills += 1
        self.prefetch_flash_bytes += nbytes
        span = self.flash_bg_ch.issue(
            max(t_ready, self.flash_ch.busy_until), dur)
        if self.tracer is not None:
            self.tracer.emit("prefetch_fill", "flash_bg", self.shard_id,
                             span[0], span[1], nbytes=nbytes)
        return span

    def flash_stream_at(self, t_ready: float,
                        nbytes: float) -> Tuple[float, float]:
        """Flash → XPU direct stream for a slice the cache cannot hold
        (dropped fill): Flash read latency + energy, no DRAM write."""
        return self.fill_at(t_ready, nbytes, dram_write=False)

    def dram_read_at(self, t_ready: float,
                     nbytes: float) -> Tuple[float, float]:
        """DRAM → XPU weight fetch, issued after its fill completes."""
        sysspec = self.system
        self.dram_bytes += nbytes
        self.n_dram_transfers += 1
        dur = sysspec.dram.transfer_latency_s(nbytes)
        self.dram_latency_s += dur
        self.dram_energy_j += sysspec.dram.transfer_energy_j(nbytes)
        span = self.dram_ch.issue(t_ready, dur)
        if self.tracer is not None:
            self.tracer.emit("dram_read", "dram", self.shard_id,
                             span[0], span[1], nbytes=nbytes)
        return span

    def matmul_at(self, t_ready: float, tokens: int, d_in: int, d_out: int,
                  bits: int) -> Tuple[float, float]:
        """Expert (or dense) matmul whose weights are available at
        ``t_ready``.  Time the compute channel sat idle waiting for that
        data is charged to ``io_stall_s``."""
        sysspec = self.system
        ops = 2.0 * tokens * d_in * d_out
        native = sysspec.compute.native_precision_bits
        speedup = max(1.0, native / max(bits, 1))
        dur = ops / (sysspec.compute.peak_ops_per_s * speedup)
        self.compute_ops += ops
        self.n_matmuls += 1
        self.compute_latency_s += dur
        # Energy scales with switched bit-width on a bit-sliced PE array.
        self.compute_energy_j += (
            sysspec.compute.energy_j_per_op * ops * (min(bits, native) / native)
        )
        self.io_stall_s += max(0.0, t_ready - self.compute_ch.busy_until)
        span = self.compute_ch.issue(t_ready, dur)
        if self.tracer is not None:
            self.tracer.emit("matmul", "compute", self.shard_id,
                             span[0], span[1], ops=ops, bits=bits)
        return span

    def _ici_issue(self, t_ready: float, nbytes: float,
                   kind: str) -> Tuple[float, float]:
        tier = self.system.interconnect or self.system.dram
        self.ici_bytes += nbytes
        self.n_ici_transfers += 1
        dur = tier.transfer_latency_s(nbytes)
        self.ici_latency_s += dur
        self.ici_energy_j += tier.transfer_energy_j(nbytes)
        span = self.ici_ch.issue(t_ready, dur)
        if self.tracer is not None:
            self.tracer.emit(kind, "ici", self.shard_id,
                             span[0], span[1], nbytes=nbytes)
        return span

    def ici_transfer_at(self, t_ready: float,
                        nbytes: float) -> Tuple[float, float]:
        """Shard-to-shard transfer (all-to-all token dispatch + combine)
        on the interconnect channel.  Uses the system's ``interconnect``
        tier; falls back to the DRAM tier's rates when the profile
        defines none (single-device profiles never issue these)."""
        return self._ici_issue(t_ready, nbytes, "a2a")

    def ici_transfer(self, nbytes: float) -> None:
        """Serialized-issue interconnect transfer (blocking)."""
        self.ici_transfer_at(self._io_ready(), nbytes)

    def migrate_at(self, t_ready: float, nbytes: float) -> Tuple[float, float]:
        """One expert slice moved shard-to-shard by placement
        re-packing: full interconnect latency + energy for the slice
        bytes, tagged in ``migration_bytes`` / ``n_migrations`` so the
        benefit of a placement can be judged against what moving to it
        cost."""
        self.migration_bytes += nbytes
        self.n_migrations += 1
        return self._ici_issue(t_ready, nbytes, "migrate")

    def migrate(self, nbytes: float) -> None:
        """Serialized-issue migration transfer (blocking)."""
        self.migrate_at(self._io_ready(), nbytes)

    def mark_prefetch_wasted(self, nbytes: float) -> None:
        """Attribute an already-charged prefetch fill as wasted: the
        predicted slice was never demanded by (or landed too late for)
        its consuming layer.  Informational — the Flash read + DRAM write
        energy was spent at issue time and stays spent."""
        sysspec = self.system
        self.prefetch_wasted_energy_j += (
            sysspec.flash.transfer_energy_j(nbytes)
            + sysspec.dram.transfer_energy_j(nbytes))

    # ---------------------------------------- serialized (legacy) events
    def miss_fill(self, nbytes: float, *, prefetch: bool = False) -> None:
        """Flash -> DRAM fill caused by a slice miss (blocking issue);
        ``prefetch`` tags speculative fills in the traffic counters."""
        self.fill_at(self._io_ready(), nbytes, prefetch=prefetch)

    def flash_stream(self, nbytes: float) -> None:
        """Direct Flash -> XPU stream for a dropped fill (blocking)."""
        self.flash_stream_at(self._io_ready(), nbytes)

    def dram_read(self, nbytes: float) -> None:
        """DRAM -> XPU weight fetch (hit path or post-fill use)."""
        self.dram_read_at(self._io_ready(), nbytes)

    def matmul(self, tokens: int, d_in: int, d_out: int, bits: int) -> None:
        """Expert (or dense) matmul at the given weight precision."""
        t_ready = self._compute_ready()
        # Serialized issue is a modeling choice, not a data dependency —
        # don't let it masquerade as IO stall.
        stall0 = self.io_stall_s
        self.matmul_at(t_ready, tokens, d_in, d_out, bits)
        self.io_stall_s = stall0

    # -------------------------------------------------------------- summary
    @property
    def io_latency_s(self) -> float:
        return self.flash_latency_s + self.dram_latency_s \
            + self.ici_latency_s

    @property
    def serial_latency_s(self) -> float:
        """What a fully serialized replay of the same events would take."""
        return self.io_latency_s + self.compute_latency_s

    @property
    def total_latency_s(self) -> float:
        """Timeline makespan.  Equals ``serial_latency_s`` when every
        event was issued through the serialized methods (no overlap)."""
        return self.now

    @property
    def overlap_saved_s(self) -> float:
        """Latency hidden by channel overlap (0 when fully serialized)."""
        return max(0.0, self.serial_latency_s - self.total_latency_s)

    @property
    def total_energy_j(self) -> float:
        return self.flash_energy_j + self.dram_energy_j \
            + self.compute_energy_j + self.ici_energy_j

    def snapshot(self) -> dict:
        return {
            "flash_bytes": self.flash_bytes,
            "dram_bytes": self.dram_bytes,
            "compute_ops": self.compute_ops,
            "flash_latency_s": self.flash_latency_s,
            "dram_latency_s": self.dram_latency_s,
            "compute_latency_s": self.compute_latency_s,
            "total_latency_s": self.total_latency_s,
            "serial_latency_s": self.serial_latency_s,
            "overlap_saved_s": self.overlap_saved_s,
            "io_stall_s": self.io_stall_s,
            "flash_busy_s": self.flash_ch.busy_s,
            "dram_busy_s": self.dram_ch.busy_s,
            "compute_busy_s": self.compute_ch.busy_s,
            "ici_busy_s": self.ici_ch.busy_s,
            "flash_energy_j": self.flash_energy_j,
            "dram_energy_j": self.dram_energy_j,
            "compute_energy_j": self.compute_energy_j,
            "total_energy_j": self.total_energy_j,
            "n_flash_transfers": self.n_flash_transfers,
            "n_dram_transfers": self.n_dram_transfers,
            "n_matmuls": self.n_matmuls,
            "n_prefetch_fills": self.n_prefetch_fills,
            "prefetch_flash_bytes": self.prefetch_flash_bytes,
            "prefetch_wasted_energy_j": self.prefetch_wasted_energy_j,
            "ici_bytes": self.ici_bytes,
            "ici_latency_s": self.ici_latency_s,
            "ici_energy_j": self.ici_energy_j,
            "n_ici_transfers": self.n_ici_transfers,
            "migration_bytes": self.migration_bytes,
            "n_migrations": self.n_migrations,
        }

    def clone(self) -> "CostLedger":
        """Deep copy of the full ledger (accumulators + channel clocks).

        Lets the replay simulator fork a timeline mid-trace: the clone
        continues issuing events independently of the original, so two
        futures of the same simulated past can be compared.  Any
        attached tracer stays with the original — forked hypothetical
        timelines must not interleave events into a real capture."""
        import copy

        tracer, self.tracer = self.tracer, None
        try:
            return copy.deepcopy(self)
        finally:
            self.tracer = tracer

    def delta_since(self, prev: Optional[dict]) -> dict:
        cur = self.snapshot()
        if prev is None:
            return cur
        return {k: cur[k] - prev.get(k, 0.0) for k in cur}

    def reset(self) -> None:
        for f in (
            "flash_bytes", "dram_bytes", "compute_ops",
            "flash_latency_s", "dram_latency_s", "compute_latency_s",
            "flash_energy_j", "dram_energy_j", "compute_energy_j",
            "io_stall_s", "prefetch_flash_bytes",
            "prefetch_wasted_energy_j",
            "ici_bytes", "ici_latency_s", "ici_energy_j",
            "migration_bytes",
        ):
            setattr(self, f, 0.0)
        self.n_flash_transfers = 0
        self.n_dram_transfers = 0
        self.n_matmuls = 0
        self.n_prefetch_fills = 0
        self.n_ici_transfers = 0
        self.n_migrations = 0
        for ch in (self.flash_ch, self.dram_ch, self.compute_ch,
                   self.flash_bg_ch, self.ici_ch):
            ch.reset()


class ShardedCostLedger:
    """Expert-parallel cost ledger: one :class:`CostLedger` per shard
    plus a shared interconnect sub-ledger for all-to-all token dispatch.

    Each shard carries its own Flash/DRAM/XPU channel clocks, so the
    per-step latency of an expert-parallel decode is the *max* over the
    shard timelines (shards progress independently) rather than the sum
    a single-device timeline would charge — that makespan semantics is
    the whole point of EP sharding in this cost model.  Energy and
    traffic accumulators simply sum across shards (energy is
    time-independent; partitioning hides latency, it does not un-spend
    joules), and the all-to-all bytes/energy live on the interconnect
    sub-ledger's ``ici_*`` accumulators.

    The aggregate exposes the same read API the engine, scheduler and
    benchmarks use on a plain :class:`CostLedger` (``snapshot`` /
    ``delta_since`` / ``total_latency_s`` / ``total_energy_j`` / ...);
    write traffic goes to the per-shard ledgers via :attr:`shards` (the
    engine routes each expert's events to its owning shard) and to
    :meth:`ici_transfer` / :meth:`ici_transfer_at` for dispatch bytes.
    With one shard and no interconnect events every aggregate equals the
    single ledger's value exactly — the ``ep_shards=1`` equivalence the
    fidelity benchmark asserts.
    """

    def __init__(self, system: SystemSpec, n_shards: int):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.system = system
        self.n_shards = int(n_shards)
        self.shards = [CostLedger(system=system, shard_id=sid)
                       for sid in range(self.n_shards)]
        # Dedicated sub-ledger for the shared interconnect channel; its
        # flash/dram/compute channels never see an event.
        self.ici = CostLedger(system=system, shard_id=-1)

    # ------------------------------------------------------------ routing
    def shard_for(self, shard: int) -> CostLedger:
        return self.shards[shard]

    def ici_transfer_at(self, t_ready: float, nbytes: float):
        return self.ici.ici_transfer_at(t_ready, nbytes)

    def ici_transfer(self, nbytes: float) -> None:
        self.ici.ici_transfer(nbytes)

    def migrate_at(self, t_ready: float, nbytes: float):
        return self.ici.migrate_at(t_ready, nbytes)

    def migrate(self, nbytes: float) -> None:
        self.ici.migrate(nbytes)

    # ------------------------------------------------------ observability
    @property
    def tracer(self):
        return self.shards[0].tracer

    def attach_tracer(self, tracer) -> None:
        """Point every shard ledger (and the interconnect sub-ledger) at
        one shared event sink; shard ids stamp the per-shard channel
        tracks, the interconnect gets shard id -1.  ``None`` detaches."""
        for sid, led in enumerate(self.shards):
            led.tracer = tracer
            led.shard_id = sid
        self.ici.tracer = tracer
        self.ici.shard_id = -1

    # ----------------------------------------------------------- timeline
    @property
    def now(self) -> float:
        """Makespan frontier: the latest completion over every shard's
        channels and the interconnect."""
        return max([led.now for led in self.shards] + [self.ici.now])

    def compute_frontier(self) -> float:
        """Latest compute-channel completion across shards — the instant
        a step's (globally synchronized) routing can be derived."""
        return max(led.compute_ch.busy_until for led in self.shards)

    # ------------------------------------------------------------ summary
    @property
    def total_latency_s(self) -> float:
        return self.now

    @property
    def serial_latency_s(self) -> float:
        """What a fully serialized single-device replay of every shard's
        events (plus the dispatch traffic) would take."""
        return sum(led.serial_latency_s for led in self.shards) \
            + self.ici.ici_latency_s

    @property
    def overlap_saved_s(self) -> float:
        return max(0.0, self.serial_latency_s - self.total_latency_s)

    @property
    def total_energy_j(self) -> float:
        return sum(led.total_energy_j for led in self.shards) \
            + self.ici.total_energy_j

    @property
    def prefetch_wasted_energy_j(self) -> float:
        return sum(led.prefetch_wasted_energy_j for led in self.shards)

    @property
    def migration_bytes(self) -> float:
        return self.ici.migration_bytes \
            + sum(led.migration_bytes for led in self.shards)

    @property
    def n_migrations(self) -> int:
        return self.ici.n_migrations \
            + sum(led.n_migrations for led in self.shards)

    @property
    def io_stall_s(self) -> float:
        return sum(led.io_stall_s for led in self.shards)

    def snapshot(self) -> dict:
        """Aggregate snapshot: accumulators summed across shards (and the
        interconnect), makespan-derived fields recomputed from the
        aggregate timelines."""
        out = self.shards[0].snapshot()
        # The ici sub-ledger's flash/dram/compute accumulators are always
        # zero, so folding its full snapshot in adds only the ici_* keys.
        for led in self.shards[1:] + [self.ici]:
            snap = led.snapshot()
            for k in out:
                out[k] += snap[k]
        out["total_latency_s"] = self.total_latency_s
        out["serial_latency_s"] = self.serial_latency_s
        out["overlap_saved_s"] = self.overlap_saved_s
        return out

    def per_shard_snapshots(self) -> list:
        return [led.snapshot() for led in self.shards]

    def delta_since(self, prev: Optional[dict]) -> dict:
        cur = self.snapshot()
        if prev is None:
            return cur
        return {k: cur[k] - prev.get(k, 0.0) for k in cur}

    def clone(self) -> "ShardedCostLedger":
        import copy

        tracer = self.tracer
        self.attach_tracer(None)
        try:
            new = copy.deepcopy(self)
        finally:
            if tracer is not None:
                self.attach_tracer(tracer)
        return new

    def reset(self) -> None:
        for led in self.shards:
            led.reset()
        self.ici.reset()
