"""Deterministic latency / energy accounting for the offload hierarchy.

This is the cost model behind the paper's Figs. 9-10: every expert-slice
transfer (Flash→DRAM on a miss, DRAM→XPU on use) and every expert matmul is
accounted against the active :class:`~repro.hw.specs.SystemSpec`.

The model is intentionally simple and auditable:

* a *miss* on a slice of ``nbytes`` costs one Flash read (latency + energy)
  plus one DRAM write,
* a *hit* (or post-fill use) costs one DRAM read into the XPU,
* expert compute costs ``2 * tokens * d_in * d_out`` MAC-ops per matmul at
  the XPU's int8 throughput; low-bit (MSB-only) compute gets a throughput
  multiplier ``8 / bits`` reflecting the bit-serial/sliced PE design of the
  paper's XPU,
* DRAM and Flash transfers overlap compute only when
  ``overlap_io_compute`` is set (the paper's decode phase is
  bandwidth-bound, i.e. serialized on misses; prefill overlaps).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.hw.specs import SystemSpec, MOBILE_SOC


def expert_weight_step_bytes(n_codes: float, n_groups: float, *,
                             quant_execution: bool,
                             dense_itemsize: int = 4) -> float:
    """HBM bytes one expert-FFN step moves for its weights (analytic).

    The batched expert FFN touches every expert's weights each step.
    Codes are uint8 (1 B/element); group metadata is an f32 scale + a
    uint8 zero-point (5 B/group), read by both paths.  Dense dequant
    additionally writes and re-reads the materialized dense tensor at
    ``dense_itemsize`` bytes/element (4 for f32, 2 for bf16 — pass the
    model dtype's width); quantized execution streams only the packed
    codes.  This is a *model* of the traffic, shared by the engine and
    the benchmarks so their persisted baselines can't diverge — it is
    not a runtime measurement.
    """
    meta = n_groups * 5.0
    if quant_execution:
        return n_codes * 1.0 + meta
    return n_codes * (1.0 + 2.0 * dense_itemsize) + meta


@dataclasses.dataclass
class CostLedger:
    """Accumulates latency and energy over a simulated inference run."""

    system: SystemSpec = dataclasses.field(default_factory=lambda: MOBILE_SOC)
    overlap_io_compute: bool = False

    # accumulators
    flash_bytes: float = 0.0
    dram_bytes: float = 0.0
    compute_ops: float = 0.0
    flash_latency_s: float = 0.0
    dram_latency_s: float = 0.0
    compute_latency_s: float = 0.0
    flash_energy_j: float = 0.0
    dram_energy_j: float = 0.0
    compute_energy_j: float = 0.0
    n_flash_transfers: int = 0
    n_dram_transfers: int = 0

    # ---------------------------------------------------------------- events
    def miss_fill(self, nbytes: float) -> None:
        """Flash -> DRAM fill caused by a slice miss."""
        sysspec = self.system
        self.flash_bytes += nbytes
        self.n_flash_transfers += 1
        self.flash_latency_s += sysspec.flash.transfer_latency_s(nbytes)
        # Flash read + DRAM write energy.
        self.flash_energy_j += sysspec.flash.transfer_energy_j(nbytes)
        self.dram_energy_j += sysspec.dram.transfer_energy_j(nbytes)

    def dram_read(self, nbytes: float) -> None:
        """DRAM -> XPU weight fetch (hit path or post-fill use)."""
        sysspec = self.system
        self.dram_bytes += nbytes
        self.n_dram_transfers += 1
        self.dram_latency_s += sysspec.dram.transfer_latency_s(nbytes)
        self.dram_energy_j += sysspec.dram.transfer_energy_j(nbytes)

    def matmul(self, tokens: int, d_in: int, d_out: int, bits: int) -> None:
        """Expert (or dense) matmul at the given weight precision."""
        sysspec = self.system
        ops = 2.0 * tokens * d_in * d_out
        native = sysspec.compute.native_precision_bits
        speedup = max(1.0, native / max(bits, 1))
        self.compute_ops += ops
        self.compute_latency_s += ops / (sysspec.compute.peak_ops_per_s * speedup)
        # Energy scales with switched bit-width on a bit-sliced PE array.
        self.compute_energy_j += (
            sysspec.compute.energy_j_per_op * ops * (min(bits, native) / native)
        )

    # -------------------------------------------------------------- summary
    @property
    def io_latency_s(self) -> float:
        return self.flash_latency_s + self.dram_latency_s

    @property
    def total_latency_s(self) -> float:
        if self.overlap_io_compute:
            return max(self.io_latency_s, self.compute_latency_s)
        return self.io_latency_s + self.compute_latency_s

    @property
    def total_energy_j(self) -> float:
        return self.flash_energy_j + self.dram_energy_j + self.compute_energy_j

    def snapshot(self) -> dict:
        return {
            "flash_bytes": self.flash_bytes,
            "dram_bytes": self.dram_bytes,
            "compute_ops": self.compute_ops,
            "flash_latency_s": self.flash_latency_s,
            "dram_latency_s": self.dram_latency_s,
            "compute_latency_s": self.compute_latency_s,
            "total_latency_s": self.total_latency_s,
            "flash_energy_j": self.flash_energy_j,
            "dram_energy_j": self.dram_energy_j,
            "compute_energy_j": self.compute_energy_j,
            "total_energy_j": self.total_energy_j,
            "n_flash_transfers": self.n_flash_transfers,
            "n_dram_transfers": self.n_dram_transfers,
        }

    def delta_since(self, prev: Optional[dict]) -> dict:
        cur = self.snapshot()
        if prev is None:
            return cur
        return {k: cur[k] - prev[k] for k in cur}

    def reset(self) -> None:
        for f in (
            "flash_bytes", "dram_bytes", "compute_ops",
            "flash_latency_s", "dram_latency_s", "compute_latency_s",
            "flash_energy_j", "dram_energy_j", "compute_energy_j",
        ):
            setattr(self, f, 0.0)
        self.n_flash_transfers = 0
        self.n_dram_transfers = 0
