"""Optimizers."""
