"""AdamW optimizer + schedules, pure JAX (no optax dependency).

Mixed-precision convention: model params may be bf16; the optimizer keeps
f32 first/second moments and (optionally) an f32 master copy, applying
updates in f32 and casting back to the param dtype.  States shard exactly
like their parameters (the sharding rules treat the optimizer pytree as
three more copies of the param tree).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"        # 'cosine' | 'linear' | 'constant'
    master_f32: bool = True


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict
    master: Optional[dict]


def init_state(params: dict, cfg: AdamWConfig) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # NB: force a copy — for leaves already in f32, ``astype`` aliases the
    # param buffer, which breaks donation (same buffer donated twice).
    master = jax.tree_util.tree_map(
        lambda p: jnp.array(p, jnp.float32, copy=True), params) \
        if cfg.master_f32 else None
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=zeros,
                      nu=jax.tree_util.tree_map(jnp.copy, zeros),
                      master=master)


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 1.0 - 0.9 * frac
    else:  # cosine
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def _is_matrix(path: tuple) -> bool:
    """Weight decay applies to matrices only (not norms/bias vectors)."""
    return True   # resolved per-leaf by ndim below


def apply_updates(params: dict, grads: dict, state: AdamWState,
                  cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    lr = schedule_lr(cfg, state.step)
    t = state.step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v, pm):
        g = g.astype(jnp.float32) * clip
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        base = pm if pm is not None else p.astype(jnp.float32)
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:
            step = step + cfg.weight_decay * base
        new_master = base - lr * step
        return new_master.astype(p.dtype), m_new, v_new, new_master

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    flat_pm = jax.tree_util.tree_leaves(state.master) \
        if state.master is not None else [None] * len(flat_p)

    outs = [upd(p, g, m, v, pm) for p, g, m, v, pm
            in zip(flat_p, flat_g, flat_m, flat_v, flat_pm)]
    new_p = tdef.unflatten([o[0] for o in outs])
    new_m = tdef.unflatten([o[1] for o in outs])
    new_v = tdef.unflatten([o[2] for o in outs])
    new_master = tdef.unflatten([o[3] for o in outs]) \
        if state.master is not None else None

    new_state = AdamWState(step=state.step + 1, mu=new_m, nu=new_v,
                           master=new_master)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, new_state, metrics
