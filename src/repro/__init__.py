"""SliceMoE reproduction: bit-sliced expert caching under miss-rate
constraints, grown into a continuous-batching serving system on JAX."""

__version__ = "0.2.0"
