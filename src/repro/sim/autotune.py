"""Offline policy autotuner over recorded / synthetic routing traces.

Sweeps engine-policy knobs (cache capacity, AMAT bit plans, slice mode,
warmup policy, ``lsb_keep_frac``, prefetch, async timeline, controller
target, expert placement — ``placement`` / ``placement_period`` /
``replicate_k`` combined with ``ep_shards``) by replaying one trace per
candidate through
:class:`~repro.sim.replay.ReplayEngine` — thousands of policy points per
minute instead of one live run per point.  Outputs the
energy/latency/miss Pareto frontier and the cheapest configuration
meeting a miss-rate SLO.

Two search modes:

* :func:`sweep` — evaluate every candidate on the full trace (exact).
* :func:`sweep` with ``successive_halving=True`` — evaluate all
  candidates on a trace prefix, keep the best ``1/eta`` fraction, resume
  the survivors (their simulation state is *kept*, not recomputed) on a
  longer prefix, repeat until the survivors finish the trace.  Losers
  report partial metrics (``partial=True``).

Candidate encoding: a dict of ``TraceMeta.engine`` knob overrides (see
:func:`repro.sim.replay.engine_config_from_meta`); :func:`grid` builds a
cartesian product of axes.  The empty dict is the recorded/default
config — always include it so "better than default" claims are measured
on the same replay, not against live numbers.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.sim.replay import ReplayEngine, ReplayReport
from repro.sim.trace import Trace

__all__ = ["TuneResult", "grid", "evaluate", "sweep", "pareto_frontier",
           "best_under_slo", "format_results"]

Policy = Union[Dict[str, Any], Tuple[str, Dict[str, Any]]]


@dataclasses.dataclass
class TuneResult:
    """One policy point's replayed cost/quality coordinates."""

    name: str
    overrides: Dict[str, Any]
    miss_rate: float               # decode-phase expert-access miss rate
    energy_j: float
    latency_s: float
    steps_per_s: float
    events_consumed: int
    partial: bool = False          # eliminated before finishing the trace
    report: Optional[ReplayReport] = None

    def meets_slo(self, miss_slo: float) -> bool:
        return not self.partial and self.miss_rate <= miss_slo

    def row(self) -> dict:
        return {
            "name": self.name, "overrides": self.overrides,
            "miss_rate": self.miss_rate, "energy_j": self.energy_j,
            "latency_s": self.latency_s,
            "steps_per_s": self.steps_per_s, "partial": self.partial,
        }


def _auto_name(overrides: Dict[str, Any]) -> str:
    if not overrides:
        return "default"
    return ",".join(f"{k}={v}" for k, v in sorted(overrides.items()))


def _normalize(policies: Sequence[Policy]) -> List[Tuple[str, dict]]:
    out = []
    for p in policies:
        if isinstance(p, dict):
            out.append((_auto_name(p), p))
        else:
            name, ov = p
            out.append((name, dict(ov)))
    return out


def grid(**axes: Sequence[Any]) -> List[Dict[str, Any]]:
    """Cartesian product of knob axes as override dicts.

    >>> from repro.sim.autotune import grid
    >>> grid(cache_bytes=[1e6, 2e6], warmup=["pcw", "empty"])[0]
    {'cache_bytes': 1000000.0, 'warmup': 'pcw'}
    """
    keys = list(axes)
    return [dict(zip(keys, combo))
            for combo in itertools.product(*(axes[k] for k in keys))]


def _result(name: str, overrides: dict, engine: ReplayEngine,
            consumed: int, *, partial: bool) -> TuneResult:
    report = engine.report() if partial else engine.finish()
    return TuneResult(
        name=name, overrides=dict(overrides),
        miss_rate=report.decode_miss_rate,
        energy_j=report.total_energy_j,
        latency_s=report.total_latency_s,
        steps_per_s=report.steps_per_s,
        events_consumed=consumed, partial=partial, report=report)


def evaluate(trace: Trace, overrides: Optional[dict] = None,
             name: Optional[str] = None) -> TuneResult:
    """Replay the full trace under one policy point."""
    overrides = dict(overrides or {})
    eng = ReplayEngine(trace.meta, **overrides)
    eng.consume_all(trace.events)
    return _result(name or _auto_name(overrides), overrides, eng,
                   len(trace.events), partial=False)


def sweep(trace: Trace, policies: Sequence[Policy], *,
          miss_slo: Optional[float] = None,
          successive_halving: bool = False, eta: int = 2,
          min_frac: float = 0.25) -> List[TuneResult]:
    """Evaluate every policy point; optionally successive-halving.

    With ``successive_halving``, rung ``i`` extends each surviving
    candidate's replay to a ``min_frac * eta**i`` fraction of the trace,
    then keeps the best ``ceil(n/eta)`` by (SLO violation, energy so
    far).  Survivor state is resumed, never recomputed — the rung cost
    is only the *new* events.
    """
    named = _normalize(policies)
    if not successive_halving:
        return [evaluate(trace, ov, name) for name, ov in named]

    n = len(trace.events)
    fracs: List[float] = []
    f = min(max(min_frac, 1e-9), 1.0)
    while f < 1.0:
        fracs.append(f)
        f *= eta
    fracs.append(1.0)

    alive = [{"name": name, "ov": ov,
              "engine": ReplayEngine(trace.meta, **ov), "pos": 0}
             for name, ov in named]
    results: List[TuneResult] = []
    for frac in fracs:
        upto = min(n, math.ceil(frac * n))
        for s in alive:
            s["engine"].consume_all(trace.events[s["pos"]:upto])
            s["pos"] = upto
        if frac >= 1.0:
            break
        keep = max(1, math.ceil(len(alive) / eta))
        if keep >= len(alive):
            continue

        def score(s):
            eng = s["engine"]
            miss = eng._decode_misses / max(eng._decode_accesses, 1)
            violated = miss_slo is not None and miss > miss_slo
            return (violated, eng.ledger.total_energy_j)

        alive.sort(key=score)
        for s in alive[keep:]:
            results.append(_result(s["name"], s["ov"], s["engine"],
                                   s["pos"], partial=True))
        alive = alive[:keep]
    for s in alive:
        results.append(_result(s["name"], s["ov"], s["engine"],
                               s["pos"], partial=False))
    return results


def pareto_frontier(results: Sequence[TuneResult],
                    *, objectives: Tuple[str, ...] = (
                        "energy_j", "latency_s", "miss_rate")
                    ) -> List[TuneResult]:
    """Non-dominated subset (all objectives minimized), stable order.

    Partial results are excluded: their metrics cover a trace prefix and
    are not comparable to full replays.
    """
    full = [r for r in results if not r.partial]

    def dominates(a: TuneResult, b: TuneResult) -> bool:
        av = [getattr(a, o) for o in objectives]
        bv = [getattr(b, o) for o in objectives]
        return all(x <= y for x, y in zip(av, bv)) and \
            any(x < y for x, y in zip(av, bv))

    return [r for r in full
            if not any(dominates(o, r) for o in full if o is not r)]


def best_under_slo(results: Sequence[TuneResult],
                   miss_slo: float) -> Optional[TuneResult]:
    """Cheapest-energy full result meeting the miss-rate SLO."""
    ok = [r for r in results if r.meets_slo(miss_slo)]
    return min(ok, key=lambda r: r.energy_j) if ok else None


def format_results(results: Sequence[TuneResult], *,
                   miss_slo: Optional[float] = None,
                   title: str = "autotune sweep") -> str:
    """Human-readable sweep table (sorted by energy, partials last)."""
    lines = [f"--- {title} ---",
             f"{'config':44s} {'miss%':>6s} {'energy mJ':>10s} "
             f"{'latency ms':>11s} {'steps/s':>9s}"]
    frontier = {id(r) for r in pareto_frontier(results)}
    for r in sorted(results, key=lambda r: (r.partial, r.energy_j)):
        flags = ""
        if id(r) in frontier:
            flags += "*"
        if miss_slo is not None and r.meets_slo(miss_slo):
            flags += "S"
        if r.partial:
            flags += "p"
        lines.append(
            f"{r.name[:42]:42s} {flags:2s} {r.miss_rate * 100:5.1f} "
            f"{r.energy_j * 1e3:10.3f} {r.latency_s * 1e3:11.3f} "
            f"{r.steps_per_s:9.0f}")
    lines.append("(* = Pareto frontier"
                 + (", S = meets SLO" if miss_slo is not None else "")
                 + ", p = eliminated early)")
    return "\n".join(lines)
