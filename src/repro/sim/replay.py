"""Model-free trace replay: the live engine's charge path, minus JAX.

:class:`ReplayEngine` subclasses :class:`~repro.core.engine.PersistentEngine`
but never builds params, jitted functions or a KV cache — it rebuilds
only the state the charge path touches (``SliceCache``,
``HotnessTracker``, ``CostLedger``, the configured prefetcher, the slice
byte-size store) from a :class:`~repro.sim.trace.TraceMeta`, then feeds
recorded/synthetic routing events through the *inherited*
``_charge_prefill`` / ``charge_step_trace`` methods.  Because those are
byte-for-byte the code the live engine runs, a replay under the recorded
config reproduces the live run's per-epoch miss counts exactly and its
energy/latency bit-for-bit — while running orders of magnitude faster
(no forward pass), which is what makes policy sweeps tractable
(:mod:`repro.sim.autotune`).

What a replay can and cannot vary (documented in docs/simulation.md):

* **faithful counterfactuals** — cache capacity, AMAT bit plan (slice
  bytes are recomputed from the recorded weight shapes), slice mode,
  warmup policy, ``lsb_keep_frac``, fused slices, prefetch on/off/top-m,
  serialized vs async timeline, system profile: these only change how
  the *fixed* routing stream is charged, exactly as they would have on
  the live engine had routing not shifted;
* **open-loop only** — knobs that feed back into routing (Cache-Prior
  ``alpha`` via the miss-rate controller, routing kind) cannot bend the
  recorded expert choices.  The replay still runs the controller and
  reports its ``alpha`` trajectory / SLO attainment against the replayed
  miss curve, but the routing stays the trace's.
"""

from __future__ import annotations

import dataclasses
import time
from types import SimpleNamespace
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.amat import MatConfig, slice_nbytes
from repro.core.engine import EngineConfig, PersistentEngine, _StepTrace
from repro.core.slices import SliceKey
from repro.core.warmup import HotnessTracker
from repro.hw.specs import SYSTEM_PROFILES
from repro.models.moe import RoutingPolicy
from repro.sim.trace import Trace, TraceMeta

__all__ = ["TraceSliceStore", "engine_config_from_meta", "ReplayEngine",
           "ReplayReport", "replay_trace"]


class TraceSliceStore:
    """Byte-size stand-in for :class:`~repro.core.slices.ExpertSliceStore`.

    Rebuilt from trace metadata for *any* AMAT bit plan: slice bytes come
    from the same :func:`~repro.core.amat.slice_nbytes` on the same
    per-expert code shapes the live store used, so byte accounting is
    identical — without holding a single weight.
    """

    def __init__(self, meta: TraceMeta, mat: MatConfig):
        self.mat = mat
        self.n_experts = meta.n_experts
        # pcw/init_* only need the flat layer keys, not weights
        self.layers: Dict[int, None] = {
            l: None for l in range(meta.n_moe_layers)}
        shapes = (meta.wi_shape, meta.wo_shape)
        self.msb_bytes_per_expert = sum(
            slice_nbytes(s, mat.high_bits, mat.group_size,
                         which="msb", shift=mat.shift) for s in shapes)
        self.lsb_bytes_per_expert = sum(
            slice_nbytes(s, mat.high_bits, mat.group_size,
                         which="lsb", shift=mat.shift) for s in shapes)

    def slice_bytes(self, key: SliceKey) -> float:
        return (self.msb_bytes_per_expert if key.kind == "msb"
                else self.lsb_bytes_per_expert)

    def highbit_expert_bytes(self) -> float:
        return self.msb_bytes_per_expert + self.lsb_bytes_per_expert

    def total_bytes(self) -> float:
        return self.highbit_expert_bytes() * len(self.layers) \
            * self.n_experts

    def all_keys(self):
        for lidx in self.layers:
            for e in range(self.n_experts):
                yield SliceKey(lidx, e, "msb")
                yield SliceKey(lidx, e, "lsb")


def engine_config_from_meta(meta: TraceMeta, **overrides) -> EngineConfig:
    """The recorded EngineConfig, with autotuner-style overrides.

    Override keys are the flat ``TraceMeta.engine`` knob names
    (``cache_bytes``, ``high_bits``, ``low_bits``, ``slice_mode``,
    ``warmup``, ``prefetch_top_m``, ``async_io``, ``ep_shards``, ...).
    Unknown keys raise, so a sweep axis typo can't silently evaluate the
    default.  ``ep_shards`` is sweepable on *any* trace — including one
    recorded before the knob existed (it defaults to 1) — because expert
    placement is a pure function of the expert ids the trace already
    carries.
    """
    e = dict(meta.engine)
    e.setdefault("ep_shards", 1)    # traces recorded before EP existed
    e.setdefault("prefetch_min_obs", 0)   # pre-confidence-floor traces
    e.setdefault("controller", None)      # pre-controller traces
    # Traces recorded before the request-level predictor existed carry
    # no kind: they ran (and must replay as) the transition baseline.
    e.setdefault("prefetch_kind", "transition")
    e.setdefault("prefetch_lookahead", 2)
    e.setdefault("prefetch_min_score", 0.02)
    # Traces recorded before placement was a policy ran the implicit
    # round-robin modulo; replay them under the same table.
    e.setdefault("placement", "round_robin")
    e.setdefault("placement_period", 64)
    e.setdefault("replicate_k", 0)
    unknown = set(overrides) - set(e)
    if unknown:
        raise KeyError(f"unknown engine override(s) {sorted(unknown)}; "
                       f"valid knobs: {sorted(e)}")
    e.update(overrides)
    ctl = e["controller"]
    if ctl is not None and not hasattr(ctl, "slos"):
        from repro.control.controller import ControllerConfig
        ctl = ControllerConfig.from_dict(ctl)
    return EngineConfig(
        mat=MatConfig(int(e["high_bits"]), int(e["low_bits"]),
                      meta.group_size),
        cache_bytes=float(e["cache_bytes"]),
        policy=RoutingPolicy(
            kind=e["policy_kind"], slice_mode=e["slice_mode"],
            theta=float(e["theta"]),
            fetch_lsb_on_miss=bool(e["fetch_lsb_on_miss"])),
        miss_rate_target=e["miss_rate_target"],
        warmup=e["warmup"],
        lsb_keep_frac=float(e["lsb_keep_frac"]),
        system=e["system"],
        fused_slices=bool(e["fused_slices"]),
        prefetch_top_m=e["prefetch_top_m"],
        async_io=bool(e["async_io"]),
        hotness_request_decay=float(e["hotness_request_decay"]),
        ep_shards=int(e["ep_shards"]),
        prefetch_min_obs=int(e["prefetch_min_obs"]),
        prefetch_kind=str(e["prefetch_kind"]),
        prefetch_lookahead=int(e["prefetch_lookahead"]),
        prefetch_min_score=float(e["prefetch_min_score"]),
        controller=ctl,
        placement=str(e["placement"]),
        placement_period=int(e["placement_period"]),
        replicate_k=int(e["replicate_k"]),
    )


@dataclasses.dataclass
class ReplayReport:
    """Everything a replayed trace yields, mirroring live telemetry."""

    n_prefills: int
    n_decode_steps: int
    miss_curve: List[float]            # fleet miss rate per decode step
    energy_curve: List[float]          # ledger energy delta per step
    decode_accesses: int
    decode_misses: int
    epoch_miss: List[Tuple[str, float]]
    epoch_counts: List[Tuple[str, int, int]]
    ledger: dict                       # final CostLedger.snapshot()
    prefetch: Optional[dict]
    alpha_curve: List[float]
    wall_s: float                      # host time, all events
    decode_wall_s: float               # host time, decode events only
    # Expert-parallel replays only: per-shard [(label, accesses, misses)]
    # epoch windows (None on single-device replays).
    per_shard_epoch_counts: Optional[list] = None
    # Controller / tenant-attributed replays only: one
    # ``StepCharge.per_tenant`` dict per decode step (None otherwise),
    # plus the final controller summary.
    per_tenant_rows: Optional[List[dict]] = None
    controller_summary: Optional[dict] = None
    # Placement-policy replays only: the migration event sequence
    # ([{step, moved, bytes}]) and the final placement summary — what
    # the live-vs-replay placement fidelity gate compares exactly.
    migration_events: Optional[List[dict]] = None
    placement: Optional[dict] = None

    @property
    def decode_miss_rate(self) -> float:
        return self.decode_misses / max(self.decode_accesses, 1)

    @property
    def total_energy_j(self) -> float:
        return self.ledger["total_energy_j"]

    @property
    def total_latency_s(self) -> float:
        return self.ledger["total_latency_s"]

    @property
    def steps_per_s(self) -> float:
        """Decode replay rate: decode steps over decode-event host time
        (prefill replay time is excluded — it has its own counter)."""
        return self.n_decode_steps / self.decode_wall_s \
            if self.decode_wall_s > 0 else float("inf")

    def summary(self) -> dict:
        return {
            "n_prefills": self.n_prefills,
            "n_decode_steps": self.n_decode_steps,
            "decode_miss_rate": self.decode_miss_rate,
            "total_energy_j": self.total_energy_j,
            "total_latency_s": self.total_latency_s,
            "replay_steps_per_s": self.steps_per_s,
            "alpha_final": self.alpha_curve[-1] if self.alpha_curve
            else 0.0,
            **({"prefetch": self.prefetch} if self.prefetch else {}),
            **({"controller": self.controller_summary}
               if self.controller_summary else {}),
        }


class ReplayEngine(PersistentEngine):
    """Trace-driven :class:`PersistentEngine`: same charge path, no model.

    Construct from a trace's metadata (plus optional config overrides),
    then :meth:`consume` events in order — or use the one-shot
    :func:`replay_trace`.  The live-only entry points (``run_prefill``,
    ``decode_batch``) are disabled.
    """

    def __init__(self, meta: TraceMeta,
                 ecfg: Optional[EngineConfig] = None, **overrides):
        # Deliberately no super().__init__: that path quantizes params
        # and jit-compiles the model.  Rebuild only the charge state.
        if ecfg is None:
            ecfg = engine_config_from_meta(meta, **overrides)
        elif overrides:
            raise ValueError("pass either ecfg or overrides, not both")
        self.meta = meta
        self.cfg = SimpleNamespace(name=meta.model, d_model=meta.d_model,
                                   n_periods=meta.n_periods)
        self.ecfg = ecfg
        self.store = TraceSliceStore(meta, ecfg.mat)
        self.layer_map = meta.layer_map()
        self.moe_positions = list(meta.moe_positions)
        self.n_moe_layers = meta.n_moe_layers
        self.n_experts = meta.n_experts
        self.resident_bytes = meta.resident_bytes
        self.expert_macs_per_token = meta.expert_macs_per_token

        # Placement must exist before the cache: the sharded cache keys
        # slice ownership off the map (replay reproduces the live
        # engine's table, not an implicit modulo).
        self.placement_policy = ecfg.build_placement_policy(
            self.n_moe_layers, self.n_experts)
        self.placement = (self.placement_policy.initial()
                          if self.placement_policy is not None else None)
        self._decode_steps = 0
        self.migration_events: List[dict] = []
        self.cache = ecfg.cache(placement=self.placement)
        self.ledger = ecfg.ledger()
        self.tracker = HotnessTracker(self.n_moe_layers, self.n_experts)
        self.requests_served = 0
        self.recorder = None
        # attach_tracer (inherited) wires a TimelineTracer through the
        # same ledgers the live engine uses — replay emits the identical
        # event stream (the live≡replay trace-equivalence gate).
        self.tracer = None
        self.buddies = None
        self.prefetcher = ecfg.build_prefetcher(
            self.n_moe_layers, self.n_experts)
        self._pf_pending = {}

        # Closed-loop SLO controller: its bit/partition decisions consume
        # only charge-path counters, so the replayed decision sequence is
        # identical to the live one (the control-loop fidelity gate).
        self.slo_controller = None
        if ecfg.controller is not None:
            from repro.control.controller import SLOController
            self.slo_controller = SLOController(
                ecfg.controller, cache_bytes=ecfg.cache_bytes)

        # Open-loop controller (see module docstring): tracks what alpha
        # the live controller would command given the replayed miss
        # curve; it cannot bend the recorded routing.
        self.controller = self.new_controller()

        # accumulators
        self.wall_s = 0.0
        self.decode_wall_s = 0.0
        self._n_prefills = 0
        self._miss_curve: List[float] = []
        self._energy_curve: List[float] = []
        self._alpha_curve: List[float] = []
        self._decode_accesses = 0
        self._decode_misses = 0
        self._per_tenant_rows: List[dict] = []
        self._finished = False

    # --------------------------------------------------------- test hook
    def force_sharded(self, n_shards: int = 1) -> "ReplayEngine":
        """Swap in the expert-parallel cache/ledger machinery at an
        arbitrary shard count *without* touching the config.

        The charge path dispatches on the component types, so forcing
        ``n_shards=1`` runs the full sharded code over a single shard —
        the equivalence the fidelity benchmark asserts against the plain
        single-device components.  Must be called before any event is
        consumed (it rebuilds cache and ledger empty).
        """
        from repro.core.placement import build_placement_policy
        from repro.core.shard import ShardedSliceCache
        from repro.hw.energy import ShardedCostLedger

        if self.requests_served or self._miss_curve:
            raise RuntimeError("force_sharded must precede consumption")
        slice_aware = self.ecfg.policy.slice_mode == "dbsc" \
            and not self.ecfg.fused_slices
        if n_shards > 1:
            self.placement_policy = build_placement_policy(
                self.ecfg.placement, self.n_moe_layers, self.n_experts,
                n_shards,
                replicate_k=self.ecfg.replicate_k or None)
            self.placement = self.placement_policy.initial()
        else:
            self.placement_policy = None
            self.placement = None
        self.cache = ShardedSliceCache(self.ecfg.cache_bytes, n_shards,
                                       slice_aware=slice_aware,
                                       placement=self.placement)
        self.ledger = ShardedCostLedger(
            SYSTEM_PROFILES[self.ecfg.system], n_shards)
        if self.tracer is not None:   # re-wire the sink onto the new ledger
            self.attach_tracer(self.tracer)
        return self

    # ------------------------------------------------- disabled live API
    def run_prefill(self, *a, **k):          # pragma: no cover - guard
        raise TypeError("ReplayEngine is trace-driven; feed events via "
                        "consume()/replay_trace()")

    def decode_batch(self, *a, **k):         # pragma: no cover - guard
        raise TypeError("ReplayEngine is trace-driven; feed events via "
                        "consume()/replay_trace()")

    # ------------------------------------------------------------- replay
    def consume(self, event) -> None:
        """Replay one recorded event through the live charge path."""
        t0 = time.perf_counter()
        if event.kind == "prefill":
            self._begin_request(event.label, event.inflight,
                                tenant=getattr(event, "tenant", "default"))
            active = getattr(event, "active", None)
            self._charge_prefill(
                np.asarray(event.ids), np.asarray(event.gates),
                None if active is None else np.asarray(active, bool))
            self._finish_prefill(event.label)
            self.controller = self.new_controller()
            self._n_prefills += 1
        elif event.kind == "decode":
            slot_mask = np.asarray(event.slot_mask, bool)
            tr = _StepTrace(
                ids=np.asarray(event.ids),
                gates=np.asarray(event.gates, np.float64),
                active=np.asarray(event.active, bool),
                critical=np.asarray(event.critical, bool),
                slot_mask=slot_mask,
                slot_accesses=np.zeros(slot_mask.shape[0], np.int64),
                slot_misses=np.zeros(slot_mask.shape[0], np.int64),
                slot_tenants=getattr(event, "slot_tenants", None))
            charge = self.charge_step_trace(tr)
            self._miss_curve.append(charge.miss_rate)
            self._energy_curve.append(
                charge.ledger_delta["total_energy_j"])
            self._decode_accesses += charge.accesses
            self._decode_misses += charge.misses
            if charge.per_tenant is not None:
                self._per_tenant_rows.append(charge.per_tenant)
            alpha = 0.0
            if self.controller is not None:
                alpha = self.controller.update(charge.miss_rate)
            self._alpha_curve.append(alpha)
        else:                                # pragma: no cover - guard
            raise ValueError(f"unknown trace event kind {event.kind!r}")
        dt = time.perf_counter() - t0
        self.wall_s += dt
        if event.kind == "decode":
            self.decode_wall_s += dt

    def consume_all(self, events: Iterable[Any]) -> "ReplayEngine":
        for ev in events:
            self.consume(ev)
        return self

    def finish(self) -> "ReplayReport":
        """Flush the open stats epoch and build the report."""
        if not self._finished:
            self._prefetch_flush()   # settle never-used pending fills
            self.cache.end_epoch()
            self._finished = True
        return self.report()

    def report(self) -> "ReplayReport":
        return ReplayReport(
            n_prefills=self._n_prefills,
            n_decode_steps=len(self._miss_curve),
            miss_curve=list(self._miss_curve),
            energy_curve=list(self._energy_curve),
            decode_accesses=self._decode_accesses,
            decode_misses=self._decode_misses,
            epoch_miss=self.cache.epoch_miss_rates(),
            epoch_counts=self.cache.epoch_counts(),
            ledger=self.ledger.snapshot(),
            prefetch=(self.prefetcher.summary()
                      if self.prefetcher is not None else None),
            alpha_curve=list(self._alpha_curve),
            wall_s=self.wall_s,
            decode_wall_s=self.decode_wall_s,
            per_shard_epoch_counts=(
                self.cache.per_shard_epoch_counts()
                if hasattr(self.cache, "per_shard_epoch_counts")
                else None),
            per_tenant_rows=(list(self._per_tenant_rows)
                             if self._per_tenant_rows else None),
            controller_summary=(self.slo_controller.summary()
                                if self.slo_controller is not None
                                else None),
            migration_events=(list(self.migration_events)
                              if self.migration_events else None),
            placement=self.placement_summary())

    # --------------------------------------------------------------- fork
    def clone(self) -> "ReplayEngine":
        """Fork the simulation: an independent engine continuing from the
        exact current state.  Immutable pieces (meta, byte store, config)
        are shared; all mutable simulation state is deep-copied via the
        components' own ``clone()`` methods."""
        import copy

        new = object.__new__(ReplayEngine)
        new.__dict__.update(self.__dict__)
        new.cache = self.cache.clone()
        new.ledger = self.ledger.clone()
        new.tracker = self.tracker.clone()
        new.prefetcher = (self.prefetcher.clone()
                          if self.prefetcher is not None else None)
        # In-flight prefetch bookkeeping is engine state, not predictor
        # state — fork it so the clone's judgments don't drain ours.
        new._pf_pending = {l: dict(m)
                           for l, m in self._pf_pending.items()}
        new.controller = copy.deepcopy(self.controller)
        new.slo_controller = copy.deepcopy(self.slo_controller)
        new.recorder = None
        new.tracer = None   # ledger.clone() already detached its sink
        # moe_positions rides along: it is never mutated today, but a
        # shared list is one in-place edit away from cross-fork bleed.
        for f in ("_miss_curve", "_energy_curve", "_alpha_curve",
                  "_per_tenant_rows", "migration_events",
                  "moe_positions"):
            setattr(new, f, list(getattr(self, f)))
        return new


def replay_trace(trace: Trace, ecfg: Optional[EngineConfig] = None,
                 *, max_events: Optional[int] = None,
                 **overrides) -> ReplayReport:
    """One-shot replay of ``trace`` (optionally truncated) under the
    recorded config or an overridden one.  Returns the report."""
    eng = ReplayEngine(trace.meta, ecfg, **overrides)
    events = trace.events if max_events is None \
        else trace.events[:max_events]
    eng.consume_all(events)
    return eng.finish()
