"""Synthetic routing-trace generators: policy studies without a model.

Each generator emits a fully-formed :class:`~repro.sim.trace.Trace`
(meta + prefill/decode events) that the replay simulator and autotuner
consume exactly like a recorded one.  All streams are deterministic in
their ``seed``; the tenant-mix generator reuses the serving subsystem's
:mod:`repro.serving.workloads` arrival/length/tenant distributions so
offline studies see the same traffic shapes the live scheduler does.

Generators (the scenario axes the paper's policy questions live on):

* :func:`zipf_trace` — stationary Zipf expert hotness, independently
  permuted per layer (the steady-workload baseline; cache-capacity and
  warmup sweeps).
* :func:`phase_shift_trace` — the hotness permutation is redrawn every
  phase (workload drift; stresses hotness aging and PCW reshaping).
* :func:`tenant_mix_trace` — per-tenant hotness rotations driven by a
  :class:`~repro.serving.workloads.WorkloadConfig` tenant mix (shared
  -cache contention between workload classes).
* :func:`transition_trace` — layer-to-layer expert choices follow a
  seeded Markov transition matrix (gives the layer-transition prefetcher
  learnable structure; its counterpoint is the near-random routing of
  ``zipf_trace``, where prefetch mostly wastes).
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import List, Optional

import numpy as np

from repro.sim.trace import DecodeEvent, PrefillEvent, Trace, TraceMeta

__all__ = ["SyntheticSpec", "zipf_trace", "phase_shift_trace",
           "tenant_mix_trace", "tenant_phase_trace", "transition_trace"]


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    """Synthetic model topology + cost constants for trace metadata.

    Shapes follow the repo's SwiGLU expert convention (``wi`` maps
    ``d_model -> 2*d_ff``, ``wo`` maps ``d_ff -> d_model``), so slice
    bytes and MAC counts behave like a real (small) MoE.
    """

    n_moe_layers: int = 4
    n_experts: int = 16
    top_k: int = 2
    d_model: int = 64
    d_ff: int = 128
    group_size: int = 32
    high_bits: int = 8
    low_bits: int = 4
    theta: float = 0.5
    cache_frac: float = 0.3      # default cache budget / total store bytes
    system: str = "mobile_soc"

    @property
    def wi_shape(self):
        return (self.d_model, 2 * self.d_ff)

    @property
    def wo_shape(self):
        return (self.d_ff, self.d_model)

    def store_bytes(self) -> float:
        from repro.core.amat import MatConfig, slice_nbytes

        mat = MatConfig(self.high_bits, self.low_bits, self.group_size)
        per_expert = sum(
            slice_nbytes(s, mat.high_bits, mat.group_size,
                         which=w, shift=mat.shift)
            for s in (self.wi_shape, self.wo_shape)
            for w in ("msb", "lsb"))
        return per_expert * self.n_moe_layers * self.n_experts

    def meta(self, **engine_overrides) -> TraceMeta:
        engine = {
            "high_bits": self.high_bits, "low_bits": self.low_bits,
            "cache_bytes": self.cache_frac * self.store_bytes(),
            "policy_kind": "cache_prior", "slice_mode": "dbsc",
            "theta": self.theta, "fetch_lsb_on_miss": True,
            "miss_rate_target": None, "warmup": "pcw",
            "lsb_keep_frac": 0.125, "system": self.system,
            "fused_slices": False, "prefetch_top_m": None,
            "async_io": False, "hotness_request_decay": 0.5,
            "ep_shards": 1, "prefetch_min_obs": 0,
            "prefetch_kind": "request", "prefetch_lookahead": 2,
            "prefetch_min_score": 0.02, "controller": None,
            "placement": "round_robin", "placement_period": 64,
            "replicate_k": 0,
        }
        unknown = set(engine_overrides) - set(engine)
        if unknown:
            raise KeyError(f"unknown engine override(s) {sorted(unknown)}")
        engine.update(engine_overrides)
        return TraceMeta(
            model=f"synthetic_L{self.n_moe_layers}_E{self.n_experts}",
            d_model=self.d_model,
            n_periods=self.n_moe_layers,      # one moe position per period
            moe_positions=(0,),
            n_moe_layers=self.n_moe_layers,
            n_experts=self.n_experts,
            top_k=self.top_k,
            group_size=self.group_size,
            wi_shape=self.wi_shape,
            wo_shape=self.wo_shape,
            resident_bytes=float(12 * self.d_model * self.d_model),
            expert_macs_per_token=(self.d_model * 2 * self.d_ff
                                   + self.d_ff * self.d_model),
            engine=engine,
        )


# --------------------------------------------------------------------------
# draw helpers
# --------------------------------------------------------------------------
def _zipf_probs(n: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** a
    return p / p.sum()


def _layer_probs(rng: np.random.Generator, spec: SyntheticSpec,
                 a: float) -> np.ndarray:
    """[L, E] per-layer hotness: one Zipf, independently permuted."""
    base = _zipf_probs(spec.n_experts, a)
    out = np.empty((spec.n_moe_layers, spec.n_experts))
    for l in range(spec.n_moe_layers):
        out[l] = base[np.argsort(rng.permutation(spec.n_experts))]
    return out


def _draw_block(rng: np.random.Generator, spec: SyntheticSpec,
                probs: np.ndarray, n_tokens: int):
    """Draw routing arrays ``[L, 1, T, k]`` for ``n_tokens`` tokens.

    Per token: ``k`` distinct experts from the layer's hotness
    distribution; gates are a sorted Dirichlet draw (dominant-head shaped
    like real routers), criticality is the DBSC single-head test.
    """
    L, E, k = spec.n_moe_layers, spec.n_experts, spec.top_k
    ids = np.empty((L, 1, n_tokens, k), np.int32)
    gates = np.empty((L, 1, n_tokens, k), np.float64)
    for l in range(L):
        for t in range(n_tokens):
            ids[l, 0, t] = rng.choice(E, size=k, replace=False,
                                      p=probs[l])
            g = np.sort(rng.dirichlet(np.ones(k)))[::-1]
            gates[l, 0, t] = g
    active = np.ones_like(ids, bool)
    critical = gates >= spec.theta
    return ids, gates, active, critical


def _append_request(events: List, rng: np.random.Generator,
                    spec: SyntheticSpec, probs: np.ndarray, *,
                    prompt_len: int, decode_steps: int,
                    label: Optional[str], request_id: Optional[int],
                    tenant: str = "default") -> None:
    ids, gates, _a, _c = _draw_block(rng, spec, probs, prompt_len)
    events.append(PrefillEvent(ids=ids, gates=gates, label=label,
                               inflight=0, request_id=request_id,
                               tenant=tenant))
    for _ in range(decode_steps):
        ids, gates, active, critical = _draw_block(rng, spec, probs, 1)
        events.append(DecodeEvent(
            ids=ids, gates=gates, active=active, critical=critical,
            slot_mask=np.ones(1, bool), slot_tenants=[tenant]))


# --------------------------------------------------------------------------
# generators
# --------------------------------------------------------------------------
def zipf_trace(spec: SyntheticSpec = SyntheticSpec(), *,
               n_requests: int = 4, prompt_len: int = 16,
               decode_steps: int = 32, zipf_a: float = 1.2,
               seed: int = 0, engine_overrides: Optional[dict] = None
               ) -> Trace:
    """Stationary Zipf-hot expert stream (per-layer permutations)."""
    rng = np.random.default_rng(seed)
    probs = _layer_probs(rng, spec, zipf_a)
    events: List = []
    for r in range(n_requests):
        _append_request(events, rng, spec, probs,
                        prompt_len=prompt_len, decode_steps=decode_steps,
                        label=f"req{r}", request_id=r)
    return Trace(meta=spec.meta(**(engine_overrides or {})),
                 events=events)


def phase_shift_trace(spec: SyntheticSpec = SyntheticSpec(), *,
                      phases: int = 3, requests_per_phase: int = 2,
                      prompt_len: int = 16, decode_steps: int = 32,
                      zipf_a: float = 1.2, seed: int = 0,
                      engine_overrides: Optional[dict] = None) -> Trace:
    """Hotness permutation redrawn each phase (workload drift)."""
    rng = np.random.default_rng(seed)
    events: List = []
    rid = 0
    for ph in range(phases):
        probs = _layer_probs(rng, spec, zipf_a)
        for _ in range(requests_per_phase):
            _append_request(
                events, rng, spec, probs, prompt_len=prompt_len,
                decode_steps=decode_steps,
                label=f"ph{ph}/req{rid}", request_id=rid)
            rid += 1
    return Trace(meta=spec.meta(**(engine_overrides or {})),
                 events=events)


def tenant_mix_trace(spec: SyntheticSpec = SyntheticSpec(), *,
                     workload=None, zipf_a: float = 1.2,
                     vocab_size: int = 1024,
                     engine_overrides: Optional[dict] = None) -> Trace:
    """Tenant-rotated hotness driven by a serving WorkloadConfig.

    Request order/lengths/tenants come from
    :func:`repro.serving.workloads.generate` (same seeded streams the
    live scheduler serves); each tenant's expert hotness is the layer
    permutation rotated by a stable per-tenant offset, so tenants
    contend for different expert neighborhoods in the shared cache.
    """
    from repro.serving.workloads import WorkloadConfig, generate

    wl = workload or WorkloadConfig()
    rng = np.random.default_rng(wl.seed)
    base = _layer_probs(rng, spec, zipf_a)
    events: List = []
    for req in generate(wl, vocab_size):
        offset = zlib.crc32(req.tenant.encode()) % spec.n_experts
        probs = np.roll(base, offset, axis=1)
        _append_request(
            events, rng, spec, probs, prompt_len=len(req.prompt),
            decode_steps=req.max_new_tokens,
            label=f"req{req.request_id}", request_id=req.request_id,
            tenant=req.tenant)
    return Trace(meta=spec.meta(**(engine_overrides or {})),
                 events=events)


def tenant_phase_trace(spec: SyntheticSpec = SyntheticSpec(), *,
                       tenants=None,
                       phases: int = 3, requests_per_phase: int = 4,
                       prompt_len: int = 16, decode_steps: int = 32,
                       zipf_a: float = 1.2, seed: int = 0,
                       engine_overrides: Optional[dict] = None) -> Trace:
    """Phase-shifting multi-tenant stream — the SLO-controller soak.

    Combines :func:`phase_shift_trace` (base hotness redrawn every
    phase) with weighted tenant attribution: each request's tenant is
    drawn from ``tenants`` and its hotness is the phase base rotated by
    the tenant's stable crc32 offset — so tenants contend for different
    expert neighborhoods *and* every phase boundary invalidates all of
    them at once.  ``tenants`` is either one name -> weight dict
    (default ``{"premium": 1.0, "batch": 2.0}``) or a sequence of
    ``phases`` such dicts, shifting the *mix itself* at each boundary —
    the traffic shape no static config can be right for on both sides.
    Decode events carry ``slot_tenants``, so the controller (live or
    replayed) sees per-tenant signals.  Labels are ``ph{phase}/req{rid}``.
    """
    if tenants is None:
        tenants = {"premium": 1.0, "batch": 2.0}
    if isinstance(tenants, dict):
        per_phase = [dict(tenants)] * phases
    else:
        per_phase = [dict(mix) for mix in tenants]
        if len(per_phase) != phases:
            raise ValueError(
                f"got {len(per_phase)} tenant mixes for {phases} phases")
    rng = np.random.default_rng(seed)
    events: List = []
    rid = 0
    for ph in range(phases):
        mix = per_phase[ph]
        names = sorted(mix)
        weights = np.array([mix[t] for t in names], np.float64)
        weights = weights / weights.sum()
        base = _layer_probs(rng, spec, zipf_a)
        for _ in range(requests_per_phase):
            tenant = names[int(rng.choice(len(names), p=weights))]
            offset = zlib.crc32(tenant.encode()) % spec.n_experts
            probs = np.roll(base, offset, axis=1)
            _append_request(
                events, rng, spec, probs, prompt_len=prompt_len,
                decode_steps=decode_steps,
                label=f"ph{ph}/req{rid}", request_id=rid, tenant=tenant)
            rid += 1
    return Trace(meta=spec.meta(**(engine_overrides or {})),
                 events=events)


def transition_trace(spec: SyntheticSpec = SyntheticSpec(), *,
                     n_requests: int = 4, prompt_len: int = 16,
                     decode_steps: int = 32, hot_targets: int = 3,
                     concentration: float = 0.85, zipf_a: float = 1.2,
                     seed: int = 0,
                     engine_overrides: Optional[dict] = None) -> Trace:
    """Markov layer-transition routing (prefetcher-learnable).

    Each expert at layer ``l`` sends ``concentration`` of its mass to
    ``hot_targets`` fixed successors at layer ``l+1`` (seeded), the rest
    uniform — the structured-routing regime where layer-transition
    prefetching *can* work, unlike the stochastic Zipf stream.
    """
    rng = np.random.default_rng(seed)
    L, E, k = spec.n_moe_layers, spec.n_experts, spec.top_k
    first_probs = _zipf_probs(E, zipf_a)[
        np.argsort(rng.permutation(E))]
    # trans[l, i]: distribution over layer-(l+1) experts given expert i
    trans = np.full((max(L - 1, 1), E, E),
                    (1.0 - concentration) / E)
    for l in range(max(L - 1, 1)):
        for i in range(E):
            targets = rng.choice(E, size=hot_targets, replace=False)
            trans[l, i, targets] += concentration / hot_targets
        trans[l] /= trans[l].sum(axis=1, keepdims=True)

    def draw_chain(n_tokens: int):
        ids = np.empty((L, 1, n_tokens, k), np.int32)
        gates = np.empty((L, 1, n_tokens, k), np.float64)
        for t in range(n_tokens):
            prev = rng.choice(E, size=k, replace=False, p=first_probs)
            for l in range(L):
                if l > 0:
                    p = trans[l - 1][prev].mean(axis=0)
                    p = p / p.sum()
                    prev = rng.choice(E, size=k, replace=False, p=p)
                ids[l, 0, t] = prev
                g = np.sort(rng.dirichlet(np.ones(k)))[::-1]
                gates[l, 0, t] = g
        active = np.ones_like(ids, bool)
        critical = gates >= spec.theta
        return ids, gates, active, critical

    events: List = []
    for r in range(n_requests):
        ids, gates, _a, _c = draw_chain(prompt_len)
        events.append(PrefillEvent(ids=ids, gates=gates, label=f"req{r}",
                                   inflight=0, request_id=r))
        for _ in range(decode_steps):
            ids, gates, active, critical = draw_chain(1)
            events.append(DecodeEvent(
                ids=ids, gates=gates, active=active, critical=critical,
                slot_mask=np.ones(1, bool)))
    return Trace(meta=spec.meta(**(engine_overrides or {})),
                 events=events)
