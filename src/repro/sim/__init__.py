"""Trace-driven cache simulation + offline policy autotuning.

The serving engine's cache/energy behavior is a deterministic function
of its routing trace — so record the trace once (or synthesize one) and
every policy question (cache budget, bit plan, warmup, prefetch,
timeline) becomes an offline replay instead of a live model run:

* :mod:`repro.sim.trace` — trace schema, engine/scheduler recorder,
  npz+jsonl (de)serialization;
* :mod:`repro.sim.synthetic` — seeded Zipf / phase-shift / tenant-mix /
  transition-matrix trace generators;
* :mod:`repro.sim.replay` — model-free replay through the live engine's
  own charge path (exact-fidelity by construction);
* :mod:`repro.sim.autotune` — policy sweeps, successive halving, Pareto
  frontier, miss-rate-SLO selection.

See docs/simulation.md for the schema, fidelity guarantees and knobs.
"""

from repro.sim.trace import (DecodeEvent, PrefillEvent, Trace, TraceMeta,
                             TraceRecorder, engine_meta, traces_equal)
from repro.sim.replay import (ReplayEngine, ReplayReport, TraceSliceStore,
                              engine_config_from_meta, replay_trace)
from repro.sim.synthetic import (SyntheticSpec, phase_shift_trace,
                                 tenant_mix_trace, tenant_phase_trace,
                                 transition_trace, zipf_trace)
from repro.sim import autotune

__all__ = [
    "Trace", "TraceMeta", "TraceRecorder", "PrefillEvent", "DecodeEvent",
    "engine_meta", "traces_equal",
    "ReplayEngine", "ReplayReport", "TraceSliceStore",
    "engine_config_from_meta", "replay_trace",
    "SyntheticSpec", "zipf_trace", "phase_shift_trace",
    "tenant_mix_trace", "tenant_phase_trace", "transition_trace",
    "autotune",
]
