"""Routing-trace schema, recorder and (de)serialization.

A **trace** is the complete model-free record of one serving run: for
every prefill and every decode step, the per-layer routing arrays the
engine's charge path consumes (expert ids, gates, active/critical masks,
slot mask), plus a :class:`TraceMeta` header carrying everything the
replay simulator needs to rebuild byte sizes and cost constants without
a model — weight-slice shapes, resident bytes, MAC counts and the
recorded :class:`~repro.core.engine.EngineConfig` knobs.

Event stream (execution order, exactly as the live engine charged it):

* :class:`PrefillEvent` — one admitted request's prompt routing
  ``ids/gates [n_periods, n_moe_pos, T, k]`` plus the request-boundary
  inputs (``label``, ``inflight``) that drive hotness aging and cache
  stats epochs.
* :class:`DecodeEvent` — one batched decode step's routing
  ``ids/gates/active/critical [n_periods, n_moe_pos, T, k]`` and the
  ``slot_mask [T]`` of live slots.

Because the replay simulator feeds these arrays through the *same*
``_charge_prefill`` / ``charge_step_trace`` code the live engine runs,
replaying a trace under the recorded config reproduces the live run's
per-epoch miss counts exactly and its energy/latency bit-for-bit (the
fidelity gate in ``benchmarks/sim_fidelity.py``).

Serialization: ``.npz`` (compact, exact) and ``.jsonl`` (line-oriented,
diffable; floats round-trip exactly via ``repr``).  The two formats are
parity-tested (``tests/test_sim.py``).

Recording a live run::

    rec = TraceRecorder()
    sched = ContinuousBatchingScheduler(engine, cfg)
    sched.attach_recorder(rec)          # or rec.attach(engine)
    ... submit / run ...
    rec.trace().save("run.npz")
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

TRACE_VERSION = 1

__all__ = [
    "TRACE_VERSION", "TraceMeta", "PrefillEvent", "DecodeEvent", "Trace",
    "TraceRecorder", "engine_meta", "traces_equal",
]


# --------------------------------------------------------------------------
# schema
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TraceMeta:
    """Model-free replay header: topology, byte-size inputs, config.

    ``wi_shape``/``wo_shape`` are the per-expert quantized code shapes —
    with ``group_size`` they let the replay recompute MSB/LSB slice bytes
    for *any* AMAT bit plan (the autotuner's bit-plan axis), via the same
    :func:`repro.core.amat.slice_nbytes` the live store uses.
    ``engine`` is the recorded EngineConfig as a flat dict; it is the
    replay default, and the knob set the autotuner overrides.
    """

    model: str
    d_model: int
    n_periods: int
    moe_positions: Tuple[int, ...]
    n_moe_layers: int
    n_experts: int
    top_k: int
    group_size: int
    wi_shape: Tuple[int, ...]
    wo_shape: Tuple[int, ...]
    resident_bytes: float
    expert_macs_per_token: int
    engine: Dict[str, Any]
    version: int = TRACE_VERSION

    def layer_map(self) -> Dict[Tuple[int, int], int]:
        """(position, period) -> flat moe layer index, in execution
        order — the same enumeration ``quantize_moe_params`` builds."""
        out = {}
        flat = 0
        for period in range(self.n_periods):
            for pos in self.moe_positions:
                out[(pos, period)] = flat
                flat += 1
        return out

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TraceMeta":
        d = dict(d)
        for f in ("moe_positions", "wi_shape", "wo_shape"):
            d[f] = tuple(int(x) for x in d[f])
        return cls(**d)


@dataclasses.dataclass
class PrefillEvent:
    """One admitted request's prompt routing + boundary metadata.

    ``active`` (optional, None = every slot) records the routing
    policy's slot-activation mask — under cumsum prefill most of the
    ``k_max`` slots are deactivated and the charge path must skip them.
    Traces recorded before the field existed load with ``active=None``
    and replay as all-active, exactly as they were charged live.
    """

    ids: np.ndarray            # [n_periods, n_moe_pos, T, k] int
    gates: np.ndarray          # float64, same shape
    active: Optional[np.ndarray] = None    # bool, same shape (or None)
    label: Optional[str] = None
    inflight: int = 0
    request_id: Optional[int] = None
    tenant: str = "default"

    kind = "prefill"
    _array_fields = ("ids", "gates", "active")
    _optional_array_fields = ("active",)   # absent in pre-EP traces


@dataclasses.dataclass
class DecodeEvent:
    """One batched decode step's routing arrays.

    ``slot_tenants`` (optional) carries per-slot tenant attribution —
    the SLO controller's input signal.  It records the step's *inputs*
    only; the controller's bit plan is deliberately NOT recorded, so a
    replay recomputes it from the same stream (the control-loop
    fidelity gate).  Pre-controller traces load with ``None``.
    """

    ids: np.ndarray            # [n_periods, n_moe_pos, T, k] int
    gates: np.ndarray          # float64
    active: np.ndarray         # bool
    critical: np.ndarray       # bool
    slot_mask: np.ndarray      # [T] bool
    slot_tenants: Optional[List] = None    # [T] tenant names / None

    kind = "decode"
    _array_fields = ("ids", "gates", "active", "critical", "slot_mask")


_EVENT_TYPES = {"prefill": PrefillEvent, "decode": DecodeEvent}
_ARRAY_DTYPES = {"ids": np.int32, "gates": np.float64, "active": bool,
                 "critical": bool, "slot_mask": bool}


@dataclasses.dataclass
class Trace:
    """Header + ordered event stream of one recorded (or synthetic) run."""

    meta: TraceMeta
    events: List[Any] = dataclasses.field(default_factory=list)

    # ----------------------------------------------------------- counters
    @property
    def n_prefills(self) -> int:
        return sum(1 for e in self.events if e.kind == "prefill")

    @property
    def n_decode_steps(self) -> int:
        return sum(1 for e in self.events if e.kind == "decode")

    def __len__(self) -> int:
        return len(self.events)

    # ------------------------------------------------------ serialization
    def save(self, path: str) -> str:
        """Write by extension: ``.npz`` or ``.jsonl``."""
        if path.endswith(".npz"):
            return self.save_npz(path)
        if path.endswith(".jsonl"):
            return self.save_jsonl(path)
        raise ValueError(f"unknown trace format for {path!r} "
                         "(want .npz or .jsonl)")

    @classmethod
    def load(cls, path: str) -> "Trace":
        if path.endswith(".npz"):
            return cls.load_npz(path)
        if path.endswith(".jsonl"):
            return cls.load_jsonl(path)
        raise ValueError(f"unknown trace format for {path!r} "
                         "(want .npz or .jsonl)")

    def save_npz(self, path: str) -> str:
        arrays: Dict[str, np.ndarray] = {}
        scalars: List[dict] = []
        for i, ev in enumerate(self.events):
            sc = {"kind": ev.kind}
            for f in dataclasses.fields(ev):
                v = getattr(ev, f.name)
                if f.name in ev._array_fields:
                    if v is None:        # optional array (e.g. active)
                        continue
                    arrays[f"e{i:06d}_{f.name}"] = np.asarray(
                        v, _ARRAY_DTYPES[f.name])
                else:
                    sc[f.name] = v
            scalars.append(sc)
        np.savez_compressed(
            path,
            meta_json=np.str_(json.dumps(self.meta.to_dict())),
            events_json=np.str_(json.dumps(scalars)),
            **arrays)
        return path

    @classmethod
    def load_npz(cls, path: str) -> "Trace":
        with np.load(path, allow_pickle=False) as z:
            meta = TraceMeta.from_dict(json.loads(str(z["meta_json"])))
            scalars = json.loads(str(z["events_json"]))
            events = []
            for i, sc in enumerate(scalars):
                etype = _EVENT_TYPES[sc.pop("kind")]
                optional = getattr(etype, "_optional_array_fields", ())
                kw = dict(sc)
                for f in etype._array_fields:
                    name = f"e{i:06d}_{f}"
                    if name in z.files:
                        kw[f] = np.asarray(z[name], _ARRAY_DTYPES[f])
                    elif f not in optional:
                        # fail fast with the missing array's name (a
                        # truncated/corrupt file), as before the
                        # optional-field support landed
                        kw[f] = np.asarray(z[name], _ARRAY_DTYPES[f])
                    # absent optional arrays keep their None default
                events.append(etype(**kw))
        return cls(meta=meta, events=events)

    def save_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(json.dumps({"type": "meta", **self.meta.to_dict()})
                    + "\n")
            for ev in self.events:
                line: Dict[str, Any] = {"type": ev.kind}
                for fld in dataclasses.fields(ev):
                    v = getattr(ev, fld.name)
                    if fld.name in ev._array_fields:
                        # tolist(): Python scalars; float repr round-trips
                        # exactly through json, keeping jsonl==npz parity.
                        line[fld.name] = None if v is None \
                            else np.asarray(v).tolist()
                    else:
                        line[fld.name] = v
                f.write(json.dumps(line) + "\n")
        return path

    @classmethod
    def load_jsonl(cls, path: str) -> "Trace":
        meta = None
        events = []
        with open(path) as f:
            for line in f:
                if not line.strip():
                    continue
                d = json.loads(line)
                t = d.pop("type")
                if t == "meta":
                    meta = TraceMeta.from_dict(d)
                    continue
                etype = _EVENT_TYPES[t]
                optional = getattr(etype, "_optional_array_fields", ())
                for fld in etype._array_fields:
                    if fld in optional and d.get(fld) is None:
                        continue        # absent/null: keep None default
                    d[fld] = np.asarray(d[fld], _ARRAY_DTYPES[fld])
                events.append(etype(**d))
        if meta is None:
            raise ValueError(f"{path}: no meta line")
        return cls(meta=meta, events=events)


def traces_equal(a: Trace, b: Trace) -> bool:
    """Exact structural equality (meta, event order, arrays, scalars)."""
    if a.meta.to_dict() != b.meta.to_dict() or len(a) != len(b):
        return False
    for ea, eb in zip(a.events, b.events):
        if ea.kind != eb.kind:
            return False
        for f in dataclasses.fields(ea):
            va, vb = getattr(ea, f.name), getattr(eb, f.name)
            if f.name in ea._array_fields:
                if (va is None) != (vb is None):
                    return False
                if va is not None and not np.array_equal(
                        np.asarray(va), np.asarray(vb)):
                    return False
            elif va != vb:
                return False
    return True


# --------------------------------------------------------------------------
# recorder
# --------------------------------------------------------------------------
def engine_meta(engine) -> TraceMeta:
    """Build the replay header from a live :class:`PersistentEngine`."""
    ecfg = engine.ecfg
    first = engine.store.layers[min(engine.store.layers)]
    return TraceMeta(
        model=engine.cfg.name,
        d_model=int(engine.cfg.d_model),
        n_periods=int(engine.cfg.n_periods),
        moe_positions=tuple(int(p) for p in engine.moe_positions),
        n_moe_layers=int(engine.n_moe_layers),
        n_experts=int(engine.n_experts),
        top_k=int(engine.cfg.moe.top_k),
        group_size=int(ecfg.mat.group_size),
        wi_shape=tuple(int(x) for x in first.wi_q.codes.shape[1:]),
        wo_shape=tuple(int(x) for x in first.wo_q.codes.shape[1:]),
        resident_bytes=float(engine.resident_bytes),
        expert_macs_per_token=int(engine.expert_macs_per_token),
        engine={
            "high_bits": ecfg.mat.high_bits,
            "low_bits": ecfg.mat.low_bits,
            "cache_bytes": ecfg.cache_bytes,
            "policy_kind": ecfg.policy.kind,
            "slice_mode": ecfg.policy.slice_mode,
            "theta": ecfg.policy.theta,
            "fetch_lsb_on_miss": ecfg.policy.fetch_lsb_on_miss,
            "miss_rate_target": ecfg.miss_rate_target,
            "warmup": ecfg.warmup,
            "lsb_keep_frac": ecfg.lsb_keep_frac,
            "system": ecfg.system,
            "fused_slices": ecfg.fused_slices,
            "prefetch_top_m": ecfg.prefetch_top_m,
            "async_io": ecfg.async_io,
            "hotness_request_decay": ecfg.hotness_request_decay,
            "ep_shards": ecfg.ep_shards,
            "prefetch_min_obs": ecfg.prefetch_min_obs,
            "prefetch_kind": ecfg.prefetch_kind,
            "prefetch_lookahead": ecfg.prefetch_lookahead,
            "prefetch_min_score": ecfg.prefetch_min_score,
            "controller": (None if ecfg.controller is None
                           else ecfg.controller.to_dict()),
            "placement": ecfg.placement,
            "placement_period": ecfg.placement_period,
            "replicate_k": ecfg.replicate_k,
        },
    )


class TraceRecorder:
    """Lightweight engine hook capturing the replayable event stream.

    Attach with :meth:`attach` (or
    ``ContinuousBatchingScheduler.attach_recorder``); the engine then
    calls :meth:`on_prefill` / :meth:`on_decode` at exactly the points
    its charge path consumes the same arrays, so the recorded order *is*
    the charged order — the property the fidelity gate relies on.
    """

    def __init__(self, engine=None):
        self.meta: Optional[TraceMeta] = None
        self.events: List[Any] = []
        if engine is not None:
            self.attach(engine)

    def attach(self, engine) -> "TraceRecorder":
        self.meta = engine_meta(engine)
        engine.recorder = self
        return self

    # ----------------------------------------------------------- callbacks
    def on_prefill(self, ids: np.ndarray, gates: np.ndarray, *,
                   active: Optional[np.ndarray] = None,
                   label: Optional[str] = None, inflight: int = 0,
                   tenant: str = "default") -> None:
        self.events.append(PrefillEvent(
            ids=np.array(ids, _ARRAY_DTYPES["ids"]),
            gates=np.array(gates, _ARRAY_DTYPES["gates"]),
            active=(None if active is None
                    else np.array(active, _ARRAY_DTYPES["active"])),
            label=label, inflight=int(inflight), tenant=tenant))

    def on_decode(self, tr) -> None:
        """``tr``: the engine's ``_StepTrace`` (pre-charge, pre-plan)."""
        self.events.append(DecodeEvent(
            ids=np.array(tr.ids, _ARRAY_DTYPES["ids"]),
            gates=np.array(tr.gates, _ARRAY_DTYPES["gates"]),
            active=np.array(tr.active, bool),
            critical=np.array(tr.critical, bool),
            slot_mask=np.array(tr.slot_mask, bool),
            slot_tenants=(None if tr.slot_tenants is None
                          else list(tr.slot_tenants))))

    def annotate_prefill(self, *, request_id: Optional[int] = None,
                         tenant: Optional[str] = None) -> None:
        """Attach request metadata to the most recent prefill event
        (called by the scheduler, which knows the Request object)."""
        for ev in reversed(self.events):
            if ev.kind == "prefill":
                if request_id is not None:
                    ev.request_id = int(request_id)
                if tenant is not None:
                    ev.tenant = tenant
                return

    # -------------------------------------------------------------- output
    def trace(self) -> Trace:
        if self.meta is None:
            raise ValueError("recorder was never attached to an engine")
        return Trace(meta=self.meta, events=list(self.events))
