"""Launch layer: meshes, sharding, train/serve drivers, dry-runs."""
