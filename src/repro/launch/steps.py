"""Step functions lowered by the dry-run / executed by the drivers.

One factory per shape kind.  All are pure jit-able functions of
(params, state..., batch) with the paper-relevant features wired in:
MoE aux-loss in training, sliding-window attention for long-context
decode on dense archs, AMAT-quantized expert decode as an option.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as MDL
from repro.optim import adamw as OPT


def make_train_step(cfg: ModelConfig, opt_cfg: OPT.AdamWConfig):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, aux = MDL.lm_loss(
                p, cfg, batch["tokens"], batch["labels"],
                prefix_embeds=batch.get("prefix_embeds"),
                encoder_frames=batch.get("encoder_frames"))
            return loss, aux

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, opt_metrics = OPT.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, "aux_loss": aux["aux_loss"], **opt_metrics}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig,
                      use_window: bool = False):
    max_seq = shape.seq_len

    def prefill_step(params, batch):
        logits, cache, _ = MDL.prefill(
            params, cfg, batch["tokens"], max_seq,
            prefix_embeds=batch.get("prefix_embeds"),
            encoder_frames=batch.get("encoder_frames"),
            use_window=use_window)
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, use_window: bool = False):
    mat = None
    if cfg.quantized_serve:
        from repro.core.amat import MatConfig
        mat = MatConfig(8, 4)

    def serve_step(params, cache, token, extras):
        logits, cache, _ = MDL.decode_step(
            params, cfg, token, cache,
            encoder_frames=extras.get("encoder_frames"),
            use_window=use_window, mat=mat)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, cache

    return serve_step


def step_for_shape(cfg: ModelConfig, shape: ShapeConfig,
                   opt_cfg: Optional[OPT.AdamWConfig] = None):
    """(fn, donate_argnums) for the shape kind.

    long_500k on dense archs uses the sliding-window attention variant
    (DESIGN.md §4); SSM/hybrid archs run their native sub-quadratic path.
    """
    use_window = (shape.name == "long_500k"
                  and cfg.sliding_window is not None
                  and cfg.arch_type not in ("ssm",))
    if shape.kind == "train":
        return make_train_step(cfg, opt_cfg or OPT.AdamWConfig()), (0, 1)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape, use_window), ()
    if shape.kind == "decode":
        return make_decode_step(cfg, use_window), (1,)
    raise ValueError(shape.kind)


def shape_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple:
    """(supported, reason).  The documented skips from DESIGN.md §4."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch without sliding-window variant "
                       "— long_500k skipped per DESIGN.md §4")
    return True, ""
