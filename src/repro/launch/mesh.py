"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run driver
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax import; everything else sees the real single CPU device.

Mesh axes:
  single-pod: (data=16, model=16)           — 256 chips (one v5e pod)
  multi-pod:  (pod=2, data=16, model=16)    — 512 chips (2 pods)

Axis roles: ``data`` shards the global batch (and FSDP weight rows),
``model`` shards heads / FFN columns / experts / long KV sequences,
``pod`` is pure data parallelism across pods (weights replicated across
pods; gradient all-reduce crosses the inter-pod links once per step).
"""

from __future__ import annotations

import jax


def _mesh_kwargs(n: int) -> dict:
    # jax.sharding.AxisType landed after 0.4.x; older jax defaults every
    # axis to Auto, which is exactly what we want — so only pass the
    # kwarg when the enum exists.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_host_mesh():
    """1-device mesh with the production axis names (tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"), **_mesh_kwargs(2))


def data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_shards(mesh) -> int:
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
