"""Sharding rules: param-tree path -> PartitionSpec, + in-graph hints.

Divisibility-safe: every rule is filtered against the actual dimension
sizes — an axis that doesn't divide the dim is dropped (GSPMD would
otherwise reject the sharding).  This is what lets one rule set serve
head counts from 14 (internvl2) to 48 (nemotron) on a 16-way model axis.

``shard_hint`` is the in-graph constraint hook used by the model code;
it resolves against a module-level "current mesh" so the model never
depends on launch wiring (and is a no-op in single-device tests).
"""

from __future__ import annotations

import contextlib
import re
from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisSpec = Union[None, str, Tuple[str, ...]]

_CURRENT_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _CURRENT_MESH
    _CURRENT_MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _CURRENT_MESH


@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    prev = get_mesh()
    set_mesh(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        set_mesh(prev)


def _axis_size(mesh: Mesh, axis: AxisSpec) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis] if axis in mesh.axis_names else 0
    return int(np.prod([_axis_size(mesh, a) for a in axis]))


def sanitize_spec(mesh: Mesh, shape: Sequence[int],
                  spec: Sequence[AxisSpec]) -> P:
    """Drop axes that are absent from the mesh or don't divide the dim."""
    clean = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            clean.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if axes and size > 0 and dim % size == 0:
            clean.append(axes[0] if len(axes) == 1 else axes)
        else:
            # try prefixes (e.g. ('pod','data') -> ('pod',))
            ok = None
            for i in range(len(axes) - 1, 0, -1):
                sub = axes[:i]
                size = int(np.prod([mesh.shape[a] for a in sub]))
                if dim % size == 0:
                    ok = sub[0] if len(sub) == 1 else sub
                    break
            clean.append(ok)
    return P(*clean)


def shard_hint(x: jax.Array, *spec: AxisSpec) -> jax.Array:
    """with_sharding_constraint against the current mesh (no-op if unset)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    if len(spec) < x.ndim:
        spec = tuple(spec) + (None,) * (x.ndim - len(spec))
    pspec = sanitize_spec(mesh, x.shape, spec)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))


# --------------------------------------------------------------------------
# Parameter sharding rules
# --------------------------------------------------------------------------
# (path regex, spec-from-ndim) — first match wins.  Specs are given for the
# *unstacked* shape; a leading period/layer-stack dim is auto-prepended.
_RULES = [
    # embeddings: vocab -> model, d_model -> data
    (r"embed$", ("model", "data")),
    (r"unembed$", ("data", "model")),
    # attention projections
    (r"(wq|wk|wv|c_wq|c_wk|c_wv)$", ("data", "model")),
    (r"(wo|c_wo)$", ("model", "data")),
    (r"(bq|bk|bv)$", ("model",)),
    # dense mlp
    (r"mlp/wi$", ("data", "model")),
    (r"mlp/wo$", ("model", "data")),
    # shared experts
    (r"shared/wi$", ("data", "model")),
    (r"shared/wo$", ("model", "data")),
    # moe
    (r"w_router$", ("data", None)),
    (r"experts/wi$", ("model", "data", None)),
    (r"experts/wo$", ("model", None, "data")),
    # AMAT-quantized serve-form experts (EXPERIMENTS.md §Perf hillclimb 1,
    # iterations 2-3).  wi codes shard on the OUTPUT dim (N): dequant is
    # local and the first einsum emits an N-sharded activation.  wo codes
    # shard on the CONTRACTION dim (F), aligned with that activation, so
    # the second einsum is a local partial dot + a small all-reduce of
    # [E,C,d] — instead of GSPMD replicating the dequantized f32 wo tile
    # (a measured 66 GB/step all-gather on maverick decode).
    (r"experts/wi_(codes|scales|zps)$", ("model", None, "data")),
    (r"experts/wo_(codes|scales|zps)$", ("model", "data", None)),
    # ssm
    (r"ssm/in_proj$", ("data", "model")),
    (r"ssm/out_proj$", ("model", "data")),
    (r"ssm/conv_w$", (None, "model")),
    (r"ssm/conv_b$", ("model",)),
    (r"ssm/(A_log|D|dt_bias)$", (None,)),
    # norms / everything 1-D: replicated
    (r".*", (None,)),
]

_STACKED_PREFIX = re.compile(r"blocks/pos\d+/|encoder/blocks/")


def param_spec(path: str, shape: Tuple[int, ...]) -> Tuple[AxisSpec, ...]:
    """Raw (unsanitized) axis spec for a param path."""
    stacked = bool(_STACKED_PREFIX.search(path))
    core_ndim = len(shape) - (1 if stacked else 0)
    for pat, spec in _RULES:
        if re.search(pat, path):
            spec = tuple(spec)[:core_ndim]
            spec = spec + (None,) * (core_ndim - len(spec))
            return ((None,) + spec) if stacked else spec
    return (None,) * len(shape)


def tree_paths(tree) -> list:
    """Flatten a pytree into ('a/b/c', leaf) pairs.

    Int-tuples (shape tuples) count as leaves, matching the ``is_leaf``
    used when flattening shape trees.
    """
    def is_shape(x):
        return isinstance(x, tuple) and all(isinstance(i, int) for i in x)

    out = []

    def rec(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k], path + (str(k),))
        elif isinstance(node, (list, tuple)) and not is_shape(node):
            for i, v in enumerate(node):
                rec(v, path + (str(i),))
        else:
            out.append(("/".join(path), node))
    rec(tree, ())
    return out


def param_shardings(mesh: Mesh, shapes_tree) -> "dict":
    """Map a param-shapes tree to a NamedSharding tree (same structure)."""
    def is_shape(x):
        return isinstance(x, tuple) and all(isinstance(i, int) for i in x)

    flat = tree_paths(shapes_tree)
    path_for_id = {}
    leaves, treedef = jax.tree_util.tree_flatten(
        shapes_tree, is_leaf=is_shape)
    # tree_paths and tree_flatten both use sorted-dict order; align by index
    assert len(flat) == len(leaves)
    out = []
    for (path, shape) in flat:
        spec = param_spec(path, shape)
        out.append(NamedSharding(mesh, sanitize_spec(mesh, shape, spec)))
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
