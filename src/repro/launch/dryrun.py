import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) pair.

MUST be executed as its own process (``python -m repro.launch.dryrun``) —
the device-count flag above is set before any jax import and jax locks
device count at first init.  Smoke tests and benchmarks never import this
module, so they see the real single CPU device.

Per pair it records into ``results/dryrun/<arch>__<shape>__<mesh>.json``:
  * compiled memory analysis (per-device argument/output/temp bytes),
  * cost analysis (HLO FLOPs, bytes accessed),
  * collective-op byte totals parsed from the post-SPMD optimized HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), and
  * the three roofline terms for TPU v5e (see EXPERIMENTS.md §Roofline).

``--all`` fans out over every supported pair in subprocesses (one compile
per process keeps peak RSS bounded on the 1-core container).
"""

import argparse
import json
import re
import subprocess
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.hw.specs import TPU_V5E
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import mesh_context
from repro.launch.specs import input_specs
from repro.launch.steps import shape_supported, step_for_shape

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../results/dryrun")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_COMP_HEADER = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?\)\s*,\s*condition=%?([\w.\-]+)\s*,\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _split_computations(text: str):
    """HLO module text -> {computation_name: [instruction lines]}."""
    comps = {}
    entry = None
    cur = None
    for line in text.splitlines():
        s = line.strip()
        m = _COMP_HEADER.match(s)
        if m and s.endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps, entry


def _line_collective(rhs: str):
    for c in _COLLECTIVES:
        if re.search(rf"\b{c}(-start)?\(", rhs):
            return c
        if f"{c}-done(" in rhs:
            return None  # counted at -start
    return None


def _collective_bytes_of_line(rhs: str) -> float:
    call = rhs.split("(", 1)
    operand_shapes = _SHAPE_RE.findall(call[1]) if len(call) > 1 else []
    if not operand_shapes:
        operand_shapes = _SHAPE_RE.findall(call[0])[:1]
    return float(sum(_shape_bytes(dt, dims) for dt, dims in operand_shapes))


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op, scaled by while trip
    counts.

    XLA HLO text lists a scan/while body computation once; a collective
    inside it executes trip-count times.  We build the computation call
    graph (while bodies/conditions, fusions, custom calls), extract each
    while's trip count from the s32 constant in its condition
    computation, and multiply collective bytes by the product of
    enclosing trip counts.
    """
    comps, entry = _split_computations(hlo_text)

    # Per-computation: local collectives, while edges, plain call edges.
    local = {}      # comp -> list[(op, bytes)]
    whiles = {}     # comp -> list[(cond, body)]
    calls = {}      # comp -> set[callee]
    for name, lines in comps.items():
        loc, wh, cl = [], [], set()
        for s in lines:
            m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)$", s)
            if not m:
                continue
            rhs = m.group(1)
            wm = _WHILE_RE.search(rhs)
            if wm:
                wh.append((wm.group(1), wm.group(2)))
                continue
            for cm in _CALL_RE.finditer(rhs):
                cl.add(cm.group(1))
            op = _line_collective(rhs)
            if op is not None:
                loc.append((op, _collective_bytes_of_line(rhs)))
        local[name] = loc
        whiles[name] = wh
        calls[name] = cl

    def trip_count(cond: str) -> int:
        consts = [int(x) for x in _CONST_S32.findall(
            "\n".join(comps.get(cond, [])))]
        return max(consts) if consts else 1

    totals = {c: 0.0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    unscaled_whiles = 0

    seen = set()

    def walk(comp: str, factor: float):
        nonlocal unscaled_whiles
        key = (comp, factor)
        if key in seen or comp not in comps:
            return
        seen.add(key)
        for op, nb in local.get(comp, []):
            totals[op] += nb * factor
            counts[op] += int(round(factor))
        for callee in calls.get(comp, ()):
            walk(callee, factor)
        for cond, body in whiles.get(comp, ()):
            t = trip_count(cond)
            if t == 1:
                unscaled_whiles += 1
            walk(body, factor * t)
            walk(cond, factor * t)

    if entry is not None:
        walk(entry, 1.0)
    else:  # fallback: flat scan, unscaled
        for name in comps:
            for op, nb in local.get(name, []):
                totals[op] += nb
                counts[op] += 1

    return {"bytes": totals, "counts": counts,
            "total_bytes": sum(totals.values()),
            "total_count": sum(counts.values()),
            "unscaled_whiles": unscaled_whiles}


def model_flops(cfg, shape) -> float:
    """6·N_active·D (train) / 2·N_active·D (inference) reference FLOPs."""
    from repro.launch.costs import model_flops_reference

    return model_flops_reference(cfg, shape)


VARIANTS = {
    "baseline": {},
    "seqpar": {"seq_parallel": True},
    "onehot": {"onehot_embed": True},
    "seqpar_onehot": {"seq_parallel": True, "onehot_embed": True},
    "int8kv": {"kv_dtype": "int8"},
    "qserve": {"quantized_serve": True},
    "qserve_int8kv": {"quantized_serve": True, "kv_dtype": "int8"},
    "ringkv": {"ring_kv": True},
    "ringkv_qserve": {"ring_kv": True, "quantized_serve": True},
    "seqpar_dots": {"seq_parallel": True, "remat_policy": "dots"},
    "seqpar_dots_padvocab": {"seq_parallel": True, "remat_policy": "dots",
                             "pad_vocab_to": 256},
}


def run_pair(arch: str, shape_name: str, mesh_kind: str,
             save: bool = True, variant: str = "baseline") -> dict:
    import dataclasses as _dc

    cfg = get_config(arch)
    if variant != "baseline":
        cfg = _dc.replace(cfg, **VARIANTS[variant])
    shape = SHAPES[shape_name]
    ok, reason = shape_supported(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant,
        "timestamp": time.time(),
    }
    if not ok:
        rec.update({"status": "skipped", "reason": reason})
        if save:
            _save(rec)
        return rec

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = int(np.prod(list(mesh.shape.values())))

    step, donate = step_for_shape(cfg, shape)
    specs = input_specs(cfg, shape, mesh)

    t0 = time.time()
    try:
        with mesh_context(mesh):
            lowered = jax.jit(step, donate_argnums=donate).lower(*specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = {}
            try:
                ma = compiled.memory_analysis()
                for attr in ("argument_size_in_bytes",
                             "output_size_in_bytes",
                             "temp_size_in_bytes",
                             "alias_size_in_bytes",
                             "generated_code_size_in_bytes"):
                    if hasattr(ma, attr):
                        mem[attr] = int(getattr(ma, attr))
            except Exception as e:          # noqa: BLE001
                mem["error"] = str(e)

            cost = {}
            try:
                ca = compiled.cost_analysis()
                if isinstance(ca, (list, tuple)):
                    ca = ca[0]
                cost = {k: float(v) for k, v in ca.items()
                        if isinstance(v, (int, float))}
            except Exception as e:          # noqa: BLE001
                cost["error"] = str(e)

            hlo = compiled.as_text()
            coll = parse_collectives(hlo)
    except Exception as e:                   # noqa: BLE001
        rec.update({"status": "error", "error": repr(e),
                    "traceback": traceback.format_exc()[-4000:]})
        if save:
            _save(rec)
        return rec

    from repro.launch.costs import analytic_costs

    ac = analytic_costs(cfg, shape)
    coll_bytes = coll["total_bytes"]

    # Analytic flops/bytes for the compute & memory terms: XLA CPU
    # cost_analysis counts while bodies once (verified), so HLO-reported
    # numbers understate scanned-layer cost by ~n_layers.  Raw HLO values
    # are kept below as diagnostics.  Collective bytes come from the HLO,
    # scaled by while trip counts.
    terms = {
        "compute_s": ac.flops / (n_chips * TPU_V5E.peak_flops_bf16),
        "memory_s": ac.hbm_bytes / (n_chips * TPU_V5E.hbm_bytes_per_s),
        "collective_s": coll_bytes / (n_chips *
                                      TPU_V5E.ici_bytes_per_s_per_link),
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)

    rec.update({
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "cost": cost,
        "collectives": coll,
        "analytic": {"flops": ac.flops, "hbm_bytes": ac.hbm_bytes,
                     **ac.detail},
        "roofline": {
            **terms,
            "dominant": dominant,
            "model_flops": mf,
            "analytic_flops": ac.flops,
            "hlo_flops_raw": cost.get("flops", 0.0),
            "hlo_bytes_raw": cost.get("bytes accessed", 0.0),
            "useful_flops_ratio": mf / ac.flops if ac.flops else None,
            "bytes_per_chip": (mem.get("argument_size_in_bytes", 0)
                               + mem.get("temp_size_in_bytes", 0)) / max(n_chips, 1),
        },
    })
    if save:
        _save(rec)
    return rec


def _save(rec: dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = "" if rec.get("variant", "baseline") == "baseline" \
        else f"__{rec['variant']}"
    fname = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json"
    with open(os.path.join(RESULTS_DIR, fname), "w") as f:
        json.dump(rec, f, indent=2)


def _already_done(arch, shape, mesh_kind) -> bool:
    fname = os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_kind}.json")
    if not os.path.exists(fname):
        return False
    with open(fname) as f:
        return json.load(f).get("status") in ("ok", "skipped")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variant", default="baseline",
                    choices=list(VARIANTS))
    ap.add_argument("--all", action="store_true",
                    help="run every pair in subprocesses (resumable)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.all:
        pairs = [(a, s, m) for a in ARCH_IDS for s in SHAPES
                 for m in ("single", "multi")]
        failed = []
        for a, s, m in pairs:
            if not args.force and _already_done(a, s, m):
                print(f"[skip-cached] {a} {s} {m}")
                continue
            print(f"[run] {a} {s} {m}", flush=True)
            r = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", a, "--shape", s, "--mesh", m],
                env={**os.environ},
            )
            if r.returncode != 0:
                failed.append((a, s, m))
        print(f"done; {len(failed)} failures: {failed}")
        return 1 if failed else 0

    assert args.arch and args.shape
    rec = run_pair(args.arch, args.shape, args.mesh, variant=args.variant)
    status = rec["status"]
    if status == "ok":
        rl = rec["roofline"]
        print(f"OK {args.arch} {args.shape} {args.mesh}: "
              f"compute={rl['compute_s']:.3e}s memory={rl['memory_s']:.3e}s "
              f"collective={rl['collective_s']:.3e}s "
              f"dominant={rl['dominant']} "
              f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s)")
        return 0
    if status == "skipped":
        print(f"SKIPPED {args.arch} {args.shape}: {rec['reason']}")
        return 0
    print(f"ERROR {args.arch} {args.shape} {args.mesh}: {rec['error']}")
    print(rec.get("traceback", ""))
    return 2


if __name__ == "__main__":
    sys.exit(main())
