"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape, mesh)`` returns the full argument pytree for the
step function selected by the shape kind (train / prefill / decode), with
NamedShardings attached so ``jax.jit(step).lower(*specs)`` both shapes and
shards the computation — the multi-pod dry-run path.

Modality stubs (the one allowed carve-out): VLM prefix patch-embeddings
and whisper encoder frame-embeddings enter here as ready-made
``[B, P, d_model]`` float tensors.
"""

from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.sharding import param_spec, sanitize_spec, tree_paths
from repro.models import model as MDL
from repro.optim.adamw import AdamWConfig

_F32_PARAM = re.compile(r"ssm/(A_log|D|dt_bias)$|_scales$")
_U8_PARAM = re.compile(r"_(codes|zps)$")


def _param_dtype(path: str, cfg) -> "jnp.dtype":
    if _U8_PARAM.search(path):
        return jnp.dtype(jnp.uint8)
    if _F32_PARAM.search(path):
        return jnp.dtype(jnp.float32)
    return jnp.dtype(cfg.dtype)


def _sds(shape, dtype, mesh: Optional[Mesh], spec) -> jax.ShapeDtypeStruct:
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    ns = NamedSharding(mesh, sanitize_spec(mesh, shape, spec))
    return jax.ShapeDtypeStruct(shape, dtype, sharding=ns)


def param_specs(cfg: ModelConfig, mesh: Optional[Mesh]):
    """SDS tree matching init_params (dtypes included)."""
    shapes = MDL.param_shapes(cfg)

    def is_shape(x):
        return isinstance(x, tuple) and all(isinstance(i, int) for i in x)

    flat = tree_paths(shapes)
    leaves, treedef = jax.tree_util.tree_flatten(shapes, is_leaf=is_shape)
    out = []
    for path, shape in flat:
        spec = param_spec(path, shape)
        out.append(_sds(shape, _param_dtype(path, cfg), mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_specs(cfg: ModelConfig, mesh: Optional[Mesh],
              opt_cfg: AdamWConfig):
    """AdamWState SDS tree (f32 moments/master shard like their params)."""
    from repro.optim.adamw import AdamWState

    p = param_specs(cfg, mesh)

    def as_f32(s):
        return jax.ShapeDtypeStruct(s.shape, jnp.float32,
                                    sharding=getattr(s, "sharding", None))
    mu = jax.tree_util.tree_map(as_f32, p)
    nu = jax.tree_util.tree_map(as_f32, p)
    master = jax.tree_util.tree_map(as_f32, p) if opt_cfg.master_f32 else None
    step = _sds((), jnp.int32, mesh, ())
    return AdamWState(step=step, mu=mu, nu=nu, master=master)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                mesh: Optional[Mesh]) -> dict:
    """Training / prefill batch inputs."""
    B, S = shape.global_batch, shape.seq_len
    bspec = ("pod", "data")
    out = {}
    s_text = S - cfg.prefix_len if cfg.prefix_len else S
    out["tokens"] = _sds((B, s_text), jnp.int32, mesh, (bspec, None))
    if shape.kind == "train":
        out["labels"] = _sds((B, s_text), jnp.int32, mesh, (bspec, None))
    if cfg.prefix_len:
        out["prefix_embeds"] = _sds((B, cfg.prefix_len, cfg.d_model),
                                    jnp.dtype(cfg.dtype), mesh,
                                    (bspec, None, None))
    if cfg.is_encdec:
        out["encoder_frames"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                                     jnp.dtype(cfg.dtype), mesh,
                                     (bspec, None, None))
    return out


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int,
                mesh: Optional[Mesh]) -> dict:
    """Decode-state SDS tree.  KV: batch->data, seq->model (flash-decoding
    style partial-softmax sharding); SSM state: batch->data, heads->model."""
    shapes = jax.eval_shape(
        lambda: MDL.init_cache(cfg, batch, max_seq))

    def attach(path, sds):
        if path.endswith("pos"):
            return _sds(sds.shape, sds.dtype, mesh, ())
        if re.search(r"/(k|v)$", path):
            spec = (None, "data", "model", None, None)
        elif re.search(r"/(k_scale|v_scale)$", path):
            spec = (None, "data", "model", None)
        elif re.search(r"/(ck|cv)$", path):
            spec = (None, "data", None, "model", None)
        elif path.endswith("state"):
            spec = (None, "data", "model", None, None)
        elif path.endswith("conv"):
            spec = (None, "data", None, "model")
        else:
            spec = (None,) * len(sds.shape)
        return _sds(sds.shape, sds.dtype, mesh, spec)

    flat = tree_paths(shapes)
    leaves, treedef = jax.tree_util.tree_flatten(shapes)
    out = [attach(path, sds) for path, sds in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def decode_token_specs(cfg: ModelConfig, batch: int,
                       mesh: Optional[Mesh]):
    return _sds((batch,), jnp.int32, mesh, ("data",))


def decode_extra_specs(cfg: ModelConfig, batch: int,
                       mesh: Optional[Mesh]) -> dict:
    out = {}
    if cfg.is_encdec:
        out["encoder_frames"] = _sds(
            (batch, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype),
            mesh, ("data", None, None))
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                mesh: Optional[Mesh], opt_cfg: Optional[AdamWConfig] = None):
    """Full argument pytree for the shape's step function.

    train   -> (params, opt_state, batch)
    prefill -> (params, batch)
    decode  -> (params, cache, token[, extras])
    """
    if shape.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        return (param_specs(cfg, mesh), opt_specs(cfg, mesh, opt_cfg),
                batch_specs(cfg, shape, mesh))
    if shape.kind == "prefill":
        return (param_specs(cfg, mesh), batch_specs(cfg, shape, mesh))
    if shape.kind == "decode":
        max_seq = shape.seq_len
        if cfg.ring_kv and cfg.sliding_window:
            max_seq = min(max_seq, cfg.sliding_window)
        return (param_specs(cfg, mesh),
                cache_specs(cfg, shape.global_batch, max_seq, mesh),
                decode_token_specs(cfg, shape.global_batch, mesh),
                decode_extra_specs(cfg, shape.global_batch, mesh))
    raise ValueError(shape.kind)
