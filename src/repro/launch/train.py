"""Training driver: ``python -m repro.launch.train --arch smollm-360m ...``

Runs real training steps on whatever devices exist (the CPU container for
the examples/tests; the production mesh when launched on a pod).  The
--mesh flag selects the sharded path: params/opt-state are device_put
against the same sharding rules the dry-run lowers with, so this driver
IS the production launcher — the container just has a 1x1 mesh.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import sharding as SH
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.specs import param_specs
from repro.launch.steps import make_train_step
from repro.models import model as MDL
from repro.optim import adamw as OPT
from repro.checkpoint import ckpt as CKPT


def train_loop(cfg, *, steps: int, global_batch: int, seq_len: int,
               opt_cfg=None, mesh=None, log_every: int = 10,
               ckpt_dir: str | None = None, seed: int = 0,
               collect_history: bool = False):
    """Returns final (params, opt_state, history)."""
    opt_cfg = opt_cfg or OPT.AdamWConfig(total_steps=steps,
                                         warmup_steps=max(steps // 10, 1))
    mesh = mesh or make_host_mesh()
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=seq_len,
                                  global_batch=global_batch, seed=seed))
    step_fn = make_train_step(cfg, opt_cfg)

    with SH.mesh_context(mesh):
        params = init_sharded_params(cfg, mesh, seed)
        opt_state = OPT.init_state(params, opt_cfg)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        history = []
        t0 = time.perf_counter()
        for step, batch in enumerate(data.batches()):
            if step >= steps:
                break
            inputs = {
                "tokens": jnp.asarray(batch["tokens"]),
                "labels": jnp.asarray(batch["labels"]),
            }
            if cfg.prefix_len:
                inputs["tokens"] = inputs["tokens"][:, :-cfg.prefix_len]
                inputs["labels"] = inputs["labels"][:, :-cfg.prefix_len]
                inputs["prefix_embeds"] = _stub_prefix(
                    cfg, global_batch, batch["step"])
            if cfg.is_encdec:
                inputs["encoder_frames"] = _stub_frames(
                    cfg, global_batch, batch["step"])
            params, opt_state, metrics = jit_step(params, opt_state, inputs)
            if collect_history or step % log_every == 0 or step == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["wall_s"] = time.perf_counter() - t0
                history.append(m)
                if step % log_every == 0 or step == steps - 1:
                    print(f"step {step:5d}  loss {m['loss']:.4f}  "
                          f"lr {m['lr']:.2e}  gnorm {m['grad_norm']:.2f}",
                          flush=True)
        if ckpt_dir:
            CKPT.save(ckpt_dir, {"params": params}, step=steps)
    return params, opt_state, history


def init_sharded_params(cfg, mesh, seed: int):
    """init_params with per-leaf device placement matching the rules."""
    params = MDL.init_params(cfg, jax.random.PRNGKey(seed))
    specs = param_specs(cfg, mesh)
    return jax.tree_util.tree_map(
        lambda p, s: jax.device_put(p, s.sharding), params, specs)


def _stub_prefix(cfg, batch, step):
    rng = np.random.default_rng((step, 0xF00D))
    return jnp.asarray(rng.standard_normal(
        (batch, cfg.prefix_len, cfg.d_model), np.float32) * 0.02,
        jnp.dtype(cfg.dtype))


def _stub_frames(cfg, batch, step):
    rng = np.random.default_rng((step, 0xFEED))
    return jnp.asarray(rng.standard_normal(
        (batch, cfg.encoder_seq, cfg.d_model), np.float32) * 0.02,
        jnp.dtype(cfg.dtype))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant of the arch")
    ap.add_argument("--mesh", choices=["host", "pod", "multipod"],
                    default="host")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = {"host": make_host_mesh,
            "pod": lambda: make_production_mesh(multi_pod=False),
            "multipod": lambda: make_production_mesh(multi_pod=True)}[
        args.mesh]()
    opt_cfg = OPT.AdamWConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(args.steps // 10, 1))
    _, _, history = train_loop(
        cfg, steps=args.steps, global_batch=args.batch, seq_len=args.seq,
        opt_cfg=opt_cfg, mesh=mesh, ckpt_dir=args.ckpt)
    print(json.dumps(history[-1]))


if __name__ == "__main__":
    main()
