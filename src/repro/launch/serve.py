"""Serving driver: ``python -m repro.launch.serve --arch qwen15-moe-repro``.

Boots a model (fresh-init or checkpoint), wraps it in the SliceMoE server
and runs a batch of synthetic requests through the full offload-simulated
pipeline, printing per-request latency/energy — the end-to-end example of
the paper's deployment scenario.

Trace tooling (repro.sim):

* ``--record-trace PATH`` — additionally capture the served traffic's
  routing trace (``.npz`` or ``.jsonl``) for offline replay/autotuning.
* ``--replay-trace PATH`` — skip the model entirely: replay a recorded
  trace through the model-free simulator under THIS command line's
  engine knobs (``--cache-mb``, ``--miss-target``, ``--warmup``,
  ``--slice-mode``, ``--high-bits``/``--low-bits``, ``--routing``,
  ``--theta``) and print the simulated report as JSON.

Observability (repro.obs, see docs/observability.md):

* ``--trace-out PATH`` — export the charge-path timeline as
  Chrome-trace JSON (per-shard channel tracks + request spans); open
  in Perfetto.  Works on both the live and ``--replay-trace`` paths,
  and the two exports are event-identical for the same trace.
* ``--metrics-out PATH`` / ``--prom-out PATH`` — per-decode-step
  metrics registry time series (JSONL) / final Prometheus text.
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.checkpoint import ckpt as CKPT
from repro.configs.base import get_config
from repro.core.amat import MatConfig
from repro.core.engine import EngineConfig
from repro.models.moe import RoutingPolicy
from repro.models.model import init_params
from repro.serving.server import Request, SliceMoEServer


# One CLI-flag -> engine-knob mapping serves both the live path (with
# defaults applied) and the replay path (explicitly-passed flags only,
# so an untouched flag replays the trace's *recorded* value).  Flags
# default to None in argparse; the live defaults live here.
DEFAULT_KNOBS = {
    "high_bits": 8, "low_bits": 4, "cache_bytes": 4.0e6,
    "policy_kind": "cache_prior", "slice_mode": "dbsc", "theta": 0.5,
    "fetch_lsb_on_miss": True,
    "miss_rate_target": 0.05, "warmup": "pcw", "async_io": False,
    "lsb_keep_frac": 0.125, "system": "mobile_soc", "fused_slices": False,
    "hotness_request_decay": 0.5,
    "ep_shards": 1, "controller": None,
    "prefetch_top_m": None, "prefetch_kind": "request",
    "prefetch_lookahead": 2, "prefetch_min_obs": 0,
    "prefetch_min_score": 0.02,
    "placement": "round_robin", "placement_period": 64, "replicate_k": 0,
}


def parse_controller(spec):
    """``--controller`` value -> ControllerConfig.

    Accepts inline JSON (a string starting with ``{``) or a path to a
    JSON file; either way the payload is a
    :class:`repro.control.ControllerConfig` dict, e.g.
    ``{"slos": {"premium": {"miss_rate": 0.05}}}``.
    """
    if spec is None:
        return None
    from repro.control import ControllerConfig

    if spec.lstrip().startswith("{"):
        payload = json.loads(spec)
    else:
        with open(spec) as f:
            payload = json.load(f)
    return ControllerConfig.from_dict(payload)


def cli_engine_knobs(args) -> dict:
    """Engine knob values from the CLI; None where the flag was unset."""
    return {
        "high_bits": args.high_bits,
        "low_bits": args.low_bits,
        "cache_bytes": (None if args.cache_mb is None
                        else args.cache_mb * 1e6),
        "policy_kind": args.routing,
        "slice_mode": args.slice_mode,
        "theta": args.theta,
        "fetch_lsb_on_miss": args.fetch_lsb_on_miss,
        "miss_rate_target": args.miss_target,
        "warmup": args.warmup,
        "async_io": args.async_io,
        "lsb_keep_frac": args.lsb_keep_frac,
        "system": args.system,
        "fused_slices": args.fused_slices,
        "hotness_request_decay": args.hotness_request_decay,
        "ep_shards": args.ep_shards,
        "controller": parse_controller(args.controller),
        "prefetch_top_m": args.prefetch_top_m,
        "prefetch_kind": args.prefetch_kind,
        "prefetch_lookahead": args.prefetch_lookahead,
        "prefetch_min_obs": args.prefetch_min_obs,
        "prefetch_min_score": args.prefetch_min_score,
        "placement": args.placement,
        "placement_period": args.placement_period,
        "replicate_k": args.replicate_k,
    }


def build_engine_config(args) -> EngineConfig:
    k = {key: (DEFAULT_KNOBS[key] if v is None else v)
         for key, v in cli_engine_knobs(args).items()}
    return EngineConfig(
        mat=MatConfig(k["high_bits"], k["low_bits"]),
        cache_bytes=k["cache_bytes"],
        policy=RoutingPolicy(kind=k["policy_kind"],
                             slice_mode=k["slice_mode"],
                             theta=k["theta"],
                             fetch_lsb_on_miss=k["fetch_lsb_on_miss"]),
        miss_rate_target=k["miss_rate_target"],
        warmup=k["warmup"],
        async_io=k["async_io"],
        lsb_keep_frac=k["lsb_keep_frac"],
        system=k["system"],
        fused_slices=k["fused_slices"],
        hotness_request_decay=k["hotness_request_decay"],
        ep_shards=k["ep_shards"],
        controller=k["controller"],
        prefetch_top_m=k["prefetch_top_m"],
        prefetch_kind=k["prefetch_kind"],
        prefetch_lookahead=k["prefetch_lookahead"],
        prefetch_min_obs=k["prefetch_min_obs"],
        prefetch_min_score=k["prefetch_min_score"],
        placement=k["placement"],
        placement_period=k["placement_period"],
        replicate_k=k["replicate_k"],
    )


def run_replay(args) -> None:
    """Model-free path: replay a recorded trace.

    Knobs the user passed explicitly override the trace's recorded
    config; everything else replays as recorded — so a bare
    ``--replay-trace t.npz`` reproduces the live run exactly.
    """
    from repro.sim import Trace
    from repro.sim.replay import ReplayEngine

    trace = Trace.load(args.replay_trace)
    overrides = {key: v for key, v in cli_engine_knobs(args).items()
                 if v is not None}
    eng = ReplayEngine(trace.meta, **overrides)
    if args.trace_out:
        from repro.obs import TimelineTracer

        eng.attach_tracer(TimelineTracer())
    eng.consume_all(trace.events)
    report = eng.finish()
    if args.trace_out:
        eng.export_trace(args.trace_out)
    out = {
        "trace": args.replay_trace,
        "model": trace.meta.model,
        "overrides": {key: (v.to_dict() if hasattr(v, "to_dict") else v)
                      for key, v in overrides.items()},
        **report.summary(),
        "epoch_miss": [
            {"epoch": label, "miss_rate": round(m, 6)}
            for label, m in report.epoch_miss],
    }
    if args.trace_out:
        out["trace_out"] = args.trace_out
    print(json.dumps(out, indent=2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen15-moe-repro")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--n-requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    # Engine knobs default to None so the replay path can tell "flag
    # passed" from "defaulted"; live serving applies DEFAULT_KNOBS.
    ap.add_argument("--cache-mb", type=float, default=None,
                    help="DRAM cache budget in MB (live default 4.0)")
    ap.add_argument("--routing", default=None,
                    choices=["topk", "cache_prior", "cumsum"])
    ap.add_argument("--slice-mode", default=None,
                    choices=["dbsc", "highbit", "lowbit", "amat_static"])
    ap.add_argument("--warmup", default=None,
                    choices=["pcw", "empty", "last_layer", "random"])
    ap.add_argument("--high-bits", type=int, default=None)
    ap.add_argument("--low-bits", type=int, default=None)
    ap.add_argument("--theta", type=float, default=None)
    ap.add_argument("--fetch-lsb-on-miss",
                    action=argparse.BooleanOptionalAction, default=None,
                    help="fetch the LSB slice on an LSB miss; "
                         "--no-fetch-lsb-on-miss degrades the expert to "
                         "MSB-only compute instead (live default: fetch)")
    ap.add_argument("--miss-target", type=float, default=None,
                    help="miss-rate constraint (live default 0.05)")
    ap.add_argument("--lsb-keep-frac", type=float, default=None,
                    help="fraction of experts whose LSB slice PCW warmup "
                         "retains (live default 0.125)")
    ap.add_argument("--system", default=None,
                    help="hardware system profile from repro.hw.specs."
                         "SYSTEM_PROFILES (live default 'mobile_soc')")
    ap.add_argument("--fused-slices",
                    action=argparse.BooleanOptionalAction, default=None,
                    help="whole-expert caching: move MSB+LSB together "
                         "(high-bit baseline; live default: split slices)")
    ap.add_argument("--hotness-request-decay", type=float, default=None,
                    help="cross-request hotness aging factor applied at "
                         "each request boundary, 1.0 = never forget "
                         "(live default 0.5)")
    ap.add_argument("--async-io", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="asynchronous slice-I/O decode timeline "
                         "(live default: serialized; --no-async-io "
                         "forces a recorded async trace back to the "
                         "serialized replay)")
    ap.add_argument("--ep-shards", type=int, default=None,
                    help="expert-parallel shards: partition experts and "
                         "their DRAM slice caches round-robin across "
                         "this many shards, charging all-to-all token "
                         "dispatch on the interconnect channel (live "
                         "default 1 = single device)")
    ap.add_argument("--placement", default=None,
                    help="expert placement policy across EP shards: "
                         "'round_robin' (live default; expert %% shards), "
                         "'hotness' (greedy balanced bin-packing by "
                         "observed hotness, periodically re-placed with "
                         "migration charged on the interconnect), or "
                         "'hotness+replicate:K' (additionally replicate "
                         "the K hottest experts on every shard)")
    ap.add_argument("--placement-period", type=int, default=None,
                    help="decode steps between hotness re-placements "
                         "(live default 64; ignored by round_robin)")
    ap.add_argument("--replicate-k", type=int, default=None,
                    help="replicate the K globally hottest experts on "
                         "every shard (requires --placement hotness; "
                         "live default 0)")
    ap.add_argument("--prefetch-top-m", type=int, default=None,
                    help="enable speculative slice prefetch: max fills "
                         "issued per routed layer (live default: off)")
    ap.add_argument("--prefetch-kind", default=None,
                    choices=["request", "transition"],
                    help="predictor: 'request' = sparsity-aware "
                         "request-level activation predictor (default), "
                         "'transition' = one-step Markov baseline")
    ap.add_argument("--prefetch-lookahead", type=int, default=None,
                    help="request predictor: how many layer executions "
                         "ahead to score candidates (live default 2)")
    ap.add_argument("--prefetch-min-obs", type=int, default=None,
                    help="confidence gate: observations a target layer "
                         "needs before its candidates issue")
    ap.add_argument("--prefetch-min-score", type=float, default=None,
                    help="request predictor: activation-share floor "
                         "under the confidence-weighted admission gate "
                         "(live default 0.02)")
    ap.add_argument("--controller", default=None, metavar="JSON|PATH",
                    help="enable the closed-loop SLO controller "
                         "(repro.control): inline ControllerConfig JSON "
                         "or a path to a JSON file, e.g. "
                         "'{\"slos\": {\"default\": "
                         "{\"miss_rate\": 0.05}}}'.  Applies to live "
                         "serving and (as an override) to --replay-trace")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--record-trace", default=None, metavar="PATH",
                    help="save the served traffic's routing trace "
                         "(.npz or .jsonl) for offline replay")
    ap.add_argument("--replay-trace", default=None, metavar="PATH",
                    help="model-free: replay a recorded trace under this "
                         "command line's engine knobs and print the "
                         "simulated report (no model is built)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export the run's charge-path timeline as "
                         "Chrome-trace JSON (open in Perfetto / "
                         "chrome://tracing); works for live serving and "
                         "--replay-trace")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the per-decode-step metrics registry "
                         "time series as JSONL (live serving only)")
    ap.add_argument("--prom-out", default=None, metavar="PATH",
                    help="write the final metrics registry state in "
                         "Prometheus text exposition format (live "
                         "serving only)")
    args = ap.parse_args()

    if args.replay_trace:
        run_replay(args)
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.ckpt:
        params = CKPT.restore(args.ckpt)["params"]
        params = jax.tree_util.tree_map(jax.numpy.asarray, params)
    else:
        params = init_params(cfg, jax.random.PRNGKey(args.seed))

    max_seq = args.prompt_len + args.max_new + 8
    server = SliceMoEServer(
        cfg, params,
        engine_cfg=build_engine_config(args) if cfg.has_moe else None,
        max_seq=max_seq)

    recorder = None
    if args.record_trace:
        from repro.sim import TraceRecorder

        recorder = server.attach_recorder(TraceRecorder())

    tracer = None
    if args.trace_out:
        from repro.obs import TimelineTracer

        tracer = server.attach_tracer(TimelineTracer())
    metrics = None
    if args.metrics_out or args.prom_out:
        from repro.obs import MetricsRegistry

        metrics = server.attach_metrics(MetricsRegistry())

    rng = np.random.default_rng(args.seed)
    for rid in range(args.n_requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=args.prompt_len).astype(np.int32)
        server.submit(Request(request_id=rid, prompt=prompt,
                              max_new_tokens=args.max_new))

    for c in server.run():
        line = {
            "request": c.request_id,
            "n_tokens": int(len(c.tokens)),
            "prefill_s": round(c.prefill_s, 3),
            "decode_s": round(c.decode_s, 3),
        }
        if c.metrics is not None:
            d = c.metrics["decode_totals"]
            line["sim_decode_energy_mJ"] = round(d["total_energy_j"] * 1e3, 3)
            line["sim_decode_latency_ms"] = round(
                d["total_latency_s"] * 1e3, 3)
            line["miss_rate"] = round(
                c.metrics["cache_stats"]["msb_misses"]
                / max(c.metrics["cache_stats"]["msb_hits"]
                      + c.metrics["cache_stats"]["msb_misses"], 1), 4)
        print(json.dumps(line))

    engine = getattr(server, "_engine", None)
    if engine is not None \
            and getattr(engine, "prefetcher", None) is not None:
        print(json.dumps({"prefetch": engine.prefetcher.summary()}))
    if engine is not None \
            and getattr(engine, "slo_controller", None) is not None:
        print(json.dumps(
            {"controller": engine.slo_controller.summary()}))
    if engine is not None and hasattr(engine, "shard_breakdown"):
        breakdown = engine.shard_breakdown()
        if breakdown is not None:
            print(json.dumps({"per_shard": [
                {k: round(v, 6) if isinstance(v, float) else v
                 for k, v in row.items() if k != "experts"}
                for row in breakdown]}))
            snap = engine.ledger.snapshot()
            print(json.dumps({
                "all_to_all_bytes": snap["ici_bytes"],
                "all_to_all_energy_mJ": round(
                    snap["ici_energy_j"] * 1e3, 6)}))
    if engine is not None and hasattr(engine, "placement_summary"):
        psum = engine.placement_summary()
        if psum is not None:
            print(json.dumps({"placement": psum}))

    if recorder is not None:
        tr = recorder.trace()
        path = tr.save(args.record_trace)
        print(json.dumps({"recorded_trace": path,
                          "n_prefills": tr.n_prefills,
                          "n_decode_steps": tr.n_decode_steps}))

    if tracer is not None:
        data = server.export_trace(args.trace_out)
        print(json.dumps({"trace_out": args.trace_out,
                          "n_trace_events": len(tracer.events),
                          "n_spans": len(tracer.spans),
                          "n_json_events": len(data["traceEvents"])}))
    if metrics is not None:
        if args.metrics_out:
            metrics.to_jsonl(args.metrics_out)
            print(json.dumps({"metrics_out": args.metrics_out,
                              "n_samples": len(metrics.series)}))
        if args.prom_out:
            with open(args.prom_out, "w") as f:
                f.write(metrics.prometheus_text())
            print(json.dumps({"prom_out": args.prom_out}))


if __name__ == "__main__":
    main()
