"""Serving driver: ``python -m repro.launch.serve --arch qwen15-moe-repro``.

Boots a model (fresh-init or checkpoint), wraps it in the SliceMoE server
and runs a batch of synthetic requests through the full offload-simulated
pipeline, printing per-request latency/energy — the end-to-end example of
the paper's deployment scenario.
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.checkpoint import ckpt as CKPT
from repro.configs.base import get_config
from repro.core.amat import MatConfig
from repro.core.engine import EngineConfig
from repro.models.moe import RoutingPolicy
from repro.models.model import init_params
from repro.serving.server import Request, SliceMoEServer


def build_engine_config(args) -> EngineConfig:
    return EngineConfig(
        mat=MatConfig(args.high_bits, args.low_bits),
        cache_bytes=args.cache_mb * 1e6,
        policy=RoutingPolicy(kind=args.routing, slice_mode=args.slice_mode,
                             theta=args.theta),
        miss_rate_target=args.miss_target,
        warmup=args.warmup,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen15-moe-repro")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--n-requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--cache-mb", type=float, default=4.0)
    ap.add_argument("--routing", default="cache_prior",
                    choices=["topk", "cache_prior", "cumsum"])
    ap.add_argument("--slice-mode", default="dbsc",
                    choices=["dbsc", "highbit", "lowbit", "amat_static"])
    ap.add_argument("--warmup", default="pcw",
                    choices=["pcw", "empty", "last_layer", "random"])
    ap.add_argument("--high-bits", type=int, default=8)
    ap.add_argument("--low-bits", type=int, default=4)
    ap.add_argument("--theta", type=float, default=0.5)
    ap.add_argument("--miss-target", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.ckpt:
        params = CKPT.restore(args.ckpt)["params"]
        params = jax.tree_util.tree_map(jax.numpy.asarray, params)
    else:
        params = init_params(cfg, jax.random.PRNGKey(args.seed))

    max_seq = args.prompt_len + args.max_new + 8
    server = SliceMoEServer(
        cfg, params,
        engine_cfg=build_engine_config(args) if cfg.has_moe else None,
        max_seq=max_seq)

    rng = np.random.default_rng(args.seed)
    for rid in range(args.n_requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=args.prompt_len).astype(np.int32)
        server.submit(Request(request_id=rid, prompt=prompt,
                              max_new_tokens=args.max_new))

    for c in server.run():
        line = {
            "request": c.request_id,
            "n_tokens": int(len(c.tokens)),
            "prefill_s": round(c.prefill_s, 3),
            "decode_s": round(c.decode_s, 3),
        }
        if c.metrics is not None:
            d = c.metrics["decode_totals"]
            line["sim_decode_energy_mJ"] = round(d["total_energy_j"] * 1e3, 3)
            line["sim_decode_latency_ms"] = round(
                d["total_latency_s"] * 1e3, 3)
            line["miss_rate"] = round(
                c.metrics["cache_stats"]["msb_misses"]
                / max(c.metrics["cache_stats"]["msb_hits"]
                      + c.metrics["cache_stats"]["msb_misses"], 1), 4)
        print(json.dumps(line))


if __name__ == "__main__":
    main()
