"""Analytic FLOP / HBM-traffic model per (arch x input-shape).

Why analytic: XLA's ``compiled.cost_analysis()`` counts a ``while`` body
ONCE, not x trip-count (verified empirically — a 10-iteration scan of
matmuls reports exactly 1/10 of the FLOPs).  Every layer stack here is a
``lax.scan``, so HLO-reported flops/bytes understate real cost by ~the
layer count.  The roofline therefore uses this analytic model for the
compute and memory terms (exact matmul accounting for a workload we
define ourselves), and the HLO numbers are recorded as diagnostics.
Collective bytes ARE taken from the HLO, scaled by while trip counts
(see dryrun.parse_collectives_scaled).

Conventions: one fused-multiply-add = 2 FLOPs.  Training cost multiplier
for in-scan weights: fwd + remat-fwd + backward(2x fwd) = 4x forward
FLOPs (we checkpoint per period, paper-standard remat).  Bytes model is
a *traffic lower bound*: each weight read once per pass from HBM,
activations r/w at block boundaries, KV cache streamed once per decode
step, optimizer state r/w in f32.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import BlockSpec, ModelConfig, ShapeConfig


@dataclasses.dataclass
class Costs:
    flops: float
    hbm_bytes: float
    detail: dict


def _mm(m, k, n) -> float:
    return 2.0 * m * k * n


def _block_fwd_flops(cfg: ModelConfig, spec: BlockSpec, T: float,
                     B: float, s_ctx: float, decode: bool) -> float:
    d = cfg.d_model
    f = 0.0
    if spec.mixer == "attn":
        h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        f += _mm(T, d, h * hd) + 2 * _mm(T, d, kv * hd) + _mm(T, h * hd, d)
        if decode:
            f += 2 * _mm(B * h, s_ctx, hd)            # scores + AV, q_len=1
        else:
            # causal: ~half the square (window-clipped)
            eff = min(s_ctx, cfg.sliding_window or s_ctx)
            f += 2 * 2.0 * B * h * s_ctx * eff * 0.5 * hd
        if cfg.is_encdec:
            f += _mm(T, d, h * hd) + _mm(T, h * hd, d) \
                + 2 * _mm(B * h, cfg.encoder_seq, hd) * (1 if decode else s_ctx / 1)
    else:
        ssm = cfg.ssm
        di = ssm.d_inner(d)
        hs, p, n = ssm.n_heads(d), ssm.head_dim, ssm.d_state
        f += _mm(T, d, ssm.in_proj_cols(d))
        f += 2.0 * T * ssm.conv_channels(d) * ssm.d_conv
        if decode:
            f += 2 * 2.0 * B * hs * p * n             # state update + out
        else:
            Q = ssm.chunk
            f += _mm(B * (T / B / Q), Q, n) * Q        # CB intra
            f += 2.0 * T * Q * hs * p                  # L*x intra
            f += 2 * 2.0 * T * n * hs * p              # states + y_off
        f += _mm(T, di, d)
    # FFN
    if spec.ffn == "dense":
        wi = 2 * cfg.d_ff if cfg.mlp_type in ("swiglu", "geglu") else cfg.d_ff
        f += _mm(T, d, wi) + _mm(T, cfg.d_ff, d)
    elif spec.ffn == "moe":
        m = cfg.moe
        wi = 2 * m.d_ff if m.mlp_type in ("swiglu", "geglu") else m.d_ff
        routed = T * m.top_k * m.capacity_factor
        f += _mm(T, d, m.n_experts)                    # router
        f += _mm(routed, d, wi) + _mm(routed, m.d_ff, d)
        if m.n_shared_experts:
            dsh = m.d_ff_shared or m.d_ff
            wish = 2 * dsh if m.mlp_type in ("swiglu", "geglu") else dsh
            f += _mm(T, d, wish) + _mm(T, dsh, d)
    return f


def param_bytes(cfg: ModelConfig) -> float:
    """Total parameter bytes, dtype-aware (bf16 / f32 ssm / uint8 codes)."""
    from repro.launch.sharding import tree_paths
    from repro.models.model import param_shapes

    total = 0.0
    for path, shape in tree_paths(param_shapes(cfg)):
        n = float(np.prod(shape))
        if path.endswith(("_codes", "_zps")):
            total += n                       # uint8
        elif path.endswith("_scales") or "A_log" in path or "/D" in path \
                or "dt_bias" in path:
            total += 4.0 * n                 # f32
        else:
            total += 2.0 * n                 # bf16
    return total


def analytic_costs(cfg: ModelConfig, shape: ShapeConfig) -> Costs:
    B = shape.global_batch
    S = shape.seq_len
    decode = shape.kind == "decode"
    T = float(B) if decode else float(B * S)
    s_ctx = float(S)
    d = cfg.d_model
    V = cfg.vocab_size

    # ---- forward flops over all layers -------------------------------
    layer_f = 0.0
    for spec in cfg.block_pattern:
        layer_f += _block_fwd_flops(cfg, spec, T, B, s_ctx, decode)
    layer_f *= cfg.n_periods
    if cfg.is_encdec and not decode:
        enc_T = float(B * cfg.encoder_seq)
        enc_f = cfg.encoder_layers * _block_fwd_flops(
            cfg, BlockSpec("attn", "dense"), enc_T, B,
            float(cfg.encoder_seq), False)
        layer_f += enc_f

    # embedding gather is ~free; unembed is a matmul
    T_loss = T if shape.kind == "train" else float(B)
    head_f = _mm(T_loss, d, V)

    if shape.kind == "train":
        # fwd + remat-recompute + bwd(2x); 'dots' policy saves matmul
        # outputs so the recompute pass skips them (elementwise only).
        remat_mult = 4.0 if cfg.remat_policy == "full" else 3.0
        flops = remat_mult * layer_f + 3.0 * head_f
    else:
        flops = layer_f + head_f

    # ---- HBM traffic --------------------------------------------------
    P = param_bytes(cfg)
    act_unit = T * d * 2.0                       # one residual tensor, bf16
    n_layers = cfg.n_layers + cfg.encoder_layers
    if shape.kind == "train":
        # weights: fwd + remat + 2x bwd reads + grad write; opt: m,v,master
        # read+write in f32 (= 6x param count in f32 bytes)
        w_traffic = 4.0 * P + P + 6.0 * (P * 2.0)
        a_traffic = 8.0 * act_unit * n_layers    # r/w at block boundaries,
        #                                          fwd + recompute + bwd
        logits_traffic = 2.0 * T_loss * V * 4.0 / 16.0  # chunked (1/16 live)
        kv_traffic = 0.0
    elif shape.kind == "prefill":
        w_traffic = P
        a_traffic = 4.0 * act_unit * n_layers
        logits_traffic = T_loss * V * 4.0
        kv_traffic = 2.0 * cfg.n_layers * B * S * cfg.n_kv_heads \
            * cfg.head_dim * 2.0 if cfg.has_attention else 0.0
    else:  # decode
        w_traffic = P
        a_traffic = 4.0 * act_unit * n_layers
        logits_traffic = T_loss * V * 4.0
        kv_traffic = 0.0
        kv_elem_bytes = 1.0 if cfg.kv_dtype == "int8" else 2.0
        for spec in cfg.block_pattern:
            if spec.mixer == "attn":
                eff = min(S, cfg.sliding_window or S) if cfg.subquadratic \
                    else S
                kv_traffic += cfg.n_periods * 2.0 * B * eff \
                    * cfg.n_kv_heads * (cfg.head_dim * kv_elem_bytes
                                        + (4.0 if cfg.kv_dtype == "int8"
                                           else 0.0))
            else:
                ssm = cfg.ssm
                kv_traffic += cfg.n_periods * B * ssm.n_heads(d) \
                    * ssm.head_dim * ssm.d_state * 4.0 * 2.0

    hbm = w_traffic + a_traffic + logits_traffic + kv_traffic
    return Costs(flops=flops, hbm_bytes=hbm, detail={
        "layer_fwd_flops": layer_f,
        "head_flops": head_f,
        "param_bytes": P,
        "weight_traffic": w_traffic,
        "activation_traffic": a_traffic,
        "kv_traffic": kv_traffic,
        "logits_traffic": logits_traffic,
    })


def model_flops_reference(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6*N_active*D (train) / 2*N_active*D (inference) — the MFU reference."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch
