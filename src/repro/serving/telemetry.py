"""Serving telemetry: per-request records and fleet aggregates.

Two clocks run through the serving subsystem:

* **simulated time** — the deterministic latency accumulated by the
  :class:`~repro.hw.energy.CostLedger` (Flash fills, DRAM reads, XPU
  matmuls on the modeled SoC).  All latency/throughput numbers the
  benchmarks report are in this clock, so results are reproducible on
  any host.
* **wall time** — host-side ``perf_counter`` spans, reported separately
  (jit compiles dominate it on small configs; it is *not* the paper
  metric).

Percentiles use the nearest-rank definition (ceil(p/100 * N)-th smallest)
— deterministic, no interpolation, exact for small N.
"""

from __future__ import annotations

import dataclasses
import math
import numbers
from typing import Dict, List, Optional


def percentile(values: List[float], p: float) -> float:
    """Nearest-rank percentile; p in [0, 100].

    Well-defined on every input the serving stack can produce:

    * empty input -> ``nan`` (never an exception — a summary over zero
      completed requests is still a summary);
    * a single sample is every percentile of itself (p=0 through 100);
    * accepts any sized iterable, including numpy arrays (no reliance
      on truthiness, which is ambiguous for ndarrays) and numpy
      scalars inside (result is always a builtin ``float``);
    * p outside [0, 100] raises ``ValueError`` even for empty input —
      a bad percentile is a caller bug, not a data condition.
    """
    if not 0 <= p <= 100:
        raise ValueError(f"percentile {p} out of range")
    ordered = sorted(float(v) for v in values)
    if len(ordered) == 0:
        return float("nan")
    if p == 0:
        return ordered[0]
    rank = math.ceil(p / 100.0 * len(ordered))
    return ordered[rank - 1]


@dataclasses.dataclass
class RequestRecord:
    """Lifecycle timestamps (simulated clock) and counters for one request."""

    request_id: int
    tenant: str = "default"
    prompt_len: int = 0
    arrival_t: float = 0.0
    admit_t: float = 0.0            # prefill started
    first_token_t: float = 0.0      # first decode token produced
    finish_t: float = 0.0
    n_generated: int = 0
    rejected: bool = False
    truncated: bool = False         # prompt clipped to fit max_seq budget
    miss_sum: float = 0.0           # per-step selection-weighted miss rates
    miss_steps: int = 0

    @property
    def ttft(self) -> float:
        return self.first_token_t - self.arrival_t

    @property
    def queue_delay(self) -> float:
        return self.admit_t - self.arrival_t

    @property
    def decode_s(self) -> float:
        return self.finish_t - self.first_token_t

    @property
    def per_token_s(self) -> float:
        if self.n_generated <= 1:
            return 0.0
        return self.decode_s / (self.n_generated - 1)

    @property
    def mean_miss_rate(self) -> float:
        return self.miss_sum / max(self.miss_steps, 1)


@dataclasses.dataclass
class StepRecord:
    """One batched decode step: fleet-level counters.

    ``latency_s`` is the step's advance of the timeline makespan.  Under
    the async slice-I/O timeline (``EngineConfig.async_io``) it is less
    than the sum of the step's transfer/compute durations; the gap is
    reported as ``overlap_saved_s`` (latency hidden by channel overlap)
    while ``io_stall_s`` is the time the XPU sat idle waiting on slice
    data this step.  Both are 0 under the serialized replay.
    """

    t: float                 # simulated time at end of step
    n_active: int
    miss_rate: float         # expert-level fleet miss rate this step
    latency_s: float         # simulated step latency
    energy_j: float
    io_stall_s: float = 0.0
    overlap_saved_s: float = 0.0
    # Per-tenant charge counters for the step (tenant -> {tokens,
    # accesses, misses, critical, critical_low}), populated when the
    # engine attributes its charge path (slot tenants known).  Feeds the
    # always-on per-tenant summary breakdown and the SLO controller.
    per_tenant: Optional[Dict[str, dict]] = None


class FleetTelemetry:
    """Aggregates request + step records into the serving report."""

    def __init__(self):
        self.requests: Dict[int, RequestRecord] = {}
        self.steps: List[StepRecord] = []
        self.rejected: List[int] = []
        # Listeners (e.g. repro.control.SLOController) receive the same
        # records as they land; each listener method is optional.
        self.listeners: List[object] = []

    def add_listener(self, listener: object) -> object:
        """Forward on_submit/on_first_token/on_step events to ``listener``
        (any missing method is skipped).  Returns the listener."""
        self.listeners.append(listener)
        return listener

    def _emit(self, method: str, record) -> None:
        for lst in self.listeners:
            fn = getattr(lst, method, None)
            if fn is not None:
                fn(record)

    # ------------------------------------------------------------ recording
    def on_submit(self, record: RequestRecord) -> None:
        self.requests[record.request_id] = record
        self._emit("on_submit", record)

    def on_reject(self, record: RequestRecord) -> None:
        record.rejected = True
        self.requests[record.request_id] = record
        self.rejected.append(record.request_id)

    def on_first_token(self, record: RequestRecord) -> None:
        """Called by the scheduler the step a request's first token lands
        (record.first_token_t is already set) — TTFT is observable here,
        not at finish, which is what admission control needs."""
        self._emit("on_first_token", record)

    def on_step(self, record: StepRecord) -> None:
        self.steps.append(record)
        self._emit("on_step", record)

    # ----------------------------------------------------------- aggregates
    def completed(self) -> List[RequestRecord]:
        return [r for r in self.requests.values()
                if not r.rejected and r.n_generated > 0]

    def miss_rate_curve(self) -> List[float]:
        """Fleet miss rate per decode step, in execution order."""
        return [s.miss_rate for s in self.steps]

    def energy_curve(self) -> List[float]:
        """Per-decode-step ledger energy, in execution order.

        With :meth:`miss_rate_curve`, this is the live half of the
        trace-replay fidelity gate: a replayed trace must reproduce both
        step-by-step (see benchmarks/sim_fidelity.py).
        """
        return [s.energy_j for s in self.steps]

    def latency_curve(self) -> List[float]:
        """Per-decode-step simulated latency, in execution order."""
        return [s.latency_s for s in self.steps]

    def steady_state_miss_rate(self, skip_frac: float = 0.5) -> float:
        """Mean fleet miss rate over the trailing (1-skip_frac) of steps."""
        curve = self.miss_rate_curve()
        if not curve:
            return float("nan")
        tail = curve[int(len(curve) * skip_frac):] or curve
        return sum(tail) / len(tail)

    def summary(self, *, total_energy_j: Optional[float] = None,
                wall_s: Optional[float] = None,
                per_shard: Optional[list] = None,
                prefetch: Optional[dict] = None,
                placement: Optional[dict] = None) -> dict:
        """Fleet aggregates.  ``per_shard`` (expert-parallel engines
        only) is the engine's shard breakdown — per-shard cache
        miss/energy/makespan rows — attached verbatim under
        ``"per_shard"``, and additionally summarized into shard-balance
        metrics (miss-rate spread, access imbalance).  ``prefetch``
        (prefetch-enabled engines only) is the prefetcher's outcome
        summary — issued/useful/late/wasted counts and the learned
        per-distance usefulness — attached verbatim under
        ``"prefetch"``.  ``placement`` (expert-parallel engines only) is
        the engine's placement summary — policy name, re-placement
        period, replica count, migration events/bytes — attached
        verbatim under ``"placement"``."""
        done = self.completed()
        ttfts = [r.ttft for r in done]
        per_tok = [r.per_token_s for r in done if r.n_generated > 1]
        n_tokens = sum(r.n_generated for r in done)
        sim_span = max((r.finish_t for r in done), default=0.0) - \
            min((r.arrival_t for r in done), default=0.0)
        out = {
            "n_requests": len(done),
            "n_rejected": len(self.rejected),
            "n_tokens": n_tokens,
            "sim_time_s": sim_span,
            "throughput_tok_per_s": n_tokens / sim_span if sim_span > 0
            else float("nan"),
            "ttft_p50_s": percentile(ttfts, 50),
            "ttft_p95_s": percentile(ttfts, 95),
            "ttft_p99_s": percentile(ttfts, 99),
            "per_token_p50_s": percentile(per_tok, 50),
            "per_token_p95_s": percentile(per_tok, 95),
            "queue_delay_p50_s": percentile(
                [r.queue_delay for r in done], 50),
            "mean_miss_rate": (
                sum(r.mean_miss_rate for r in done) / len(done)
                if done else float("nan")),
            "steady_state_miss_rate": self.steady_state_miss_rate(),
            "mean_batch_occupancy": (
                sum(s.n_active for s in self.steps) / len(self.steps)
                if self.steps else 0.0),
        }
        # Decode stall/overlap breakdown (async timeline; both 0 when
        # the engine replays serialized).
        decode_s = sum(s.latency_s for s in self.steps)
        stall_s = sum(s.io_stall_s for s in self.steps)
        saved_s = sum(s.overlap_saved_s for s in self.steps)
        out["decode_io_stall_s"] = stall_s
        out["decode_overlap_saved_s"] = saved_s
        out["decode_io_stall_frac"] = (
            stall_s / decode_s if decode_s > 0 else 0.0)
        out["decode_overlap_saved_frac"] = (
            saved_s / (decode_s + saved_s) if decode_s + saved_s > 0
            else 0.0)
        if total_energy_j is not None:
            out["energy_per_token_j"] = (
                total_energy_j / n_tokens if n_tokens else float("nan"))
        if wall_s is not None:
            out["wall_s"] = wall_s
            out["wall_tok_per_s"] = n_tokens / wall_s if wall_s > 0 \
                else float("nan")
        per_tenant: Dict[str, int] = {}
        for r in done:
            per_tenant[r.tenant] = per_tenant.get(r.tenant, 0) \
                + r.n_generated
        if len(per_tenant) > 1:
            out["tokens_per_tenant"] = per_tenant
        out["per_tenant"] = self.per_tenant_summary()
        if per_shard is not None:
            out["per_shard"] = per_shard
            rates = [row["miss_rate"] for row in per_shard]
            accs = [row["accesses"] for row in per_shard]
            if rates:
                mean_rate = sum(rates) / len(rates)
                mean_acc = sum(accs) / len(accs)
                # Spread (max-min) and imbalance factor (max/mean): the
                # quantities the hotness placement exists to shrink.
                out["shard_miss_spread"] = max(rates) - min(rates)
                out["shard_miss_imbalance"] = (
                    max(rates) / mean_rate if mean_rate > 0 else 1.0)
                out["shard_access_imbalance"] = (
                    max(accs) / mean_acc if mean_acc > 0 else 1.0)
        if prefetch is not None:
            out["prefetch"] = prefetch
        if placement is not None:
            out["placement"] = placement
        return out

    def per_tenant_summary(self) -> Dict[str, dict]:
        """Per-tenant breakdown: request-level percentiles always, plus
        charge-attributed miss rate and energy when the steps carry
        ``per_tenant`` counters (energy is split by the tenant's token
        share of each step — the only attribution a shared batched step
        admits)."""
        groups: Dict[str, List[RequestRecord]] = {}
        for r in self.completed():
            groups.setdefault(r.tenant, []).append(r)
        out: Dict[str, dict] = {}
        for tenant in sorted(groups):
            rs = groups[tenant]
            ttfts = [r.ttft for r in rs]
            per_tok = [r.per_token_s for r in rs if r.n_generated > 1]
            out[tenant] = {
                "n_requests": len(rs),
                "n_tokens": sum(r.n_generated for r in rs),
                "ttft_p50_s": percentile(ttfts, 50),
                "ttft_p95_s": percentile(ttfts, 95),
                "per_token_p50_s": percentile(per_tok, 50),
                "per_token_p95_s": percentile(per_tok, 95),
                "mean_miss_rate": (
                    sum(r.mean_miss_rate for r in rs) / len(rs)),
            }
        acc: Dict[str, int] = {}
        miss: Dict[str, int] = {}
        energy: Dict[str, float] = {}
        for s in self.steps:
            if not s.per_tenant:
                continue
            step_tokens = sum(int(row.get("tokens", 0))
                              for row in s.per_tenant.values())
            for tenant, row in s.per_tenant.items():
                acc[tenant] = acc.get(tenant, 0) \
                    + int(row.get("accesses", 0))
                miss[tenant] = miss.get(tenant, 0) \
                    + int(row.get("misses", 0))
                if step_tokens > 0:
                    energy[tenant] = energy.get(tenant, 0.0) + \
                        s.energy_j * int(row.get("tokens", 0)) / step_tokens
        for tenant, cell in out.items():
            if acc.get(tenant):
                cell["charged_miss_rate"] = miss[tenant] / acc[tenant]
            if tenant in energy and cell["n_tokens"]:
                cell["energy_per_token_j"] = \
                    energy[tenant] / cell["n_tokens"]
        return out


def format_summary(s: dict, title: str = "serving summary") -> str:
    """Render a summary dict as an indented text block.

    Handles everything :meth:`FleetTelemetry.summary` can emit: nested
    dicts, lists of dicts (``per_shard`` rows get an indexed sub-block
    each), numpy scalars (formatted as numbers, not
    ``np.float32(...)`` reprs), ``nan``, and empty containers.
    """
    lines = [f"--- {title} ---"]

    def _scalar(v) -> str:
        if isinstance(v, bool):
            return str(v)
        if isinstance(v, numbers.Integral):
            return str(int(v))
        if isinstance(v, numbers.Real):
            return f"{float(v):.6g}"
        return str(v)

    def _emit(d: dict, indent: int) -> None:
        pad = " " * indent
        for k, v in d.items():
            if isinstance(v, dict):
                lines.append(f"{pad}{k:>26}:")
                _emit(v, indent + 2)
            elif isinstance(v, (list, tuple)) and \
                    any(isinstance(e, dict) for e in v):
                lines.append(f"{pad}{k:>26}:")
                for i, e in enumerate(v):
                    if isinstance(e, dict):
                        lines.append(f"{pad}  {f'[{i}]':>26}:")
                        _emit(e, indent + 4)
                    else:
                        lines.append(f"{pad}  {f'[{i}]':>26}: {_scalar(e)}")
            elif isinstance(v, (list, tuple)):
                body = ", ".join(_scalar(e) for e in v)
                lines.append(f"{pad}{k:>26}: [{body}]")
            else:
                lines.append(f"{pad}{k:>26}: {_scalar(v)}")

    _emit(s, 2)
    return "\n".join(lines)
