"""Single-batch serving loop (the paper's deployment scenario, Fig. 1a).

On-device MoE serving processes one request at a time: prefill the prompt
(layer-parallel, streams experts from Flash), then decode token-by-token
under the miss-rate constraint.  This server wraps
:class:`~repro.core.engine.SliceMoEEngine` with a request queue, per-request
metrics and an end-of-sequence check, and is the driver behind
``examples/serve_slicemoe.py``.

For *non-MoE* architectures (dense/ssm/vlm/audio) a plain engine runs the
same prefill/decode without the expert cache simulation — SliceMoE's
technique is inapplicable there (DESIGN.md §4) but the serving path still
works, so every assigned arch is servable.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import EngineConfig, SliceMoEEngine
from repro.models import model as MDL


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 32
    eos_token: Optional[int] = None


@dataclasses.dataclass
class Completion:
    request_id: int
    tokens: np.ndarray
    prefill_s: float
    decode_s: float
    metrics: Optional[dict] = None


class PlainEngine:
    """Prefill+decode without offload simulation (non-MoE archs)."""

    def __init__(self, cfg: ModelConfig, params: dict, max_seq: int):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(partial(MDL.prefill, cfg=cfg,
                                        max_seq=max_seq))
        self._decode = jax.jit(partial(MDL.decode_step, cfg=cfg))

    def generate(self, prompt: np.ndarray, n_steps: int,
                 eos: Optional[int] = None, **kw):
        logits, cache, _ = self._prefill(
            self.params, tokens=jnp.asarray(prompt)[None], **kw)
        token = jnp.argmax(logits, -1).astype(jnp.int32)
        out = []
        for _ in range(n_steps):
            out.append(int(token[0]))
            if eos is not None and out[-1] == eos:
                break
            logits, cache, _ = self._decode(self.params, token=token,
                                            cache=cache)
            token = jnp.argmax(logits, -1).astype(jnp.int32)
        return np.asarray(out, np.int32), None


class SliceMoEServer:
    def __init__(self, cfg: ModelConfig, params: dict,
                 engine_cfg: Optional[EngineConfig] = None,
                 max_seq: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.engine_cfg = engine_cfg
        self.queue: List[Request] = []
        self.completions: List[Completion] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _fresh_engine(self):
        if self.cfg.has_moe and self.engine_cfg is not None:
            ecfg = dataclasses.replace(self.engine_cfg,
                                       max_seq=self.max_seq)
            return SliceMoEEngine(self.cfg, self.params, ecfg)
        return PlainEngine(self.cfg, self.params, self.max_seq)

    def run(self) -> List[Completion]:
        """Drain the queue, one request at a time (single-batch)."""
        while self.queue:
            req = self.queue.pop(0)
            engine = self._fresh_engine()
            t0 = time.perf_counter()
            if isinstance(engine, SliceMoEEngine):
                logits = engine.prefill(jnp.asarray(req.prompt)[None])
                t1 = time.perf_counter()
                first = jnp.argmax(logits, -1).astype(jnp.int32)
                toks, metrics = engine.decode(first, req.max_new_tokens)
                toks = np.asarray(toks[0])
                if req.eos_token is not None:
                    stop = np.nonzero(toks == req.eos_token)[0]
                    if stop.size:
                        toks = toks[:stop[0] + 1]
                t2 = time.perf_counter()
            else:
                t1 = time.perf_counter()
                toks, metrics = engine.generate(
                    req.prompt, req.max_new_tokens, eos=req.eos_token)
                t2 = time.perf_counter()
            self.completions.append(Completion(
                request_id=req.request_id, tokens=toks,
                prefill_s=t1 - t0, decode_s=t2 - t1, metrics=metrics))
        return self.completions
