"""Single-batch serving API (the paper's deployment scenario, Fig. 1a).

:class:`SliceMoEServer` keeps the seed's submit/run interface but is now
a thin compatibility wrapper over the continuous-batching scheduler in
:mod:`repro.serving.scheduler`, run with ``max_batch=1``: requests drain
FIFO from a :class:`collections.deque` (the seed's ``list.pop(0)`` was
O(n²) under load), one at a time, through a *persistent* engine — so
unlike the seed, the slice cache and hotness statistics stay warm across
requests.  Pass ``persistent=False`` to restore the seed's
fresh-engine-per-request behavior (the cold baseline the serving
benchmark measures against).

For *non-MoE* architectures (dense/ssm/vlm/audio) a plain engine runs the
same prefill/decode without the expert cache simulation — SliceMoE's
technique is inapplicable there (DESIGN.md §4) but the serving path still
works, so every assigned arch is servable.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import EngineConfig, PersistentEngine, SliceMoEEngine
from repro.models import model as MDL
from repro.serving.scheduler import (Completion, ContinuousBatchingScheduler,
                                     Request, SchedulerConfig)

__all__ = ["Request", "Completion", "PlainEngine", "SliceMoEServer"]


class PlainEngine:
    """Prefill+decode without offload simulation (non-MoE archs)."""

    def __init__(self, cfg: ModelConfig, params: dict, max_seq: int):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(partial(MDL.prefill, cfg=cfg,
                                        max_seq=max_seq))
        self._decode = jax.jit(partial(MDL.decode_step, cfg=cfg))

    def generate(self, prompt: np.ndarray, n_steps: int,
                 eos: Optional[int] = None, **kw):
        logits, cache, _ = self._prefill(
            self.params, tokens=jnp.asarray(prompt)[None], **kw)
        token = jnp.argmax(logits, -1).astype(jnp.int32)
        out = []
        for _ in range(n_steps):
            out.append(int(token[0]))
            if eos is not None and out[-1] == eos:
                break
            logits, cache, _ = self._decode(self.params, token=token,
                                            cache=cache)
            token = jnp.argmax(logits, -1).astype(jnp.int32)
        return np.asarray(out, np.int32), None


class SliceMoEServer:
    def __init__(self, cfg: ModelConfig, params: dict,
                 engine_cfg: Optional[EngineConfig] = None,
                 max_seq: int = 256, *, persistent: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.engine_cfg = engine_cfg
        self.persistent = persistent
        self.queue: Deque[Request] = deque()
        self.completions: List[Completion] = []
        self._engine: Optional[PersistentEngine] = None
        self._recorder = None
        self._tracer = None
        self._metrics = None
        # The scheduler behind the most recent run() (telemetry access).
        self.last_scheduler = None

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def attach_tracer(self, tracer):
        """Capture the engine's charge-path timeline (persistent MoE
        serving only, like :meth:`attach_recorder`).  The tracer wires
        into the shared engine as soon as it exists; export with
        ``server.export_trace(path)`` after :meth:`run`."""
        if not (self._moe_serving() and self.persistent):
            raise ValueError("timeline tracing requires persistent MoE "
                             "serving (has_moe + engine_cfg + "
                             "persistent=True)")
        self._tracer = tracer
        if self._engine is not None:
            self._engine.attach_tracer(tracer)
        return tracer

    def export_trace(self, path: str) -> dict:
        if self._engine is None or self._tracer is None:
            raise ValueError("no traced run: call attach_tracer() "
                             "before run()")
        return self._engine.export_trace(path)

    def attach_metrics(self, registry):
        """Sample the metrics registry per decode step (persistent MoE
        serving only).  The sampler wires into the scheduler each
        :meth:`run` builds."""
        if not (self._moe_serving() and self.persistent):
            raise ValueError("metrics sampling requires persistent MoE "
                             "serving (has_moe + engine_cfg + "
                             "persistent=True)")
        self._metrics = registry
        return registry

    def attach_recorder(self, recorder):
        """Record the served traffic's routing trace (persistent MoE
        serving only — a fresh-engine-per-request run has no single
        engine whose state a trace could replay against).  The recorder
        wires into the shared engine as soon as it exists."""
        if not (self._moe_serving() and self.persistent):
            raise ValueError("trace recording requires persistent MoE "
                             "serving (has_moe + engine_cfg + "
                             "persistent=True)")
        self._recorder = recorder
        if self._engine is not None:
            recorder.attach(self._engine)
        return recorder

    def _moe_serving(self) -> bool:
        return self.cfg.has_moe and self.engine_cfg is not None

    def _fresh_engine(self):
        if self._moe_serving():
            ecfg = dataclasses.replace(self.engine_cfg,
                                       max_seq=self.max_seq)
            return SliceMoEEngine(self.cfg, self.params, ecfg)
        return PlainEngine(self.cfg, self.params, self.max_seq)

    def _shared_engine(self) -> PersistentEngine:
        if self._engine is None:
            ecfg = dataclasses.replace(self.engine_cfg,
                                       max_seq=self.max_seq)
            self._engine = PersistentEngine(self.cfg, self.params, ecfg)
            if self._recorder is not None:
                self._recorder.attach(self._engine)
            if self._tracer is not None:
                self._engine.attach_tracer(self._tracer)
        return self._engine

    def run(self) -> List[Completion]:
        """Drain the queue FIFO, one request at a time (single-batch)."""
        if self._moe_serving() and self.persistent:
            sched = ContinuousBatchingScheduler(
                self._shared_engine(),
                SchedulerConfig(max_batch=1, max_queue=len(self.queue) + 1))
            if self._metrics is not None:
                sched.attach_metrics(self._metrics)
            self.last_scheduler = sched
            # Validate the whole queue before draining any of it: raising
            # mid-drain would strand already-dequeued requests.
            bad = [r for r in self.queue if not sched.servable(r)]
            if bad:
                raise ValueError(
                    "unservable request(s) "
                    f"{[r.request_id for r in bad]}: need 1 <= "
                    "max_new_tokens and prompt_len + max_new_tokens + 1 "
                    f"<= max_seq (max_seq={self.max_seq})")
            while self.queue:
                sched.submit(self.queue.popleft())
            self.completions.extend(sched.run())
            return self.completions
        # Cold path: a fresh engine per request (the seed baseline), or a
        # plain engine for non-MoE archs.
        while self.queue:
            req = self.queue.popleft()
            engine = self._fresh_engine()
            t0 = time.perf_counter()
            if isinstance(engine, SliceMoEEngine):
                logits = engine.prefill(jnp.asarray(req.prompt)[None])
                t1 = time.perf_counter()
                first = jnp.argmax(logits, -1).astype(jnp.int32)
                toks, metrics = engine.decode(first, req.max_new_tokens)
                toks = np.asarray(toks[0])
                if req.eos_token is not None:
                    stop = np.nonzero(toks == req.eos_token)[0]
                    if stop.size:
                        toks = toks[:stop[0] + 1]
                t2 = time.perf_counter()
            else:
                t1 = time.perf_counter()
                toks, metrics = engine.generate(
                    req.prompt, req.max_new_tokens, eos=req.eos_token)
                t2 = time.perf_counter()
            self.completions.append(Completion(
                request_id=req.request_id, tokens=toks,
                prefill_s=t1 - t0, decode_s=t2 - t1, metrics=metrics))
        return self.completions
