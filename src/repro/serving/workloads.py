"""Traffic scenario generation for the serving subsystem.

Produces deterministic (seeded) request streams with configurable
arrival processes, prompt/output length distributions and multi-tenant
mixes, so the scheduler can be exercised under the traffic shapes a
production deployment sees:

* ``poisson``     — exponential inter-arrival gaps at ``rate`` req/s of
  *simulated* time (the steady-traffic baseline).
* ``bursty``      — Poisson bursts: idle gaps between bursts of
  ``burst_size`` near-simultaneous arrivals (flash-crowd shape; stresses
  admission control and queue depth).
* ``closed_loop`` — all requests available at t=0 (offered load is
  admission-limited; measures pure service capacity).

Tenants model distinct workload classes sharing one engine (e.g. chat
vs. summarization): each has its own length distributions and a mix
weight.  Token ids are drawn from a per-tenant Zipf so different tenants
exercise *different* expert subsets — the interesting case for a shared
slice cache.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.scheduler import Request


@dataclasses.dataclass(frozen=True)
class LengthDist:
    """Integer length distribution: 'fixed' | 'uniform' | 'lognormal'.

    ``max_len`` bounds the lognormal's unbounded upper tail (a rare
    multi-sigma draw used to exceed the scheduler's prompt+max_new
    budget and get the whole request rejected at admission).  ``None``
    keeps the tail unbounded.
    """

    kind: str = "fixed"
    value: int = 32              # fixed: the value; lognormal: the median
    low: int = 8                 # uniform bounds
    high: int = 64
    sigma: float = 0.4           # lognormal shape
    max_len: Optional[int] = None  # upper clip for unbounded draws

    def sample(self, rng: np.random.Generator) -> int:
        if self.kind == "fixed":
            return int(self.value)
        if self.kind == "uniform":
            return int(rng.integers(self.low, self.high + 1))
        if self.kind == "lognormal":
            x = rng.lognormal(mean=np.log(max(self.value, 1)),
                              sigma=self.sigma)
            return int(np.clip(round(x), 1, self.max_len))
        raise ValueError(f"unknown length dist {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    name: str = "default"
    weight: float = 1.0
    prompt_len: LengthDist = dataclasses.field(
        default_factory=lambda: LengthDist("fixed", 32))
    output_len: LengthDist = dataclasses.field(
        default_factory=lambda: LengthDist("fixed", 16))
    # Zipf skew of the tenant's token distribution; token ids are offset
    # per-tenant so tenants route to different experts.
    zipf_a: float = 1.3
    eos_token: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    kind: str = "poisson"        # 'poisson' | 'bursty' | 'closed_loop'
    n_requests: int = 16
    rate: float = 2.0            # mean arrivals per simulated second
    burst_size: int = 4          # bursty only
    burst_gap_s: float = 2.0     # bursty: mean gap between bursts
    seed: int = 0
    tenants: Tuple[TenantSpec, ...] = (TenantSpec(),)


# Generated streams are plain scheduler Requests (arrival_time and
# tenant are first-class Request fields); the old name stays as an alias.
TimedRequest = Request


def _arrival_times(cfg: WorkloadConfig,
                   rng: np.random.Generator) -> np.ndarray:
    n = cfg.n_requests
    if cfg.kind == "closed_loop":
        return np.zeros(n)
    if cfg.kind == "poisson":
        gaps = rng.exponential(1.0 / max(cfg.rate, 1e-9), size=n)
        return np.cumsum(gaps)
    if cfg.kind == "bursty":
        times = []
        t = 0.0
        while len(times) < n:
            for _ in range(cfg.burst_size):
                if len(times) >= n:
                    break
                # jitter within the burst keeps arrival order well-defined
                times.append(t + rng.uniform(0.0, 1e-3))
            t += rng.exponential(cfg.burst_gap_s)
        return np.asarray(sorted(times))
    raise ValueError(f"unknown workload kind {cfg.kind!r}")


def _sample_prompt(tenant: TenantSpec, length: int, vocab_size: int,
                   rng: np.random.Generator) -> np.ndarray:
    # Zipf-distributed ids, rotated by a per-tenant offset so tenants
    # occupy different token (and therefore expert) neighborhoods.
    # crc32, not hash(): str hash is salted per interpreter and would
    # break the seeded-stream determinism promise.
    raw = rng.zipf(tenant.zipf_a, size=length)
    offset = zlib.crc32(tenant.name.encode()) % vocab_size
    return ((raw + offset) % vocab_size).astype(np.int32)


def generate(cfg: WorkloadConfig, vocab_size: int,
             *, start_id: int = 0) -> List[Request]:
    """Deterministic request stream, sorted by arrival time."""
    rng = np.random.default_rng(cfg.seed)
    arrivals = _arrival_times(cfg, rng)

    weights = np.asarray([t.weight for t in cfg.tenants], np.float64)
    weights = weights / weights.sum()

    out: List[Request] = []
    for i, t_arr in enumerate(arrivals):
        tenant = cfg.tenants[int(rng.choice(len(cfg.tenants), p=weights))]
        plen = tenant.prompt_len.sample(rng)
        olen = tenant.output_len.sample(rng)
        out.append(Request(
            request_id=start_id + i,
            prompt=_sample_prompt(tenant, plen, vocab_size, rng),
            max_new_tokens=max(1, olen),
            arrival_time=float(t_arr),
            tenant=tenant.name,
            eos_token=tenant.eos_token,
        ))
    out.sort(key=lambda r: (r.arrival_time, r.request_id))
    return out


def generate_phased(phases: Sequence[WorkloadConfig], vocab_size: int,
                    *, gap_s: float = 0.0) -> List[Request]:
    """Concatenate per-phase streams into one phase-shifting workload.

    Each phase is a full :class:`WorkloadConfig` (its own tenant mix,
    arrival process and seed); phase ``k``'s arrivals are offset to start
    ``gap_s`` after the last arrival of phase ``k-1``, and request ids
    continue across phases.  This is how the SLO-controller soak builds
    traffic whose tenant mix *changes* mid-run — the case a static
    config cannot be right for on both sides of the shift.
    """
    out: List[Request] = []
    t0 = 0.0
    start_id = 0
    for cfg in phases:
        reqs = generate(cfg, vocab_size, start_id=start_id)
        for r in reqs:
            r.arrival_time = float(r.arrival_time) + t0
        out.extend(reqs)
        start_id += len(reqs)
        t0 = (max(r.arrival_time for r in reqs) if reqs else t0) + gap_s
    return out


def scenario(name: str, *, n_requests: int = 16, rate: float = 2.0,
             seed: int = 0) -> WorkloadConfig:
    """Named presets used by benchmarks and examples."""
    chat = TenantSpec(
        name="chat", weight=3.0,
        prompt_len=LengthDist("uniform", low=12, high=48),
        output_len=LengthDist("lognormal", value=16, sigma=0.5,
                              max_len=64))
    summarize = TenantSpec(
        name="summarize", weight=1.0,
        prompt_len=LengthDist("uniform", low=32, high=64),
        output_len=LengthDist("fixed", value=8))
    presets = {
        "steady": WorkloadConfig(
            kind="poisson", n_requests=n_requests, rate=rate, seed=seed),
        "bursty": WorkloadConfig(
            kind="bursty", n_requests=n_requests, rate=rate,
            burst_size=4, burst_gap_s=2.0 / max(rate, 1e-9), seed=seed),
        "closed_loop": WorkloadConfig(
            kind="closed_loop", n_requests=n_requests, seed=seed),
        "multi_tenant": WorkloadConfig(
            kind="poisson", n_requests=n_requests, rate=rate, seed=seed,
            tenants=(chat, summarize)),
    }
    if name not in presets:
        raise ValueError(
            f"unknown scenario {name!r}; have {sorted(presets)}")
    return presets[name]
