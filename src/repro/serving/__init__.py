"""Serving subsystem: continuous batching over a persistent SliceMoE engine.

Layers:
  * :mod:`repro.serving.scheduler` — admission control + continuous
    batching (slot packing, interleaved prefill, per-sequence retirement)
  * :mod:`repro.serving.workloads` — deterministic traffic generation
    (Poisson / bursty / closed-loop, multi-tenant mixes)
  * :mod:`repro.serving.telemetry` — per-request records, fleet
    percentiles, energy/token, warm-vs-cold miss curves
  * :mod:`repro.serving.server` — the seed's single-batch API, kept as a
    compatibility wrapper over the scheduler
"""

from repro.serving.scheduler import (Completion, ContinuousBatchingScheduler,
                                     Request, SchedulerConfig)
from repro.serving.server import PlainEngine, SliceMoEServer
from repro.serving.telemetry import FleetTelemetry, percentile
from repro.serving.workloads import (LengthDist, TenantSpec, TimedRequest,
                                     WorkloadConfig, generate, scenario)

__all__ = [
    "Completion", "ContinuousBatchingScheduler", "Request",
    "SchedulerConfig", "PlainEngine", "SliceMoEServer", "FleetTelemetry",
    "percentile", "LengthDist", "TenantSpec", "TimedRequest",
    "WorkloadConfig", "generate", "scenario",
]
