"""Continuous-batching scheduler over a persistent SliceMoE engine.

Replaces the seed's one-request-at-a-time loop: requests are admitted
into a fixed pool of ``max_batch`` decode *slots*, prefills interleave
with batched decode steps over all active slots, and sequences retire
individually on EOS or their token budget (their slot is immediately
refillable).  The engine — and with it the slice cache, the hotness
tracker and the cost ledger — persists across every request the
scheduler serves, so steady-state traffic runs against a *warm* cache.

Scheduling loop (one ``step()``):

  1. **Admission** — while a slot is free and the queue's head has
     arrived (simulated clock), pop it, run its prefill against the warm
     cache, and scatter its KV cache into the free slot.  Queue depth is
     bounded by ``max_queue``; submissions beyond it are rejected.
  2. **Batched decode** — one jitted ``decode_step`` over all
     ``max_batch`` slots with per-sequence positions; padding slots are
     masked out of cost accounting.
  3. **Retirement** — per-sequence EOS / length check; finished slots
     free up for the next admission.

The simulated clock is the cost ledger's accumulated latency, so
admission timing, TTFT and throughput are deterministic functions of the
workload and the modeled hardware — not of host jit times.

Per-request state (KV slot, step count, miss-rate-controller ``alpha``)
lives in :class:`ActiveSeq`; the batched call uses the mean alpha of the
active sequences (slots share one routing boost per step, a deliberate
simplification documented in docs/serving.md).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.engine import PersistentEngine
from repro.serving.telemetry import (FleetTelemetry, RequestRecord,
                                     StepRecord)


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 32
    eos_token: Optional[int] = None
    arrival_time: float = 0.0     # simulated seconds
    tenant: str = "default"


@dataclasses.dataclass
class Completion:
    request_id: int
    tokens: np.ndarray
    prefill_s: float              # wall seconds (host)
    decode_s: float
    metrics: Optional[dict] = None


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 4
    max_queue: int = 64
    # Truncate prompts down to a multiple of this many tokens (0 = exact
    # lengths).  Bounds the number of distinct prefill jit traces under
    # length-diverse workloads.  Setting this is itself explicit consent
    # to (up to bucket_prompts-1 tokens of) truncation — it applies to
    # admitted prompts regardless of `truncate_prompts`, and clipped
    # requests are flagged on telemetry either way.
    bucket_prompts: int = 0
    # Admit over-budget prompts by clipping them to the KV budget
    # (keeping the tail, recorded on telemetry as ``truncated``).  Off by
    # default: the output for a clipped request is not the output for
    # the full prompt, so silent truncation must be opted into —
    # otherwise admission rejects any request whose full token budget
    # (prompt + max_new_tokens) cannot fit under ``max_seq``.
    truncate_prompts: bool = False
    # Admission-control hook: called with the Request at submit time;
    # returning False rejects it (recorded on telemetry like any other
    # rejection).  When None and the engine carries an SLO controller
    # (EngineConfig.controller), the controller's admit_request is wired
    # in automatically — its throttle actuator needs a say in admission.
    admission_hook: Optional[Callable[["Request"], bool]] = None


@dataclasses.dataclass
class ActiveSeq:
    """Per-request state pinned to one decode slot."""

    slot: int
    request: Request
    record: RequestRecord
    controller: object                 # MissRateController | None
    alpha: float = 0.0
    last_token: int = 0
    generated: List[int] = dataclasses.field(default_factory=list)
    ledger_base: Optional[dict] = None # snapshot at decode start
    wall_prefill_s: float = 0.0
    wall_decode_t0: float = 0.0
    prefill_end_t: float = 0.0         # sim clock when prefill settled


class ContinuousBatchingScheduler:
    """Admission control + continuous batching over a PersistentEngine."""

    def __init__(self, engine: PersistentEngine,
                 cfg: Optional[SchedulerConfig] = None):
        self.engine = engine
        self.cfg = cfg or SchedulerConfig()
        if self.cfg.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[ActiveSeq]] = \
            [None] * self.cfg.max_batch
        self.batch_cache = engine.init_batch_cache(self.cfg.max_batch)
        self.telemetry = FleetTelemetry()
        self.completions: List[Completion] = []
        self.sim_time = 0.0
        self._ledger_mark = engine.ledger.total_latency_s
        self._admission_hook = self.cfg.admission_hook
        ctl = getattr(engine, "slo_controller", None)
        if ctl is not None:
            # Close the loop: the controller reads live telemetry (TTFT,
            # step records) and, absent an explicit hook, gates admission.
            ctl.attach_telemetry(self.telemetry)
            if self._admission_hook is None:
                self._admission_hook = ctl.admit_request

    def attach_recorder(self, recorder):
        """Wire a :class:`repro.sim.trace.TraceRecorder` into the engine.

        The engine hooks capture the replayable routing arrays; the
        scheduler additionally annotates each prefill event with the
        request id and tenant (which only it knows), so offline replays
        can be segmented per request / per tenant.  Returns the recorder
        for chaining.
        """
        return recorder.attach(self.engine)

    def attach_metrics(self, registry):
        """Sample a :class:`repro.obs.metrics.MetricsRegistry` per decode
        step: registers a :class:`~repro.obs.metrics.MetricsSampler` as a
        telemetry listener (the same mechanism the SLO controller rides),
        folding each StepRecord plus engine-side state — cache occupancy,
        ledger traffic, prefetch outcomes, controller actuation — into
        one catalog.  Returns the registry for chaining."""
        from repro.obs.metrics import MetricsSampler
        self.telemetry.add_listener(MetricsSampler(registry, self.engine))
        return registry

    # --------------------------------------------------------------- intake
    def servable(self, req: Request) -> bool:
        """Whether the request's *full* token budget fits the KV budget.

        Gates on ``len(prompt) + max_new_tokens``, not just the decode
        budget — a long prompt admitted on ``max_new_tokens`` alone would
        overflow its KV slot (or be silently truncated, which changes the
        answer).  With ``truncate_prompts`` the prompt side is waived:
        admission clips it to the budget and flags the request.
        (``bucket_prompts`` rounding is a separate, explicit opt-in and
        still applies to admitted prompts.)
        """
        max_seq = self.engine.ecfg.max_seq
        if not 1 <= req.max_new_tokens < max_seq - 1:
            return False
        if self.cfg.truncate_prompts:
            return True
        return len(req.prompt) + req.max_new_tokens + 1 <= max_seq

    def submit(self, req: Request) -> bool:
        """Admission control: reject queue overflow and unservable sizes.

        Rejecting here (rather than raising mid-run) keeps one bad
        request from aborting every in-flight sequence.
        """
        record = RequestRecord(
            request_id=req.request_id,
            tenant=getattr(req, "tenant", "default"),
            prompt_len=len(req.prompt),
            arrival_t=getattr(req, "arrival_time", 0.0))
        if len(self.queue) >= self.cfg.max_queue or not self.servable(req):
            self.telemetry.on_reject(record)
            return False
        if self._admission_hook is not None \
                and not self._admission_hook(req):
            self.telemetry.on_reject(record)
            return False
        self.telemetry.on_submit(record)
        self.queue.append(req)
        return True

    # ---------------------------------------------------------------- clock
    def _advance_clock(self) -> float:
        """Fold new ledger latency into the simulated clock; return delta."""
        now = self.engine.ledger.total_latency_s
        delta = now - self._ledger_mark
        self._ledger_mark = now
        self.sim_time += delta
        return delta

    # ------------------------------------------------------------ admission
    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def n_active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def _clip_prompt(self, req: Request) -> np.ndarray:
        """Fit the prompt under the KV budget (keeping its tail).

        Truncation is recorded on the request's telemetry record and in
        its completion metrics — the output for a clipped request is not
        the output for the full prompt.
        """
        prompt = np.asarray(req.prompt, np.int32)
        budget = self.engine.ecfg.max_seq - req.max_new_tokens - 1
        if budget < 1:
            raise ValueError(
                f"request {req.request_id}: max_new_tokens="
                f"{req.max_new_tokens} leaves no room for a prompt under "
                f"max_seq={self.engine.ecfg.max_seq}")
        if len(prompt) > budget:
            prompt = prompt[-budget:]
        q = self.cfg.bucket_prompts
        if q > 1 and len(prompt) > q:
            # Round down to a multiple of q, keeping the most recent
            # tokens (same tail-keep rule as the budget clip above).
            prompt = prompt[-(len(prompt) // q) * q:]
        if len(prompt) != len(req.prompt):
            self.telemetry.requests[req.request_id].truncated = True
        return prompt

    def _admit_one(self, req: Request, slot: int) -> None:
        record = self.telemetry.requests[req.request_id]
        record.admit_t = self.sim_time
        t0 = time.perf_counter()
        prompt = self._clip_prompt(req)
        # Per-request stats epochs are only meaningful when requests run
        # one at a time; under batching, concurrent sequences would bleed
        # into whichever epoch was opened last, mislabeling their misses.
        # Fleet-level numbers come from telemetry either way.
        label = f"req{req.request_id}" if self.cfg.max_batch == 1 else None
        logits, kv_cache, _info = self.engine.run_prefill(
            jnp.asarray(prompt)[None], label=label,
            inflight=self.n_active(), tenant=req.tenant)
        if self.engine.recorder is not None:
            self.engine.recorder.annotate_prefill(
                request_id=req.request_id, tenant=req.tenant)
        wall = time.perf_counter() - t0
        self._advance_clock()
        trc = getattr(self.engine, "tracer", None)
        if trc is not None:
            # Admission spans on the request's own track, in the same
            # sim-clock coordinates as the channel events.
            track = f"req{req.request_id}"
            trc.span("queue", track, record.arrival_t, record.admit_t,
                     request=req.request_id, tenant=req.tenant,
                     queue_delay_s=record.admit_t - record.arrival_t)
            trc.span("prefill", track, record.admit_t, self.sim_time,
                     request=req.request_id, slot=slot,
                     prompt_len=len(prompt))

        seq = ActiveSeq(
            slot=slot, request=req, record=record,
            controller=self.engine.new_controller(),
            last_token=int(jnp.argmax(logits, -1)[0]),
            ledger_base=self.engine.ledger.snapshot(),
            wall_prefill_s=wall,
            wall_decode_t0=time.perf_counter(),
            prefill_end_t=self.sim_time)
        self.batch_cache = self.engine.install_slot(
            self.batch_cache, kv_cache, slot)
        self.slots[slot] = seq

    def _admit(self) -> int:
        admitted = 0
        free = self._free_slots()
        while free and self.queue:
            req = self.queue[0]
            arrival = getattr(req, "arrival_time", 0.0)
            if arrival > self.sim_time:
                if self.n_active() == 0 and admitted == 0:
                    # fleet idle: fast-forward to the next arrival
                    self.sim_time = arrival
                else:
                    break
            self.queue.popleft()
            self._admit_one(req, free.pop(0))
            admitted += 1
        return admitted

    # --------------------------------------------------------------- decode
    def _decode_step(self) -> None:
        active = [s for s in self.slots if s is not None]
        if not active:
            return
        tokens = np.zeros(self.cfg.max_batch, np.int32)
        slot_mask = np.zeros(self.cfg.max_batch, bool)
        slot_tenants: List[Optional[str]] = [None] * self.cfg.max_batch
        for seq in active:
            tokens[seq.slot] = seq.last_token
            slot_mask[seq.slot] = True
            slot_tenants[seq.slot] = seq.request.tenant
        alphas = [seq.alpha for seq in active]
        alpha = float(np.mean(alphas)) if alphas else 0.0

        step_t0 = self.sim_time
        logits, self.batch_cache, charge = self.engine.decode_batch(
            jnp.asarray(tokens), self.batch_cache,
            alpha=alpha, slot_active=slot_mask,
            slot_tenants=slot_tenants)
        next_tokens = np.asarray(
            jnp.argmax(logits, axis=-1).astype(jnp.int32))
        step_latency = self._advance_clock()
        trc = getattr(self.engine, "tracer", None)
        if trc is not None:
            # One span per batched decode step on the shared steps
            # track; trc.step is the engine's step index, the id every
            # channel event of this step carries.
            trc.span("decode_step", "steps", step_t0, self.sim_time,
                     step=trc.step, n_active=len(active),
                     miss_rate=charge.miss_rate)
        self.telemetry.on_step(StepRecord(
            t=self.sim_time, n_active=len(active),
            miss_rate=charge.miss_rate, latency_s=step_latency,
            energy_j=charge.ledger_delta["total_energy_j"],
            io_stall_s=max(0.0, charge.ledger_delta.get(
                "io_stall_s", 0.0)),
            overlap_saved_s=max(0.0, charge.ledger_delta.get(
                "overlap_saved_s", 0.0)),
            per_tenant=charge.per_tenant))

        for seq in active:
            tok = int(next_tokens[seq.slot])
            seq.generated.append(tok)
            seq.last_token = tok
            if len(seq.generated) == 1:
                seq.record.first_token_t = self.sim_time
                self.telemetry.on_first_token(seq.record)
            seq.record.n_generated = len(seq.generated)
            slot_miss = float(charge.per_slot_miss[seq.slot])
            seq.record.miss_sum += slot_miss
            seq.record.miss_steps += 1
            if seq.controller is not None:
                seq.alpha = seq.controller.update(slot_miss)
            done = len(seq.generated) >= seq.request.max_new_tokens or \
                (seq.request.eos_token is not None
                 and tok == seq.request.eos_token)
            if done:
                self._retire(seq)

    def _retire(self, seq: ActiveSeq) -> None:
        seq.record.finish_t = self.sim_time
        trc = getattr(self.engine, "tracer", None)
        if trc is not None:
            rid = seq.request.request_id
            track = f"req{rid}"
            trc.span("decode", track, seq.prefill_end_t, self.sim_time,
                     request=rid, n_tokens=len(seq.generated),
                     ttft_s=seq.record.ttft,
                     queue_delay_s=seq.record.queue_delay)
            trc.span("retire", track, self.sim_time, self.sim_time,
                     request=rid)
        # Retirement fires on the step that produced EOS, so the token
        # list never holds tokens past it — no truncation scan needed.
        toks = np.asarray(seq.generated, np.int32)
        self.completions.append(Completion(
            request_id=seq.request.request_id,
            tokens=toks,
            prefill_s=seq.wall_prefill_s,
            decode_s=time.perf_counter() - seq.wall_decode_t0,
            metrics={
                "ttft_s": seq.record.ttft,
                "queue_delay_s": seq.record.queue_delay,
                "mean_miss_rate": seq.record.mean_miss_rate,
                "alpha_final": seq.alpha,
                "prompt_truncated": seq.record.truncated,
                # Exact for max_batch=1; overlaps concurrent requests
                # otherwise (fleet totals live in telemetry.summary()).
                "decode_totals": self.engine.ledger.delta_since(
                    seq.ledger_base),
                "cache_stats": self.engine.cache.stats.snapshot(),
                # ^ likewise: the current stats window, per-request only
                #   when requests run one at a time.
            }))
        self.slots[seq.slot] = None
        self.batch_cache = self.engine.clear_slot(
            self.batch_cache, seq.slot)

    # ------------------------------------------------------------------ run
    def step(self) -> bool:
        """One scheduler tick.  Returns False when fully idle."""
        self._admit()
        if self.n_active() == 0:
            return bool(self.queue)
        self._decode_step()
        return True

    def run(self) -> List[Completion]:
        """Drive until the queue drains and every sequence retires."""
        while self.step():
            pass
        self.engine._prefetch_flush()   # settle never-used pending fills
        self.engine.cache.end_epoch()   # flush the last request's window
        return self.completions

    def summary(self, **kw) -> dict:
        kw.setdefault("per_shard", self.engine.shard_breakdown())
        kw.setdefault("placement", self.engine.placement_summary())
        pf = getattr(self.engine, "prefetcher", None)
        if pf is not None:
            kw.setdefault("prefetch", pf.summary())
        return self.telemetry.summary(
            total_energy_j=self.engine.ledger.total_energy_j, **kw)
