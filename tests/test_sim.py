"""Trace-driven simulator: round-tripping, seeding, replay, autotune.

Fast tests run model-free (synthetic traces, numpy-only replay); the
live-engine fidelity gate — record from a real PersistentEngine, replay,
compare exactly — is marked slow like the other engine integrations.
"""

import dataclasses

import numpy as np
import pytest

from repro.serving.workloads import (LengthDist, TenantSpec,
                                     WorkloadConfig)
from repro.sim import (ReplayEngine, SyntheticSpec, Trace, TraceRecorder,
                       phase_shift_trace, replay_trace, tenant_mix_trace,
                       traces_equal, transition_trace, zipf_trace)
from repro.sim import autotune as at
from repro.sim.replay import engine_config_from_meta

SPEC = SyntheticSpec(n_moe_layers=3, n_experts=12, top_k=2)


def small_trace(seed=0, **kw):
    kw.setdefault("n_requests", 3)
    kw.setdefault("prompt_len", 6)
    kw.setdefault("decode_steps", 10)
    return zipf_trace(SPEC, seed=seed, **kw)


# --------------------------------------------------------------------------
# synthetic generators
# --------------------------------------------------------------------------
def test_synthetic_seeding_deterministic():
    a, b = small_trace(seed=7), small_trace(seed=7)
    assert traces_equal(a, b)
    assert not traces_equal(a, small_trace(seed=8))


def test_phase_shift_changes_hotness():
    tr = phase_shift_trace(SPEC, phases=2, requests_per_phase=1,
                           prompt_len=4, decode_steps=40, seed=0)
    # expert histograms of the two phases should differ materially
    half = len(tr.events) // 2
    def hist(events):
        ids = np.concatenate([e.ids.reshape(-1) for e in events])
        return np.bincount(ids, minlength=SPEC.n_experts)
    h1, h2 = hist(tr.events[:half]), hist(tr.events[half:])
    # cosine similarity below that of a stationary stream split in half
    cos = h1 @ h2 / (np.linalg.norm(h1) * np.linalg.norm(h2))
    st = small_trace(seed=0, n_requests=2, decode_steps=40)
    s1 = hist(st.events[:len(st.events) // 2])
    s2 = hist(st.events[len(st.events) // 2:])
    cos_st = s1 @ s2 / (np.linalg.norm(s1) * np.linalg.norm(s2))
    assert cos < cos_st


def test_tenant_mix_reuses_workload_distributions():
    wl = WorkloadConfig(
        kind="closed_loop", n_requests=40, seed=3,
        tenants=(TenantSpec(name="chat", weight=3.0,
                            output_len=LengthDist("fixed", 4)),
                 TenantSpec(name="sum", weight=1.0,
                            output_len=LengthDist("fixed", 4))))
    tr = tenant_mix_trace(SPEC, workload=wl)
    tenants = [e.tenant for e in tr.events if e.kind == "prefill"]
    assert len(tenants) == 40
    frac_chat = tenants.count("chat") / len(tenants)
    assert 0.55 <= frac_chat <= 0.92      # 3:1 mix within tolerance
    # identical seed => identical stream
    assert traces_equal(tr, tenant_mix_trace(SPEC, workload=wl))


def test_transition_structure_is_prefetchable():
    """Markov routing must be materially more prefetchable than Zipf.

    A tiny cache keeps the predictor's residency filter out of the
    comparison (with a warm cache, correctly-predicted hot experts are
    resident, so the prefetch slot is spent elsewhere and 'accuracy'
    measures the cache, not the routing structure)."""
    kw = dict(n_requests=2, prompt_len=8, decode_steps=100, seed=0)
    structured = transition_trace(SPEC, hot_targets=1,
                                  concentration=0.95, **kw)
    random_ish = zipf_trace(SPEC, **kw)
    accs = {}
    for name, tr in (("markov", structured), ("zipf", random_ish)):
        rep = replay_trace(tr, prefetch_top_m=2, warmup="empty",
                           prefetch_kind="transition",
                           cache_bytes=0.05 * SPEC.store_bytes())
        accs[name] = rep.prefetch["accuracy"]
    assert accs["markov"] > accs["zipf"] + 0.1, accs


# --------------------------------------------------------------------------
# serialization
# --------------------------------------------------------------------------
def test_roundtrip_npz_jsonl_parity(tmp_path):
    tr = small_trace(seed=5)
    p_npz = tr.save(str(tmp_path / "t.npz"))
    p_jsonl = tr.save(str(tmp_path / "t.jsonl"))
    a, b = Trace.load(p_npz), Trace.load(p_jsonl)
    assert traces_equal(tr, a)
    assert traces_equal(a, b)
    # replay determinism across formats and across repeated replays
    reps = [replay_trace(x) for x in (tr, a, b, tr)]
    for r in reps[1:]:
        assert r.ledger == reps[0].ledger
        assert r.miss_curve == reps[0].miss_curve
        assert r.epoch_counts == reps[0].epoch_counts


def test_save_unknown_extension_raises(tmp_path):
    with pytest.raises(ValueError):
        small_trace().save(str(tmp_path / "t.csv"))


# --------------------------------------------------------------------------
# replay
# --------------------------------------------------------------------------
def test_replay_epoch_structure():
    tr = small_trace(n_requests=2)
    rep = replay_trace(tr)
    labels = [label for label, _a, _m in rep.epoch_counts]
    assert labels == ["req0/prefill", "req0/decode",
                      "req1/prefill", "req1/decode"]
    assert rep.n_prefills == 2
    assert rep.n_decode_steps == 20
    assert len(rep.miss_curve) == 20
    assert rep.decode_accesses > 0


def test_replay_warm_cache_beats_cold_warmup():
    tr = small_trace(n_requests=4, decode_steps=20)
    warm = replay_trace(tr)                       # pcw default
    cold = replay_trace(tr, warmup="empty")
    assert warm.decode_miss_rate < cold.decode_miss_rate
    assert warm.total_energy_j < cold.total_energy_j


def test_replay_capacity_monotone():
    tr = small_trace(n_requests=3, decode_steps=20)
    base = tr.meta.engine["cache_bytes"]
    misses = [replay_trace(tr, cache_bytes=base * s).decode_miss_rate
              for s in (0.5, 1.0, 4.0)]
    assert misses[0] >= misses[1] >= misses[2]
    assert misses[0] > misses[2]


def test_replay_bit_plan_changes_bytes():
    tr = small_trace()
    mat84 = replay_trace(tr)
    mat63 = replay_trace(tr, high_bits=6, low_bits=3)
    assert mat63.ledger["flash_bytes"] < mat84.ledger["flash_bytes"]


def test_replay_engine_rejects_live_api():
    eng = ReplayEngine(small_trace().meta)
    with pytest.raises(TypeError):
        eng.run_prefill(None)
    with pytest.raises(TypeError):
        eng.decode_batch(None, None)


def test_engine_config_from_meta_rejects_unknown_knob():
    meta = small_trace().meta
    with pytest.raises(KeyError):
        engine_config_from_meta(meta, cache_byte=1e6)   # typo'd knob
    with pytest.raises(KeyError):
        SPEC.meta(not_a_knob=1)


def test_clone_forks_are_isolated():
    tr = small_trace(n_requests=4, decode_steps=12)
    cut = len(tr.events) // 2
    eng = ReplayEngine(tr.meta)
    eng.consume_all(tr.events[:cut])
    fork = eng.clone()
    # both futures replay the same remainder -> identical reports...
    rep_a = eng.consume_all(tr.events[cut:]).finish()
    rep_b = fork.consume_all(tr.events[cut:]).finish()
    assert rep_a.ledger == rep_b.ledger
    assert rep_a.miss_curve == rep_b.miss_curve
    assert rep_a.epoch_counts == rep_b.epoch_counts
    # ...and match an unforked straight-through replay exactly
    rep_c = replay_trace(tr)
    assert rep_a.ledger == rep_c.ledger
    assert rep_a.miss_curve == rep_c.miss_curve
    # diverging one fork must not disturb the other (state isolation)
    fork2 = ReplayEngine(tr.meta)
    fork2.consume_all(tr.events[:cut])
    fork3 = fork2.clone()
    before = fork2.ledger.snapshot()
    fork3.consume_all(tr.events[cut:])
    assert fork2.ledger.snapshot() == before


# --------------------------------------------------------------------------
# autotune
# --------------------------------------------------------------------------
def test_grid_cartesian_product():
    g = at.grid(cache_bytes=[1e6, 2e6], warmup=["pcw", "empty"],
                async_io=[False, True])
    assert len(g) == 8
    assert {frozenset(d.items()) for d in g} == \
        {frozenset(d.items()) for d in g}          # all distinct
    assert all(set(d) == {"cache_bytes", "warmup", "async_io"} for d in g)


def test_sweep_pareto_and_slo():
    tr = small_trace(n_requests=3, decode_steps=16)
    base = tr.meta.engine["cache_bytes"]
    policies = [{}] + at.grid(cache_bytes=[base * 2, base * 6],
                              warmup=["pcw", "empty"])
    results = at.sweep(tr, policies)
    assert len(results) == 5
    frontier = at.pareto_frontier(results)
    assert frontier
    # no frontier member may dominate another
    for a in frontier:
        for b in frontier:
            if a is b:
                continue
            assert not (a.energy_j <= b.energy_j
                        and a.latency_s <= b.latency_s
                        and a.miss_rate <= b.miss_rate
                        and (a.energy_j < b.energy_j
                             or a.latency_s < b.latency_s
                             or a.miss_rate < b.miss_rate))
    slo = sorted(r.miss_rate for r in results)[2]  # attainable SLO
    best = at.best_under_slo(results, slo)
    assert best is not None and best.miss_rate <= slo
    assert all(best.energy_j <= r.energy_j for r in results
               if r.meets_slo(slo))


def test_successive_halving_resume_is_exact():
    """A halving survivor's metrics equal a from-scratch full replay —
    the resumed state is the state, not an approximation."""
    tr = small_trace(n_requests=4, decode_steps=12)
    base = tr.meta.engine["cache_bytes"]
    policies = [("small", {"cache_bytes": base * 0.5}),
                ("default", {}),
                ("big", {"cache_bytes": base * 4}),
                ("big-empty", {"cache_bytes": base * 4,
                               "warmup": "empty"})]
    halved = at.sweep(tr, policies, successive_halving=True,
                      min_frac=0.25)
    assert len(halved) == 4
    full = {r.name: r for r in at.sweep(tr, policies)}
    for r in halved:
        if r.partial:
            assert r.events_consumed < len(tr.events)
            continue
        assert r.events_consumed == len(tr.events)
        assert r.energy_j == full[r.name].energy_j
        assert r.miss_rate == full[r.name].miss_rate
    assert any(not r.partial for r in halved)


# --------------------------------------------------------------------------
# workloads satellite: bounded lognormal draws
# --------------------------------------------------------------------------
def test_lengthdist_lognormal_max_len_clips_tail():
    rng = np.random.default_rng(0)
    heavy = LengthDist("lognormal", value=32, sigma=3.0, max_len=48)
    draws = [heavy.sample(rng) for _ in range(500)]
    assert max(draws) <= 48 and min(draws) >= 1
    # the same tail unbounded demonstrably exceeds the budget
    rng = np.random.default_rng(0)
    unbounded = LengthDist("lognormal", value=32, sigma=3.0)
    assert max(unbounded.sample(rng) for _ in range(500)) > 48


def test_lengthdist_max_len_keeps_requests_servable():
    """Regression: with max_len under the scheduler budget, no generated
    request can exceed prompt+max_new; before, a tail draw could."""
    from repro.serving.workloads import generate

    wl = WorkloadConfig(
        kind="closed_loop", n_requests=64, seed=1,
        tenants=(TenantSpec(
            prompt_len=LengthDist("lognormal", value=24, sigma=2.0,
                                  max_len=32),
            output_len=LengthDist("lognormal", value=8, sigma=2.0,
                                  max_len=15)),))
    for r in generate(wl, vocab_size=128):
        assert len(r.prompt) + r.max_new_tokens + 1 <= 48


# --------------------------------------------------------------------------
# live fidelity gate (slow: real engine + jit)
# --------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("async_io,prefetch",
                         [(False, None), (True, 4)])
def test_live_record_replay_fidelity(async_io, prefetch, tmp_path):
    import jax

    from repro.configs.base import get_config
    from repro.core.amat import MatConfig
    from repro.core.engine import EngineConfig, PersistentEngine
    from repro.models.model import init_params
    from repro.models.moe import RoutingPolicy
    from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                         SchedulerConfig)
    from repro.serving.workloads import generate

    cfg = dataclasses.replace(get_config("qwen15-moe-repro"), n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = PersistentEngine(cfg, params, EngineConfig(
        mat=MatConfig(8, 4), cache_bytes=1.0e6,
        policy=RoutingPolicy(kind="cache_prior", slice_mode="dbsc"),
        miss_rate_target=0.1, warmup="pcw", max_seq=64,
        async_io=async_io, prefetch_top_m=prefetch))
    sched = ContinuousBatchingScheduler(
        engine, SchedulerConfig(max_batch=2, max_queue=8))
    rec = sched.attach_recorder(TraceRecorder())
    wl = WorkloadConfig(
        kind="closed_loop", n_requests=3, seed=0,
        tenants=(TenantSpec(prompt_len=LengthDist("fixed", 12),
                            output_len=LengthDist("fixed", 6)),))
    for r in generate(wl, cfg.vocab_size):
        sched.submit(r)
    sched.run()

    trace = rec.trace()
    assert trace.n_prefills == 3
    # request ids + tenants annotated by the scheduler
    pf = [e for e in trace.events if e.kind == "prefill"]
    assert sorted(e.request_id for e in pf) == [0, 1, 2]

    # round trip through disk, then replay: exact live reproduction
    loaded = Trace.load(trace.save(str(tmp_path / "live.npz")))
    rep = replay_trace(loaded)
    assert rep.miss_curve == sched.telemetry.miss_rate_curve()
    assert rep.energy_curve == sched.telemetry.energy_curve()
    assert rep.epoch_counts == engine.cache.epoch_counts()
    live = engine.ledger.snapshot()
    for key in ("total_energy_j", "total_latency_s", "flash_bytes",
                "dram_bytes", "compute_ops", "n_flash_transfers",
                "n_prefetch_fills"):
        a, b = rep.ledger[key], live[key]
        assert a == b or abs(a - b) <= 1e-6 * max(abs(a), abs(b)), \
            (key, a, b)
    if prefetch:
        assert rep.prefetch == engine.prefetcher.summary()
