"""End-to-end system tests: train-improves-loss, serve pipeline,
quantized-decode fidelity — the integration layer above the unit tests."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# full-system integration: runs in the CI 'slow' job (pytest -m slow), not the fast tier-1 gate.
pytestmark = pytest.mark.slow

from repro.configs.base import get_config
from repro.core.amat import MatConfig
from repro.core.engine import EngineConfig, SliceMoEEngine
from repro.launch.train import train_loop
from repro.models.moe import RoutingPolicy
from repro.models.model import init_params
from repro.optim import adamw as OPT
from repro.serving.server import Request, SliceMoEServer


@pytest.mark.slow
class TestTraining:
    def test_loss_decreases_dense(self):
        cfg = get_config("smollm-360m").reduced()
        _, _, hist = train_loop(cfg, steps=25, global_batch=4, seq_len=32,
                                opt_cfg=OPT.AdamWConfig(
                                    lr=3e-3, total_steps=25, warmup_steps=2),
                                log_every=1000, collect_history=True)
        losses = [h["loss"] for h in hist]
        assert losses[-1] < losses[0] - 0.1, losses

    def test_loss_decreases_moe(self):
        cfg = get_config("qwen15-moe-repro")
        cfg = dataclasses.replace(cfg, n_layers=2)
        _, _, hist = train_loop(cfg, steps=20, global_batch=4, seq_len=32,
                                opt_cfg=OPT.AdamWConfig(
                                    lr=3e-3, total_steps=20, warmup_steps=2),
                                log_every=1000, collect_history=True)
        losses = [h["loss"] for h in hist]
        assert losses[-1] < losses[0] - 0.05, losses


class TestServing:
    def test_server_moe_arch(self):
        cfg = get_config("deepseek-v2-lite-repro")
        cfg = dataclasses.replace(cfg, n_layers=2)
        params = init_params(cfg, jax.random.PRNGKey(0))
        server = SliceMoEServer(
            cfg, params,
            engine_cfg=EngineConfig(
                mat=MatConfig(8, 4), cache_bytes=1e6,
                policy=RoutingPolicy(kind="cache_prior"),
                miss_rate_target=0.1),
            max_seq=64)
        rng = np.random.default_rng(0)
        for i in range(2):
            server.submit(Request(
                request_id=i,
                prompt=rng.integers(0, cfg.vocab_size, 24).astype(np.int32),
                max_new_tokens=8))
        done = server.run()
        assert len(done) == 2
        for c in done:
            assert len(c.tokens) == 8
            assert c.metrics is not None
            assert c.metrics["decode_totals"]["total_energy_j"] > 0

    def test_server_dense_arch(self):
        cfg = get_config("smollm-360m").reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        server = SliceMoEServer(cfg, params, engine_cfg=None, max_seq=64)
        server.submit(Request(request_id=0,
                              prompt=np.arange(16, dtype=np.int32),
                              max_new_tokens=4))
        done = server.run()
        assert len(done[0].tokens) == 4


class TestQuantizedDecodeFidelity:
    """AMAT decode must track the float model; naive low-bit-everything
    (lowbit mode) must be measurably worse than DBSC at equal cache."""

    @pytest.fixture(scope="class")
    def setup(self):
        cfg = get_config("qwen15-moe-repro")
        cfg = dataclasses.replace(cfg, n_layers=2)
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                                  cfg.vocab_size)
        from repro.models.model import prefill
        logits_oracle, _, _ = prefill(params, cfg, toks, max_seq=64)
        return cfg, params, toks, logits_oracle

    def _engine_logits(self, cfg, params, toks, slice_mode, theta=0.5):
        eng = SliceMoEEngine(cfg, params, EngineConfig(
            mat=MatConfig(8, 4), cache_bytes=50e6,   # everything fits
            policy=RoutingPolicy(kind="topk", slice_mode=slice_mode,
                                 theta=theta),
            warmup="pcw", max_seq=64))
        return np.asarray(eng.prefill(toks)), eng

    def test_highbit_engine_close_to_float(self, setup):
        cfg, params, toks, oracle = setup
        logits, _ = self._engine_logits(cfg, params, toks, "highbit")
        top_f = np.argsort(np.asarray(oracle)[0])[-5:]
        top_q = np.argsort(logits[0])[-5:]
        assert len(set(top_f) & set(top_q)) >= 3

    def test_dbsc_decode_vs_lowbit_decode(self, setup):
        """DBSC (critical experts high-bit) should be at least as close to
        the high-bit decode as uniformly-low-bit decode is."""
        cfg, params, toks, _ = setup

        def decode_logits(slice_mode):
            eng = SliceMoEEngine(cfg, params, EngineConfig(
                mat=MatConfig(8, 2),      # aggressive low bits: 2b
                cache_bytes=50e6,
                policy=RoutingPolicy(kind="topk", slice_mode=slice_mode,
                                     theta=0.3),
                warmup="pcw", max_seq=64))
            logits = eng.prefill(toks)
            first = jnp.argmax(logits, -1).astype(jnp.int32)
            ps = eng._policy_state()
            out, eng.kv_cache, _ = eng._jit_decode(
                eng.qparams, token=first, cache=eng.kv_cache,
                policy_state=ps, alpha=jnp.float32(0.0))
            return np.asarray(out)

        hi = decode_logits("highbit")
        db = decode_logits("dbsc")
        lo = decode_logits("lowbit")
        err_db = np.abs(db - hi).max()
        err_lo = np.abs(lo - hi).max()
        assert err_db <= err_lo + 1e-5, (err_db, err_lo)


class TestQuantExecutionParity:
    """Quantized execution (packed-code Pallas kernels) vs the
    dense-dequant reference path, end-to-end through the engine: at f32
    model dtype both jitted fns must agree to kernel-accumulation
    accuracy (1e-4)."""

    @pytest.fixture(scope="class")
    def setup(self):
        cfg = get_config("qwen15-moe-repro")
        cfg = dataclasses.replace(cfg, n_layers=2, dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0,
                                  cfg.vocab_size)
        return cfg, params, toks

    def _run(self, setup, quant_execution: bool):
        cfg, params, toks = setup
        eng = SliceMoEEngine(cfg, params, EngineConfig(
            mat=MatConfig(8, 4), cache_bytes=50e6,
            policy=RoutingPolicy(kind="topk", slice_mode="dbsc",
                                 quant_execution=quant_execution),
            warmup="pcw", max_seq=48))
        prefill_logits = eng.prefill(toks)
        first = jnp.argmax(prefill_logits, -1).astype(jnp.int32)
        tokens, _ = eng.decode(first, 4)
        ps = eng._policy_state()
        decode_logits, _, _ = eng._jit_decode(
            eng.qparams, token=first, cache=eng.kv_cache,
            policy_state=ps, alpha=jnp.float32(0.0))
        return (np.asarray(prefill_logits), np.asarray(decode_logits),
                np.asarray(tokens), eng)

    def test_decode_logits_match_dense_path(self, setup):
        pre_d, dec_d, tok_d, _ = self._run(setup, False)
        pre_q, dec_q, tok_q, _ = self._run(setup, True)
        np.testing.assert_allclose(pre_q, pre_d, atol=1e-4)
        np.testing.assert_allclose(dec_q, dec_d, atol=1e-4)
        np.testing.assert_array_equal(tok_q, tok_d)

    def test_quant_execution_moves_fewer_weight_bytes(self, setup):
        """The point of the tentpole: packed-code execution must stream
        >= 2x fewer expert-weight HBM bytes than dense dequant."""
        *_, eng = self._run(setup, True)
        dense = eng.expert_weight_bytes_per_step(quant_execution=False)
        quant = eng.expert_weight_bytes_per_step(quant_execution=True)
        assert quant * 2 <= dense, (quant, dense)

    def test_qparams_carry_transposed_wo_codes(self, setup):
        """quant_execution engines pre-transpose wo codes at quantize
        time so the hot path never transposes at step time."""
        *_, eng = self._run(setup, True)
        for blk in eng.qparams["blocks"].values():
            if "moe" in blk:
                e = blk["moe"]["experts"]
                assert "wo_codes_t" in e
                P, E, F, d = e["wo_q"].codes.shape
                assert e["wo_codes_t"].shape == (P, E, d, F)


@pytest.mark.slow
class TestTrainSSMDonation:
    def test_train_loop_ssm_arch_donation_safe(self):
        """Regression: f32 SSM params (A_log/D/dt_bias) must not alias the
        f32 optimizer master copy — jit donation of (params, opt_state)
        fails with 'donate the same buffer twice' if they do."""
        cfg = get_config("mamba2-2.7b").reduced()
        _, _, hist = train_loop(cfg, steps=3, global_batch=2, seq_len=16,
                                opt_cfg=OPT.AdamWConfig(
                                    lr=1e-3, total_steps=3, warmup_steps=1),
                                log_every=1000, collect_history=True)
        assert np.isfinite(hist[-1]["loss"])
