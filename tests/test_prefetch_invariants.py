"""Property-based invariants for the request-level prefetcher.

Three layers of guarantees, each tested at the level where it actually
holds:

* **Planner level** (`RequestPrefetcher.plan` / `plan_prefill`): pure
  functions of predictor state — budget caps, residency/pending
  exclusion, and the *per-call* monotonicity of the confidence gates
  (raising ``min_obs`` or ``min_score`` can only shrink the candidate
  set).  Note the monotonicity claim is deliberately per-call: at run
  level the outcome-feedback loop (``_p_useful``) breaks it, because a
  stricter gate changes which fills get judged and therefore future
  admission decisions.

* **Accounting level** (`mark_*` counters): the outcome partition
  ``issued == useful + late + wasted + in_flight`` under arbitrary
  interleavings, and the Laplace bounds of the learned per-distance
  usefulness.

* **Engine level** (`ReplayEngine` on synthetic traces): the same
  conservation through the real judge/flush path, exact agreement
  between the wasted counter and ``CostLedger.prefetch_wasted_energy_j``,
  and clone isolation of the full in-flight bookkeeping.

Runs under real ``hypothesis`` when installed; otherwise conftest.py
installs tests/_hypothesis_compat.py (same API, fixed-seed examples).
"""

import numpy as np

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.prefetch import RequestPrefetcher, TransitionPrefetcher
from repro.core.slices import SliceKey
from repro.sim import ReplayEngine, SyntheticSpec, replay_trace, zipf_trace

SPEC = SyntheticSpec(n_moe_layers=3, n_experts=12, top_k=2)


def small_trace(seed=0, **kw):
    kw.setdefault("n_requests", 3)
    kw.setdefault("prompt_len", 6)
    kw.setdefault("decode_steps", 12)
    return zipf_trace(SPEC, seed=seed, **kw)


def trained_prefetcher(seed, n_layers=3, n_experts=8, n_steps=6, **kw):
    """A RequestPrefetcher whose predictor has seen a random-but-seeded
    prefill plus ``n_steps`` decode observations per layer."""
    pf = RequestPrefetcher(n_layers, n_experts, seed=seed, **kw)
    rng = np.random.default_rng(seed)
    pf.begin_request(0.5)
    for layer in range(n_layers):
        ids = rng.integers(0, n_experts, size=(4, 2))
        pf.observe_prefill(layer, ids, rng.random((4, 2)))
    for _ in range(n_steps):
        for layer in range(n_layers):
            ids = rng.integers(0, n_experts, size=(2, 2))
            crit = ids.reshape(-1)[:2]
            pf.observe(layer, ids, rng.random((2, 2)), crit_ids=crit)
    return pf


def no_residency(_key):
    return False


def unit_bytes(_key):
    return 100.0


# ==========================================================================
# Planner level
# ==========================================================================
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), top_m=st.integers(1, 8),
       lookahead=st.integers(1, 4))
def test_plan_candidates_valid_and_within_top_m(seed, top_m, lookahead):
    pf = trained_prefetcher(seed, top_m=top_m, lookahead=lookahead)
    cands = pf.plan(0, np.array([0, 1]), is_resident=no_residency,
                    slice_bytes=unit_bytes, lsb_allowed=True)
    assert len(cands) <= top_m
    for key, d in cands:
        assert isinstance(key, SliceKey)
        assert 0 <= key.layer < pf.n_layers
        assert 0 <= key.expert < pf.n_experts
        assert key.kind in ("msb", "lsb")
        assert 1 <= d <= lookahead
        # the planned target really is `d` hops from the source layer
        assert key.layer == (0 + d) % pf.n_layers


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       resident_mod=st.integers(2, 4))
def test_plan_skips_resident_and_pending(seed, resident_mod):
    pf = trained_prefetcher(seed, top_m=64)
    resident = lambda k: k.expert % resident_mod == 0
    pend = [SliceKey(layer, 1, "msb") for layer in range(pf.n_layers)]
    cands = pf.plan(0, np.array([0, 1]), is_resident=resident,
                    slice_bytes=unit_bytes, pending=pend,
                    lsb_allowed=True)
    for key, _d in cands:
        assert not resident(key)
        assert key not in pend


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       lo=st.integers(0, 4), hi=st.integers(5, 40))
def test_plan_min_obs_monotone_per_call(seed, lo, hi):
    """Raising min_obs on identical predictor state can only remove
    candidates — with an unbounded budget the stricter plan is a strict
    subset; with any budget its size is non-increasing."""
    base = trained_prefetcher(seed, top_m=10_000)
    loose, strict = base.clone(), base.clone()
    loose.min_obs, strict.min_obs = lo, hi
    args = dict(is_resident=no_residency, slice_bytes=unit_bytes,
                lsb_allowed=True)
    got_loose = {k for k, _ in loose.plan(0, np.array([0, 1]), **args)}
    got_strict = {k for k, _ in strict.plan(0, np.array([0, 1]), **args)}
    assert got_strict <= got_loose
    assert len(got_strict) <= len(got_loose)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       lo=st.floats(0.0, 0.05), extra=st.floats(0.01, 0.5))
def test_plan_min_score_monotone_per_call(seed, lo, extra):
    base = trained_prefetcher(seed, top_m=10_000)
    loose, strict = base.clone(), base.clone()
    loose.min_score, strict.min_score = lo, lo + extra
    args = dict(is_resident=no_residency, slice_bytes=unit_bytes,
                lsb_allowed=True)
    got_loose = {k for k, _ in loose.plan(0, np.array([0, 1]), **args)}
    got_strict = {k for k, _ in strict.plan(0, np.array([0, 1]), **args)}
    assert got_strict <= got_loose


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), lo=st.integers(0, 1),
       hi=st.integers(2, 40), budget=st.integers(1, 30))
def test_plan_prefill_min_obs_monotone_and_budget(seed, lo, hi, budget):
    base = trained_prefetcher(seed, top_m=10_000)
    loose, strict = base.clone(), base.clone()
    loose.min_obs, strict.min_obs = lo, hi
    args = dict(is_resident=no_residency, slice_bytes=unit_bytes)
    got_loose = {k for k, _ in loose.plan_prefill(**args)}
    got_strict = {k for k, _ in strict.plan_prefill(**args)}
    assert got_strict <= got_loose
    capped = base.clone()
    assert len(capped.plan_prefill(budget=budget, **args)) <= budget


def test_plan_prefill_default_budget_and_distance_zero():
    pf = trained_prefetcher(3, top_m=2)
    cands = pf.plan_prefill(is_resident=no_residency,
                            slice_bytes=unit_bytes)
    assert len(cands) <= pf.top_m * pf.n_layers
    assert all(d == 0 for _k, d in cands)
    assert all(k.kind == "msb" for k, _d in cands)   # admission: MSB only


def test_plan_prefill_scores_from_fresh_admission_only():
    """plan_prefill keys off the *current* admission's prompt routing
    (pfrac), not the cross-request freq EMA: after begin_request with no
    new prefill observation, nothing is issued even though freq still
    carries (decayed) mass from earlier traffic."""
    pf = trained_prefetcher(5, top_m=8)
    assert pf.plan_prefill(is_resident=no_residency,
                           slice_bytes=unit_bytes)
    pf.begin_request(decay=1.0)   # keep freq mass, clear pfrac
    assert pf.predictor.freq.sum() > 0
    assert pf.plan_prefill(is_resident=no_residency,
                           slice_bytes=unit_bytes) == []


@settings(max_examples=30, deadline=None)
@given(score=st.floats(0.0, 1.0), p_lo=st.floats(0.01, 0.99),
       bump=st.floats(0.0, 0.5))
def test_admission_gate_monotone_in_confidence(score, p_lo, bump):
    """If a (score, p_useful) pair clears the gate, the same score at
    higher confidence clears it too — the self-throttle only ever cuts
    off the *low*-confidence side."""
    pf = RequestPrefetcher(2, 4, min_score=0.05)
    p_hi = min(p_lo + bump, 1.0)
    if pf._gate(score, p_lo):
        assert pf._gate(score, p_hi)


@settings(max_examples=20, deadline=None)
@given(marks=st.lists(st.tuples(st.sampled_from(["u", "l", "w"]),
                                st.integers(0, 3)),
                      min_size=0, max_size=40))
def test_p_useful_stays_in_open_unit_interval(marks):
    pf = RequestPrefetcher(2, 4, lookahead=3)
    for outcome, d in marks:
        pf.mark_issued(distance=d)
        {"u": pf.mark_useful, "l": pf.mark_late,
         "w": pf.mark_wasted}[outcome](distance=d)
    for d in range(6):    # beyond lookahead clamps to the last bucket
        assert 0.0 < pf._p_useful(d) < 1.0


# ==========================================================================
# Accounting level
# ==========================================================================
@settings(max_examples=20, deadline=None)
@given(events=st.lists(st.sampled_from(["i", "u", "l", "w"]),
                       min_size=0, max_size=60))
def test_outcome_conservation_under_interleaving(events):
    """issued == useful + late + wasted + in_flight at every point of
    any issue/resolve interleaving (resolves without a matching issue
    are dropped, as the engine never judges what it didn't issue)."""
    pf = RequestPrefetcher(2, 4)
    for ev in events:
        if ev == "i":
            pf.mark_issued(distance=1)
        elif pf.in_flight > 0:
            {"u": pf.mark_useful, "l": pf.mark_late,
             "w": pf.mark_wasted}[ev](distance=1)
        assert pf.issued == pf.useful + pf.late + pf.wasted + pf.in_flight
        assert pf.in_flight >= 0
    s = pf.summary()
    assert s["issued"] == s["useful"] + s["late"] + s["wasted"] \
        + s["in_flight"]
    assert 0.0 <= s["accuracy"] <= 1.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_clone_isolation_planner(seed):
    """clone() forks everything: the fork plans identically at the fork
    point, then the original's further learning and outcome marks leave
    the clone's state untouched."""
    pf = trained_prefetcher(seed, top_m=6)
    fork = pf.clone()
    args = dict(is_resident=no_residency, slice_bytes=unit_bytes,
                lsb_allowed=True)
    assert pf.plan(0, np.array([0, 1]), **args) \
        == fork.plan(0, np.array([0, 1]), **args)
    before = (fork.issued, fork.predictor.act.copy(),
              fork.predictor.trans.copy(), fork.dist_issued.copy())
    pf.mark_issued(distance=1)
    pf.mark_wasted(distance=1)
    pf.observe(1, np.array([2, 3]), np.array([0.5, 0.5]))
    pf.begin_request(0.0)
    assert fork.issued == before[0]
    np.testing.assert_array_equal(fork.predictor.act, before[1])
    np.testing.assert_array_equal(fork.predictor.trans, before[2])
    np.testing.assert_array_equal(fork.dist_issued, before[3])


def test_begin_request_ages_state_and_clears_transition_chain():
    pf = trained_prefetcher(11)
    act_before = pf.predictor.act.copy()
    pf.begin_request(decay=0.25)
    np.testing.assert_allclose(pf.predictor.act, act_before * 0.25)
    assert pf.predictor._prev is None
    assert pf.predictor.pfrac.sum() == 0.0


# ==========================================================================
# Transition baseline (kept behavior)
# ==========================================================================
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), top_m=st.integers(1, 6),
       resident_mod=st.integers(2, 5))
def test_transition_predict_respects_residency_and_budget(
        seed, top_m, resident_mod):
    tp = TransitionPrefetcher(3, 8, top_m=top_m, seed=seed)
    rng = np.random.default_rng(seed)
    for _ in range(5):
        for layer in (1, 2):
            tp.observe(layer, rng.integers(0, 8, 2), rng.integers(0, 8, 2))
    resident = np.arange(8) % resident_mod == 0
    pred = tp.predict(0, np.array([0, 1]), resident=resident)
    assert pred.size <= top_m
    assert np.all((pred >= 0) & (pred < 8))
    assert not np.any(resident[pred])


def test_transition_min_transitions_gates_cold_layers():
    tp = TransitionPrefetcher(3, 8, top_m=4, min_transitions=3)
    assert tp.predict(0, np.array([0])).size == 0     # cold: silent
    for _ in range(3):
        tp.observe(1, np.array([0]), np.array([1]))
    assert tp.predict(0, np.array([0])).size > 0      # warmed past floor
    assert tp.predict(1, np.array([1])).size == 0     # other layer still cold


def test_transition_conservation_counters():
    tp = TransitionPrefetcher(3, 8)
    tp.mark_issued(5)
    tp.mark_useful(2)
    tp.mark_late(1)
    tp.mark_wasted(2)
    assert tp.in_flight == 0
    s = tp.summary()
    assert s["issued"] == s["useful"] + s["late"] + s["wasted"]


# ==========================================================================
# Engine level: the real judge / flush / ledger path
# ==========================================================================
PF_KW = dict(prefetch_top_m=4, prefetch_kind="request",
             prefetch_lookahead=2, prefetch_min_score=0.02,
             async_io=True, warmup="empty")


def run_engine(trace, n_events=None, **overrides):
    kw = dict(PF_KW)
    kw.update(overrides)
    eng = ReplayEngine(trace.meta, **kw)
    events = trace.events if n_events is None else trace.events[:n_events]
    eng.consume_all(events)
    return eng


def test_engine_conservation_mid_run_and_after_flush():
    tr = small_trace(seed=0)
    eng = run_engine(tr, n_events=len(tr.events) // 2)
    pf = eng.prefetcher
    assert pf.issued > 0
    assert pf.issued == pf.useful + pf.late + pf.wasted + pf.in_flight
    eng.consume_all(tr.events[len(tr.events) // 2:])
    eng.finish()
    assert pf.in_flight == 0
    assert pf.issued == pf.useful + pf.late + pf.wasted
    assert not eng._pf_pending


def test_engine_flush_is_idempotent():
    tr = small_trace(seed=1)
    eng = run_engine(tr)
    eng.finish()
    snap = eng.prefetcher.summary()
    eng._prefetch_flush()
    eng.finish()
    assert eng.prefetcher.summary() == snap


def test_wasted_energy_matches_ledger_exactly():
    """Every wasted fill is one MSB slice under highbit mode, so the
    ledger's wasted-energy attribution must equal the wasted count times
    the per-slice fill energy (Flash read + DRAM write) to the float."""
    tr = small_trace(seed=2, n_requests=4, decode_steps=16)
    eng = run_engine(tr, slice_mode="highbit", cache_bytes=2.0e5)
    eng.finish()
    pf, led = eng.prefetcher, eng.ledger
    nb = eng.store.slice_bytes(SliceKey(0, 0, "msb"))
    per_fill = led.system.flash.transfer_energy_j(nb) \
        + led.system.dram.transfer_energy_j(nb)
    assert pf.wasted > 0    # small cache: some fills must die unused
    np.testing.assert_allclose(
        led.prefetch_wasted_energy_j, pf.wasted * per_fill, rtol=1e-9)


def test_issued_matches_ledger_prefetch_fill_count():
    """The request predictor charges exactly one background fill per
    issued candidate — capacity-skipped candidates count in neither."""
    tr = small_trace(seed=3)
    eng = run_engine(tr)
    eng.finish()
    assert eng.prefetcher.issued == eng.ledger.snapshot()["n_prefetch_fills"]
    assert eng.prefetcher.issued > 0


def test_engine_min_obs_gate_silences_run():
    tr = small_trace(seed=4)
    eng = run_engine(tr, prefetch_min_obs=10**6)
    eng.finish()
    assert eng.prefetcher.issued == 0
    assert eng.ledger.snapshot()["n_prefetch_fills"] == 0


def test_engine_clone_prefetch_isolation():
    """Forking mid-run forks the in-flight bookkeeping: the original
    draining its pending fills must not move the clone's counters, and
    both flush to independent, internally-conserved totals."""
    tr = small_trace(seed=5)
    eng = run_engine(tr, n_events=len(tr.events) // 2)
    fork = eng.clone()
    frozen = fork.prefetcher.summary()
    eng.consume_all(tr.events[len(tr.events) // 2:])
    eng.finish()
    assert fork.prefetcher.summary() == frozen
    fork.finish()
    fpf = fork.prefetcher
    assert fpf.in_flight == 0
    assert fpf.issued == fpf.useful + fpf.late + fpf.wasted


def test_replay_report_carries_conserved_prefetch_summary():
    tr = small_trace(seed=6)
    rep = replay_trace(tr, **PF_KW)
    s = rep.prefetch
    assert s is not None and s["kind"] == "request"
    assert s["in_flight"] == 0
    assert s["issued"] == s["useful"] + s["late"] + s["wasted"]
    assert s["issued"] == rep.ledger["n_prefetch_fills"]


def test_prefetch_off_charges_nothing():
    tr = small_trace(seed=7)
    rep = replay_trace(tr, async_io=True, warmup="empty")
    assert rep.prefetch is None
    assert rep.ledger["n_prefetch_fills"] == 0
    assert rep.ledger["prefetch_wasted_energy_j"] == 0.0
