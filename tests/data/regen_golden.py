"""Regenerate the golden-trace regression fixture.

Run from the repo root after an *intentional* charge-path change:

    PYTHONPATH=src python tests/data/regen_golden.py

Writes ``golden_trace.npz`` (a seeded synthetic routing trace) and
``golden_expected.json`` (the expected replay observables for each
pinned engine configuration).  tests/test_golden_trace.py replays the
trace and compares: per-epoch miss *counts* exactly (integer fidelity —
rates alone can agree by coincidence), energy/latency at rtol 1e-6, and
prefetch outcome counters exactly.

Commit both files together with the change that moved the numbers, and
say why in the commit message — a diff here is a claim that the charge
path's behavior legitimately changed.
"""

import json
import pathlib
import sys

HERE = pathlib.Path(__file__).resolve().parent
sys.path.insert(0, str(HERE.parents[1] / "src"))

from repro.sim import SyntheticSpec, replay_trace, zipf_trace  # noqa: E402

SPEC = SyntheticSpec(n_moe_layers=3, n_experts=12, top_k=2)
TRACE_KW = dict(seed=20260808, n_requests=4, prompt_len=8,
                decode_steps=16, zipf_a=1.3)

# Pinned replay configurations.  "baseline" exercises the plain demand
# path (prefetch off, serialized); "request_prefetch" locks in the
# request-level predictor's full judge/flush behavior on the async
# timeline; "transition_prefetch" pins the Markov baseline so predictor
# work cannot silently shift it.
CONFIGS = {
    "baseline": dict(warmup="pcw"),
    "request_prefetch": dict(
        prefetch_top_m=4, prefetch_kind="request", prefetch_lookahead=2,
        prefetch_min_score=0.02, async_io=True, warmup="empty",
        cache_bytes=2.5e5),
    "transition_prefetch": dict(
        prefetch_top_m=4, prefetch_kind="transition", async_io=True,
        warmup="pcw"),
}

LEDGER_KEYS = ("total_energy_j", "flash_bytes", "dram_bytes",
               "n_flash_transfers", "n_dram_transfers",
               "n_prefetch_fills", "prefetch_wasted_energy_j")


def main() -> None:
    trace = zipf_trace(SPEC, **TRACE_KW)
    trace_path = trace.save(str(HERE / "golden_trace.npz"))

    expected = {"trace_kw": TRACE_KW, "configs": {}}
    for name, overrides in CONFIGS.items():
        rep = replay_trace(trace, **overrides)
        row = {
            "overrides": {k: v for k, v in overrides.items()},
            "epoch_counts": [[label, int(a), int(m)]
                             for label, a, m in rep.epoch_counts],
            "decode_accesses": int(rep.decode_accesses),
            "decode_misses": int(rep.decode_misses),
            "total_energy_j": rep.total_energy_j,
            "total_latency_s": rep.total_latency_s,
            "ledger": {k: rep.ledger[k] for k in LEDGER_KEYS},
        }
        if rep.prefetch is not None:
            row["prefetch"] = {k: rep.prefetch[k] for k in
                               ("kind", "issued", "useful", "late",
                                "wasted", "in_flight")}
        expected["configs"][name] = row

    out = HERE / "golden_expected.json"
    out.write_text(json.dumps(expected, indent=2) + "\n")
    print(f"wrote {trace_path}")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
