"""Performance-variant correctness (the §Perf hillclimb knobs).

Each variant must preserve model semantics: one-hot embedding and
window-sliced decode exactly; int8 KV and AMAT-quantized serving within
quantization tolerance.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# perf-variant sweeps: runs in the CI 'slow' job (pytest -m slow), not the fast tier-1 gate.
pytestmark = pytest.mark.slow

from repro.core.amat import MatConfig
from repro.configs.base import get_config
from repro.models.model import (decode_step, forward, init_params, prefill,
                                unembed)
from repro.models.moe import quantize_params_for_serve


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama4-scout-17b-a16e").reduced()
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    lp, cache, _ = prefill(params, cfg, toks, max_seq=32)
    t = jnp.argmax(lp, -1).astype(jnp.int32)
    ld_ref, _, _ = decode_step(params, cfg, t, cache)
    return cfg, params, toks, t, ld_ref


class TestVariants:
    def test_onehot_embed_exact(self, setup):
        cfg, params, toks, t, ref = setup
        c1 = dataclasses.replace(cfg, onehot_embed=True)
        lp, cache, _ = prefill(params, c1, toks, max_seq=32)
        ld, _, _ = decode_step(params, c1, t, cache)
        np.testing.assert_allclose(np.asarray(ld), np.asarray(ref),
                                   atol=1e-4)

    def test_int8_kv_close(self, setup):
        cfg, params, toks, t, ref = setup
        c2 = dataclasses.replace(cfg, kv_dtype="int8")
        lp, cache, _ = prefill(params, c2, toks, max_seq=32)
        assert cache["pos0"]["k"].dtype == jnp.int8
        assert "k_scale" in cache["pos0"]
        ld, _, _ = decode_step(params, c2, t, cache)
        rel = float(jnp.linalg.norm(ld - ref) / jnp.linalg.norm(ref))
        assert rel < 0.05, rel

    def test_quantized_serve_close(self, setup):
        cfg, params, toks, t, ref = setup
        c3 = dataclasses.replace(cfg, quantized_serve=True)
        mat = MatConfig(8, 4)
        qp = quantize_params_for_serve(params, c3, mat)
        assert "wi_codes" in qp["blocks"]["pos0"]["moe"]["experts"]
        lp, cache, _ = prefill(qp, c3, toks, max_seq=32, mat=mat)
        ld, _, _ = decode_step(qp, c3, t, cache, mat=mat)
        rel = float(jnp.linalg.norm(ld - ref) / jnp.linalg.norm(ref))
        assert rel < 0.05, rel

    def test_window_sliced_decode_exact(self):
        cfg = get_config("smollm-360m").reduced()
        cfg = dataclasses.replace(cfg, dtype="float32", sliding_window=8,
                                  always_swa=True)
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(2), (1, 20), 0,
                                  cfg.vocab_size)
        lp, cache, _ = prefill(params, cfg, toks, max_seq=24)
        t = jnp.argmax(lp, -1).astype(jnp.int32)
        ld, _, _ = decode_step(params, cfg, t, cache)
        toks_full = jnp.concatenate([toks, t[:, None]], 1)
        h, _ = forward(params, cfg, toks_full)
        oracle = unembed(params, cfg, h[:, -1])
        np.testing.assert_allclose(np.asarray(ld), np.asarray(oracle),
                                   atol=1e-4)

    def test_seq_parallel_noop_on_host(self, setup):
        """Without a mesh, seq_parallel hints are identity."""
        cfg, params, toks, t, ref = setup
        c4 = dataclasses.replace(cfg, seq_parallel=True)
        lp, cache, _ = prefill(params, c4, toks, max_seq=32)
        ld, _, _ = decode_step(params, c4, t, cache)
        np.testing.assert_allclose(np.asarray(ld), np.asarray(ref),
                                   atol=1e-5)

    def test_quantized_serve_init_params(self):
        cfg = dataclasses.replace(
            get_config("llama4-scout-17b-a16e").reduced(),
            quantized_serve=True)
        params = init_params(cfg, jax.random.PRNGKey(0))
        e = params["blocks"]["pos0"]["moe"]["experts"]
        assert e["wi_codes"].dtype == jnp.uint8
        assert e["wi_scales"].dtype == jnp.float32
