"""Shared fixtures.  NOTE: no XLA device-count flags here — tests must see
the real single CPU device (the 512-device flag is dryrun.py-only)."""

import os
import sys

# The real hypothesis package when available; otherwise the deterministic
# seeded-sample shim so the suite collects and runs everywhere.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_compat
    _hypothesis_compat.install()

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
