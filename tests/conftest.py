"""Shared fixtures.  NOTE: no XLA device-count flags here — tests must see
the real single CPU device (the 512-device flag is dryrun.py-only)."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
