"""MoE dispatch/combine + quantized expert path."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# jitted MoE end-to-end paths: runs in the CI 'slow' job (pytest -m slow), not the fast tier-1 gate.
pytestmark = pytest.mark.slow
from hypothesis import given, settings, strategies as st

from repro.core.amat import MAT84, amat_quantize
from repro.models.moe import (MoECfg, RoutingPolicy, capacity, combine,
                              dispatch, dispatch_indices, moe_apply,
                              moe_param_shapes, topk_select)


def _params(key, d, cfg: MoECfg):
    shapes = moe_param_shapes(d, cfg)
    leaves, treedef = jax.tree_util.tree_flatten(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    ks = jax.random.split(key, len(leaves))
    init = [jax.random.normal(k, s) * 0.1 for k, s in zip(ks, leaves)]
    return jax.tree_util.tree_unflatten(treedef, init)


CFG = MoECfg(n_experts=8, top_k=2, d_ff=32, capacity_factor=4.0)
D = 32   # >= quant group size (G32) for the quantized-expert tests


class TestDispatch:
    def test_positions_unique_per_expert(self, rng):
        probs = jax.nn.softmax(jax.random.normal(rng, (64, 8)), -1)
        gates, ids = topk_select(probs, 2)
        cap = capacity(64, 2, 8, 4.0)
        pos, keep = dispatch_indices(ids, gates, 8, cap)
        ids_np, pos_np, keep_np = map(np.asarray, (ids, pos, keep))
        seen = set()
        for t in range(64):
            for kk in range(2):
                if keep_np[t, kk]:
                    slot = (ids_np[t, kk], pos_np[t, kk])
                    assert slot not in seen, "double-booked expert slot"
                    seen.add(slot)
                    assert pos_np[t, kk] < cap

    def test_roundtrip_identity_when_experts_identity(self, rng):
        """dispatch -> (identity expert) -> combine == gate-weighted sum."""
        T = 32
        x = jax.random.normal(rng, (T, D))
        probs = jax.nn.softmax(jax.random.normal(
            jax.random.fold_in(rng, 1), (T, 8)), -1)
        gates, ids = topk_select(probs, 2)
        cap = capacity(T, 2, 8, 4.0)
        pos, keep = dispatch_indices(ids, gates, 8, cap)
        buf = dispatch(x, ids, pos, keep, 8, cap)
        y = combine(buf, ids, pos, keep, gates)
        # identity experts: y == sum_k gate_k * x == x (gates normalized)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-5)

    def test_capacity_drops_counted(self, rng):
        """With tiny capacity, overflow tokens are dropped, not corrupted."""
        T = 64
        x = jnp.ones((T, D))
        ids = jnp.zeros((T, 1), jnp.int32)       # all to expert 0
        gates = jnp.ones((T, 1))
        cap = 8
        pos, keep = dispatch_indices(ids, gates, 8, cap)
        assert int(np.asarray(keep).sum()) == 8
        buf = dispatch(x, ids, pos, keep, 8, cap)
        assert float(jnp.sum(buf[0])) == pytest.approx(8 * D)
        assert float(jnp.sum(buf[1:])) == 0.0


class TestMoEApply:
    def test_output_shape_and_aux(self, rng):
        params = _params(rng, D, CFG)
        x = jax.random.normal(rng, (32, D))
        y, aux = moe_apply(params, x, CFG)
        assert y.shape == (32, D)
        assert float(aux["aux_loss"]) > 0
        assert float(aux["dropped_frac"]) < 0.2

    def test_quantized_matches_float_closely(self, rng):
        params = _params(rng, D, CFG)
        x = jax.random.normal(rng, (32, D)) * 0.5
        y_float, aux_f = moe_apply(params, x, CFG)

        qp = dict(params)
        qp["experts"] = {
            "wi_q": amat_quantize(params["experts"]["wi"], MAT84),
            "wo_q": amat_quantize(params["experts"]["wo"], MAT84),
        }
        y_q, _ = moe_apply(qp, x, CFG, mat=MAT84,
                           gate_override=(aux_f["gates"], aux_f["ids"]))
        rel = float(jnp.linalg.norm(y_q - y_float)
                    / (jnp.linalg.norm(y_float) + 1e-9))
        assert rel < 0.05, f"8-bit expert path diverges: rel={rel}"

    def test_quant_execution_matches_dense_dequant(self, rng):
        """Tentpole parity: the packed-code kernel path must reproduce
        the gather-then-dequantize path at f32 to kernel-accumulation
        accuracy, for every use_lsb mask shape."""
        params = _params(rng, D, CFG)
        x = jax.random.normal(rng, (32, D)) * 0.5
        _, aux_f = moe_apply(params, x, CFG)
        go = (aux_f["gates"], aux_f["ids"])
        qp = dict(params)
        qp["experts"] = {
            "wi_q": amat_quantize(params["experts"]["wi"], MAT84),
            "wo_q": amat_quantize(params["experts"]["wo"], MAT84),
        }
        for ul in (None, jnp.ones(8, bool), jnp.zeros(8, bool),
                   jnp.arange(8) % 3 == 0):
            y_dense, _ = moe_apply(qp, x, CFG, mat=MAT84,
                                   gate_override=go, use_lsb=ul,
                                   quant_execution=False)
            y_kern, _ = moe_apply(qp, x, CFG, mat=MAT84,
                                  gate_override=go, use_lsb=ul,
                                  quant_execution=True)
            np.testing.assert_allclose(np.asarray(y_kern),
                                       np.asarray(y_dense), atol=1e-4)

    def test_quant_execution_uses_transposed_wo_codes(self, rng):
        """A pre-transposed wo code buffer (engine layout) must give the
        same result as the canonical layout."""
        params = _params(rng, D, CFG)
        x = jax.random.normal(rng, (16, D)) * 0.5
        _, aux_f = moe_apply(params, x, CFG)
        go = (aux_f["gates"], aux_f["ids"])
        wo_q = amat_quantize(params["experts"]["wo"], MAT84)
        base = {
            "wi_q": amat_quantize(params["experts"]["wi"], MAT84),
            "wo_q": wo_q,
        }
        qp = dict(params)
        qp["experts"] = base
        y_canon, _ = moe_apply(qp, x, CFG, mat=MAT84, gate_override=go,
                               quant_execution=True)
        qp_t = dict(params)
        qp_t["experts"] = dict(base,
                               wo_codes_t=jnp.swapaxes(wo_q.codes, -1, -2))
        y_t, _ = moe_apply(qp_t, x, CFG, mat=MAT84, gate_override=go,
                           quant_execution=True)
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_canon),
                                   atol=1e-4)

    def test_use_lsb_selects_precision(self, rng):
        params = _params(rng, D, CFG)
        x = jax.random.normal(rng, (16, D)) * 0.5
        qp = dict(params)
        qp["experts"] = {
            "wi_q": amat_quantize(params["experts"]["wi"], MAT84),
            "wo_q": amat_quantize(params["experts"]["wo"], MAT84),
        }
        _, aux = moe_apply(params, x, CFG)
        go = (aux["gates"], aux["ids"])
        y_hi, _ = moe_apply(qp, x, CFG, mat=MAT84, gate_override=go,
                            use_lsb=jnp.ones(8, bool))
        y_lo, _ = moe_apply(qp, x, CFG, mat=MAT84, gate_override=go,
                            use_lsb=jnp.zeros(8, bool))
        # 4-bit path differs measurably from 8-bit path
        assert float(jnp.linalg.norm(y_hi - y_lo)) > 1e-4

    def test_policy_dbsc_demand_consistent(self, rng):
        params = _params(rng, D, CFG)
        x = jax.random.normal(rng, (16, D))
        policy = RoutingPolicy(kind="cache_prior", slice_mode="dbsc",
                               theta=0.5)
        state = {"alpha": jnp.float32(0.0),
                 "cached_msb": jnp.ones(8, bool),
                 "cached_lsb": jnp.ones(8, bool)}
        y, aux = moe_apply(params, x, CFG, policy=policy, policy_state=state)
        ids, crit = np.asarray(aux["ids"]), np.asarray(aux["critical"])
        msb, lsb = np.asarray(aux["msb_needed"]), np.asarray(aux["lsb_needed"])
        # every selected expert demands its MSB
        assert msb[np.unique(ids)].all()
        # lsb demand only from critical selections
        crit_experts = np.unique(ids[crit]) if crit.any() else np.array([], int)
        assert set(np.nonzero(lsb)[0]) == set(crit_experts.tolist())

    def test_shared_expert_added(self, rng):
        cfg_s = dataclasses.replace(CFG, n_shared_experts=1, d_ff_shared=32)
        params = _params(rng, D, cfg_s)
        x = jax.random.normal(rng, (16, D))
        y_with, _ = moe_apply(params, x, cfg_s)
        p2 = dict(params)
        p2["shared"] = jax.tree_util.tree_map(jnp.zeros_like, params["shared"])
        y_without, _ = moe_apply(p2, x, cfg_s)
        assert float(jnp.linalg.norm(y_with - y_without)) > 1e-4


class TestPropertyBased:
    @settings(max_examples=20, deadline=None)
    @given(T=st.integers(4, 64), E=st.sampled_from([4, 8, 16]),
           k=st.integers(1, 3), seed=st.integers(0, 999))
    def test_combine_bounded_by_max_expert_output(self, T, E, k, seed):
        """Gate-weighted combine is a convex mix (no amplification)."""
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (T, D))
        probs = jax.nn.softmax(
            jax.random.normal(jax.random.fold_in(key, 1), (T, E)), -1)
        gates, ids = topk_select(probs, min(k, E))
        cap = capacity(T, min(k, E), E, 8.0)
        pos, keep = dispatch_indices(ids, gates, E, cap)
        buf = dispatch(x, ids, pos, keep, E, cap)
        y = combine(buf, ids, pos, keep, gates)
        assert float(jnp.max(jnp.abs(y))) <= float(jnp.max(jnp.abs(x))) + 1e-4
