"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# interpret-mode Pallas kernels: runs in the CI 'slow' job (pytest -m slow), not the fast tier-1 gate.
pytestmark = pytest.mark.slow
from hypothesis import given, settings, strategies as st

from repro.core.amat import PAPER_CONFIGS, amat_quantize
from repro.kernels.amat_matmul.kernel import amat_matmul_pallas
from repro.kernels.amat_matmul.ops import (amat_expert_matmul_qt,
                                           amat_expert_matmul_t,
                                           amat_matmul, amat_matmul_qt)
from repro.kernels.amat_matmul.ref import (amat_batched_matmul_ref,
                                           amat_batched_matmul_t_ref,
                                           amat_matmul_ref)
from repro.kernels.expert_matmul.ops import expert_matmul_qt
from repro.kernels.expert_matmul.ref import expert_matmul_ref
from repro.quant.groupquant import quantize

SHAPES_MKN = [
    (8, 32, 16),        # minimal
    (16, 64, 48),       # non-128 N
    (128, 256, 128),    # MXU-aligned
    (7, 96, 33),        # ragged M/N (padding path)
    (1, 32, 128),       # decode-like single token
]


class TestAmatMatmul:
    @pytest.mark.parametrize("mkn", SHAPES_MKN, ids=str)
    @pytest.mark.parametrize("mode,shift", [("high", 0), ("low", 4),
                                            ("low", 2)])
    @pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, rng, mkn, mode, shift, xdtype):
        M, K, N = mkn
        x = jax.random.normal(rng, (M, K)).astype(xdtype)
        w = jax.random.normal(jax.random.fold_in(rng, 1), (K, N)) * 0.1
        qt = quantize(w, bits=8, group_size=32, asymmetric=True)
        out = amat_matmul_qt(x, qt, shift=shift, mode=mode)
        ref = amat_matmul_ref(x, qt.codes, qt.scales, qt.zero_points,
                              group_size=32, shift=shift, mode=mode)
        tol = 1e-4 if xdtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=tol * max(1.0, float(jnp.max(jnp.abs(ref)))))

    def test_block_size_invariance(self, rng):
        M, K, N = 64, 128, 64
        x = jax.random.normal(rng, (M, K))
        w = jax.random.normal(jax.random.fold_in(rng, 1), (K, N)) * 0.1
        qt = quantize(w, bits=8, group_size=32, asymmetric=True)
        outs = [
            amat_matmul(x, qt.codes, qt.scales, qt.zero_points,
                        bm=bm, bn=bn, bk=bk)
            for bm, bn, bk in [(16, 16, 32), (64, 64, 64), (32, 64, 128)]
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                       atol=1e-4)

    def test_approximates_float_matmul(self, rng):
        """High path should track the unquantized matmul closely."""
        x = jax.random.normal(rng, (32, 128))
        w = jax.random.normal(jax.random.fold_in(rng, 1), (128, 64)) * 0.1
        qt = quantize(w, bits=8, group_size=32, asymmetric=True)
        out = amat_matmul_qt(x, qt)
        exact = x @ w
        rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
        assert rel < 0.01

    def test_pallas_call_pads_ragged_m(self, rng):
        """Regression: decode batches are rarely multiples of bm — the
        raw pallas entry point must pad M internally, not assert."""
        for M in (1, 7, 130):
            x = jax.random.normal(rng, (M, 64))
            w = jax.random.normal(jax.random.fold_in(rng, M), (64, 128)) * 0.1
            qt = quantize(w, bits=8, group_size=32, asymmetric=True)
            out = amat_matmul_pallas(x, qt.codes, qt.scales, qt.zero_points,
                                     bm=128, bn=128, bk=64, interpret=True)
            ref = amat_matmul_ref(x, qt.codes, qt.scales, qt.zero_points)
            assert out.shape == (M, 128)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=1e-3)


class TestAmatBatchedMatmul:
    """The quantized-execution kernel: per-expert use_lsb via scalar
    prefetch, across all paper MAT configs and ragged shapes."""

    @pytest.mark.parametrize("mat", PAPER_CONFIGS, ids=lambda m: m.name)
    @pytest.mark.parametrize("emkn", [(4, 16, 64, 32), (3, 7, 96, 33),
                                      (2, 1, 32, 128), (5, 130, 160, 16)],
                             ids=str)
    def test_matches_ref_paper_configs(self, rng, mat, emkn):
        E, M, K, N = emkn
        x = jax.random.normal(rng, (E, M, K))
        w = jax.random.normal(jax.random.fold_in(rng, 1), (E, K, N)) * 0.1
        qt = amat_quantize(w, mat)
        ul = jnp.arange(E) % 2 == 0               # mixed per-expert mask
        out = amat_expert_matmul_qt(x, qt, ul, shift=mat.shift)
        ref = amat_batched_matmul_ref(x, qt.codes, qt.scales,
                                      qt.zero_points, ul,
                                      group_size=mat.group_size,
                                      shift=mat.shift)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4)

    @pytest.mark.parametrize("mat", PAPER_CONFIGS, ids=lambda m: m.name)
    def test_transposed_variant_matches_ref(self, rng, mat):
        E, M, K, N = 3, 9, 64, 48
        x = jax.random.normal(rng, (E, M, K))
        w = jax.random.normal(jax.random.fold_in(rng, 1), (E, K, N)) * 0.1
        qt = amat_quantize(w, mat)
        ct = jnp.swapaxes(qt.codes, -1, -2)       # output-major wo layout
        ul = jnp.arange(E) % 2 == 1
        out = amat_expert_matmul_t(x, ct, qt.scales, qt.zero_points, ul,
                                   shift=mat.shift,
                                   group_size=mat.group_size)
        ref = amat_batched_matmul_t_ref(x, ct, qt.scales, qt.zero_points,
                                        ul, group_size=mat.group_size,
                                        shift=mat.shift)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4)
        # and the transposed layout agrees with the K-major kernel
        canon = amat_expert_matmul_qt(x, qt, ul, shift=mat.shift)
        np.testing.assert_allclose(np.asarray(out), np.asarray(canon),
                                   atol=1e-4)

    def test_use_lsb_extremes_match_static_modes(self, rng):
        """all-ones == high-bit dequant; all-zeros == AMAT truncation."""
        E, M, K, N = 2, 8, 64, 32
        x = jax.random.normal(rng, (E, M, K))
        w = jax.random.normal(jax.random.fold_in(rng, 1), (E, K, N)) * 0.1
        qt = quantize(w, bits=8, group_size=32, asymmetric=True)
        hi = amat_expert_matmul_qt(x, qt, jnp.ones(E, bool), shift=4)
        lo = amat_expert_matmul_qt(x, qt, jnp.zeros(E, bool), shift=4)
        for e in range(E):
            hi_ref = amat_matmul_ref(x[e], qt.codes[e], qt.scales[e],
                                     qt.zero_points[e], mode="high")
            lo_ref = amat_matmul_ref(x[e], qt.codes[e], qt.scales[e],
                                     qt.zero_points[e], shift=4,
                                     mode="low")
            np.testing.assert_allclose(np.asarray(hi[e]),
                                       np.asarray(hi_ref), atol=1e-4)
            np.testing.assert_allclose(np.asarray(lo[e]),
                                       np.asarray(lo_ref), atol=1e-4)
        assert float(jnp.linalg.norm(hi - lo)) > 1e-3

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 999), E=st.integers(1, 5))
    def test_property_random_masks(self, seed, E):
        key = jax.random.PRNGKey(seed)
        M, K, N = 6, 32, 16
        x = jax.random.normal(key, (E, M, K))
        w = jax.random.normal(jax.random.fold_in(key, 1), (E, K, N)) * 0.1
        qt = quantize(w, bits=8, group_size=32, asymmetric=True)
        ul = jax.random.bernoulli(jax.random.fold_in(key, 2), shape=(E,))
        out = amat_expert_matmul_qt(x, qt, ul, shift=4)
        ref = amat_batched_matmul_ref(x, qt.codes, qt.scales,
                                      qt.zero_points, ul, shift=4)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4)

    def test_block_size_invariance(self, rng):
        E, M, K, N = 2, 32, 128, 64
        x = jax.random.normal(rng, (E, M, K))
        w = jax.random.normal(jax.random.fold_in(rng, 1), (E, K, N)) * 0.1
        qt = quantize(w, bits=8, group_size=32, asymmetric=True)
        ul = jnp.array([True, False])
        outs = [amat_expert_matmul_qt(x, qt, ul, shift=4, bm=bm, bn=bn,
                                      bk=bk)
                for bm, bn, bk in [(16, 16, 32), (32, 64, 64),
                                   (128, 128, 128)]]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                       atol=1e-4)


class TestExpertMatmul:
    @pytest.mark.parametrize("eckn", [(4, 16, 64, 32), (8, 33, 96, 128),
                                      (2, 128, 128, 128), (3, 1, 32, 16)],
                             ids=str)
    def test_matches_ref(self, rng, eckn):
        E, C, K, N = eckn
        x = jax.random.normal(rng, (E, C, K))
        w = jax.random.normal(jax.random.fold_in(rng, 1), (E, K, N)) * 0.1
        qt = quantize(w, bits=8, group_size=32, asymmetric=True)
        ul = jnp.arange(E) % 2 == 0
        out = expert_matmul_qt(x, qt, ul, shift=4)
        ref = expert_matmul_ref(x, qt.codes, qt.scales, qt.zero_points, ul,
                                group_size=32, shift=4)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4)

    def test_use_lsb_flag_changes_result(self, rng):
        E, C, K, N = 2, 8, 64, 32
        x = jax.random.normal(rng, (E, C, K))
        w = jax.random.normal(jax.random.fold_in(rng, 1), (E, K, N)) * 0.1
        qt = quantize(w, bits=8, group_size=32, asymmetric=True)
        hi = expert_matmul_qt(x, qt, jnp.ones(E, bool), shift=4)
        lo = expert_matmul_qt(x, qt, jnp.zeros(E, bool), shift=4)
        assert float(jnp.linalg.norm(hi - lo)) > 1e-3

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 999), E=st.integers(1, 6))
    def test_property_random_flags(self, seed, E):
        key = jax.random.PRNGKey(seed)
        C, K, N = 8, 32, 16
        x = jax.random.normal(key, (E, C, K))
        w = jax.random.normal(jax.random.fold_in(key, 1), (E, K, N)) * 0.1
        qt = quantize(w, bits=8, group_size=32, asymmetric=True)
        ul = jax.random.bernoulli(jax.random.fold_in(key, 2), shape=(E,))
        out = expert_matmul_qt(x, qt, ul, shift=4)
        ref = expert_matmul_ref(x, qt.codes, qt.scales, qt.zero_points,
                                ul, group_size=32, shift=4)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4)


class TestFlashAttention:
    @pytest.mark.parametrize(
        "dims",
        [(1, 16, 16, 4, 2, 32, True, None),
         (2, 24, 40, 8, 2, 32, True, None),
         (1, 17, 33, 4, 4, 64, True, 8),      # ragged + sliding window
         (1, 16, 16, 4, 2, 32, False, None)], # non-causal (encoder)
        ids=str)
    def test_matches_ref(self, rng, dims):
        from repro.kernels.flash_attn.ops import flash_attention
        from repro.kernels.flash_attn.ref import flash_attention_ref

        B, Sq, Sk, H, Hkv, D, causal, win = dims
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (B, Sq, H, D))
        k = jax.random.normal(ks[1], (B, Sk, Hkv, D))
        v = jax.random.normal(ks[2], (B, Sk, Hkv, D))
        out = flash_attention(q, k, v, causal=causal, sliding_window=win,
                              bq=8, bk=8)
        ref = flash_attention_ref(q, k, v, causal=causal,
                                  sliding_window=win)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4)

    def test_block_size_invariance(self, rng):
        from repro.kernels.flash_attn.ops import flash_attention

        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (1, 32, 4, 32))
        k = jax.random.normal(ks[1], (1, 32, 2, 32))
        v = jax.random.normal(ks[2], (1, 32, 2, 32))
        outs = [flash_attention(q, k, v, bq=bq, bk=bk)
                for bq, bk in [(8, 8), (16, 32), (32, 16)]]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                       atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 999), sq=st.integers(4, 24),
           sk=st.integers(4, 24))
    def test_property_random_shapes(self, seed, sq, sk):
        from repro.kernels.flash_attn.ops import flash_attention
        from repro.kernels.flash_attn.ref import flash_attention_ref

        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (1, sq, 2, 16))
        k = jax.random.normal(ks[1], (1, sk, 2, 16))
        v = jax.random.normal(ks[2], (1, sk, 2, 16))
        out = flash_attention(q, k, v, bq=8, bk=8)
        ref = flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4)
