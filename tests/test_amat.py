"""AMAT quantization: Table-1 orderings + algebraic invariants (paper §4.2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.amat import (MAT84, PAPER_CONFIGS, MatConfig, amat_quantize,
                             dequant_high, dequant_low, dequant_mixed,
                             lsb_slice, msb_slice, reconstruct, truncate)
from repro.quant.groupquant import (dequantize, quantization_error, quantize)


def _weights(key, shape=(64, 128), scale=0.05, bias=0.01):
    return jax.random.normal(key, shape) * scale + bias


class TestSliceAlgebra:
    def test_reconstruct_lossless(self, rng):
        """MSB/LSB slices must reassemble the exact high-bit code."""
        for cfg in PAPER_CONFIGS:
            qt = amat_quantize(_weights(rng), cfg)
            m = msb_slice(qt.codes, cfg.shift)
            l = lsb_slice(qt.codes, cfg.shift)
            assert jnp.array_equal(reconstruct(m, l, cfg.shift), qt.codes)

    def test_msb_slice_is_truncated_code(self, rng):
        qt = amat_quantize(_weights(rng), MAT84)
        lo = truncate(qt, low_bits=4)
        assert jnp.array_equal(lo.codes, msb_slice(qt.codes, 4))

    def test_msb_range(self, rng):
        for cfg in PAPER_CONFIGS:
            qt = amat_quantize(_weights(rng), cfg)
            m = msb_slice(qt.codes, cfg.shift)
            assert int(jnp.max(m)) < 2 ** cfg.low_bits

    def test_zp_truncated_with_code(self, rng):
        qt = amat_quantize(_weights(rng), MAT84)
        lo = truncate(qt, low_bits=4)
        assert jnp.array_equal(lo.zero_points, qt.zero_points >> 4)
        assert jnp.allclose(lo.scales, qt.scales * 16.0)


class TestTable1Orderings:
    """The paper's qualitative claims, asserted as orderings."""

    @pytest.mark.parametrize("cfg", PAPER_CONFIGS, ids=lambda c: c.name)
    def test_amat_close_to_base_lowbit(self, rng, cfg):
        w = _weights(rng)
        qt = amat_quantize(w, cfg)
        amat_err = float(quantization_error(w, truncate(qt, low_bits=cfg.low_bits)))
        base_err = float(quantization_error(
            w, quantize(w, bits=cfg.low_bits, group_size=cfg.group_size,
                        asymmetric=True)))
        # AMAT low-bit within 2x of independently-quantized low-bit
        assert amat_err < 2.0 * base_err + 1e-6

    @pytest.mark.parametrize("cfg", PAPER_CONFIGS, ids=lambda c: c.name)
    def test_naive_trunc_catastrophic(self, rng, cfg):
        """Naive truncation (no zp/scale adjustment) must be far worse."""
        w = _weights(rng)
        qt = amat_quantize(w, cfg)
        amat_err = float(quantization_error(w, truncate(qt, low_bits=cfg.low_bits)))
        naive_err = float(quantization_error(
            w, truncate(qt, low_bits=cfg.low_bits, truncate_zp=False,
                        rescale=False)))
        # at 2-bit the AMAT error is itself large, compressing the ratio —
        # the paper's PPL blow-up (1e6-1e10) is the *model-level* effect
        assert naive_err > 2.5 * amat_err

    def test_high_bit_path_unchanged(self, rng):
        """AMAT must not degrade the high-bit path at all."""
        w = _weights(rng)
        for cfg in PAPER_CONFIGS:
            qt = amat_quantize(w, cfg)
            base = quantize(w, bits=cfg.high_bits,
                            group_size=cfg.group_size, asymmetric=True)
            assert jnp.allclose(dequant_high(qt), dequantize(base))


class TestMixedDequant:
    def test_mixed_matches_pure_paths(self, rng):
        w = jax.random.normal(rng, (6, 64, 32)) * 0.1
        qt = amat_quantize(w, MAT84)
        use_lsb = jnp.array([True, False, True, False, True, False])
        mixed = dequant_mixed(qt, use_lsb, 4)
        hi = dequant_high(qt)
        lo = dequant_low(qt, MAT84)
        for e in range(6):
            expected = hi[e] if bool(use_lsb[e]) else lo[e]
            np.testing.assert_allclose(mixed[e], expected, rtol=1e-6)

    def test_all_high_equals_dequant(self, rng):
        w = jax.random.normal(rng, (4, 32, 16)) * 0.1
        qt = amat_quantize(w, MAT84)
        mixed = dequant_mixed(qt, jnp.ones(4, bool), 4)
        np.testing.assert_allclose(mixed, dequant_high(qt), rtol=1e-6)


class TestPropertyBased:
    @settings(max_examples=25, deadline=None)
    @given(
        high=st.sampled_from([4, 6, 8]),
        shift_frac=st.integers(1, 3),
        seed=st.integers(0, 2**16),
        scale=st.floats(1e-3, 10.0),
        bias=st.floats(-1.0, 1.0),
    )
    def test_roundtrip_error_bounded(self, high, shift_frac, seed, scale,
                                     bias):
        """Dequant error bounded by half a quantization step, any dist."""
        low = max(high - shift_frac * 2, 2)
        if low >= high:
            low = high - 1
        cfg = MatConfig(high, low)
        w = jax.random.normal(jax.random.PRNGKey(seed), (32, 64)) \
            * scale + bias
        qt = amat_quantize(w, cfg)
        err = jnp.max(jnp.abs(dequant_high(qt) - w))
        max_step = jnp.max(qt.scales)
        # value rounding (0.5 step) + integer zero-point rounding (0.5 step)
        assert float(err) <= float(max_step) * 1.01 + 1e-6

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_slices_partition_bits(self, seed):
        """Every code bit lands in exactly one slice (MAT84)."""
        w = jax.random.normal(jax.random.PRNGKey(seed), (32, 32))
        qt = amat_quantize(w, MAT84)
        m = msb_slice(qt.codes, 4).astype(jnp.uint32)
        l = lsb_slice(qt.codes, 4).astype(jnp.uint32)
        assert int(jnp.max(l)) < 16
        assert jnp.array_equal((m << 4) + l, qt.codes.astype(jnp.uint32))
