"""Minimal ``hypothesis`` fallback for environments without the package.

The tier-1 suite uses a small, well-behaved subset of hypothesis:
``@settings(max_examples=N, deadline=None)`` stacked on ``@given(...)``
with ``integers / floats / booleans / lists / tuples / sampled_from``
strategies.  When the real package is importable, ``conftest.py`` never
loads this module.  When it is not, this shim re-implements that subset
as a fixed-seed sample loop: each example draws from a
``numpy.random.Generator`` seeded by the example index, so runs are
deterministic everywhere and failures are reproducible.

This intentionally does NOT implement shrinking, ``assume``, stateful
testing, or the database — the suite does not use them.  Environments
with ``hypothesis`` installed (see requirements-dev.txt) get the real
thing, including shrinking.
"""

from __future__ import annotations

import functools
import inspect
import sys
import types

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


# --------------------------------------------------------------------------
# Strategies
# --------------------------------------------------------------------------
class SearchStrategy:
    """A strategy is just a callable drawing one example from an rng."""

    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: np.random.Generator):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(
        lambda rng: elements[int(rng.integers(0, len(elements)))])


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example_from(rng) for _ in range(n)]
    return SearchStrategy(draw)


def tuples(*element_strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.example_from(rng) for s in element_strategies))


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def one_of(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: strategies[int(rng.integers(0, len(strategies)))]
        .example_from(rng))


# --------------------------------------------------------------------------
# given / settings
# --------------------------------------------------------------------------
def given(*pos_strategies, **kw_strategies):
    """Run the test once per example with drawn arguments filled in.

    Like real hypothesis, positional strategies bind to the *rightmost*
    parameters of the test function, and the wrapper's signature hides
    every strategy-provided parameter so pytest does not mistake them
    for fixtures.
    """

    def decorate(fn):
        sig = inspect.signature(fn)
        params = [p for p in sig.parameters.values()]
        draw_map = dict(kw_strategies)
        if pos_strategies:
            free = [p.name for p in params if p.name not in draw_map]
            for name, strat in zip(free[-len(pos_strategies):],
                                   pos_strategies):
                draw_map[name] = strat

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_compat_max_examples",
                        DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rng = np.random.default_rng(0xC0FFEE + i)
                drawn = {name: s.example_from(rng)
                         for name, s in draw_map.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as exc:
                    raise AssertionError(
                        f"falsifying example #{i}: {drawn!r}") from exc

        wrapper.__signature__ = sig.replace(
            parameters=[p for p in params if p.name not in draw_map])
        wrapper._compat_max_examples = DEFAULT_MAX_EXAMPLES
        return wrapper

    return decorate


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_kw):
    """Record max_examples on a @given-wrapped test (deadline etc. ignored)."""

    def decorate(fn):
        if hasattr(fn, "_compat_max_examples"):
            fn._compat_max_examples = max_examples
        return fn

    return decorate


def install() -> None:
    """Register this shim as ``hypothesis`` / ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = types.SimpleNamespace(all=lambda: [])

    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "tuples", "just", "one_of", "SearchStrategy"):
        setattr(st, name, globals()[name])

    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
