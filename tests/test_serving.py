"""Continuous-batching serving subsystem: scheduler, workloads, telemetry,
warm-cache persistence, and the batched decode path."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# scheduler + engine end-to-end: runs in the CI 'slow' job (pytest -m slow), not the fast tier-1 gate.
pytestmark = pytest.mark.slow

from repro.configs.base import get_config
from repro.core.amat import MatConfig
from repro.core.engine import EngineConfig, PersistentEngine
from repro.models import model as MDL
from repro.models.moe import RoutingPolicy
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     SchedulerConfig)
from repro.serving.telemetry import FleetTelemetry, RequestRecord, percentile
from repro.serving.workloads import (LengthDist, TenantSpec, WorkloadConfig,
                                     generate, scenario)


# ==========================================================================
# Shared model fixture (module-scoped: params + engine config)
# ==========================================================================
@pytest.fixture(scope="module")
def moe_setup():
    cfg = get_config("qwen15-moe-repro")
    cfg = dataclasses.replace(cfg, n_layers=2)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _ecfg(**over) -> EngineConfig:
    base = dict(
        mat=MatConfig(8, 4), cache_bytes=2.5e6,
        policy=RoutingPolicy(kind="cache_prior", slice_mode="dbsc"),
        miss_rate_target=0.1, warmup="pcw", max_seq=64)
    base.update(over)
    return EngineConfig(**base)


def _requests(cfg, n, *, prompt_len=12, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(request_id=i,
                    prompt=rng.integers(
                        0, cfg.vocab_size, prompt_len).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


# ==========================================================================
# Batched decode path (models/model.py vector positions)
# ==========================================================================
class TestBatchedDecode:
    def test_staggered_batch_matches_separate_decodes(self, moe_setup):
        """Two sequences at different positions, decoded in one batched
        call, must produce bit-identical logits to separate decodes."""
        cfg, params = moe_setup
        max_seq = 32
        pA = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0,
                                cfg.vocab_size)
        pB = jax.random.randint(jax.random.PRNGKey(2), (1, 17), 0,
                                cfg.vocab_size)
        lA, cA, _ = MDL.prefill(params, cfg, pA, max_seq=max_seq)
        lB, cB, _ = MDL.prefill(params, cfg, pB, max_seq=max_seq)
        tA = jnp.argmax(lA, -1).astype(jnp.int32)
        tB = jnp.argmax(lB, -1).astype(jnp.int32)
        rA, _, _ = MDL.decode_step(params, cfg, token=tA, cache=cA)
        rB, _, _ = MDL.decode_step(params, cfg, token=tB, cache=cB)

        batched = MDL.init_cache(cfg, 2, max_seq)
        batched["pos"] = jnp.zeros((2,), jnp.int32)
        for slot, pc in ((0, cA), (1, cB)):
            batched = PersistentEngine.install_slot(batched, pc, slot)
        lb, cb, _ = MDL.decode_step(
            params, cfg, token=jnp.concatenate([tA, tB]), cache=batched)
        np.testing.assert_array_equal(np.asarray(lb[0]), np.asarray(rA[0]))
        np.testing.assert_array_equal(np.asarray(lb[1]), np.asarray(rB[0]))
        np.testing.assert_array_equal(np.asarray(cb["pos"]), [11, 18])

    def test_token_mask_prevents_padding_capacity_steal(self):
        """Padding rows (retired slots) must not occupy MoE expert
        capacity: without the mask they can evict a live token's expert
        assignment under the capacity limit."""
        from repro.models import moe as M

        d = 16
        mcfg = M.MoECfg(n_experts=2, top_k=1, d_ff=8,
                        capacity_factor=0.01, mlp_type="gelu")
        key = jax.random.PRNGKey(0)
        params = {
            "w_router": jax.random.normal(key, (d, 2)),
            "experts": {
                "wi": jax.random.normal(key, (2, d, 8)) * 0.1,
                "wo": jax.random.normal(key, (2, 8, d)) * 0.1,
            },
        }
        # 12 identical tokens all route to one expert; cap floors at 8,
        # so rows 8+ get dropped when every row competes.
        x = jnp.broadcast_to(jax.random.normal(key, (d,)), (12, d))
        policy = RoutingPolicy(kind="topk", slice_mode="highbit")
        y_unmasked, _ = M.moe_apply(params, x, mcfg, policy=policy)
        assert float(jnp.abs(y_unmasked[11]).max()) == 0.0   # starved

        mask = np.zeros(12, bool)
        mask[11] = True
        y_masked, aux = M.moe_apply(params, x, mcfg, policy=policy,
                                    token_mask=jnp.asarray(mask))
        assert float(jnp.abs(y_masked[11]).max()) > 0.0      # served
        # padding rows are inactive in the trace and demand no slices
        assert not bool(np.asarray(aux["active"])[:11].any())


# ==========================================================================
# Scheduler: fairness, retirement, admission
# ==========================================================================
class TestScheduler:
    def test_all_requests_complete_fifo(self, moe_setup):
        cfg, params = moe_setup
        engine = PersistentEngine(cfg, params, _ecfg())
        sched = ContinuousBatchingScheduler(
            engine, SchedulerConfig(max_batch=1, max_queue=8))
        reqs = _requests(cfg, 3)
        for r in reqs:
            assert sched.submit(r)
        done = sched.run()
        # single-slot: strict FIFO completion order, full token budgets
        assert [c.request_id for c in done] == [0, 1, 2]
        assert all(len(c.tokens) == 4 for c in done)

    def test_batched_run_completes_everyone(self, moe_setup):
        """Continuous batching with more requests than slots: every
        request retires, none starves, per-request budgets honored."""
        cfg, params = moe_setup
        engine = PersistentEngine(cfg, params, _ecfg())
        sched = ContinuousBatchingScheduler(
            engine, SchedulerConfig(max_batch=2, max_queue=8))
        reqs = _requests(cfg, 5, max_new=3)
        for r in reqs:
            sched.submit(r)
        done = sched.run()
        assert sorted(c.request_id for c in done) == [0, 1, 2, 3, 4]
        assert all(len(c.tokens) == 3 for c in done)
        # decode steps ran with >1 active slot (true batching, not serial)
        assert any(s.n_active > 1 for s in sched.telemetry.steps)

    def test_eos_retires_early_and_frees_slot(self, moe_setup):
        cfg, params = moe_setup
        engine = PersistentEngine(cfg, params, _ecfg())
        sched = ContinuousBatchingScheduler(
            engine, SchedulerConfig(max_batch=1, max_queue=8))
        probe = _requests(cfg, 1, max_new=4)[0]
        sched.submit(probe)
        first_tok = int(sched.run()[0].tokens[0])

        engine2 = PersistentEngine(cfg, params, _ecfg())
        sched2 = ContinuousBatchingScheduler(
            engine2, SchedulerConfig(max_batch=1, max_queue=8))
        r0, r1 = _requests(cfg, 2, max_new=4)
        r0 = dataclasses.replace(r0, eos_token=first_tok)
        sched2.submit(r0)
        sched2.submit(r1)
        done = sched2.run()
        by_id = {c.request_id: c for c in done}
        assert len(by_id[0].tokens) == 1          # stopped at EOS
        assert by_id[0].tokens[-1] == first_tok
        assert len(by_id[1].tokens) == 4          # slot freed, r1 served

    def test_admission_control_rejects_overflow(self, moe_setup):
        cfg, params = moe_setup
        engine = PersistentEngine(cfg, params, _ecfg())
        sched = ContinuousBatchingScheduler(
            engine, SchedulerConfig(max_batch=1, max_queue=2))
        reqs = _requests(cfg, 4, max_new=2)
        accepted = [sched.submit(r) for r in reqs]
        assert accepted == [True, True, False, False]
        done = sched.run()
        assert len(done) == 2
        assert sched.summary()["n_rejected"] == 2

    def test_long_prompt_rejected_by_full_token_budget(self, moe_setup):
        """Regression: admission used to gate on max_new_tokens alone, so
        a long prompt sailed through ``servable`` and only survived by
        being silently truncated.  The gate must consider the *full*
        budget (prompt + new tokens) against max_seq."""
        cfg, params = moe_setup
        engine = PersistentEngine(cfg, params, _ecfg())   # max_seq=64
        sched = ContinuousBatchingScheduler(
            engine, SchedulerConfig(max_batch=1, max_queue=8))
        long_prompt = np.random.default_rng(0).integers(
            0, cfg.vocab_size, 60).astype(np.int32)
        bad = Request(request_id=0, prompt=long_prompt, max_new_tokens=8)
        assert not sched.servable(bad)
        assert not sched.submit(bad)
        ok = Request(request_id=1, prompt=long_prompt[:50],
                     max_new_tokens=8)                    # 50+8+1 <= 64
        assert sched.submit(ok)
        done = sched.run()
        assert [c.request_id for c in done] == [1]
        assert len(done[0].tokens) == 8
        assert not done[0].metrics["prompt_truncated"]
        # the KV slot never overflowed its budget
        assert int(np.asarray(sched.batch_cache["pos"]).max()) \
            <= engine.ecfg.max_seq

    def test_truncate_prompts_opt_in(self, moe_setup):
        """With ``truncate_prompts`` the same long prompt is admitted,
        clipped to the KV budget (tail kept) and flagged."""
        cfg, params = moe_setup
        engine = PersistentEngine(cfg, params, _ecfg())
        sched = ContinuousBatchingScheduler(
            engine, SchedulerConfig(max_batch=1, max_queue=8,
                                    truncate_prompts=True))
        long_prompt = np.random.default_rng(0).integers(
            0, cfg.vocab_size, 60).astype(np.int32)
        req = Request(request_id=0, prompt=long_prompt, max_new_tokens=8)
        assert sched.submit(req)
        done = sched.run()
        assert len(done) == 1 and len(done[0].tokens) == 8
        assert done[0].metrics["prompt_truncated"]
        assert sched.telemetry.requests[0].truncated
        assert int(np.asarray(sched.batch_cache["pos"]).max()) \
            <= engine.ecfg.max_seq

    def test_unservable_request_rejected_not_fatal(self, moe_setup):
        """A request whose token budget can't fit under max_seq must be
        rejected at submit, not abort the run mid-flight."""
        cfg, params = moe_setup
        engine = PersistentEngine(cfg, params, _ecfg())   # max_seq=64
        sched = ContinuousBatchingScheduler(
            engine, SchedulerConfig(max_batch=1, max_queue=8))
        bad = Request(request_id=9, prompt=np.zeros(4, np.int32),
                      max_new_tokens=64)
        ok = _requests(cfg, 1, max_new=2)[0]
        assert not sched.submit(bad)
        assert sched.submit(ok)
        done = sched.run()
        assert [c.request_id for c in done] == [0]
        assert sched.summary()["n_rejected"] == 1


# ==========================================================================
# Warm-cache persistence across requests
# ==========================================================================
class TestWarmCachePersistence:
    def test_second_identical_request_misses_less(self, moe_setup):
        """The tentpole claim: a repeated request against the persistent
        engine must see a strictly lower prefill miss rate — the slice
        cache survived the first request."""
        cfg, params = moe_setup
        engine = PersistentEngine(cfg, params, _ecfg())
        sched = ContinuousBatchingScheduler(
            engine, SchedulerConfig(max_batch=1, max_queue=4))
        prompt = np.random.default_rng(7).integers(
            0, cfg.vocab_size, 16).astype(np.int32)
        for i in range(2):
            sched.submit(Request(request_id=i, prompt=prompt.copy(),
                                 max_new_tokens=3))
        sched.run()
        rates = dict(engine.cache.epoch_miss_rates())
        assert rates["req0/prefill"] == 1.0       # cold start
        assert rates["req1/prefill"] < rates["req0/prefill"]

    def test_hotness_accumulates_across_requests(self, moe_setup):
        cfg, params = moe_setup
        engine = PersistentEngine(cfg, params, _ecfg())
        sched = ContinuousBatchingScheduler(
            engine, SchedulerConfig(max_batch=1, max_queue=4))
        for r in _requests(cfg, 2, max_new=2):
            sched.submit(r)
        sched.run()
        assert engine.requests_served == 2
        assert engine.tracker.hotness().max() > 0

    def test_fresh_engines_stay_cold(self, moe_setup):
        """Control: fresh engine per request -> every prefill is 100%
        cold (this is the seed baseline the benchmark beats)."""
        cfg, params = moe_setup
        prompt = np.random.default_rng(7).integers(
            0, cfg.vocab_size, 16).astype(np.int32)
        for _ in range(2):
            engine = PersistentEngine(cfg, params, _ecfg())
            sched = ContinuousBatchingScheduler(
                engine, SchedulerConfig(max_batch=1, max_queue=2))
            sched.submit(Request(request_id=0, prompt=prompt.copy(),
                                 max_new_tokens=2))
            sched.run()
            rates = dict(engine.cache.epoch_miss_rates())
            assert rates["req0/prefill"] == 1.0


# ==========================================================================
# Workload generation
# ==========================================================================
class TestWorkloads:
    def test_deterministic_under_seed(self):
        cfg = scenario("multi_tenant", n_requests=12, rate=3.0, seed=42)
        a = generate(cfg, vocab_size=1024)
        b = generate(cfg, vocab_size=1024)
        assert len(a) == len(b) == 12
        for ra, rb in zip(a, b):
            assert ra.arrival_time == rb.arrival_time
            assert ra.tenant == rb.tenant
            assert ra.max_new_tokens == rb.max_new_tokens
            np.testing.assert_array_equal(ra.prompt, rb.prompt)

    def test_deterministic_across_interpreters(self):
        """Prompt streams must not depend on the per-process str-hash
        salt (regression: tenant offsets used hash())."""
        import os
        import subprocess
        import sys

        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        script = (
            "from repro.serving.workloads import generate, scenario\n"
            "reqs = generate(scenario('multi_tenant', n_requests=4,"
            " seed=0), 512)\n"
            "print([int(r.prompt.sum()) for r in reqs])\n")
        outs = []
        for salt in ("0", "12345"):
            env = dict(os.environ,
                       PYTHONHASHSEED=salt,
                       PYTHONPATH=os.path.join(root, "src"))
            r = subprocess.run([sys.executable, "-c", script],
                               capture_output=True, text=True, env=env,
                               cwd=root)
            assert r.returncode == 0, r.stderr
            outs.append(r.stdout.strip())
        assert outs[0] == outs[1], outs

    def test_different_seeds_differ(self):
        base = scenario("steady", n_requests=8, rate=3.0, seed=0)
        other = dataclasses.replace(base, seed=1)
        a = generate(base, vocab_size=1024)
        b = generate(other, vocab_size=1024)
        assert any(ra.arrival_time != rb.arrival_time
                   for ra, rb in zip(a, b))

    def test_arrivals_sorted_and_shapes(self):
        for kind in ("poisson", "bursty", "closed_loop"):
            cfg = WorkloadConfig(kind=kind, n_requests=10, rate=5.0,
                                 seed=3)
            reqs = generate(cfg, vocab_size=512)
            times = [r.arrival_time for r in reqs]
            assert times == sorted(times)
            assert all(r.prompt.dtype == np.int32 for r in reqs)
            assert all(0 <= r.prompt.min() and
                       r.prompt.max() < 512 for r in reqs)
        closed = generate(WorkloadConfig(kind="closed_loop", n_requests=4),
                          vocab_size=512)
        assert all(r.arrival_time == 0.0 for r in closed)

    def test_tenant_mix_and_length_dists(self):
        chatty = TenantSpec(name="a", weight=1.0,
                            prompt_len=LengthDist("uniform", low=4, high=8),
                            output_len=LengthDist("fixed", 5))
        cfg = WorkloadConfig(kind="closed_loop", n_requests=20, seed=0,
                             tenants=(chatty,))
        reqs = generate(cfg, vocab_size=256)
        assert all(4 <= len(r.prompt) <= 8 for r in reqs)
        assert all(r.max_new_tokens == 5 for r in reqs)
        assert all(r.tenant == "a" for r in reqs)


# ==========================================================================
# Telemetry math
# ==========================================================================
class TestTelemetry:
    def test_percentile_nearest_rank(self):
        vals = [1.0, 2.0, 3.0, 4.0, 5.0]
        assert percentile(vals, 50) == 3.0
        assert percentile(vals, 95) == 5.0
        assert percentile(vals, 100) == 5.0
        assert percentile(vals, 0) == 1.0
        assert percentile([7.0], 99) == 7.0
        assert math.isnan(percentile([], 50))
        # order-independence
        assert percentile([5.0, 1.0, 3.0, 2.0, 4.0], 50) == 3.0
        with pytest.raises(ValueError):
            percentile(vals, 101)

    def test_request_record_derived_metrics(self):
        r = RequestRecord(request_id=0, arrival_t=1.0, admit_t=1.5,
                          first_token_t=2.0, finish_t=4.0, n_generated=5)
        assert r.ttft == 1.0
        assert r.queue_delay == 0.5
        assert r.decode_s == 2.0
        assert r.per_token_s == 0.5       # 2.0s over 4 inter-token gaps

    def test_summary_aggregates(self):
        t = FleetTelemetry()
        for i in range(4):
            rec = RequestRecord(request_id=i, arrival_t=0.0,
                                admit_t=0.0, first_token_t=float(i + 1),
                                finish_t=float(i + 2), n_generated=2)
            t.on_submit(rec)
        rej = RequestRecord(request_id=99)
        t.on_reject(rej)
        s = t.summary(total_energy_j=16.0)
        assert s["n_requests"] == 4
        assert s["n_rejected"] == 1
        assert s["n_tokens"] == 8
        assert s["ttft_p50_s"] == 2.0
        assert s["energy_per_token_j"] == 2.0


# ==========================================================================
# Cache epochs (cross-request stats windows)
# ==========================================================================
class TestCacheEpochs:
    def test_epoch_rollover_preserves_contents(self):
        from repro.core.cache import SliceCache
        from repro.core.slices import SliceKey

        c = SliceCache(100)
        c.begin_epoch("r0")
        c.access(SliceKey(0, 0, "msb"), 10)     # miss
        c.access(SliceKey(0, 0, "msb"), 10)     # hit
        c.begin_epoch("r1")
        assert SliceKey(0, 0, "msb") in c       # contents survive
        c.access(SliceKey(0, 0, "msb"), 10)     # warm hit in new epoch
        c.end_epoch()
        rates = dict(c.epoch_miss_rates())
        assert rates["r0"] == 0.5
        assert rates["r1"] == 0.0
        assert c.used == 10
