"""Golden-trace regression gate (fast tier-1).

``tests/data/golden_trace.npz`` is a committed synthetic routing trace;
``tests/data/golden_expected.json`` holds the replay observables for
three pinned engine configurations (plain demand path, request-level
prefetch, Markov-transition prefetch).  Any charge-path change that
moves these numbers fails here *loudly* — per-epoch miss **counts**
must match exactly (integer fidelity: rates can agree by coincidence
while the counts differ), energy/latency at rtol 1e-6, and prefetch
outcome counters exactly.

Intentional changes regenerate the fixture:

    PYTHONPATH=src python tests/data/regen_golden.py

and commit both files with the explanation.
"""

import json
import pathlib

import numpy as np
import pytest

from repro.sim import Trace, replay_trace

DATA = pathlib.Path(__file__).resolve().parent / "data"


@pytest.fixture(scope="module")
def golden():
    trace = Trace.load(str(DATA / "golden_trace.npz"))
    expected = json.loads((DATA / "golden_expected.json").read_text())
    return trace, expected


@pytest.fixture(scope="module")
def reports(golden):
    """Replay each pinned config once; every test compares against its
    slice of the expectations."""
    trace, expected = golden
    return {name: replay_trace(trace, **row["overrides"])
            for name, row in expected["configs"].items()}


def test_golden_trace_shape(golden):
    trace, expected = golden
    kw = expected["trace_kw"]
    assert trace.n_prefills == kw["n_requests"]
    assert trace.n_decode_steps == kw["n_requests"] * kw["decode_steps"]
    assert trace.meta.n_moe_layers == 3
    assert trace.meta.n_experts == 12


@pytest.mark.parametrize("name", ["baseline", "request_prefetch",
                                  "transition_prefetch"])
def test_golden_epoch_miss_counts_exact(reports, golden, name):
    _trace, expected = golden
    want = [(label, a, m)
            for label, a, m in expected["configs"][name]["epoch_counts"]]
    assert reports[name].epoch_counts == want


@pytest.mark.parametrize("name", ["baseline", "request_prefetch",
                                  "transition_prefetch"])
def test_golden_decode_totals_exact(reports, golden, name):
    _trace, expected = golden
    row = expected["configs"][name]
    rep = reports[name]
    assert rep.decode_accesses == row["decode_accesses"]
    assert rep.decode_misses == row["decode_misses"]


@pytest.mark.parametrize("name", ["baseline", "request_prefetch",
                                  "transition_prefetch"])
def test_golden_energy_latency_rtol(reports, golden, name):
    _trace, expected = golden
    row = expected["configs"][name]
    rep = reports[name]
    np.testing.assert_allclose(rep.total_energy_j, row["total_energy_j"],
                               rtol=1e-6)
    np.testing.assert_allclose(rep.total_latency_s,
                               row["total_latency_s"], rtol=1e-6)
    for key, want in row["ledger"].items():
        np.testing.assert_allclose(rep.ledger[key], want, rtol=1e-6,
                                   err_msg=f"ledger[{key}]")


@pytest.mark.parametrize("name", ["request_prefetch",
                                  "transition_prefetch"])
def test_golden_prefetch_outcomes_exact(reports, golden, name):
    _trace, expected = golden
    want = expected["configs"][name]["prefetch"]
    got = reports[name].prefetch
    assert {k: got[k] for k in want} == want
    assert got["in_flight"] == 0
    assert got["issued"] == got["useful"] + got["late"] + got["wasted"]


def test_golden_predictor_accuracy_smoke(golden):
    """Same cell the CI predictor-accuracy smoke runs: at a cache the
    working set nearly fits (8e5 B) with a mild confidence gate, the
    request predictor repays more fills than it writes off."""
    trace, _expected = golden
    rep = replay_trace(trace, prefetch_top_m=4, prefetch_kind="request",
                       prefetch_lookahead=2, prefetch_min_obs=2,
                       prefetch_min_score=0.05, async_io=True,
                       warmup="empty", cache_bytes=8e5)
    p = rep.prefetch
    assert p["in_flight"] == 0
    assert p["issued"] == p["useful"] + p["late"] + p["wasted"]
    assert p["useful"] > p["wasted"], p


def test_golden_replay_is_deterministic(golden):
    """Two independent replays of the same fixture agree bit-for-bit —
    the property the whole golden gate rests on."""
    trace, expected = golden
    ov = expected["configs"]["request_prefetch"]["overrides"]
    a, b = replay_trace(trace, **ov), replay_trace(trace, **ov)
    assert a.epoch_counts == b.epoch_counts
    assert a.miss_curve == b.miss_curve
    assert a.total_energy_j == b.total_energy_j
    assert a.prefetch == b.prefetch
