"""Attention / RoPE / MLP building blocks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def _qkv(key, b=2, sq=16, sk=16, h=4, kv=2, d=32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, sk, kv, d))
    v = jax.random.normal(ks[2], (b, sk, kv, d))
    return q, k, v


class TestRoPE:
    def test_preserves_norm(self, rng):
        x = jax.random.normal(rng, (2, 8, 4, 32))
        pos = jnp.arange(8)[None, :]
        y = L.apply_rope(x, pos, 10000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x, np.float64), axis=-1),
            np.linalg.norm(np.asarray(y, np.float64), axis=-1), rtol=1e-4)

    def test_relative_property(self, rng):
        """<rope(q,m), rope(k,n)> depends only on m-n."""
        q = jax.random.normal(rng, (1, 1, 1, 16))
        k = jax.random.normal(jax.random.fold_in(rng, 1), (1, 1, 1, 16))

        def score(m, n):
            qr = L.apply_rope(q, jnp.array([[m]]), 1e4)
            kr = L.apply_rope(k, jnp.array([[n]]), 1e4)
            return float(jnp.sum(qr * kr))

        assert abs(score(5, 3) - score(10, 8)) < 1e-4
        assert abs(score(0, 0) - score(7, 7)) < 1e-4

    def test_position_zero_identity(self, rng):
        x = jax.random.normal(rng, (1, 1, 2, 16))
        y = L.apply_rope(x, jnp.zeros((1, 1)), 1e4)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


class TestAttention:
    def test_causal_mask(self, rng):
        """Changing future keys must not change past outputs."""
        q, k, v = _qkv(rng)
        out1 = L.attention(q, k, v, causal=True)
        k2 = k.at[:, -1].set(99.0)
        v2 = v.at[:, -1].set(99.0)
        out2 = L.attention(q, k2, v2, causal=True)
        np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                                   np.asarray(out2[:, :-1]), atol=1e-5)
        assert not np.allclose(np.asarray(out1[:, -1]),
                               np.asarray(out2[:, -1]))

    def test_blockwise_matches_plain(self, rng):
        q, k, v = _qkv(rng, sq=24, sk=40)
        plain = L.attention(q, k, v, causal=True, q_offset=16)
        block = L.blockwise_attention(q, k, v, causal=True, q_offset=16,
                                      block_kv=8)
        np.testing.assert_allclose(np.asarray(plain), np.asarray(block),
                                   atol=1e-4)

    def test_blockwise_sliding_window(self, rng):
        q, k, v = _qkv(rng, sq=16, sk=16)
        plain = L.attention(q, k, v, causal=True, sliding_window=4)
        block = L.blockwise_attention(q, k, v, causal=True,
                                      sliding_window=4, block_kv=8)
        np.testing.assert_allclose(np.asarray(plain), np.asarray(block),
                                   atol=1e-4)

    def test_softcap(self, rng):
        q, k, v = _qkv(rng)
        a = L.attention(q * 10, k * 10, v, causal=True, logit_softcap=5.0)
        assert not np.any(np.isnan(np.asarray(a)))

    def test_decode_matches_full(self, rng):
        """Single-token decode == last row of full attention."""
        q, k, v = _qkv(rng, sq=8, sk=8)
        full = L.attention(q, k, v, causal=True)
        dec = L.decode_attention(q[:, -1], k, v, cur_pos=jnp.asarray(8))
        np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(dec),
                                   atol=1e-5)

    def test_decode_ignores_stale_cache(self, rng):
        q, k, v = _qkv(rng, sq=1, sk=16)
        d1 = L.decode_attention(q[:, 0], k, v, cur_pos=jnp.asarray(4))
        k2 = k.at[:, 10:].set(7.0)
        d2 = L.decode_attention(q[:, 0], k2, v, cur_pos=jnp.asarray(4))
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-6)

    def test_gqa_equals_repeated_mha(self, rng):
        q, k, v = _qkv(rng, h=8, kv=2)
        gqa = L.attention(q, k, v, causal=True)
        kr = L._expand_kv(k, 4)
        vr = L._expand_kv(v, 4)
        mha = L.attention(q, kr, vr, causal=True)
        np.testing.assert_allclose(np.asarray(gqa), np.asarray(mha),
                                   atol=1e-5)


class TestMLP:
    @pytest.mark.parametrize("mlp_type", ["swiglu", "geglu", "relu2", "gelu"])
    def test_shapes_and_finiteness(self, rng, mlp_type):
        d, f = 32, 64
        shapes = L.mlp_param_shapes(d, f, mlp_type)
        params = {k: jax.random.normal(jax.random.fold_in(rng, i), s) * 0.05
                  for i, (k, s) in enumerate(shapes.items())}
        x = jax.random.normal(rng, (4, d))
        y = L.mlp_apply(params, x, mlp_type)
        assert y.shape == (4, d)
        assert np.isfinite(np.asarray(y)).all()

    def test_relu2_nonnegative_preactivation(self, rng):
        """Squared-ReLU output is a nonneg combination of wo rows."""
        d, f = 16, 32
        params = {"wi": jax.random.normal(rng, (d, f)),
                  "wo": jnp.eye(f)[:, :d].astype(jnp.float32) * 0 + 1}
        x = jax.random.normal(rng, (4, d))
        h = np.square(np.maximum(np.asarray(x @ params["wi"]), 0))
        assert (h >= 0).all()


class TestNorms:
    def test_rmsnorm_scale_invariant_direction(self, rng):
        x = jax.random.normal(rng, (4, 32))
        s = jnp.zeros(32)
        y1 = L.rms_norm(x, s)
        y2 = L.rms_norm(x * 10.0, s)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)

    def test_layernorm_zero_mean(self, rng):
        x = jax.random.normal(rng, (4, 32)) + 3.0
        y = L.layer_norm(x, jnp.ones(32), jnp.zeros(32))
        np.testing.assert_allclose(np.asarray(y).mean(-1), 0.0, atol=1e-5)
