"""Sharding rules + spec/init consistency (the dry-run's foundation)."""

import jax
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import param_spec, sanitize_spec
from repro.launch.specs import batch_specs, cache_specs, param_specs
from repro.models import model as MDL


class FakeMesh:
    """Minimal stand-in so rule tests don't need 256 devices."""

    def __init__(self, shape_dict):
        self.shape = shape_dict
        self.axis_names = tuple(shape_dict)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


class TestSanitize:
    def test_drops_nondivisible(self):
        spec = sanitize_spec(MESH, (40, 128), ("model", "data"))
        assert spec[0] is None and spec[1] == "data"

    def test_keeps_divisible(self):
        spec = sanitize_spec(MESH, (64, 128), ("model", "data"))
        assert spec[0] == "model" and spec[1] == "data"

    def test_compound_axis_prefix_fallback(self):
        # 32 divides by pod*data=32; 16 only by pod*? -> prefix ('pod',)
        spec = sanitize_spec(MESH3, (32,), (("pod", "data"),))
        assert spec[0] == ("pod", "data")
        spec = sanitize_spec(MESH3, (2,), (("pod", "data"),))
        assert spec[0] == "pod"
        spec = sanitize_spec(MESH3, (3,), (("pod", "data"),))
        assert spec[0] is None

    def test_missing_axis_dropped(self):
        spec = sanitize_spec(MESH, (64,), ("pod",))
        assert spec[0] is None


class TestRules:
    def test_expert_weights_expert_parallel(self):
        spec = param_spec("blocks/pos0/moe/experts/wi", (4, 128, 512, 1024))
        assert spec == (None, "model", "data", None)

    def test_attention_projections(self):
        assert param_spec("blocks/pos0/wq", (4, 512, 512)) == \
            (None, "data", "model")
        assert param_spec("blocks/pos0/wo", (4, 512, 512)) == \
            (None, "model", "data")

    def test_embed_vocab_sharded(self):
        assert param_spec("embed", (50000, 512)) == ("model", "data")

    def test_norms_replicated(self):
        assert param_spec("blocks/pos0/mlp_norm", (4, 512)) == (None, None)
        assert param_spec("final_norm", (512,)) == (None,)


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestSpecInitConsistency:
    """param_specs (dry-run SDS) must exactly match init_params output."""

    def test_shapes_dtypes_match(self, arch):
        cfg = get_config(arch).reduced()
        params = MDL.init_params(cfg, jax.random.PRNGKey(0))
        specs = param_specs(cfg, mesh=None)
        p_flat = jax.tree_util.tree_leaves(params)
        s_flat = jax.tree_util.tree_leaves(specs)
        assert len(p_flat) == len(s_flat)
        p_struct = jax.tree_util.tree_structure(params)
        s_struct = jax.tree_util.tree_structure(specs)
        assert p_struct == s_struct
        for p, s in zip(p_flat, s_flat):
            assert p.shape == s.shape, (arch, p.shape, s.shape)
            assert p.dtype == s.dtype, (arch, p.dtype, s.dtype)


class TestInputSpecs:
    def test_batch_specs_vlm_prefix(self):
        cfg = get_config("internvl2-1b")
        b = batch_specs(cfg, SHAPES["train_4k"], mesh=None)
        assert b["tokens"].shape == (256, 4096 - cfg.prefix_len)
        assert b["prefix_embeds"].shape == (256, cfg.prefix_len, cfg.d_model)

    def test_batch_specs_encdec(self):
        cfg = get_config("whisper-small")
        b = batch_specs(cfg, SHAPES["prefill_32k"], mesh=None)
        assert b["encoder_frames"].shape == (32, 1500, 768)
        assert "labels" not in b

    def test_cache_specs_match_init_cache(self):
        cfg = get_config("jamba-v0.1-52b").reduced()
        specs = cache_specs(cfg, batch=2, max_seq=32, mesh=None)
        real = MDL.init_cache(cfg, 2, 32)
        r_flat = jax.tree_util.tree_leaves(real)
        s_flat = jax.tree_util.tree_leaves(specs)
        assert len(r_flat) == len(s_flat)
        for r, s in zip(r_flat, s_flat):
            assert r.shape == s.shape and r.dtype == s.dtype


class TestShardedExecution:
    """End-to-end on the 1x1 host mesh (sharding machinery exercised)."""

    def test_train_step_runs_under_mesh(self):
        from repro.launch.sharding import mesh_context
        from repro.launch.steps import make_train_step
        from repro.optim import adamw as OPT

        cfg = get_config("smollm-360m").reduced()
        mesh = make_host_mesh()
        opt_cfg = OPT.AdamWConfig(total_steps=5, warmup_steps=1)
        step = make_train_step(cfg, opt_cfg)
        with mesh_context(mesh):
            params = MDL.init_params(cfg, jax.random.PRNGKey(0))
            opt_state = OPT.init_state(params, opt_cfg)
            toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                      cfg.vocab_size)
            batch = {"tokens": toks, "labels": toks}
            params, opt_state, metrics = jax.jit(step)(params, opt_state,
                                                       batch)
            assert np.isfinite(float(metrics["loss"]))
