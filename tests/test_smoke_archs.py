"""Per-arch smoke tests (assignment requirement).

For EVERY assigned architecture: instantiate the REDUCED variant
(2 layers-per-pattern, d_model <= 256, <= 4 experts) and run one forward
+ one train step + one decode step on CPU, asserting output shapes and
the absence of NaNs.  Full configs are exercised only via the dry-run.
"""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

# every architecture smoke-compiled: runs in the CI 'slow' job (pytest -m slow), not the fast tier-1 gate.
pytestmark = pytest.mark.slow

from repro.configs.base import ARCH_IDS, REPRO_IDS, get_config
from repro.models import model as MDL
from repro.optim import adamw as OPT

ALL_IDS = ARCH_IDS + REPRO_IDS


def _inputs(cfg, key, B=2, S=16):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.prefix_len:
        kw["prefix_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.prefix_len, cfg.d_model),
            jnp.dtype(cfg.dtype)) * 0.1
    if cfg.is_encdec:
        kw["encoder_frames"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.encoder_seq, cfg.d_model),
            jnp.dtype(cfg.dtype)) * 0.1
    return toks, kw


@pytest.fixture(scope="module", params=ALL_IDS)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    return request.param, cfg, params


class TestSmoke:
    def test_reduced_respects_limits(self, arch_setup):
        _, cfg, _ = arch_setup
        assert cfg.d_model <= 512
        assert cfg.n_layers <= 2 * len(cfg.block_pattern)
        if cfg.moe:
            assert cfg.moe.n_experts <= 4

    def test_forward_shapes_no_nan(self, arch_setup):
        name, cfg, params = arch_setup
        toks, kw = _inputs(cfg, jax.random.PRNGKey(1))
        h, aux = MDL.forward(params, cfg, toks, **kw)
        S = 16 + (cfg.prefix_len or 0)
        assert h.shape == (2, S, cfg.d_model)
        assert not bool(jnp.any(jnp.isnan(h.astype(jnp.float32)))), name
        logits = MDL.unembed(params, cfg, h[:, -1])
        assert logits.shape == (2, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()

    def test_train_step_no_nan(self, arch_setup):
        name, cfg, params = arch_setup
        toks, kw = _inputs(cfg, jax.random.PRNGKey(2))
        opt_cfg = OPT.AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=1)
        opt_state = OPT.init_state(params, opt_cfg)

        def loss_fn(p):
            loss, _ = MDL.lm_loss(p, cfg, toks, toks,
                                  prefix_embeds=kw.get("prefix_embeds"),
                                  encoder_frames=kw.get("encoder_frames"))
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert np.isfinite(float(loss)), name
        new_params, _, metrics = OPT.apply_updates(params, grads, opt_state,
                                                   opt_cfg)
        assert np.isfinite(float(metrics["grad_norm"]))
        # params actually changed
        delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                          - b.astype(jnp.float32))))
                    for a, b in zip(jax.tree_util.tree_leaves(new_params),
                                    jax.tree_util.tree_leaves(params)))
        assert delta > 0

    def test_decode_step_no_nan(self, arch_setup):
        name, cfg, params = arch_setup
        toks, kw = _inputs(cfg, jax.random.PRNGKey(3))
        logits_p, cache, _ = MDL.prefill(params, cfg, toks, max_seq=32, **kw)
        token = jnp.argmax(logits_p, -1).astype(jnp.int32)
        dec_kw = {"encoder_frames": kw["encoder_frames"]} \
            if cfg.is_encdec else {}
        logits_d, cache2, _ = MDL.decode_step(params, cfg, token, cache,
                                              **dec_kw)
        assert logits_d.shape == (2, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits_d)).all(), name
        assert int(cache2["pos"]) == int(cache["pos"]) + 1
