"""slicelint (repro.analysis): rule fixtures, baseline semantics, CLI.

Each rule gets a known-bad fixture (the seeded violation MUST be caught)
and a known-good twin (the fixed form MUST pass) — the static half of
the ISSUE-10 acceptance gate.  The regression tests at the bottom pin
the real violations the first slicelint run surfaced in src/repro.
"""

from __future__ import annotations

import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import Baseline, lint_paths
from repro.analysis.__main__ import main as slicelint_main


def write_tree(root: Path, files: dict) -> None:
    for rel, body in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(body))


def lint(root: Path, rules=None):
    return lint_paths([root], root, rules=rules)


def rule_ids(findings):
    return {f.rule for f in findings}


# --------------------------------------------------------------- purity

PURITY_BAD = {
    "core/engine.py": """
        import time
        import numpy as np

        def charge(issued, demand):
            t0 = time.perf_counter()
            rng = np.random.default_rng()
            demanded = set(int(e) for e in demand)
            for e in issued - demanded:
                print(e)
            return t0, rng
    """,
}

PURITY_GOOD = {
    "core/engine.py": """
        import numpy as np

        def charge(issued, demand, now, seed):
            rng = np.random.default_rng(seed)
            demanded = set(int(e) for e in demand)
            if 3 in demanded:            # membership is order-free: fine
                pass
            for e in sorted(issued - demanded):
                print(e)
            return now, rng
    """,
}


def test_purity_bad_fixture_fires(tmp_path):
    write_tree(tmp_path, PURITY_BAD)
    findings = lint(tmp_path, rules=["purity"])
    idents = {f.ident for f in findings}
    assert any("wall-clock" in i for i in idents), idents
    assert any("unseeded-rng" in i for i in idents), idents
    assert any("set-order" in i for i in idents), idents


def test_purity_good_fixture_clean(tmp_path):
    write_tree(tmp_path, PURITY_GOOD)
    assert lint(tmp_path, rules=["purity"]) == []


def test_purity_ignores_non_charge_path_modules(tmp_path):
    # Same offending code outside the charge-path module list: no rule.
    write_tree(tmp_path, {"launch/serve.py":
                          PURITY_BAD["core/engine.py"]})
    assert lint(tmp_path, rules=["purity"]) == []


def test_purity_allows_id_in_hash_only(tmp_path):
    write_tree(tmp_path, {"core/placement.py": """
        class PlacementMap:
            def __hash__(self):
                return id(self)

            def lookup(self, table):
                return table[id(self)]
    """})
    findings = lint(tmp_path, rules=["purity"])
    assert [f.ident for f in findings] == ["PlacementMap.lookup:id-call"]


# ---------------------------------------------------------------- clone

CLONE_BAD = {
    "sim/replay.py": """
        class Engine:
            def __init__(self):
                self.curve = []
                self.pending = {}
                self.name = "x"          # immutable: not demanded

            def clone(self):
                new = Engine()
                new.curve = list(self.curve)
                return new               # pending is shared!
    """,
}

CLONE_GOOD_DEEPCOPY = {
    "sim/replay.py": """
        import copy

        class Engine:
            def __init__(self):
                self.curve = []
                self.pending = {}

            def clone(self):
                return copy.deepcopy(self)
    """,
}

CLONE_GOOD_SETATTR_LOOP = {
    "sim/replay.py": """
        class Engine:
            def __init__(self):
                self.curve = []
                self.pending = {}

            def clone(self):
                new = object.__new__(Engine)
                new.__dict__.update(self.__dict__)
                new.pending = dict(self.pending)
                for f in ("curve",):
                    setattr(new, f, list(getattr(self, f)))
                return new
    """,
}


def test_clone_bad_fixture_fires(tmp_path):
    write_tree(tmp_path, CLONE_BAD)
    findings = lint(tmp_path, rules=["clone"])
    assert [f.ident for f in findings] == ["Engine.pending"]


def test_clone_good_fixtures_clean(tmp_path):
    for fixture in (CLONE_GOOD_DEEPCOPY, CLONE_GOOD_SETATTR_LOOP):
        for p in tmp_path.rglob("*.py"):
            p.unlink()
        write_tree(tmp_path, fixture)
        assert lint(tmp_path, rules=["clone"]) == []


# --------------------------------------------------------------- ledger

LEDGER_BAD = {
    "hw/energy.py": """
        class CostLedger:
            flash_bytes: float = 0.0
            n_flash_transfers: int = 0
            n_orphan: int = 0            # missing from snapshot + reset

            def fill_at(self, t, nbytes):
                # charges the channel but pairs no counter/accumulator
                return self.flash_ch.issue(t, nbytes)

            def snapshot(self):
                return {
                    "flash_bytes": self.flash_bytes,
                    "n_flash_transfers": self.n_flash_transfers,
                }

            def reset(self):
                self.flash_bytes = 0.0
                self.n_flash_transfers = 0
    """,
    "core/engine.py": """
        def charge(led):
            led.fill_at(0.0, 4.0)
            led.bogus_at(0.0, 4.0)       # not a CostLedger method
    """,
}

LEDGER_GOOD = {
    "hw/energy.py": """
        class CostLedger:
            flash_bytes: float = 0.0
            n_flash_transfers: int = 0

            def fill_at(self, t, nbytes):
                self.flash_bytes += nbytes
                self.n_flash_transfers += 1
                return self.flash_ch.issue(t, nbytes)

            def miss_fill(self, nbytes):
                # delegation inherits fill_at's counters: clean
                self.fill_at(0.0, nbytes)

            def snapshot(self):
                return {
                    "flash_bytes": self.flash_bytes,
                    "n_flash_transfers": self.n_flash_transfers,
                }

            def reset(self):
                self.flash_bytes = 0.0
                self.n_flash_transfers = 0
    """,
    "core/engine.py": """
        def charge(led):
            led.fill_at(0.0, 4.0)
            led.miss_fill(4.0)
    """,
}


def test_ledger_bad_fixture_fires(tmp_path):
    write_tree(tmp_path, LEDGER_BAD)
    idents = {f.ident for f in lint(tmp_path, rules=["ledger"])}
    assert "CostLedger.fill_at:no-counter" in idents
    assert "CostLedger.fill_at:no-accumulator" in idents
    assert "CostLedger.n_orphan:not-in-snapshot" in idents
    assert "CostLedger.n_orphan:not-in-reset" in idents
    assert any(i.startswith("call:led.bogus_at") for i in idents), idents
    # the known call site is NOT flagged
    assert not any("fill_at" in i for i in idents if i.startswith("call:"))


def test_ledger_good_fixture_clean(tmp_path):
    write_tree(tmp_path, LEDGER_GOOD)
    assert lint(tmp_path, rules=["ledger"]) == []


# ---------------------------------------------------------------- knobs

KNOBS_BAD = {
    "core/engine.py": """
        class EngineConfig:
            alpha: int = 1
            beta: float = 0.5            # serialized nowhere
    """,
    "sim/trace.py": """
        def engine_meta(engine):
            return TraceMeta(engine={"alpha": engine.alpha})
    """,
    "launch/serve.py": """
        DEFAULT_KNOBS = {"alpha": 1, "gamma": 2}

        def cli_engine_knobs(args):
            return {"alpha": args.alpha}
    """,
    "sim/replay.py": """
        def engine_config_from_meta(meta, **overrides):
            e = dict(meta.engine)
            e.update(overrides)
            return (e["alpha"],)
    """,
}

KNOBS_GOOD = {
    "core/engine.py": """
        class EngineConfig:
            alpha: int = 1
            beta: float = 0.5
    """,
    "sim/trace.py": """
        def engine_meta(engine):
            return TraceMeta(engine={"alpha": engine.alpha,
                                     "beta": engine.beta})
    """,
    "launch/serve.py": """
        DEFAULT_KNOBS = {"alpha": 1, "beta": 0.5}

        def cli_engine_knobs(args):
            return {"alpha": args.alpha, "beta": args.beta}
    """,
    "sim/replay.py": """
        def engine_config_from_meta(meta, **overrides):
            e = dict(meta.engine)
            e.update(overrides)
            return (e["alpha"], e.get("beta", 0.5))
    """,
}


def test_knobs_bad_fixture_fires(tmp_path):
    write_tree(tmp_path, KNOBS_BAD)
    idents = {f.ident for f in lint(tmp_path, rules=["knobs"])}
    # beta reaches no surface: one finding per surface
    assert {i for i in idents if i.startswith("beta:")} == {
        "beta:missing-from:TraceMeta",
        "beta:missing-from:serve.py",
        "beta:missing-from:replay/autotune",
    }
    # DEFAULT_KNOBS and cli_engine_knobs disagree about gamma...
    assert "cli-skew:gamma" in idents
    # ...and gamma maps to no EngineConfig field at all.
    assert "orphan:serve.py:gamma" in idents


def test_knobs_good_fixture_clean(tmp_path):
    write_tree(tmp_path, KNOBS_GOOD)
    assert lint(tmp_path, rules=["knobs"]) == []


# ------------------------------------------------- suppression + baseline

def test_inline_suppression(tmp_path):
    write_tree(tmp_path, {"core/engine.py": """
        import time

        def f():
            return time.time()  # slicelint: ignore[purity] startup stamp
    """})
    assert lint(tmp_path, rules=["purity"]) == []
    # ignore[*] works; ignore[other-rule] does not suppress
    write_tree(tmp_path, {"core/engine.py": """
        import time

        def f():
            return time.time()  # slicelint: ignore[clone]
    """})
    assert len(lint(tmp_path, rules=["purity"])) == 1


def test_baseline_split_semantics(tmp_path):
    write_tree(tmp_path, PURITY_BAD)
    findings = lint(tmp_path, rules=["purity"])
    assert findings
    bl = Baseline({f.key: f.message for f in findings})

    # everything baselined -> no new findings
    new, baselined, stale = bl.split(findings)
    assert new == [] and len(baselined) == len(findings) and stale == []

    # removing one entry resurfaces exactly that finding as new
    victim = findings[0]
    del bl.entries[victim.key]
    new, baselined, stale = bl.split(findings)
    assert [f.key for f in new] == [victim.key]

    # a stale entry (fixed violation) is reported for removal
    bl.entries["purity::core/engine.py::gone"] = "old"
    _, _, stale = bl.split(findings)
    assert stale == ["purity::core/engine.py::gone"]


def test_baseline_roundtrip_and_version_gate(tmp_path):
    path = tmp_path / "bl.json"
    Baseline({"k": "msg"}).save(path)
    assert Baseline.load(path).entries == {"k": "msg"}
    path.write_text(json.dumps({"version": 99, "findings": {}}))
    with pytest.raises(ValueError):
        Baseline.load(path)
    assert Baseline.load(tmp_path / "missing.json").entries == {}


# ------------------------------------------------------------------- CLI

def cli(tmp_path, *argv):
    return slicelint_main([str(tmp_path), "--root", str(tmp_path), *argv])


def test_cli_exit_codes(tmp_path, capsys):
    write_tree(tmp_path, PURITY_BAD)
    (tmp_path / "pyproject.toml").write_text("")   # root marker

    assert cli(tmp_path, "--rule", "purity") == 1  # new findings
    out = capsys.readouterr().out
    assert "[purity]" in out and "core/engine.py" in out

    assert cli(tmp_path, "--rule", "purity", "--write-baseline") == 0
    assert cli(tmp_path, "--rule", "purity") == 0  # all baselined
    capsys.readouterr()

    # fix the file -> baseline goes stale; --strict-baseline enforces
    write_tree(tmp_path, PURITY_GOOD)
    assert cli(tmp_path, "--rule", "purity") == 0
    assert "stale" in capsys.readouterr().out
    assert cli(tmp_path, "--rule", "purity", "--strict-baseline") == 1

    assert cli(tmp_path, "--rule", "nope") == 2    # unknown rule
    assert slicelint_main([str(tmp_path / "missing.py")]) == 2


def test_cli_list_rules(capsys):
    assert slicelint_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in ("purity", "clone", "ledger", "knobs"):
        assert rid in out


def test_repo_tree_is_clean_against_committed_baseline():
    """The ISSUE-10 acceptance gate, as a test: linting src/repro with
    the committed baseline yields zero new findings."""
    root = Path(__file__).resolve().parent.parent
    findings = lint_paths([root / "src" / "repro"], root)
    bl = Baseline.load(root / ".slicelint.json")
    new, _, stale = bl.split(findings)
    assert new == [], [f.render() for f in new]
    assert stale == [], stale


# ------------------------------------- regressions for the fixed findings

def test_cost_ledger_counts_matmuls():
    """[ledger] matmul_at charged compute without an event counter."""
    from repro.hw.energy import CostLedger

    led = CostLedger()
    led.matmul(tokens=2, d_in=4, d_out=8, bits=8)
    led.matmul_at(led.now, tokens=2, d_in=4, d_out=8, bits=4)
    snap = led.snapshot()
    assert snap["n_matmuls"] == 2
    assert snap["compute_ops"] == pytest.approx(2 * 2.0 * 2 * 4 * 8)
    led.reset()
    assert led.n_matmuls == 0 and led.snapshot()["n_matmuls"] == 0


def test_serve_cli_knob_parity_runtime():
    """[knobs] serve.py dropped lsb_keep_frac / system / fused_slices /
    hotness_request_decay / fetch_lsb_on_miss: a --replay-trace of a
    run recorded with a non-default value silently reverted it.  The
    CLI knob surface must now cover the trace header exactly."""
    import dataclasses
    from types import SimpleNamespace

    from repro.core.engine import EngineConfig
    from repro.launch.serve import (DEFAULT_KNOBS, build_engine_config,
                                    cli_engine_knobs)
    from repro.analysis.knobs import ALIASES, ALLOWLIST

    flat = set()
    for f in dataclasses.fields(EngineConfig):
        if f.name not in ALLOWLIST:
            flat |= ALIASES.get(f.name, {f.name})
    assert set(DEFAULT_KNOBS) == flat

    ns = SimpleNamespace(
        cache_mb=None, routing=None, miss_target=None, controller=None,
        **{k: None for k in DEFAULT_KNOBS
           if k not in ("cache_bytes", "policy_kind", "miss_rate_target",
                        "controller")})
    knobs = cli_engine_knobs(ns)
    assert set(knobs) == set(DEFAULT_KNOBS)

    # all-defaults CLI builds the library-default config (knob defaults
    # in DEFAULT_KNOBS that differ from EngineConfig defaults are the
    # serving profile: cache size + miss target)
    ecfg = build_engine_config(ns)
    assert ecfg.lsb_keep_frac == EngineConfig().lsb_keep_frac
    assert ecfg.system == EngineConfig().system
    assert ecfg.fused_slices == EngineConfig().fused_slices
    assert ecfg.hotness_request_decay == EngineConfig().hotness_request_decay
    assert ecfg.policy.fetch_lsb_on_miss == \
        EngineConfig().policy.fetch_lsb_on_miss


def test_replay_clone_forks_moe_positions():
    """[clone] ReplayEngine.clone shared the moe_positions list with its
    parent; one in-place edit would have bled across forks."""
    from repro.sim import Trace
    from repro.sim.replay import ReplayEngine

    trace = Trace.load(str(
        Path(__file__).resolve().parent / "data" / "golden_trace.npz"))
    eng = ReplayEngine(trace.meta)
    fork = eng.clone()
    assert fork.moe_positions == eng.moe_positions
    assert fork.moe_positions is not eng.moe_positions


def test_charge_path_set_iteration_is_sorted():
    """[purity] the sync prefetch-judgment loop iterated a raw int set;
    set order is an implementation detail of the hash table, so the
    ledger's wasted-prefetch charge *sequence* (and any tracer capture
    of it) depended on interpreter internals rather than on the trace.
    The static rule now pins the loop to sorted() — assert the pattern
    stays dead in the charge-path modules."""
    from repro.analysis import lint_paths as lp

    root = Path(__file__).resolve().parent.parent
    findings = [f for f in lp([root / "src" / "repro"], root,
                              rules=["purity"])
                if "set-order" in f.ident]
    assert findings == [], [f.render() for f in findings]
