"""Data pipeline / optimizer / checkpoint substrates."""


import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as CKPT
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import adamw as OPT


class TestData:
    def _data(self, **kw):
        base = dict(vocab_size=256, seq_len=32, global_batch=8, seed=3)
        base.update(kw)
        return SyntheticLM(DataConfig(**base))

    def test_deterministic(self):
        a = self._data().sample_batch(5, 8)
        b = self._data().sample_batch(5, 8)
        np.testing.assert_array_equal(a, b)

    def test_steps_differ(self):
        d = self._data()
        assert not np.array_equal(d.sample_batch(0, 8), d.sample_batch(1, 8))

    def test_host_shard_consistent_with_global(self):
        d = self._data()
        full = d.sample_batch(2, 8)
        sh = d.host_shard(2, shard_idx=1, n_shards=4)
        np.testing.assert_array_equal(sh["tokens"], full[2:4, :-1])
        np.testing.assert_array_equal(sh["labels"], full[2:4, 1:])

    def test_labels_shifted(self):
        d = self._data()
        b = next(d.batches())
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_tokens_in_range(self):
        b = self._data().sample_batch(0, 8)
        assert b.min() >= 0 and b.max() < 256

    def test_nonuniform_distribution(self):
        """Zipf structure: top tokens should dominate."""
        b = self._data(global_batch=16).sample_batch(0, 16)
        counts = np.bincount(b.reshape(-1), minlength=256)
        assert counts.max() > 3 * np.median(counts[counts > 0])


class TestAdamW:
    def test_minimizes_quadratic(self):
        cfg = OPT.AdamWConfig(lr=0.1, weight_decay=0.0, total_steps=200,
                              warmup_steps=1, schedule="constant")
        params = {"w": jnp.array([5.0, -3.0])}
        state = OPT.init_state(params, cfg)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = OPT.apply_updates(params, grads, state, cfg)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.1

    def test_grad_clip(self):
        cfg = OPT.AdamWConfig(grad_clip=1.0, total_steps=10, warmup_steps=1)
        params = {"w": jnp.zeros(4)}
        state = OPT.init_state(params, cfg)
        _, _, m = OPT.apply_updates(params, {"w": jnp.full(4, 1e6)}, state,
                                    cfg)
        assert float(m["grad_norm"]) > 1e5   # reported pre-clip

    def test_weight_decay_only_matrices(self):
        cfg = OPT.AdamWConfig(lr=1e-2, weight_decay=1.0, total_steps=10,
                              warmup_steps=1, schedule="constant")
        params = {"mat": jnp.ones((4, 4)), "vec": jnp.ones(4)}
        state = OPT.init_state(params, cfg)
        zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
        new, _, _ = OPT.apply_updates(params, zeros, state, cfg)
        assert float(jnp.max(new["mat"])) < 1.0       # decayed
        np.testing.assert_allclose(np.asarray(new["vec"]), 1.0)  # untouched

    def test_schedule_warmup_and_decay(self):
        cfg = OPT.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              schedule="cosine")
        lr0 = float(OPT.schedule_lr(cfg, jnp.asarray(0)))
        lr10 = float(OPT.schedule_lr(cfg, jnp.asarray(10)))
        lr99 = float(OPT.schedule_lr(cfg, jnp.asarray(99)))
        assert lr0 < lr10
        assert lr99 < lr10
        assert lr99 >= 0.09           # cosine floor ~0.1 * lr

    def test_bf16_params_f32_master(self):
        cfg = OPT.AdamWConfig(lr=1e-4, total_steps=10, warmup_steps=1,
                              schedule="constant", weight_decay=0.0)
        params = {"w": jnp.ones(64, jnp.bfloat16)}
        state = OPT.init_state(params, cfg)
        for _ in range(10):
            params, state, _ = OPT.apply_updates(
                params, {"w": jnp.full(64, 1e-3, jnp.bfloat16)}, state, cfg)
        # master accumulates below bf16 resolution
        assert state.master["w"].dtype == jnp.float32
        assert float(jnp.max(jnp.abs(state.master["w"] - 1.0))) > 0


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {
            "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones(5, jnp.bfloat16),
                  "d": (jnp.zeros(2, jnp.int32), jnp.ones((), jnp.float32))},
        }
        CKPT.save(str(tmp_path / "ck"), tree, step=42)
        back = CKPT.restore(str(tmp_path / "ck"))
        assert CKPT.restore_step(str(tmp_path / "ck")) == 42
        for orig, rest in zip(jax.tree_util.tree_leaves(tree),
                              jax.tree_util.tree_leaves(back)):
            assert str(orig.dtype) == str(rest.dtype)
            np.testing.assert_array_equal(
                np.asarray(orig, np.float32), np.asarray(rest, np.float32))

    def test_structure_preserved(self, tmp_path):
        tree = {"x": [jnp.ones(2), {"y": jnp.zeros(3)}]}
        CKPT.save(str(tmp_path / "ck2"), tree)
        back = CKPT.restore(str(tmp_path / "ck2"))
        assert isinstance(back["x"], list)
        assert isinstance(back["x"][1], dict)

    def test_model_params_roundtrip(self, tmp_path):
        from repro.configs.base import get_config
        from repro.models.model import init_params

        cfg = get_config("smollm-360m").reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        CKPT.save(str(tmp_path / "model"), {"params": params})
        back = CKPT.restore(str(tmp_path / "model"))["params"]
        assert jax.tree_util.tree_structure(params) == \
            jax.tree_util.tree_structure(back)
