"""PCW warmup + SliceMoE engine integration (the paper's core claims)."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

# engine decode integration: runs in the CI 'slow' job (pytest -m slow), not the fast tier-1 gate.
pytestmark = pytest.mark.slow

from repro.configs.base import get_config
from repro.core.amat import MatConfig
from repro.core.cache import SliceCache
from repro.core.engine import EngineConfig, SliceMoEEngine
from repro.core.slices import ExpertSliceStore, SliceKey
from repro.core.warmup import (HotnessTracker, init_last_layer, init_random,
                               pcw_reshape)
from repro.models.model import init_params
from repro.models.moe import RoutingPolicy


@pytest.fixture(scope="module")
def small_store(rng):
    w = {
        l: {"wi": jax.random.normal(jax.random.fold_in(rng, l),
                                    (8, 32, 64)) * 0.1,
            "wo": jax.random.normal(jax.random.fold_in(rng, 100 + l),
                                    (8, 64, 32)) * 0.1}
        for l in range(3)
    }
    return ExpertSliceStore.from_float(w, MatConfig(8, 4))


class TestStore:
    def test_slice_sizes(self, small_store):
        s = small_store
        # MSB (4-bit codes + metadata) is bigger than LSB (4 raw bits)
        assert s.msb_bytes_per_expert > s.lsb_bytes_per_expert
        # both slices together beat storing hi+lo copies (Matryoshka wins)
        duplicated = s.highbit_expert_bytes() + s.msb_bytes_per_expert
        assert s.highbit_expert_bytes() < duplicated

    def test_total_bytes(self, small_store):
        assert small_store.total_bytes() == pytest.approx(
            small_store.highbit_expert_bytes() * 3 * 8)


class TestPCW:
    def _hot_tracker(self, L=3, E=8):
        t = HotnessTracker(L, E)
        # expert e hotness proportional to E-e on every layer
        for l in range(L):
            reps = np.concatenate([np.full(E - e, e) for e in range(E)])
            t.observe(l, reps.reshape(-1, 1),
                      np.ones_like(reps, float).reshape(-1, 1))
        return t

    def test_reshape_keeps_hot_evicts_cold(self, small_store):
        cache = SliceCache(small_store.msb_bytes_per_expert * 10)
        # fill with a cold-biased set
        for l in range(3):
            for e in range(8):
                cache.insert(SliceKey(l, e, "lsb"),
                             small_store.lsb_bytes_per_expert)
        tracker = self._hot_tracker()
        summary = pcw_reshape(cache, small_store, tracker,
                              lsb_keep_frac=0.2)
        assert summary["evicted_lsb"] > 0
        assert summary["installed_msb"] > 0
        msb, lsb = cache.residency(3, 8)
        # hottest experts (low index) must be MSB-resident
        assert msb[:, 0].all()
        assert cache.used <= cache.capacity

    def test_baseline_inits(self, small_store):
        cache = SliceCache(small_store.msb_bytes_per_expert * 6)
        init_last_layer(cache, small_store)
        assert all(k.layer == 2 for k in cache.resident_keys())
        init_random(cache, small_store, seed=1)
        assert cache.used <= cache.capacity
        assert len(cache) > 0


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_config("deepseek-v2-lite-repro")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run(cfg, params, **over):
    base = dict(
        mat=MatConfig(8, 4), cache_bytes=1.5e6,
        policy=RoutingPolicy(kind="cache_prior", slice_mode="dbsc"),
        miss_rate_target=0.05, warmup="pcw", max_seq=80)
    base.update(over)
    eng = SliceMoEEngine(cfg, params, EngineConfig(**base))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 48), 0,
                              cfg.vocab_size)
    logits = eng.prefill(toks)
    first = jnp.argmax(logits, -1).astype(jnp.int32)
    _, metrics = eng.decode(first, 24)
    return eng, metrics


class TestEngine:
    def test_controller_reduces_miss_rate(self, engine_setup):
        cfg, params = engine_setup
        eng, metrics = _run(cfg, params)
        steps = metrics["per_step"]
        early = np.mean([s["miss_rate"] for s in steps[:8]])
        late = np.mean([s["miss_rate"] for s in steps[-8:]])
        assert late <= early + 1e-9
        assert eng.alpha > 0  # controller engaged

    def test_dbsc_cheaper_than_highbit_baseline(self, engine_setup):
        """Paper Fig. 9: DBSC beats whole-expert high-bit caching."""
        cfg, params = engine_setup
        _, m_dbsc = _run(cfg, params)
        _, m_high = _run(
            cfg, params,
            policy=RoutingPolicy(kind="cache_prior", slice_mode="highbit"),
            fused_slices=True)
        e_dbsc = m_dbsc["decode_totals"]["total_energy_j"]
        e_high = m_high["decode_totals"]["total_energy_j"]
        assert e_dbsc < e_high, (e_dbsc, e_high)

    def test_pcw_beats_empty_init(self, engine_setup):
        """Paper Fig. 10: warmup reduces early-decode cost vs empty cache."""
        cfg, params = engine_setup
        _, m_pcw = _run(cfg, params, warmup="pcw")
        _, m_empty = _run(cfg, params, warmup="empty")
        e_pcw = m_pcw["decode_totals"]["total_energy_j"]
        e_empty = m_empty["decode_totals"]["total_energy_j"]
        assert e_pcw < e_empty, (e_pcw, e_empty)

    def test_non_moe_arch_rejected(self):
        cfg = get_config("smollm-360m").reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="inapplicable"):
            SliceMoEEngine(cfg, params, EngineConfig())

    def test_decode_produces_tokens(self, engine_setup):
        cfg, params = engine_setup
        eng, metrics = _run(cfg, params)
        assert metrics["cache_stats"]["msb_hits"] > 0


class TestPrefetcher:
    def test_transition_model_learns(self):
        from repro.core.prefetch import TransitionPrefetcher

        pf = TransitionPrefetcher(n_layers=3, n_experts=8, top_m=2)
        # deterministic pattern: layer l expert i -> layer l+1 expert i+1
        for _ in range(20):
            for l in range(1, 3):
                prev = np.array([2, 4])
                cur = np.array([3, 5])
                pf.observe(l, prev, cur)
        pred = pf.predict(0, np.array([2, 4]))
        assert set(pred.tolist()) == {3, 5}

    def test_cold_start_ties_not_index_biased(self):
        """Regression: under the uniform smoothing prior ``argsort`` used
        to return experts 0..m-1 on every call.  Ties must break by a
        seeded random permutation — varied across calls, reproducible
        across runs."""
        from repro.core.prefetch import TransitionPrefetcher

        def draw(seed):
            pf = TransitionPrefetcher(n_layers=3, n_experts=16, top_m=4,
                                      seed=seed)
            return [tuple(sorted(pf.predict(0, np.array([1])).tolist()))
                    for _ in range(16)]

        preds = draw(seed=0)
        assert any(p != (0, 1, 2, 3) for p in preds), \
            "cold-start predictions still index-biased"
        # every expert is reachable under ties, not just the first m
        assert len({e for p in preds for e in p}) > 4
        assert preds == draw(seed=0)          # deterministic per seed
        assert preds != draw(seed=1)          # but seed-sensitive

    def test_single_layer_model_never_predicts(self):
        """Regression: the counts buffer is floored to one transition
        matrix, so a 1-layer model used to 'predict' experts for layer 1
        — a layer that does not exist (phantom fills under async)."""
        from repro.core.prefetch import TransitionPrefetcher

        pf = TransitionPrefetcher(n_layers=1, n_experts=8, top_m=4)
        assert pf.predict(0, np.array([1, 2])).size == 0

    def test_residency_mask_filters_predictions(self):
        """A predicted expert whose slice is already cached is a wasted
        prefetch slot; the residency mask must exclude it."""
        from repro.core.prefetch import TransitionPrefetcher

        pf = TransitionPrefetcher(n_layers=3, n_experts=8, top_m=2)
        for _ in range(20):
            pf.observe(1, np.array([2, 4]), np.array([3, 5]))
        resident = np.zeros(8, bool)
        resident[3] = True
        pred = pf.predict(0, np.array([2, 4]), resident=resident)
        assert 3 not in pred.tolist()
        assert 5 in pred.tolist()
        # all-resident: nothing left worth prefetching
        assert pf.predict(0, np.array([2, 4]),
                          resident=np.ones(8, bool)).size == 0

    def test_engine_prefetch_runs_and_tracks_accuracy(self, engine_setup):
        cfg, params = engine_setup
        eng, metrics = _run(
            cfg, params,
            policy=RoutingPolicy(kind="topk", slice_mode="highbit"),
            fused_slices=True, prefetch_top_m=4, warmup="empty",
            miss_rate_target=None)
        assert eng.prefetcher is not None
        assert eng.prefetcher.issued > 0
        assert 0.0 <= eng.prefetcher.accuracy <= 1.0

    def test_prefetch_worse_than_cache_aware(self, engine_setup):
        """The paper's §2.1 claim: prefetching under diverse routing loses
        to cache-aware routing on Flash traffic."""
        cfg, params = engine_setup
        _, m_pf = _run(
            cfg, params,
            policy=RoutingPolicy(kind="topk", slice_mode="highbit"),
            fused_slices=True, prefetch_top_m=4, warmup="empty",
            miss_rate_target=None)
        _, m_dbsc = _run(cfg, params, warmup="pcw")
        e_pf = m_pf["decode_totals"]["flash_bytes"]
        e_db = m_dbsc["decode_totals"]["flash_bytes"]
        assert e_db < e_pf, (e_db, e_pf)
