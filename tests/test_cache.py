"""SliceCache: LRU semantics, DBSC LSB-first eviction, capacity invariants."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cache import SliceCache, SliceTooLargeError
from repro.core.slices import SliceKey


MSB = lambda l, e: SliceKey(l, e, "msb")       # noqa: E731
LSB = lambda l, e: SliceKey(l, e, "lsb")       # noqa: E731


class TestBasics:
    def test_hit_miss(self):
        c = SliceCache(100)
        assert not c.access(MSB(0, 0), 10)     # cold miss, fills
        assert c.access(MSB(0, 0), 10)         # hit
        assert c.stats.msb_misses == 1 and c.stats.msb_hits == 1

    def test_capacity_never_exceeded(self):
        c = SliceCache(35)
        for e in range(10):
            c.access(MSB(0, e), 10)
            assert c.used <= 35
        assert len(c) == 3

    def test_lru_order(self):
        c = SliceCache(30)
        for e in range(3):
            c.access(MSB(0, e), 10)
        c.access(MSB(0, 0), 10)        # bump 0 to MRU
        c.access(MSB(0, 3), 10)        # evicts 1 (LRU)
        assert MSB(0, 0) in c and MSB(0, 1) not in c

    def test_oversized_insert_raises(self):
        """An oversized fill must be *signalled*, not silently dropped —
        ``[]`` used to be indistinguishable from "already resident"."""
        c = SliceCache(5)
        with pytest.raises(SliceTooLargeError):
            c.insert(MSB(0, 0), 10)
        assert MSB(0, 0) not in c and c.used == 0

    def test_oversized_access_counts_drop(self):
        """``access(fill_on_miss=True)`` swallows the drop but counts it,
        so callers (and epochs) can see fills that never landed."""
        c = SliceCache(5)
        assert not c.access(MSB(0, 0), 10)
        assert MSB(0, 0) not in c
        assert c.stats.n_dropped == 1 and c.stats.msb_misses == 1
        assert not c.access(MSB(0, 0), 10)      # still a miss, still drops
        assert c.stats.n_dropped == 2

    def test_inflight_ready_times(self):
        """In-flight fill state: ready times survive until settled or the
        entry is evicted."""
        c = SliceCache(20)
        c.insert(MSB(0, 0), 10)
        c.mark_inflight(MSB(0, 0), ready_t=3.5)
        assert c.ready_time(MSB(0, 0)) == 3.5
        assert c.ready_time(MSB(0, 1)) == 0.0       # nothing in flight
        c.settle(now=2.0)                           # still flying
        assert c.ready_time(MSB(0, 0)) == 3.5
        c.settle(now=3.5)                           # landed
        assert c.ready_time(MSB(0, 0)) == 0.0
        c.mark_inflight(MSB(0, 0), ready_t=9.0)
        c.insert(MSB(0, 1), 10)
        c.insert(MSB(0, 2), 10)                     # evicts MSB(0, 0)
        assert MSB(0, 0) not in c
        assert c.ready_time(MSB(0, 0)) == 0.0       # record went with it


class TestDBSCPolicy:
    def test_lsb_evicted_before_msb(self):
        c = SliceCache(30, slice_aware=True)
        c.access(MSB(0, 0), 10)
        c.access(LSB(0, 0), 10)
        c.access(MSB(0, 1), 10)
        # full; next fill must evict the LSB even though it's younger
        c.access(MSB(0, 2), 10)
        assert LSB(0, 0) not in c
        assert MSB(0, 0) in c and MSB(0, 1) in c and MSB(0, 2) in c

    def test_lsb_hits_do_not_gain_priority(self):
        c = SliceCache(30, slice_aware=True)
        c.access(LSB(0, 0), 10)
        c.access(LSB(0, 1), 10)
        c.access(LSB(0, 0), 10)        # hit — but stays low priority
        c.access(MSB(0, 0), 10)
        c.access(MSB(0, 1), 10)        # evicts LSB(0,0) first (FIFO in seg)
        assert LSB(0, 0) not in c

    def test_slice_unaware_single_lru(self):
        c = SliceCache(30, slice_aware=False)
        c.access(LSB(0, 0), 10)
        c.access(MSB(0, 0), 10)
        c.access(LSB(0, 0), 10)        # bump (single LRU treats all equal)
        c.access(MSB(0, 1), 10)
        c.access(MSB(0, 2), 10)        # evicts MSB(0,0), not the LSB
        assert LSB(0, 0) in c and MSB(0, 0) not in c


class TestResidency:
    def test_residency_masks(self):
        c = SliceCache(1000)
        c.access(MSB(0, 1), 10)
        c.access(LSB(2, 3), 10)
        msb, lsb = c.residency(4, 8)
        assert msb[0, 1] and not msb[0, 2]
        assert lsb[2, 3] and not lsb[0, 1]

    def test_reorder_by_ranking(self):
        c = SliceCache(30)
        for e in range(3):
            c.access(MSB(0, e), 10)
        # rank 1 highest -> evicted last
        c.reorder_by({MSB(0, 0): 0.5, MSB(0, 1): 0.9, MSB(0, 2): 0.1})
        c.access(MSB(0, 3), 10)        # evicts rank-0.1 (expert 2)
        assert MSB(0, 2) not in c and MSB(0, 1) in c


class TestPropertyBased:
    @settings(max_examples=40, deadline=None)
    @given(
        capacity=st.integers(10, 200),
        ops=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 7),
                               st.booleans(), st.integers(5, 25)),
                     min_size=1, max_size=120),
    )
    def test_invariants_hold_under_any_trace(self, capacity, ops):
        c = SliceCache(capacity)
        for layer, expert, is_lsb, nbytes in ops:
            key = SliceKey(layer, expert, "lsb" if is_lsb else "msb")
            c.access(key, nbytes)
            # invariant 1: capacity respected
            assert c.used <= capacity
            # invariant 2: used == sum of resident sizes
            total = sum(c._msb.values()) + sum(c._lsb.values())
            assert abs(c.used - total) < 1e-9
        # invariant 3: stats add up
        assert c.stats.accesses == len(ops)
