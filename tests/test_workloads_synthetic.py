"""Phase-shift / tenant-mix generators: serving workloads
(``generate_phased``) and synthetic traces (``tenant_phase_trace``).

All model-free and seeded — determinism, tenant-weight proportions and
phase-boundary structure are exact claims, not statistical ones, except
where noted (proportions get a generous tolerance on a large draw).
"""

import collections

import numpy as np
import pytest

from repro.serving.workloads import (LengthDist, TenantSpec,
                                     WorkloadConfig, generate,
                                     generate_phased)
from repro.sim import SyntheticSpec, tenant_phase_trace, traces_equal

VOCAB = 512


def _phase_cfg(tenants, *, n=6, seed=0, kind="poisson", rate=4.0):
    specs = tuple(
        TenantSpec(name=name, weight=w,
                   prompt_len=LengthDist("fixed", 8),
                   output_len=LengthDist("fixed", 4))
        for name, w in tenants)
    return WorkloadConfig(kind=kind, n_requests=n, rate=rate, seed=seed,
                          tenants=specs)


# ==========================================================================
# serving/workloads.py::generate_phased
# ==========================================================================
class TestGeneratePhased:
    def test_seeded_determinism(self):
        phases = [_phase_cfg([("a", 1.0), ("b", 3.0)], seed=1),
                  _phase_cfg([("a", 1.0)], seed=2)]
        xs = generate_phased(phases, VOCAB)
        ys = generate_phased(phases, VOCAB)
        assert len(xs) == len(ys) == 12
        for x, y in zip(xs, ys):
            assert x.request_id == y.request_id
            assert x.tenant == y.tenant
            assert x.arrival_time == y.arrival_time
            assert np.array_equal(x.prompt, y.prompt)

    def test_request_ids_continue_across_phases(self):
        phases = [_phase_cfg([("a", 1.0)], n=3),
                  _phase_cfg([("b", 1.0)], n=4)]
        reqs = generate_phased(phases, VOCAB)
        assert [r.request_id for r in reqs] == list(range(7))

    def test_phase_arrivals_are_offset_and_ordered(self):
        phases = [_phase_cfg([("a", 1.0)], n=4, seed=0),
                  _phase_cfg([("b", 1.0)], n=4, seed=1)]
        reqs = generate_phased(phases, VOCAB, gap_s=5.0)
        first, second = reqs[:4], reqs[4:]
        # every phase-1 arrival lands >= gap after phase 0's last
        assert min(r.arrival_time for r in second) \
            >= max(r.arrival_time for r in first) + 5.0
        assert all(r.tenant == "a" for r in first)
        assert all(r.tenant == "b" for r in second)

    def test_phase_mix_shift_changes_tenant_population(self):
        phases = [_phase_cfg([("a", 1.0), ("b", 3.0)], n=200, seed=0),
                  _phase_cfg([("a", 1.0)], n=50, seed=1)]
        reqs = generate_phased(phases, VOCAB)
        counts = collections.Counter(r.tenant for r in reqs[:200])
        # weight 3:1 -> expect ~150 b; generous tolerance on 200 draws
        assert 120 <= counts["b"] <= 180
        assert all(r.tenant == "a" for r in reqs[200:])

    def test_matches_single_generate_for_one_phase(self):
        cfg = _phase_cfg([("a", 2.0), ("b", 1.0)], n=8, seed=3)
        alone = generate(cfg, VOCAB)
        phased = generate_phased([cfg], VOCAB)
        assert len(alone) == len(phased)
        for x, y in zip(alone, phased):
            assert x.tenant == y.tenant
            assert x.arrival_time == y.arrival_time
            assert np.array_equal(x.prompt, y.prompt)


# ==========================================================================
# sim/synthetic.py::tenant_phase_trace
# ==========================================================================
SPEC = SyntheticSpec(n_moe_layers=3, n_experts=12, top_k=2)


def _trace(**kw):
    kw.setdefault("phases", 2)
    kw.setdefault("requests_per_phase", 3)
    kw.setdefault("prompt_len", 6)
    kw.setdefault("decode_steps", 8)
    return tenant_phase_trace(SPEC, **kw)


def _prefills(trace):
    return [e for e in trace.events if e.kind == "prefill"]


class TestTenantPhaseTrace:
    def test_seeded_determinism(self):
        assert traces_equal(_trace(seed=5), _trace(seed=5))

    def test_seed_changes_stream(self):
        assert not traces_equal(_trace(seed=5), _trace(seed=6))

    def test_phase_boundaries_in_labels(self):
        labels = [e.label for e in _prefills(_trace())]
        assert len(labels) == 6
        assert [l.split("/")[0] for l in labels] == ["ph0"] * 3 + ["ph1"] * 3
        # request ids continue across phases
        assert [int(l.split("req")[1]) for l in labels] == list(range(6))

    def test_decode_events_carry_tenants(self):
        trace = _trace()
        by_label = {e.label: e.tenant for e in _prefills(trace)}
        decodes = [e for e in trace.events if e.kind == "decode"]
        assert decodes
        for e in decodes:
            assert e.slot_tenants is not None
            assert all(t in {"premium", "batch"} for t in e.slot_tenants
                       if t is not None)
        assert set(by_label.values()) <= {"premium", "batch"}

    def test_per_phase_mix_list(self):
        trace = _trace(tenants=[{"only_a": 1.0}, {"only_b": 1.0}],
                       requests_per_phase=4)
        tenants = [e.tenant for e in _prefills(trace)]
        assert tenants == ["only_a"] * 4 + ["only_b"] * 4

    def test_mix_length_must_match_phases(self):
        with pytest.raises(ValueError):
            _trace(tenants=[{"a": 1.0}], phases=2)

    def test_tenant_weight_proportions(self):
        trace = _trace(tenants={"hot": 4.0, "cold": 1.0}, phases=1,
                       requests_per_phase=200, decode_steps=1,
                       prompt_len=4, seed=0)
        counts = collections.Counter(
            e.tenant for e in _prefills(trace))
        # 4:1 weights -> ~160 hot of 200; generous tolerance
        assert 130 <= counts["hot"] <= 190

    def test_tenants_occupy_shifted_expert_neighborhoods(self):
        # Same phase base, different crc32 rotation: the hot expert set
        # of one tenant's prefill differs from the other's.
        trace = _trace(tenants=[{"premium": 1.0, "batch": 1.0}],
                       phases=1, requests_per_phase=20, seed=2)
        hot = collections.defaultdict(collections.Counter)
        for e in _prefills(trace):
            hot[e.tenant].update(np.asarray(e.ids)[..., 0].ravel().tolist())
        assert set(hot) == {"premium", "batch"}
        top = {t: {e for e, _ in c.most_common(3)}
               for t, c in hot.items()}
        assert top["premium"] != top["batch"]
